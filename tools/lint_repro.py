#!/usr/bin/env python
"""Repo-specific AST lint for accelerator-code pitfalls.

Rules (each one is a real bug class this codebase has to guard
against, not a style preference):

* ``interpret-true`` — a ``pallas_call``/kernel invocation with
  ``interpret=True`` outside ``tests/``: interpreter-mode kernels
  silently bypass real lowering, so shipping one in ``src/`` or
  ``tools/`` turns a compiled path into a Python emulation.
* ``missing-block-until-ready`` — a function that takes >= 2
  ``perf_counter()`` samples and touches jax but never calls
  ``block_until_ready``: jax dispatch is async, so the measured window
  closes before the device work does and the timing is fiction.
* ``mutable-default-arg`` — a ``def`` with a list/dict/set/bytearray
  default: shared across calls, a classic state-leak.
* ``np-in-jax-loop`` — a ``np.*`` call inside a function passed to
  ``lax.scan`` / ``lax.fori_loop`` / ``lax.while_loop`` (or decorated
  ``@jit``): numpy on tracers either crashes at trace time or silently
  constant-folds a value that should be traced.

Findings are keyed ``path::rule::qualname`` and suppressed by exact
key match against ``tools/lint_allowlist.txt`` (one key per line,
``#`` comments).  Exit status is 1 if any non-allowlisted finding
remains — wired as a CI step.

Usage:
    python tools/lint_repro.py [--root .] [--allowlist tools/lint_allowlist.txt]
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import sys

RULES = ("interpret-true", "missing-block-until-ready",
         "mutable-default-arg", "np-in-jax-loop")

_SKIP_DIRS = {".git", "__pycache__", ".dse_cache", ".cache", "build",
              "node_modules", ".venv"}
_MUTABLE_NODES = (ast.List, ast.Dict, ast.Set)
_JAX_LOOP_FUNCS = {"scan", "fori_loop", "while_loop"}


class Finding:
    def __init__(self, path: str, rule: str, qualname: str, line: int,
                 detail: str) -> None:
        self.path = path
        self.rule = rule
        self.qualname = qualname
        self.line = line
        self.detail = detail

    @property
    def key(self) -> str:
        return f"{self.path}::{self.rule}::{self.qualname}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.qualname}: " \
               f"{self.detail}"


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _full_name(node: ast.expr) -> str:
    """Dotted name of an expression ('np.add', 'jax.lax.scan'), best
    effort ('' for anything not a plain attribute chain)."""
    parts: "list[str]" = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, rel: str, in_tests: bool) -> None:
        self.path = path
        self.rel = rel
        self.in_tests = in_tests
        self.findings: "list[Finding]" = []
        self.scope: "list[str]" = []
        # function name -> def node, for resolving loop-body callbacks
        # passed by name (body functions are defined before use)
        self.defs: "dict[str, ast.FunctionDef]" = {}
        self._jax_loop_depth = 0

    # ---- scope bookkeeping -------------------------------------------
    def _qual(self, name: str = "") -> str:
        parts = self.scope + ([name] if name else [])
        return ".".join(parts) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def _visit_func(self, node) -> None:
        self.defs[node.name] = node
        self._check_mutable_defaults(node)
        self.scope.append(node.name)
        self._check_timing(node)
        self.generic_visit(node)
        self.scope.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    # ---- rule: mutable-default-arg -----------------------------------
    def _check_mutable_defaults(self, node) -> None:
        a = node.args
        for d in list(a.defaults) + [d for d in a.kw_defaults if d]:
            if isinstance(d, _MUTABLE_NODES) or (
                    isinstance(d, ast.Call)
                    and _call_name(d) in ("list", "dict", "set",
                                          "bytearray")):
                self.findings.append(Finding(
                    self.rel, "mutable-default-arg",
                    self._qual(node.name), d.lineno,
                    "mutable default argument is shared across calls"))

    # ---- rule: missing-block-until-ready ------------------------------
    def _check_timing(self, node) -> None:
        n_timers = 0
        uses_jax = False
        blocks = False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = _call_name(sub)
                if name == "perf_counter":
                    n_timers += 1
                elif name == "block_until_ready":
                    blocks = True
            full = _full_name(sub) if isinstance(
                sub, (ast.Attribute, ast.Name)) else ""
            if full.split(".")[0] in ("jax", "jnp", "lax") or \
                    full in ("jit",):
                uses_jax = True
        if n_timers >= 2 and uses_jax and not blocks:
            self.findings.append(Finding(
                self.rel, "missing-block-until-ready", self._qual(),
                node.lineno,
                "times a jax computation without block_until_ready; "
                "async dispatch makes the window meaningless"))

    # ---- rules: interpret-true + np-in-jax-loop -----------------------
    def visit_Call(self, node: ast.Call) -> None:
        if not self.in_tests:
            for kw in node.keywords:
                if kw.arg == "interpret" and isinstance(
                        kw.value, ast.Constant) and kw.value.value is True:
                    self.findings.append(Finding(
                        self.rel, "interpret-true", self._qual(),
                        node.lineno,
                        "interpret=True outside tests/ bypasses real "
                        "kernel lowering"))
        fname = _full_name(node.func)
        leaf = fname.rsplit(".", 1)[-1]
        if leaf in _JAX_LOOP_FUNCS and (
                "." not in fname or fname.split(".")[0] in ("lax", "jax")):
            for arg in node.args:        # body/cond callback position
                self._scan_loop_body(arg)    # varies per loop primitive
        self.generic_visit(node)

    def _scan_loop_body(self, arg: ast.expr) -> None:
        body: "ast.AST | None" = None
        if isinstance(arg, ast.Lambda):
            body = arg
        elif isinstance(arg, ast.Name) and arg.id in self.defs:
            body = self.defs[arg.id]
        if body is None:
            return
        for sub in ast.walk(body):
            if isinstance(sub, ast.Call):
                full = _full_name(sub.func)
                if full.startswith("np.") or full.startswith("numpy."):
                    self.findings.append(Finding(
                        self.rel, "np-in-jax-loop", self._qual(),
                        sub.lineno,
                        f"{full}() inside a lax loop body runs on "
                        "tracers (crash or silent constant-fold)"))


def lint_file(path: pathlib.Path, root: pathlib.Path) -> "list[Finding]":
    rel = path.relative_to(root).as_posix()
    in_tests = rel.startswith("tests/") or "/tests/" in rel
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(rel, "mutable-default-arg", "<parse>", e.lineno
                        or 0, f"unparseable: {e.msg}")]
    v = _Visitor(str(path), rel, in_tests)
    v.visit(tree)
    return v.findings


def iter_py_files(root: pathlib.Path):
    for sub in ("src", "tools", "tests"):
        base = root / sub
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if not any(part in _SKIP_DIRS for part in p.parts):
                yield p


def load_allowlist(path: pathlib.Path) -> "set[str]":
    if not path.exists():
        return set()
    out = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=str(
        pathlib.Path(__file__).resolve().parents[1]))
    ap.add_argument("--allowlist", default=None,
                    help="default: <root>/tools/lint_allowlist.txt")
    ap.add_argument("--print-keys", action="store_true",
                    help="emit allowlist keys instead of diagnostics")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root).resolve()
    allow_path = pathlib.Path(args.allowlist) if args.allowlist else \
        root / "tools" / "lint_allowlist.txt"
    allow = load_allowlist(allow_path)

    findings: "list[Finding]" = []
    n_files = 0
    for p in iter_py_files(root):
        n_files += 1
        findings.extend(lint_file(p, root))

    bad = [f for f in findings if f.key not in allow]
    if args.print_keys:
        for f in findings:
            print(f.key)
        return 0
    for f in bad:
        print(f)
    print(f"lint: {n_files} files, {len(findings)} finding(s), "
          f"{len(findings) - len(bad)} allowlisted, {len(bad)} failing")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
