"""Surrogate-error report: analytic predictions vs the golden matrix.

Emits one CSV row per pinned golden schedule row (the 15-bench x
13-design x {1,4} matrix) with the surrogate's predicted cycles, the
pinned true cycles, the relative error, and the per-bench Spearman rank
correlation.  Rows for uncalibrated trace families (the serving
benches, where the pruned sweep falls back to exhaustive) are flagged
``calibrated=0`` and excluded from the summary stats.  CI publishes the
CSV next to the Fig-4 sweep artifacts so predictor drift is visible per
commit; the hard accuracy gates live in ``tests/test_surrogate.py``.

Usage::

    PYTHONPATH=src python tools/surrogate_report.py [--csv out.csv]

With no ``--csv`` the report goes to stdout.  The trailing ``#``
summary line carries the aggregate stats (median/max relative error,
worst per-bench rho).
"""
from __future__ import annotations

import argparse
import collections
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.bench import get_trace
from repro.core.dse.ratio import spearman_rho
from repro.core.dse.surrogate import (CALIBRATED_BENCHES,
                                      CALIBRATION_DESIGNS, TraceFeatures,
                                      predict)
from repro.core.sim import prepare_trace

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parents[1]
               / "tests" / "golden_schedule.json")


def build_report() -> "tuple[list[dict], dict]":
    """Per-row records plus aggregate stats over the golden matrix."""
    golden = json.loads(GOLDEN_PATH.read_text())
    by_bench: dict = collections.defaultdict(list)
    for g in golden:
        by_bench[g["bench"]].append(g)

    records, rhos = [], {}
    for bench in sorted(by_bench):
        pt = prepare_trace(get_trace(bench))
        feats = TraceFeatures(pt)
        preds, truths = [], []
        for g in by_bench[bench]:
            dp = CALIBRATION_DESIGNS[g["design"]]
            p = predict(pt, dp, g["unroll"], feats)
            rel = abs(p.cycles - g["cycles"]) / g["cycles"]
            preds.append(p.cycles)
            truths.append(g["cycles"])
            records.append({
                "bench": bench, "design": g["design"],
                "unroll": g["unroll"], "true_cycles": g["cycles"],
                "pred_cycles": p.cycles, "rel_err": rel,
                "calibrated": int(bench in CALIBRATED_BENCHES),
            })
        rhos[bench] = spearman_rho(truths, preds)

    for r in records:
        r["bench_rho"] = rhos[r["bench"]]
    rel_cal = sorted(r["rel_err"] for r in records if r["calibrated"])
    finite = [r for b, r in rhos.items()
              if r == r and b in CALIBRATED_BENCHES]
    stats = {
        "rows": len(records),
        "calibrated_rows": len(rel_cal),
        "median_rel_err": rel_cal[len(rel_cal) // 2],
        "max_rel_err": rel_cal[-1],
        "min_bench_rho": min(finite),
    }
    return records, stats


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser(
        description="Surrogate cycle-predictor error report "
                    "(vs tests/golden_schedule.json).")
    ap.add_argument("--csv", default=None,
                    help="write the report here instead of stdout")
    args = ap.parse_args(argv)

    records, stats = build_report()
    cols = ("bench", "design", "unroll", "true_cycles", "pred_cycles",
            "rel_err", "bench_rho", "calibrated")
    lines = [",".join(cols)]
    for r in records:
        lines.append(",".join(
            f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    lines.append(f"# rows={stats['rows']} "
                 f"calibrated_rows={stats['calibrated_rows']} "
                 f"median_rel_err={stats['median_rel_err']:.4f} "
                 f"max_rel_err={stats['max_rel_err']:.4f} "
                 f"min_bench_rho={stats['min_bench_rho']:.4f}")
    text = "\n".join(lines) + "\n"
    if args.csv:
        pathlib.Path(args.csv).write_text(text)
        print(f"wrote {args.csv}: {lines[-1][2:]}")
    else:
        sys.stdout.write(text)


if __name__ == "__main__":
    main()
