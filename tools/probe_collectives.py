"""Perf-iteration probe: print the largest trip-weighted collectives and
dots of a dry-run cell's compiled HLO (the §Perf 'profile').

  PYTHONPATH=src python tools/probe_collectives.py <arch> <shape> [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re  # noqa: E402
import sys  # noqa: E402

import repro.launch.dryrun as dr  # noqa: E402
import repro.launch.roofline as rl  # noqa: E402


def comp_weights(ana):
    weights = {ana.entry: 1.0}
    order = [ana.entry]
    seen = {ana.entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        c = ana.comps.get(name)
        if not c:
            continue
        for kind, callee, mult in c.calls:
            weights[callee] = weights.get(callee, 0) + weights[name] * mult
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
        for grp in c.branch_groups:
            for g in grp:
                weights[g] = weights.get(g, 0) + weights[name]
                if g not in seen:
                    seen.add(g)
                    order.append(g)
    return weights


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    multi = "--multi-pod" in sys.argv
    captured = {}
    orig = rl.analyze_hlo

    def cap(hlo):
        captured["hlo"] = hlo
        return orig(hlo)

    dr.analyze_hlo = cap
    prof = "opt" if "--opt" in sys.argv else "baseline"
    dr.run_cell(arch, shape, multi, verbose=False, profile=prof)
    hlo = captured["hlo"]
    ana = rl.HloAnalysis(hlo)
    weights = comp_weights(ana)

    rows, dots = [], []
    cur = None
    for raw in hlo.splitlines():
        h = rl._HEADER_RE.match(raw)
        if h and not raw.startswith(" "):
            cur = h.group("name")
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = re.search(r"=\s*(.*?)\s*(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(?:-start)?\(", raw)
        if m and "-done" not in raw:
            b = rl._shape_list_bytes(m.group(1))
            w = weights.get(cur, 0)
            rows.append((b * w, b, w, m.group(2), raw.strip()[:150]))
        md = re.search(r"=\s*(.*?)\s*dot\(", raw)
        if md:
            b = rl._shape_list_bytes(md.group(1))
            dots.append((b * weights.get(cur, 0), raw.strip()[:150]))

    rows.sort(reverse=True)
    dots.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"\n==== {arch} x {shape} collectives: "
          f"{total/2**30:.1f} GiB total, {len(rows)} sites ====")
    for r in rows[:14]:
        print(f"{r[0]/2**30:9.2f}GiB raw={r[1]/2**20:8.1f}MiB x{r[2]:6.0f} "
              f"{r[3]:16s} {r[4][:110]}")
    print("---- largest dots (result bytes x trips) ----")
    for d in dots[:6]:
        print(f"{d[0]/2**30:9.2f}GiB  {d[1][:130]}")


if __name__ == "__main__":
    main()
