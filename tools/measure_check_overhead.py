#!/usr/bin/env python
"""Price the event-logging hook in the compiled cycle loop.

Builds two variants of the C extension — the default build (events
recorded when a buffer is passed; this run passes NULL, so the hook is
a single branch per issue) and a ``-DREPRO_NO_EVENTS`` build with the
hook compiled out entirely — then times ``schedule()`` on a golden
benchmark through each and reports the relative overhead of the
enabled-but-idle hook.

CI runs this with ``--assert-pct 5``: the issue-event log must be free
when nobody asks for it.  Exits 0 with a note when no C compiler is
available (the pure-Python loop has its own no-recording fast path).

Usage:
    PYTHONPATH=src python tools/measure_check_overhead.py \
        [--bench gemm_ncubed] [--design hb_ntx-2R2W] [--unroll 4]
        [--repeats 200] [--assert-pct 5]
"""
from __future__ import annotations

import argparse
import ctypes
import pathlib
import statistics
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "tests"))


def _bind(defines: "tuple[str, ...]"):
    from repro.core.sim import _cycle_ext

    so = _cycle_ext.build_library(defines)
    return _cycle_ext.bind_run_schedule(ctypes.CDLL(so))


def _time_variant(fn, pt, cfg, repeats: int) -> float:
    from repro.core.sim.scheduler import _schedule_c

    _schedule_c(fn, pt, cfg)                     # warm up / validate
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = _schedule_c(fn, pt, cfg)
        samples.append(time.perf_counter() - t0)
        assert res is not None
    return statistics.median(samples)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="gemm_ncubed")
    ap.add_argument("--design", default="hb_ntx-2R2W")
    ap.add_argument("--unroll", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=200)
    ap.add_argument("--assert-pct", type=float, default=None,
                    help="fail if the idle hook costs more than this "
                         "percentage over the compiled-out build")
    args = ap.parse_args(argv)

    try:
        with_hook = _bind(())
        without_hook = _bind(("REPRO_NO_EVENTS",))
    except Exception as e:
        print(f"no C toolchain ({type(e).__name__}: {e}); the overhead "
              "contract only applies to the compiled loop — skipping")
        return 0

    from repro.core.bench import get_trace
    from repro.core.sim import prepare_trace
    from test_golden_schedule import _config

    pt = prepare_trace(get_trace(args.bench))
    cfg = _config(pt, args.design, args.unroll)

    # interleave the two variants so drift hits both equally
    t_on = _time_variant(with_hook, pt, cfg, args.repeats)
    t_off = _time_variant(without_hook, pt, cfg, args.repeats)
    t_on2 = _time_variant(with_hook, pt, cfg, args.repeats)
    t_on = min(t_on, t_on2)

    pct = (t_on - t_off) / t_off * 100.0
    print(f"{args.bench}/{args.design}@u{args.unroll} "
          f"({pt.n_nodes} nodes, median of {args.repeats}):")
    print(f"  hook compiled in, disabled: {t_on * 1e6:9.2f} us")
    print(f"  hook compiled out:          {t_off * 1e6:9.2f} us")
    print(f"  idle-hook overhead:         {pct:+8.2f} %")
    if args.assert_pct is not None and pct > args.assert_pct:
        print(f"FAIL: overhead {pct:.2f}% exceeds the "
              f"{args.assert_pct:.1f}% budget", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
