"""Measure line coverage of ``repro.core`` without coverage.py.

The CI coverage gate (``pytest --cov=repro.core --cov-fail-under``)
needs a measured baseline, but the dev container deliberately carries
no extra packages.  This harness approximates coverage.py with a
``sys.settrace`` line tracer scoped to ``src/repro/core`` over the same
test subset the CI job runs, and reports hit / executable-line ratios
per module.

The executable-line denominator is every line emitted by the compiled
code objects (``co_lines``), which *includes* docstring lines that
coverage.py excludes — so the percentage printed here is a lower bound
on what coverage.py will report, and pinning ``--cov-fail-under``
at-or-below it is safe.

Usage::

    PYTHONPATH=src python tools/measure_core_coverage.py
"""
from __future__ import annotations

import os
import pathlib
import sys
import threading

ROOT = pathlib.Path(__file__).resolve().parents[1]
CORE = ROOT / "src" / "repro" / "core"
PREFIX = str(CORE) + os.sep

# the CI coverage job's test selection (keep in sync with ci.yml)
CORE_TESTS = [
    "tests/test_amm.py", "tests/test_arbiter.py", "tests/test_bench.py",
    "tests/test_c_fallback.py", "tests/test_conformance.py",
    "tests/test_golden_schedule.py", "tests/test_jax_cycle.py",
    "tests/test_prepared.py", "tests/test_replay.py",
    "tests/test_runner.py", "tests/test_semantics.py",
    "tests/test_serving.py", "tests/test_simulator.py",
    "tests/test_spec_edges.py",
]

covered: dict[str, set[int]] = {}


def _tracer(frame, event, arg):
    fn = frame.f_code.co_filename
    if not fn.startswith(PREFIX):
        return None
    if event == "line":
        covered.setdefault(fn, set()).add(frame.f_lineno)
    return _tracer


def _executable_lines(path: pathlib.Path) -> set[int]:
    code = compile(path.read_text(), str(path), "exec")
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, _, ln in co.co_lines() if ln is not None)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_lines"))
    return lines


def main() -> None:
    import pytest

    threading.settrace(_tracer)
    sys.settrace(_tracer)
    rc = pytest.main(["-q", "-p", "no:cacheprovider", *CORE_TESTS])
    sys.settrace(None)
    threading.settrace(None)
    if rc != 0:
        raise SystemExit(f"test run failed (rc={rc}); baseline not valid")

    total_exec = total_hit = 0
    rows = []
    for path in sorted(CORE.rglob("*.py")):
        if path.name == "_cycle_loop.c":
            continue
        ex = _executable_lines(path)
        hit = covered.get(str(path), set()) & ex
        total_exec += len(ex)
        total_hit += len(hit)
        pct = 100.0 * len(hit) / max(len(ex), 1)
        rows.append((pct, str(path.relative_to(ROOT)), len(hit), len(ex)))
    for pct, name, hit, ex in sorted(rows):
        print(f"{pct:6.1f}%  {hit:5d}/{ex:<5d}  {name}")
    print(f"\nTOTAL repro.core: {total_hit}/{total_exec} lines = "
          f"{100.0 * total_hit / max(total_exec, 1):.1f}% "
          f"(lower bound vs coverage.py)")


if __name__ == "__main__":
    main()
