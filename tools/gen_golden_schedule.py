"""Extend tests/golden_schedule.json without disturbing pinned rows.

The golden file's original rows were captured from the *seed*
scheduler and pin its cycle-exact behavior; they must never be
regenerated.  This tool only

* appends rows for (bench, design, unroll) combinations that are not
  yet covered — e.g. the ``-b4`` leaf-sub-banked DEFAULT_DESIGNS points
  and benches added after the seed — capturing the current C/pure-py
  loops (asserted equal before a row is written), and
* back-fills the stall-breakdown fields (``bank_conflict_stalls``,
  ``parity_fanout_stalls``, ``write_pair_stalls``,
  ``parity_path_reads``, ``write_pair_rmws``) on rows that predate
  them, again from the agreeing loops, leaving the seed-pinned fields
  byte-identical.

Usage::

    PYTHONPATH=src python tools/gen_golden_schedule.py [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "tests"))

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parents[1]
               / "tests" / "golden_schedule.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)

    from test_golden_schedule import (_DESIGNS, _STALL_FIELDS as STALL_FIELDS,
                                      _config)

    from repro.core.bench import BENCHMARKS, get_trace
    from repro.core.sim import _cycle_ext, prepare_trace
    from repro.core.sim.scheduler import _schedule_c, _schedule_py

    fast = _cycle_ext.load()
    if fast is None:
        raise SystemExit("golden generation needs the compiled C loop "
                         "(results are cross-checked against pure-py)")

    rows = json.loads(GOLDEN_PATH.read_text())
    have = {(r["bench"], r["design"], r["unroll"]) for r in rows}

    def result_for(bench: str, design: str, unroll: int):
        pt = prepare_trace(get_trace(bench))
        cfg = _config(pt, design, unroll)
        res = _schedule_c(fast, pt, cfg)
        ref = _schedule_py(pt, cfg)
        assert res == ref, (bench, design, unroll, res, ref)
        return res

    added = filled = 0
    for r in rows:
        if all(f in r for f in STALL_FIELDS):
            continue
        res = result_for(r["bench"], r["design"], r["unroll"])
        assert res.cycles == r["cycles"], \
            f"pinned row drifted: {r} vs cycles={res.cycles}"
        for f in STALL_FIELDS:
            r[f] = getattr(res, f)
        filled += 1

    for bench in sorted(BENCHMARKS):
        for design in sorted(_DESIGNS):
            for unroll in (1, 4):
                if (bench, design, unroll) in have:
                    continue
                res = result_for(bench, design, unroll)
                row = {
                    "bench": bench, "design": design, "unroll": unroll,
                    "cycles": res.cycles, "issued": res.issued,
                    "mem_issued": res.mem_issued,
                    "avg_mem_parallelism": round(
                        res.avg_mem_parallelism, 9),
                }
                row.update({f: getattr(res, f) for f in STALL_FIELDS})
                rows.append(row)
                added += 1
                print(f"+ {bench} {design} u{unroll}: "
                      f"cycles={res.cycles}", flush=True)

    print(f"{added} rows added, {filled} rows back-filled, "
          f"{len(rows)} total")
    if not args.dry_run:
        GOLDEN_PATH.write_text(json.dumps(rows, indent=1) + "\n")
        print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
