"""Fit the analytic sweep-surrogate coefficients against the golden matrix.

Deterministic, dependency-free calibration of
``repro.core.dse.surrogate``: a weighted least-squares init followed by
fixed-step coordinate descent on a rank-aware loss over the calibrated
312-row subset of the pinned golden matrix
(``tests/golden_schedule.json`` restricted to
``surrogate.CALIBRATED_BENCHES`` — golden rows for uncalibrated trace
families like the LLM-serving benches are conformance pins, not fit
data; the pruned sweep runs those exhaustively), then closed-form
least-squares slopes for the per-kind stall models.  Writes the result
to ``src/repro/core/dse/_surrogate_coef.py`` as checked-in constants.

The loss couples the relative cycle error with a per-bench Spearman
shortfall penalty — the pruned-sweep use case needs *ranking* fidelity
within each bench at least as much as absolute accuracy::

    loss = mean(rel_err^2) + 5.0 * sum_b max(0, 0.93 - rho_b)

Usage::

    PYTHONPATH=src python tools/fit_surrogate.py [--dry-run]
"""
from __future__ import annotations

import argparse
import collections
import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.bench import get_trace
from repro.core.dse.ratio import spearman_rho
from repro.core.dse.surrogate import (CALIBRATED_BENCHES,
                                      CALIBRATION_DESIGNS, TraceFeatures)
from repro.core.sim import prepare_trace

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parents[1]
               / "tests" / "golden_schedule.json")
COEF_PATH = (pathlib.Path(__file__).resolve().parents[1]
             / "src" / "repro" / "core" / "dse" / "_surrogate_coef.py")

STEPS = (0.2, 0.08, 0.03, 0.01)
RHO_TARGET = 0.93
RHO_WEIGHT = 5.0

# which design kinds can produce which stall field (matches the C
# cycle loop's arbitration branches)
STALL_KINDS = {
    "bank_conflict_stalls": ("banked", "remap"),
    "parity_fanout_stalls": ("h_ntx_rd", "b_ntx_wr", "hb_ntx"),
    "write_pair_stalls": ("b_ntx_wr", "hb_ntx"),
}
STALL_FEATURE = {
    "bank_conflict_stalls": "sum_conf",
    "parity_fanout_stalls": "sum_top2",
    "write_pair_stalls": "sum_wr",
}


def _collect_rows():
    golden = json.loads(GOLDEN_PATH.read_text())
    feats_of = {}
    rows = []
    kind_of = {name: dp.kind for name, dp in CALIBRATION_DESIGNS.items()}
    for g in golden:
        if g["bench"] not in CALIBRATED_BENCHES:
            continue
        tf = feats_of.get(g["bench"])
        if tf is None:
            tf = TraceFeatures(prepare_trace(get_trace(g["bench"])))
            feats_of[g["bench"]] = tf
        r = tf.features(CALIBRATION_DESIGNS[g["design"]], g["unroll"])
        r["g"] = g
        r["kind"] = kind_of[g["design"]]
        r["y"] = g["cycles"]
        rows.append(r)
    return rows


def _basemax(r):
    return max(r["dep"], r["fu"])


def _memraw(r):
    return max(r["port"], r["conf"])


def _base_x(r):
    return [_basemax(r), min(r["dep"], r["fu"])]


def _port_x(r):
    return [_memraw(r), r["band"], r["couple"],
            min(_basemax(r), _memraw(r)), 1.0]


def _excess(r):
    return max(0.0, r["conf"] - 0.5 * _basemax(r))


def _wfit(x, y, fallback):
    """Least squares weighted by 1/max(y, 1) (relative-error flavored)."""
    x = np.asarray(x, float)
    y = np.asarray(y, float)
    if len(y) <= x.shape[1]:
        return np.array(fallback, float)
    w = 1.0 / np.maximum(y, 1.0)
    coef, *_ = np.linalg.lstsq(x * w[:, None], y * w, rcond=None)
    return coef


def _predict_with(r, bc, pc, ic):
    bv = float(np.dot(_base_x(r), bc))
    pv = float(np.dot(_port_x(r), pc))
    iv = bv + ic * _excess(r)
    return max(bv, pv, iv)


def fit(rows):
    kinds = sorted({r["kind"] for r in rows})
    bench_of = collections.defaultdict(list)
    for r in rows:
        bench_of[r["g"]["bench"]].append(r)

    # ---- least-squares init ----
    base_rows = [r for r in rows if _basemax(r) >= _memraw(r)]
    bc = _wfit([_base_x(r) for r in base_rows],
               [r["y"] for r in base_rows], [1.0, 0.05])
    pcs, ics = {}, {}
    for k in kinds:
        strict = [r for r in rows
                  if r["kind"] == k and _memraw(r) > _basemax(r)]
        pcs[k] = (_wfit([_port_x(r) for r in strict],
                        [r["y"] for r in strict],
                        [0.9, 0.1, 0.1, 0.1, 1.0])
                  if len(strict) >= 6
                  else np.array([0.9, 0.1, 0.1, 0.1, 1.0]))
        ics[k] = 0.1

    def loss(bc, pcs, ics):
        s = 0.0
        preds = {}
        for r in rows:
            p = _predict_with(r, bc, pcs[r["kind"]], ics[r["kind"]])
            preds[id(r)] = p
            s += ((p - r["y"]) / r["y"]) ** 2
        s /= len(rows)
        for b, rs in bench_of.items():
            rho = spearman_rho([preds[id(r)] for r in rs],
                               [r["y"] for r in rs])
            if rho == rho:          # nan (constant bench) counts as met
                s += RHO_WEIGHT * max(0.0, RHO_TARGET - rho)
        return s

    # ---- coordinate descent through the max() (non-smooth, so no
    # gradients; fixed step schedule keeps it deterministic) ----
    for step in STEPS:
        for _ in range(6):
            improved = False
            for ci in range(len(bc)):
                for sgn in (1, -1):
                    cand = bc.copy()
                    cand[ci] += sgn * step
                    if loss(cand, pcs, ics) < loss(bc, pcs, ics) - 1e-9:
                        bc = cand
                        improved = True
            for k in kinds:
                for ci in range(len(pcs[k])):
                    for sgn in (1, -1):
                        cand = pcs[k].copy()
                        cand[ci] += sgn * step * (
                            10.0 if ci == len(cand) - 1 else 1.0)
                        new = {**pcs, k: cand}
                        if loss(bc, new, ics) < loss(bc, pcs, ics) - 1e-9:
                            pcs[k] = cand
                            improved = True
                for sgn in (1, -1):
                    cand = max(0.0, ics[k] + sgn * step)
                    new = {**ics, k: cand}
                    if loss(bc, pcs, new) < loss(bc, pcs, ics) - 1e-9:
                        ics[k] = cand
                        improved = True
            if not improved:
                break
    return bc, pcs, ics, bench_of


def fit_stalls(rows):
    """Closed-form nonneg slope per (stall field, kind): y = slope*x."""
    out = {}
    for field, kinds in STALL_KINDS.items():
        feat = STALL_FEATURE[field]
        slopes = {}
        for k in kinds:
            pts = [(r[feat], r["g"].get(field))
                   for r in rows if r["kind"] == k and field in r["g"]]
            sxx = sum(x * x for x, _ in pts)
            sxy = sum(x * y for x, y in pts)
            slopes[k] = max(0.0, sxy / sxx) if sxx > 0 else 0.0
        out[field] = slopes
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)

    rows = _collect_rows()
    bc, pcs, ics, bench_of = fit(rows)
    stalls = fit_stalls(rows)

    stats = {}
    allrel = []
    for b, rs in sorted(bench_of.items()):
        preds = [_predict_with(r, bc, pcs[r["kind"]], ics[r["kind"]])
                 for r in rs]
        rel = [abs(p - r["y"]) / r["y"] for p, r in zip(preds, rs)]
        rho = spearman_rho(preds, [r["y"] for r in rs])
        allrel.extend(rel)
        stats[b] = {"rho": None if rho != rho else round(rho, 4),
                    "medrel": round(float(np.median(rel)), 4),
                    "maxrel": round(float(np.max(rel)), 4)}
        print(f"{b:12s} rho={stats[b]['rho']} medrel={stats[b]['medrel']} "
              f"maxrel={stats[b]['maxrel']}")
    stats["_all"] = {"n_rows": len(rows),
                     "medrel": round(float(np.median(allrel)), 4),
                     "maxrel": round(float(np.max(allrel)), 4)}
    print(f"ALL medrel={stats['_all']['medrel']} "
          f"maxrel={stats['_all']['maxrel']}")

    bad = [b for b, s in stats.items()
           if b != "_all" and s["rho"] is not None and s["rho"] < 0.9]
    assert not bad, f"fit below rank target on {bad}"
    assert stats["_all"]["medrel"] <= 0.06, stats["_all"]
    assert stats["_all"]["maxrel"] <= 0.25, stats["_all"]

    kinds = sorted(pcs)
    lines = ['"""Fitted surrogate coefficients — GENERATED, '
             'do not edit by hand.',
             "",
             "Regenerate with::",
             "",
             "    PYTHONPATH=src python tools/fit_surrogate.py",
             "",
             "The fit is deterministic (weighted least-squares init + "
             "fixed-step",
             "coordinate descent on the 312 pinned golden rows), so "
             "regeneration is",
             'reproducible; tests/test_surrogate.py pins the resulting '
             'accuracy.',
             '"""',
             "",
             f"BASE = ({bc[0]:.6f}, {bc[1]:.6f})",
             "",
             "PORT = {"]
    for k in kinds:
        vals = ", ".join(f"{v:.6f}" for v in pcs[k])
        lines.append(f'    "{k}": ({vals}),')
    lines += ["}", "", "INTF = {"]
    for k in kinds:
        lines.append(f'    "{k}": {ics[k]:.6f},')
    lines += ["}", "", "STALL = {"]
    for field in sorted(stalls):
        entries = ", ".join(f'"{k}": {v:.6f}'
                            for k, v in sorted(stalls[field].items()))
        lines.append(f'    "{field}": {{{entries}}},')
    lines += [
        "}",
        "",
        "# drift guard: the fitted stall models must cover exactly the",
        "# scheduler's stall taxonomy (re-fit after changing STALL_KEYS)",
        "from repro.core.sim.arbiter import STALL_KEYS as _STALL_KEYS"
        "  # noqa: E402",
        "",
        "assert set(STALL) == {f\"{k}_stalls\" for k in _STALL_KEYS}, \\",
        "    \"surrogate STALL coefficients out of sync with STALL_KEYS; "
        "re-run \" \\",
        "    \"tools/fit_surrogate.py\"",
    ]
    stats_py = json.dumps(stats, indent=4).replace("null", "None")
    lines += ["", f"FIT_STATS = {stats_py}", ""]

    text = "\n".join(lines)
    if args.dry_run:
        print(text)
    else:
        COEF_PATH.write_text(text)
        print(f"wrote {COEF_PATH}")


if __name__ == "__main__":
    main()
