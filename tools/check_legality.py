#!/usr/bin/env python
"""Run the independent legality checker over the full golden matrix.

For every row of ``tests/golden_schedule.json`` (15 benches x 13
designs x unroll points = 390 rows) and every requested backend, the
schedule is re-run with issue-event logging and
``repro.core.verify.verify_result`` validates the event log against
rules compiled straight from the AMMSpecs, plus the static hazard
lower bounds.  A per-row report lands in ``--out`` (CSV; uploaded as a
CI artifact) and the exit status is nonzero if any row produced a
violation.

Usage:
    PYTHONPATH=src python tools/check_legality.py \
        [--backends py,c,jax] [--stride 1] [--out legality_report.csv]
"""
from __future__ import annotations

import argparse
import csv
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "tests"))

from repro.core.bench import get_trace                      # noqa: E402
from repro.core.sim import prepare_trace                    # noqa: E402
from repro.core.verify import (check_schedule, static_bounds,  # noqa: E402
                               verify_result)

_FIELDS = ("bench", "design", "unroll", "backend", "cycles", "ok",
           "n_violations", "violations", "bound_critical_path",
           "bound_port_pressure", "bound_bank_conflict",
           "bound_parity_pressure", "tight")


def _bound_cols(bounds: dict, cycles: int) -> dict:
    row = {f"bound_{k}": v for k, v in bounds.items()}
    row["tight"] = ";".join(sorted(k for k, v in bounds.items()
                                   if v == cycles))
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="py,c,jax",
                    help="comma-separated backend list (default py,c,jax)")
    ap.add_argument("--stride", type=int, default=1,
                    help="check every Nth golden row (default 1 = all)")
    ap.add_argument("--out", default="legality_report.csv")
    args = ap.parse_args(argv)
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]

    golden = json.loads((pathlib.Path(__file__).resolve().parents[1]
                         / "tests" / "golden_schedule.json").read_text())
    rows = golden[::args.stride]

    from test_golden_schedule import _config  # reuse the pinned harness

    by_bench: "dict[str, list]" = {}
    for g in rows:
        by_bench.setdefault(g["bench"], []).append(g)

    report: "list[dict]" = []
    n_bad = 0
    tight_rows = 0
    for bench, bench_rows in sorted(by_bench.items()):
        pt = prepare_trace(get_trace(bench))
        cfgs = [_config(pt, g["design"], g["unroll"]) for g in bench_rows]

        per_backend: "dict[str, list]" = {}
        for be in backends:
            if be == "jax":
                from repro.core.sim.jax_cycle import schedule_batched

                results, events = schedule_batched(pt, cfgs,
                                                   collect_events=True)
                per_backend[be] = [
                    verify_result(pt, cfg, res, ev, backend="jax")
                    for cfg, res, ev in zip(cfgs, results, events)]
            else:
                per_backend[be] = [check_schedule(pt, cfg, backend=be)
                                   for cfg in cfgs]

        for i, g in enumerate(bench_rows):
            for be in backends:
                rep = per_backend[be][i]
                if not rep.ok:
                    n_bad += 1
                if rep.bounds and any(v == rep.result.cycles
                                      for v in rep.bounds.values()):
                    tight_rows += 1
                report.append(dict(
                    bench=g["bench"], design=g["design"],
                    unroll=g["unroll"], backend=be,
                    cycles=rep.result.cycles, ok=int(rep.ok),
                    n_violations=len(rep.violations),
                    violations=" | ".join(str(v)
                                          for v in rep.violations[:5]),
                    **_bound_cols(rep.bounds, rep.result.cycles)))
        done = sum(1 for r in report)
        print(f"[{done:4d} rows] {bench}: "
              f"{len(bench_rows)} designs x {len(backends)} backends, "
              f"{n_bad} violations so far", flush=True)

    with open(args.out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=_FIELDS)
        w.writeheader()
        w.writerows(report)

    print(f"\nchecked {len(report)} (row, backend) pairs: "
          f"{n_bad} with violations; static bounds tight on "
          f"{tight_rows} of them; report -> {args.out}")
    if n_bad:
        print("LEGALITY CHECK FAILED", file=sys.stderr)
        return 1
    if tight_rows == 0:
        print("WARNING: no static bound was tight on any golden row",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
