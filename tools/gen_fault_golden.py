"""Regenerate tests/golden_faults.json: pinned seeded fault campaigns.

Each golden row is the full classified outcome of one deterministic
campaign — ``FaultConfig(n_faults=32, n_cycles=96, seed=7)`` at the
canonical 256x32b DSE geometry — for one design per AMM kind.  The
rows pin:

* the aggregate :class:`repro.core.fault.Resilience` summary (counts,
  SDC rate, corrected/detected fractions, detection latency), and
* the per-fault worst-outcome sequence,

so any drift in the fault model, the replay fault hook, the classifier
or the per-spec RNG seeding fails ``tests/test_fault.py`` loudly.

Usage::

    PYTHONPATH=src python tools/gen_fault_golden.py [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import pathlib

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parents[1]
               / "tests" / "golden_faults.json")

# one representative per design kind, by DSE design label
DESIGN_LABELS = (
    "banked8",
    "multipump-2R2W",
    "h_ntx_rd-4R1W",
    "b_ntx_wr-1R2W",
    "hb_ntx-4R2W",
    "lvt-2R2W",
    "lvt-4R2W",
    "remap-4R2W",
)
DEPTH = 256
WIDTH_BITS = 32


def campaign_config():
    from repro.core.fault import FaultConfig

    return FaultConfig(n_faults=32, n_cycles=96, seed=7)


def generate() -> list[dict]:
    from repro.core.dse.sweep import DEFAULT_DESIGNS, _spec_for
    from repro.core.fault import run_campaign

    by_label = {d.label: d for d in DEFAULT_DESIGNS}
    cfg = campaign_config()
    rows = []
    for label in DESIGN_LABELS:
        spec = _spec_for(by_label[label], DEPTH, WIDTH_BITS)
        res = run_campaign(spec, cfg)
        r = res.resilience
        rows.append({
            "design": label,
            "spec": res.spec_label,
            "cover": r.cover,
            "n_faults": r.n_faults,
            "n_reads": r.n_reads,
            "benign": r.benign,
            "corrected": r.corrected,
            "detected": r.detected,
            "sdc": r.sdc,
            "sdc_rate": round(r.sdc_rate, 9),
            "corrected_frac": round(r.corrected_frac, 9),
            "detected_frac": round(r.detected_frac, 9),
            "det_latency": round(r.det_latency, 9),
            "outcomes": list(res.outcomes),
        })
        print(f"{label:16s} cover={r.cover:8s} sdc={r.sdc:5d} "
              f"corr={r.corrected:5d} det={r.detected:5d} "
              f"lat={r.det_latency:.2f}", flush=True)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    rows = generate()
    if not args.dry_run:
        GOLDEN_PATH.write_text(json.dumps(rows, indent=1) + "\n")
        print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
