"""Sweep-runner behavior: cache hit/miss/invalidation, parallel-vs-serial
equality, deterministic ordering, and the stall-counter fix."""
import json

import pytest

from repro.core.amm.spec import AMMSpec
from repro.core.bench import get_trace
from repro.core.dse import (DEFAULT_DESIGNS, DesignPoint, run_sweep, sweep)
from repro.core.dse.runner import SweepCache, point_key
from repro.core.sim import (ScheduleConfig, TraceBuilder, prepare_trace,
                            schedule)
from repro.core.dse import runner as runner_mod

DESIGNS = [DesignPoint("banked", n_banks=4), DesignPoint("lvt", 2, 2),
           DesignPoint("multipump", 2, 2)]
UNROLLS = (1, 4)


@pytest.fixture()
def pt():
    return prepare_trace(get_trace("gemm_ncubed"))


# ----------------------------------------------------------------------
# cache
# ----------------------------------------------------------------------
def test_cache_miss_then_hit(tmp_path, pt):
    cache = SweepCache(tmp_path)
    pts1 = run_sweep(pt, DESIGNS, UNROLLS, cache=cache)
    assert cache.hits == 0 and cache.misses == len(pts1)

    cache2 = SweepCache(tmp_path)
    pts2 = run_sweep(pt, DESIGNS, UNROLLS, cache=cache2)
    assert cache2.hits == len(pts2) and cache2.misses == 0
    assert pts1 == pts2


def test_cache_extension_is_incremental(tmp_path, pt):
    """A --full-style extension of a cached sweep only pays for the new
    points."""
    cache = SweepCache(tmp_path)
    run_sweep(pt, DESIGNS, (1,), cache=cache)
    cache2 = SweepCache(tmp_path)
    pts = run_sweep(pt, DESIGNS, (1, 4), cache=cache2)
    assert cache2.hits == len(DESIGNS)            # unroll=1 reused
    assert cache2.misses == len(DESIGNS)          # unroll=4 computed
    assert [p.unroll for p in pts] == [1, 4] * len(DESIGNS)


def test_cache_key_invalidation(pt):
    fp = pt.fingerprint
    dp = DESIGNS[0]
    base = point_key(fp, dp, 1, 2)
    assert point_key(fp, dp, 2, 2) != base        # unroll
    assert point_key(fp, dp, 1, 3) != base        # mem_latency
    assert point_key(fp, DESIGNS[1], 1, 2) != base  # design
    other = prepare_trace(get_trace("kmp"))
    assert point_key(other.fingerprint, dp, 1, 2) != base  # trace content
    assert point_key(fp, dp, 1, 2) == base        # stable


def test_cache_tolerates_corrupt_entries(tmp_path, pt):
    cache = SweepCache(tmp_path)
    pts1 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache)
    key = point_key(pt.fingerprint, DESIGNS[0], 1, 2)
    path = cache._path(key)
    path.write_text("{not json")
    cache2 = SweepCache(tmp_path)
    pts2 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache2)
    assert cache2.misses == 1 and pts1 == pts2
    # the corrupt entry was rewritten with the fresh result
    assert json.loads(path.read_text())["point"]["cycles"] == pts1[0].cycles


# ----------------------------------------------------------------------
# parallel
# ----------------------------------------------------------------------
def test_parallel_equals_serial(pt, monkeypatch):
    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    serial = run_sweep(pt, DESIGNS, UNROLLS, jobs=1)
    parallel = run_sweep(pt, DESIGNS, UNROLLS, jobs=2)
    assert serial == parallel
    order = [(p.design, p.unroll) for p in parallel]
    assert order == [(d.label, u) for d in DESIGNS for u in UNROLLS]


def test_parallel_with_cache_populates_and_reuses(tmp_path, pt, monkeypatch):
    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    cache = SweepCache(tmp_path)
    pts1 = run_sweep(pt, DESIGNS, UNROLLS, jobs=2, cache=cache)
    cache2 = SweepCache(tmp_path)
    pts2 = run_sweep(pt, DESIGNS, UNROLLS, jobs=2, cache=cache2)
    assert cache2.hits == len(pts2) and pts1 == pts2


def test_sweep_wrapper_matches_runner(pt):
    assert sweep(pt, DESIGNS, UNROLLS) == run_sweep(pt, DESIGNS, UNROLLS)


def test_small_sweeps_stay_serial(pt, monkeypatch):
    """The tiny-work heuristic must not spin up worker processes."""
    def boom(jobs):
        raise AssertionError("pool should not be created for tiny work")
    monkeypatch.setattr(runner_mod, "_get_pool", boom)
    run_sweep(pt, DESIGNS[:1], (1,), jobs=8)      # tiny: serial path


# ----------------------------------------------------------------------
# stall accounting (satellite fix)
# ----------------------------------------------------------------------
def test_bank_conflict_stalls_count_unique_accesses():
    """16 loads to one bank through 1 port/bank: every deferred access is
    delayed many cycles, but each must be counted once."""
    tb = TraceBuilder("conflict")
    a = tb.declare_array("a", 4)
    n_ops = 16
    for i in range(n_ops):
        tb.load(a, i * 8)                         # stride 8 words, 8 banks
    tr = tb.build()
    res = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("banked", 8, 8, 256, n_banks=8)},
        fu_counts={}, ports_per_bank=1))
    # all ops hit bank 0 with 1 port: op k is delayed iff k >= 1
    assert res.bank_conflict_stalls == n_ops - 1
    assert res.cycles >= n_ops


def test_conflict_free_design_reports_zero_stalls():
    tb = TraceBuilder("nostall")
    a = tb.declare_array("a", 4)
    for i in range(32):
        tb.load(a, i * 8)
    res = schedule(tb.build(), ScheduleConfig(
        mem={0: AMMSpec("lvt", 4, 1, 256)}, fu_counts={}))
    assert res.bank_conflict_stalls == 0


# ----------------------------------------------------------------------
# pool lifecycle
# ----------------------------------------------------------------------
def test_pool_growth_drains_old_pool_and_registers_atexit(monkeypatch):
    """Growing the shared pool must wait on the old one (not abandon its
    workers) and the first pool must register the atexit teardown."""
    import atexit

    registered = []
    real_register = atexit.register

    def spy(fn, *args, **kwargs):
        # wrap, don't replace: the first executor in the process makes
        # multiprocessing lazily register its own exit hook through here
        registered.append(fn)
        return real_register(fn, *args, **kwargs)

    monkeypatch.setattr(runner_mod, "_POOL", None)
    monkeypatch.setattr(runner_mod, "_POOL_WORKERS", 0)
    monkeypatch.setattr(runner_mod, "_ATEXIT_REGISTERED", False)
    monkeypatch.setattr(atexit, "register", spy)
    try:
        p1 = runner_mod._get_pool(1)
        assert registered.count(runner_mod.shutdown_pool) == 1
        p2 = runner_mod._get_pool(2)            # grow: replaces the pool
        assert p2 is not p1
        # the old pool was shut down with wait=True: its manager thread
        # is gone and submitting raises
        with pytest.raises(RuntimeError):
            p1.submit(id, 0)
        assert registered.count(runner_mod.shutdown_pool) == 1  # only once
        assert runner_mod._get_pool(1) is p2    # shrink request: reuse
    finally:
        runner_mod.shutdown_pool()


def test_shutdown_pool_resets_state():
    runner_mod._get_pool(1)
    runner_mod.shutdown_pool()
    assert runner_mod._POOL is None and runner_mod._POOL_WORKERS == 0
    runner_mod.shutdown_pool()                  # idempotent


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------
def test_runner_cli_smoke(tmp_path, capsys):
    import dataclasses

    from repro.core.dse import DSEPoint

    runner_mod.main(["--bench", "gemm_ncubed", "--jobs", "1",
                     "--unrolls", "1", "--cache-dir", str(tmp_path)])
    out = capsys.readouterr().out
    lines = [l for l in out.splitlines() if l and not l.startswith("#")]
    # header and rows derive from DSEPoint.row(): every field present,
    # none drifting (the old hand-written header omitted cycle_ns)
    fields = [f.name for f in dataclasses.fields(DSEPoint)]
    assert lines[0] == ",".join(fields)
    assert "cycle_ns" in lines[0]
    assert len(lines) == 1 + len(DEFAULT_DESIGNS)
    assert all(len(l.split(",")) == len(fields) for l in lines[1:])
    assert "# cache:" in out
