"""Pipeline-parallel executor: staged shard_map/ppermute schedule must
equal the sequential layer stack (subprocess with 4 fake devices)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential():
    code = """
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh
    from repro.runtime.pipeline import pipeline_apply, split_stages

    L, D, M, MB = 8, 16, 6, 4
    rng = np.random.default_rng(0)
    params = {
        "w": jnp.asarray(rng.standard_normal((L, D, D)) * 0.2, jnp.float32),
        "b": jnp.asarray(rng.standard_normal((L, D)) * 0.1, jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

    def layer_fn(lp, h):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    # sequential reference
    def seq(h):
        def body(c, lp):
            return layer_fn(lp, c), None
        out, _ = jax.lax.scan(body, h, params)
        return out
    want = jax.vmap(seq)(x)

    mesh = make_test_mesh((4,), ("pod",))
    staged = split_stages(params, 4)
    got = jax.jit(lambda s, m: pipeline_apply(
        layer_fn, s, m, mesh, axis="pod"))(staged, x)
    err = float(jnp.abs(got - want).max())
    print("PIPE_ERR", err)
    assert err < 1e-5
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPE_ERR" in out.stdout
