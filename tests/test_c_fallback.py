"""Edge tests for the ``_MAX_C_PARITY_PATHS`` C-fallback guard (ISSUE 5).

The compiled cycle loop keeps fixed-size per-read parity-path buffers
(``_MAX_C_PARITY_PATHS`` leaf paths).  An NTX read tree with ``k``
levels fans a parity read out over ``2**k`` paths, so a port config
with ``2**k > _MAX_C_PARITY_PATHS`` cannot be arbitrated by the C loop:
``schedule()`` must fall back to the pure-Python reference loop with
identical results — never truncate the path set silently.
"""
import pytest

from repro.core.amm.spec import AMMSpec
from repro.core.sim import _cycle_ext, prepare_trace
from repro.core.sim.scheduler import (_MAX_C_PARITY_PATHS, ScheduleConfig,
                                      _schedule_c, _schedule_py, schedule)
from repro.core.sim.trace import TraceBuilder


def _trace(n_ops: int = 24, depth: int = 1024):
    tb = TraceBuilder("deep_tree")
    a = tb.declare_array("a", 4)
    prev = ()
    for i in range(n_ops):
        # same-leaf pressure: consecutive reads collide on direct leaves
        # so the parity-path machinery is actually exercised
        nid = (tb.load(a, (i * 3) % 8) if i % 4 else
               tb.store(a, (i * 5) % depth, prev))
        prev = (nid,)
    return prepare_trace(tb.build())


def _cfg(spec: AMMSpec) -> ScheduleConfig:
    return ScheduleConfig(mem={0: spec}, fu_counts={})


def test_overflowing_parity_paths_rejects_c_loop():
    fast = _cycle_ext.load()
    if fast is None:
        pytest.skip("no C compiler available")
    # 256 read ports -> k = 8 -> 2**8 = 256 parity paths > 128 buffer
    spec = AMMSpec("h_ntx_rd", 256, 1, 1024)
    assert (1 << spec.read_tree_levels) > _MAX_C_PARITY_PATHS
    assert _schedule_c(fast, _trace(), _cfg(spec)) is None


def test_overflow_falls_back_to_python_with_identical_results():
    spec = AMMSpec("h_ntx_rd", 256, 1, 1024)
    pt = _trace()
    res = schedule(pt, _cfg(spec))          # public path: must not raise
    assert res == _schedule_py(pt, _cfg(spec))
    assert res.cycles > 0 and res.mem_issued == pt.trace.n_mem


def test_hb_ntx_overflow_also_falls_back():
    spec = AMMSpec("hb_ntx", 256, 2, 1024)
    pt = _trace()
    fast = _cycle_ext.load()
    if fast is not None:
        assert _schedule_c(fast, pt, _cfg(spec)) is None
    assert schedule(pt, _cfg(spec)) == _schedule_py(pt, _cfg(spec))


def test_explicit_c_backend_never_silently_degrades(monkeypatch):
    """backend='c' must raise when the extension is unavailable instead
    of silently timing the Python loop under a C label; 'auto' keeps
    the silent fallback."""
    import repro.core.sim._cycle_ext as ext

    monkeypatch.setattr(ext, "_FN", None)
    monkeypatch.setattr(ext, "_TRIED", True)
    pt = _trace()
    spec = AMMSpec("ideal", 2, 2, 64)
    with pytest.raises(RuntimeError, match="backend='c'"):
        schedule(pt, _cfg(spec), backend="c")
    assert schedule(pt, _cfg(spec), backend="auto") \
        == _schedule_py(pt, _cfg(spec))


def test_boundary_tree_depth_still_uses_c_loop():
    """k = 7 -> exactly _MAX_C_PARITY_PATHS paths: the C loop must keep
    handling it (the guard is strictly 'greater than')."""
    fast = _cycle_ext.load()
    if fast is None:
        pytest.skip("no C compiler available")
    spec = AMMSpec("h_ntx_rd", 128, 1, 1024)
    assert (1 << spec.read_tree_levels) == _MAX_C_PARITY_PATHS
    pt = _trace()
    res = _schedule_c(fast, pt, _cfg(spec))
    assert res is not None                  # no spurious fallback
    assert res == _schedule_py(pt, _cfg(spec))
