"""Pinning tests for the whole-trace replay engine
(``repro.core.amm.replay``): for every design the scanned replay must be
bit-exact with the per-step path AND the plain-RAM oracle — read values
(direct and parity paths), final logical content, and the flat leaf/bank
state itself — under jit (replay is always jit-compiled) and under vmap
batching across instances and seeds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.amm import AMMSpec, make_amm
from repro.core.amm import replay as rp
from test_amm import DEPTH, SPECS, ram_oracle, random_trace

T = 12


def _trace(spec, seed, n_cycles=T):
    return random_trace(spec, n_cycles, np.random.default_rng(seed))


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
def test_replay_bit_exact_with_step_path(spec):
    rng = np.random.default_rng(rp.spec_seed(spec, salt="replay"))
    init = rng.integers(0, 2**32, DEPTH, dtype=np.uint32)
    ra, wa, wv, wm = random_trace(spec, T, rng)
    want_reads, want_mem = ram_oracle(init, ra, wa, wv, wm)

    # per-step path (pytree state, one jit'd dispatch per cycle)
    sim = make_amm(spec, jnp.asarray(init))
    state = sim.state
    step_vals = []
    for t in range(T):
        state, vals = sim.step(state, jnp.asarray(ra[t]), jnp.asarray(wa[t]),
                               jnp.asarray(wv[t]), jnp.asarray(wm[t]))
        step_vals.append(np.asarray(vals))

    # whole-trace path (flat state, one scan)
    flat = rp.init_flat(spec, jnp.asarray(init))
    flat, result = rp.replay(spec, flat, ra, wa, wv, wm)

    np.testing.assert_array_equal(np.asarray(result.read_vals),
                                  np.stack(step_vals))
    np.testing.assert_array_equal(np.asarray(result.read_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(result.parity_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(rp.peek_flat(spec, flat)),
                                  want_mem)
    # the flat state itself is bit-identical to the flattened step state,
    # so the two paths are interchangeable mid-sequence
    step_flat = rp.flatten_state(spec, state)
    assert set(step_flat) == set(flat)
    for key in flat:
        np.testing.assert_array_equal(np.asarray(step_flat[key]),
                                      np.asarray(flat[key]), err_msg=key)
    # and unflatten() round-trips back into the step path
    resumed = rp.unflatten_state(spec, flat)
    np.testing.assert_array_equal(np.asarray(sim.peek(resumed)), want_mem)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
def test_replay_vmap_instances_and_seeds(spec):
    """vmap across B (init values, trace seed) pairs == B solo replays."""
    B = 3
    rng = np.random.default_rng(rp.spec_seed(spec, salt="vmap"))
    inits = rng.integers(0, 2**32, (B, DEPTH), dtype=np.uint32)
    traces = [_trace(spec, seed) for seed in range(B)]
    ra, wa, wv, wm = (np.stack([tr[i] for tr in traces]) for i in range(4))

    states = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[rp.init_flat(spec, jnp.asarray(v)) for v in inits])
    states, batched = rp.replay_batched(spec, states, ra, wa, wv, wm)

    for b in range(B):
        want_reads, want_mem = ram_oracle(inits[b], ra[b], wa[b], wv[b], wm[b])
        np.testing.assert_array_equal(np.asarray(batched.read_vals[b]),
                                      want_reads)
        np.testing.assert_array_equal(np.asarray(batched.parity_vals[b]),
                                      want_reads)
        solo = jax.tree.map(lambda x: x[b], states)
        np.testing.assert_array_equal(np.asarray(rp.peek_flat(spec, solo)),
                                      want_mem)


def test_replay_shared_trace_broadcast():
    """share_trace=True: one op stream against many design instances."""
    spec = AMMSpec("hb_ntx", 4, 2, DEPTH)
    B = 4
    rng = np.random.default_rng(11)
    inits = rng.integers(0, 2**32, (B, DEPTH), dtype=np.uint32)
    ra, wa, wv, wm = _trace(spec, seed=5)
    states = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[rp.init_flat(spec, jnp.asarray(v)) for v in inits])
    _, batched = rp.replay_batched(spec, states, ra, wa, wv, wm,
                                   share_trace=True)
    for b in range(B):
        want_reads, _ = ram_oracle(inits[b], ra, wa, wv, wm)
        np.testing.assert_array_equal(np.asarray(batched.read_vals[b]),
                                      want_reads)


@pytest.mark.parametrize("n_write", [2, 3, 4])
def test_remap_no_bank_sharing_invariant(n_write):
    """The remap table's "always one spare bank" claim: within any cycle,
    no two live (masked) writes may ever be steered to the same physical
    bank — n_write + 1 banks guarantee a free one for every port."""
    spec = AMMSpec("remap", 2, n_write, DEPTH)
    for seed in range(4):
        ra, wa, wv, wm = _trace(spec, seed, n_cycles=40)
        flat = rp.init_flat(spec)
        _, result = rp.replay(spec, flat, ra, wa, wv, wm)
        banks = np.asarray(result.write_banks)          # [T, W]
        assert banks.shape == wa.shape
        n_banks = spec.n_write + 1
        for t in range(banks.shape[0]):
            live = banks[t][wm[t]]
            assert np.all(live >= 0) and np.all(live < n_banks)
            assert len(set(live.tolist())) == len(live), (
                f"cycle {t}: two writes share a bank: {banks[t]} mask {wm[t]}")
            # idle ports must not claim a bank
            assert np.all(banks[t][~wm[t]] == -1)


def test_h_tables_geometry():
    """Path tables: direct is a singleton of the write set; write and
    parity sets intersect exactly in the all-ref leaf paths."""
    tb = rp.h_tables(32, 2)
    assert tb.leaf_depth == 8
    assert tb.direct.shape == (32,)
    assert tb.write_paths.shape == (32, 4)
    assert tb.parity_paths.shape == (32, 4)
    for a in range(32):
        assert tb.direct[a] in tb.write_paths[a]
        # direct leaf never appears on the reconstruction path
        assert tb.direct[a] not in tb.parity_paths[a]
        # each path set hits distinct leaves
        assert len(set(tb.write_paths[a])) == 4
        assert len(set(tb.parity_paths[a])) == 4
        # both contain the all-ref leaf (last base-3 digit pattern 22)
        assert tb.write_paths[a][-1] == tb.parity_paths[a][-1] == 8