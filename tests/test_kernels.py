"""Per-kernel shape/dtype sweeps through the *default* dispatch
(mode=compiled: real Pallas lowering on TPU/GPU, the XLA grid path on
CPU) vs ref.py.  Explicit per-mode parity lives in test_kernel_parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import amm_gather, kv_decode, pack_amm_banks, ssd_chunk
from repro.kernels import ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,nb,n", [
    (64, 8, 2, 16), (128, 16, 4, 64), (256, 128, 4, 128), (512, 32, 8, 256),
])
def test_amm_gather_sweep(dtype, v, d, nb, n):
    table = jnp.asarray(RNG.standard_normal((v, d)), dtype)
    idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    got = amm_gather(table, idx, n_banks=nb)
    want = ref.amm_gather_ref(table, idx)
    assert jnp.array_equal(got, want), "XOR reconstruction must be bit-exact"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,nb,n", [(64, 8, 2, 16), (128, 16, 4, 63)])
def test_amm_gather_replay_oracle(dtype, v, d, nb, n):
    """Kernel vs the replay-backed functional-model oracle: the Pallas
    XOR-reconstruction path and the H-NTX-Rd parity path must agree
    bit-for-bit (and both must equal a plain gather)."""
    table = jnp.asarray(RNG.standard_normal((v, d)), dtype)
    idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    want = ref.amm_gather_replay_ref(table, idx)
    assert jnp.array_equal(want, ref.amm_gather_ref(table, idx))
    got = amm_gather(table, idx, n_banks=nb)
    assert jnp.array_equal(got, want)


def test_amm_parity_invariant():
    """parity bank == XOR of data banks, and reconstruction uses it."""
    table = jnp.asarray(RNG.integers(0, 2**31, (64, 4)), jnp.uint32)
    banks, parity = pack_amm_banks(table.view(jnp.float32), 4)
    x = banks[0] ^ banks[1] ^ banks[2] ^ banks[3]
    assert jnp.array_equal(x, parity)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 127), min_size=8, max_size=8))
def test_amm_gather_hypothesis_indices(idx):
    table = jnp.asarray(RNG.standard_normal((128, 8)), jnp.float32)
    got = amm_gather(table, jnp.asarray(idx, jnp.int32), n_banks=4)
    assert jnp.array_equal(got, ref.amm_gather_ref(
        table, jnp.asarray(idx, jnp.int32)))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("b,hq,hkv,s,d,nb", [
    (2, 4, 2, 64, 16, 4), (1, 8, 8, 128, 32, 8), (3, 6, 2, 96, 8, 4),
])
def test_kv_decode_sweep(dtype, tol, b, hq, hkv, s, d, nb):
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    lens = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)
    got = kv_decode(q, k, v, lens, n_banks=nb)
    want = ref.kv_decode_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_kv_decode_respects_lengths():
    """Tokens beyond the per-sequence length must not affect output."""
    b, hq, hkv, s, d = 2, 2, 2, 32, 8
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    lens = jnp.asarray([10, 20], jnp.int32)
    out1 = kv_decode(q, k, v, lens, n_banks=4)
    k2 = k.at[:, :, 25:, :].set(999.0)
    v2 = v.at[:, :, 25:, :].set(-999.0)
    out2 = kv_decode(q, k2, v2, lens, n_banks=4)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


@pytest.mark.parametrize("bt,h,q,p,n", [(1, 2, 8, 4, 4), (2, 3, 16, 8, 8)])
def test_ssd_chunk_sweep(bt, h, q, p, n):
    x = jnp.asarray(RNG.standard_normal((bt, h, q, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (bt, h, q)), jnp.float32)
    la = -dt * jnp.asarray(RNG.uniform(0.5, 2.0, (1, h, 1)), jnp.float32)
    cum = jnp.cumsum(la, axis=-1)
    B = jnp.asarray(RNG.standard_normal((bt, q, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((bt, q, n)), jnp.float32)
    h_in = jnp.asarray(RNG.standard_normal((bt, h, p, n)), jnp.float32)
    y1, h1 = ssd_chunk(x, dt, cum, B, C, h_in)
    y2, h2 = ref.ssd_chunk_ref(x, dt, cum, B, C, h_in)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_ssd_chunk_matches_recurrence():
    """Kernel chunk == naive per-token recurrence over the same chunk."""
    from repro.models.ssm import ssd_reference
    bt, h, q, p, n = 1, 2, 12, 4, 6
    x = jnp.asarray(RNG.standard_normal((bt, q, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.3, (bt, q, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, h), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((bt, q, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((bt, q, n)), jnp.float32)
    y_ref, h_ref = ssd_reference(x, dt, A, B, C)
    la = dt * A[None, None, :]
    cum = jnp.cumsum(la, axis=1)
    xk = jnp.transpose(x, (0, 2, 1, 3))
    y_k, h_k = ssd_chunk(xk, jnp.transpose(dt, (0, 2, 1)),
                         jnp.transpose(cum, (0, 2, 1)), B, C,
                         jnp.zeros((bt, h, p, n), jnp.float32))
    np.testing.assert_allclose(np.asarray(jnp.transpose(y_k, (0, 2, 1, 3))),
                               np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref), atol=1e-4)
