"""Autotune harness tests (ISSUE 8): candidate legality, shape-bucket
keys, table round-trip, dispatch fallbacks, and a tiny end-to-end tune."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.ops import _pick_block, amm_gather, kv_decode

RNG = np.random.default_rng(5)


def test_candidates_are_legal():
    for cfg in autotune.candidates("amm_gather", v=1024, d=64, nb=4, n=384):
        assert 384 % cfg["block_n"] == 0
    for cfg in autotune.candidates("kv_decode", b=2, hq=8, hkv=2, s=64,
                                   d=16, nb=4):
        assert (8 // 2) % cfg["block_h"] == 0
    for cfg in autotune.candidates("ssd_chunk", bt=1, h=6, q=16, p=8, n=4):
        assert 6 % cfg["block_h"] == 0
    with pytest.raises(KeyError):
        autotune.candidates("nope")


def test_shape_key_pow2_bucketing():
    k1 = autotune.shape_key("amm_gather", "cpu", "xla", v=1000, n=200)
    k2 = autotune.shape_key("amm_gather", "cpu", "xla", v=1024, n=256)
    k3 = autotune.shape_key("amm_gather", "cpu", "xla", v=1025, n=256)
    assert k1 == k2 != k3


def test_pick_block_relegalizes():
    assert _pick_block(128, 256) == 128
    assert _pick_block(128, 96) == 96
    assert _pick_block(128, 97) == 97      # prime: whole-shape block
    assert _pick_block(4, 6) == 3
    assert _pick_block(1, 5) == 1


def test_table_roundtrip_and_fallback(tmp_path):
    path = tmp_path / "cache.json"
    entries = {
        autotune.shape_key("amm_gather", "cpu", "xla", v=64, n=32):
            {"config": {"block_n": 16}, "us": 1.0},
    }
    autotune.save_table(entries, str(path))
    loaded = autotune.load_table(str(path), refresh=True)
    assert loaded == json.loads(path.read_text())["entries"]
    try:
        got = autotune.get_config("amm_gather", "cpu", "xla", v=64, n=32)
        assert got == {"block_n": 16}
        # miss -> kernel default
        miss = autotune.get_config("amm_gather", "cpu", "xla", v=8192, n=8192)
        assert miss == autotune.DEFAULTS["amm_gather"]
    finally:
        autotune.load_table(refresh=True)      # restore the real table


def test_corrupt_table_reads_as_empty(tmp_path):
    path = tmp_path / "junk.json"
    path.write_text("{not json")
    try:
        assert autotune.load_table(str(path), refresh=True) == {}
    finally:
        autotune.load_table(refresh=True)


def test_tune_end_to_end_records_winner():
    table = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 64, 32), jnp.int32)
    entries = {}
    entry = autotune.tune("amm_gather", (table, idx, 2),
                          dict(v=64, d=8, nb=2, n=32), repeat=2,
                          entries=entries)
    assert entry["config"] in [r["config"] for r in entry["swept"]]
    assert entry["us"] == min(r["us"] for r in entry["swept"])
    assert len(entries) == 1
    key = next(iter(entries))
    assert key.startswith(f"amm_gather|{jax.default_backend()}|")


def test_tuned_config_changes_nothing_numerically():
    """Whatever block the table picks, results must equal the oracle —
    dispatch through the real checked-in table."""
    table = jnp.asarray(RNG.standard_normal((1024, 128)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 1024, 256), jnp.int32)
    got = amm_gather(table, idx, n_banks=4)        # tuned dispatch
    assert jnp.array_equal(got, ref.amm_gather_ref(table, idx))
    q = jnp.asarray(RNG.standard_normal((2, 8, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 4, 64, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 4, 64, 32)), jnp.float32)
    lens = jnp.asarray([64, 17], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(kv_decode(q, k, v, lens, n_banks=4)),
        np.asarray(ref.kv_decode_ref(q, k, v, lens)), atol=2e-5, rtol=2e-5)
