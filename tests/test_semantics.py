"""Deeper semantic oracles: MoE vs dense-mixture reference, hybrid
sequential decode vs full forward, planner/data integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, tiny_variant
from repro.configs.base import RuntimeConfig
from repro.models import decode_step, forward, init_model, make_cache
from repro.models.moe import MoEConfig, moe_apply, moe_init

RT = RuntimeConfig(remat="none")


def test_moe_matches_dense_mixture_oracle():
    """With capacity >= S*K/E guaranteed (cf large), no token drops —
    the capacity-dispatch output must equal the naive dense mixture."""
    cfg = MoEConfig(d_model=16, d_ff_expert=32, n_experts=4, top_k=2,
                    capacity_factor=4.0, act="silu", gated=True)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)

    got = moe_apply(params, cfg, x)

    # oracle: run every expert densely, combine with renormalized top-k
    logits = (x @ params["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, cfg.top_k)
    top_g = top_g / top_g.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        up = x @ params["w_up"][e]
        up = jax.nn.silu(x @ params["w_gate"][e]) * up
        outs.append(up @ params["w_down"][e])
    dense = jnp.stack(outs, axis=-2)                     # [B,S,E,D]
    want = jnp.zeros_like(x)
    for k in range(cfg.top_k):
        sel = jnp.take_along_axis(
            dense, top_e[..., k][..., None, None], axis=-2)[..., 0, :]
        want = want + top_g[..., k][..., None] * sel
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_hybrid_sequential_decode_matches_forward():
    """zamba2 (mamba + shared attn): decoding token-by-token from an
    empty cache must reproduce the full forward's final logits."""
    arch = tiny_variant(get_arch("zamba2-2.7b"))
    params = init_model(jax.random.PRNGKey(0), arch)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, arch.vocab - 1, (2, 6)), jnp.int32)

    logits_full, _ = forward(params, arch, {"tokens": toks}, RT)

    cache = make_cache(arch, 8, 2)
    step = jax.jit(lambda p, c, t: decode_step(p, arch, c, t, RT))
    for i in range(6):
        logits_d, cache = step(params, cache, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               atol=2e-2, rtol=2e-2)


def test_encdec_sequential_decode_matches_forward():
    """seamless (enc-dec): prefill + decode must agree with forward."""
    from repro.models import prefill
    arch = tiny_variant(get_arch("seamless-m4t-medium"))
    params = init_model(jax.random.PRNGKey(0), arch)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(1, arch.vocab - 1, (2, 8)), jnp.int32)
    frames = jnp.asarray(rng.standard_normal((2, 8, arch.d_model)),
                         jnp.float32)
    batch = {"tokens": toks, "frames": frames}
    logits_full, _ = forward(params, arch, batch, RT)
    _, cache = prefill(params, arch,
                       {"tokens": toks[:, :7], "frames": frames}, 12, RT)
    logits_d, _ = decode_step(params, arch, cache, toks[:, 7:8], RT)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_full[:, 7]),
                               atol=3e-2, rtol=3e-2)


def test_zipf_alpha_drives_planner_locality():
    """The data pipeline's skew knob feeds the planner: heavier zipf
    (more repeated hot tokens) lowers spatial locality of the embedding
    stream — the paper's trace->design coupling, end to end."""
    from repro.core.locality import spatial_locality_np
    from repro.memory.planner import embedding_stream
    arch = get_arch("qwen3-1.7b")
    flat = embedding_stream(arch, n=4096, zipf_alpha=1.01)
    hot = embedding_stream(arch, n=4096, zipf_alpha=2.5)
    l_flat = spatial_locality_np(flat)
    l_hot = spatial_locality_np(hot)
    # both are low-locality gather streams; the hot one revisits a few
    # rows (temporal, not spatial) and both stay below the AMM threshold
    assert l_flat < 0.3 and l_hot < 0.3
