"""Property tests: every AMM design must be semantically identical to an
ideal multiport RAM under arbitrary op sequences (the paper's core
correctness claim for algorithmic multi-porting), with the XOR parity
path agreeing with the direct path at every step."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.amm import AMM_KINDS, AMMSpec, make_amm

DEPTH = 32

SPECS = [
    AMMSpec("ideal", 2, 2, DEPTH),
    AMMSpec("h_ntx_rd", 2, 1, DEPTH),
    AMMSpec("h_ntx_rd", 4, 1, DEPTH),
    AMMSpec("b_ntx_wr", 1, 2, DEPTH),
    AMMSpec("hb_ntx", 2, 2, DEPTH),
    AMMSpec("hb_ntx", 4, 2, DEPTH),
    AMMSpec("lvt", 2, 2, DEPTH),
    AMMSpec("lvt", 4, 3, DEPTH),
    AMMSpec("remap", 2, 2, DEPTH),
    AMMSpec("remap", 2, 4, DEPTH),
]


def ops_strategy(spec: AMMSpec, n_steps: int = 12):
    step = st.tuples(
        st.lists(st.integers(0, DEPTH - 1), min_size=spec.n_read,
                 max_size=spec.n_read),
        st.lists(st.tuples(st.integers(0, DEPTH - 1),
                           st.integers(0, 2**32 - 1), st.booleans()),
                 min_size=spec.n_write, max_size=spec.n_write),
    )
    return st.lists(step, min_size=1, max_size=n_steps)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
def test_amm_matches_ram_oracle(spec):
    rng = np.random.default_rng(hash(spec.describe()) % 2**31)
    init = rng.integers(0, 2**32, DEPTH, dtype=np.uint32)
    sim = make_amm(spec, jnp.asarray(init))
    state = sim.state
    oracle = init.copy()
    for t in range(25):
        ra = rng.integers(0, DEPTH, spec.n_read).astype(np.int32)
        wa = rng.integers(0, DEPTH, spec.n_write).astype(np.int32)
        wv = rng.integers(0, 2**32, spec.n_write, dtype=np.uint32)
        wm = rng.integers(0, 2, spec.n_write).astype(bool)
        state, vals = sim.step(state, jnp.asarray(ra), jnp.asarray(wa),
                               jnp.asarray(wv), jnp.asarray(wm))
        np.testing.assert_array_equal(np.asarray(vals), oracle[ra])
        for p in range(spec.n_write):
            if wm[p]:
                oracle[wa[p]] = wv[p]
        np.testing.assert_array_equal(np.asarray(sim.peek(state)), oracle)
        a = int(rng.integers(0, DEPTH))
        assert int(sim.read(state, jnp.int32(a))) == int(oracle[a])
        assert int(sim.read_parity(state, jnp.int32(a))) == int(oracle[a])


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_hb_ntx_hypothesis(data):
    spec = AMMSpec("hb_ntx", 4, 2, DEPTH)
    ops = data.draw(ops_strategy(spec))
    sim = make_amm(spec)
    state = sim.state
    oracle = np.zeros(DEPTH, np.uint32)
    for reads, writes in ops:
        ra = jnp.asarray(reads, jnp.int32)
        wa = jnp.asarray([w[0] for w in writes], jnp.int32)
        wv = jnp.asarray([w[1] for w in writes], jnp.uint32)
        wm = jnp.asarray([w[2] for w in writes])
        state, vals = sim.step(state, ra, wa, wv, wm)
        np.testing.assert_array_equal(np.asarray(vals), oracle[np.asarray(reads)])
        for a, v, m in writes:
            if m:
                oracle[a] = v
    np.testing.assert_array_equal(np.asarray(sim.peek(state)), oracle)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_lvt_hypothesis(data):
    spec = AMMSpec("lvt", 2, 3, DEPTH)
    ops = data.draw(ops_strategy(spec))
    sim = make_amm(spec)
    state = sim.state
    oracle = np.zeros(DEPTH, np.uint32)
    for reads, writes in ops:
        state, vals = sim.step(
            state, jnp.asarray(reads, jnp.int32),
            jnp.asarray([w[0] for w in writes], jnp.int32),
            jnp.asarray([w[1] for w in writes], jnp.uint32),
            jnp.asarray([w[2] for w in writes]))
        np.testing.assert_array_equal(np.asarray(vals),
                                      oracle[np.asarray(reads)])
        for a, v, m in writes:
            if m:
                oracle[a] = v
    np.testing.assert_array_equal(np.asarray(sim.peek(state)), oracle)


def test_spec_formulas():
    s = AMMSpec("h_ntx_rd", 4, 1, 64)
    assert s.leaf_banks() == (9, 16)            # 3^2 leaves, depth N/4
    assert s.storage_bits() == 9 * 16 * 32      # (3/2)^2 overhead
    s = AMMSpec("hb_ntx", 2, 2, 64)
    assert s.leaf_banks() == (9, 16)
    assert AMMSpec("lvt", 3, 2, 64).leaf_banks() == (6, 64)
    assert AMMSpec("remap", 1, 3, 64).leaf_banks() == (4, 64)
    assert AMMSpec("lvt", 2, 4, 64).table_bits() == 64 * 2
    assert AMMSpec("multipump", 2, 2, 64).frequency_factor == 0.5
    assert AMMSpec("hb_ntx", 4, 2, 64).conflict_free
    assert not AMMSpec("banked", 2, 2, 64, n_banks=4).conflict_free


def test_spec_validation():
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 3, 1, 64)           # non-pow2 reads
    with pytest.raises(ValueError):
        AMMSpec("b_ntx_wr", 1, 3, 64)           # B gives exactly 2W
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 2, 1, 63)           # depth not divisible
