"""Property tests: every AMM design must be semantically identical to an
ideal multiport RAM under arbitrary op sequences (the paper's core
correctness claim for algorithmic multi-porting), with the XOR parity
path agreeing with the direct path at every cycle.

Whole traces are replayed in one compiled call through ``sim.replay``
(the ``lax.scan`` engine in ``repro.core.amm.replay``); the per-step
path is pinned bit-exact against it in ``tests/test_replay.py``."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.amm import AMM_KINDS, AMMSpec, make_amm

DEPTH = 32

SPECS = [
    AMMSpec("ideal", 2, 2, DEPTH),
    AMMSpec("h_ntx_rd", 2, 1, DEPTH),
    AMMSpec("h_ntx_rd", 4, 1, DEPTH),
    AMMSpec("b_ntx_wr", 1, 2, DEPTH),
    AMMSpec("hb_ntx", 2, 2, DEPTH),
    AMMSpec("hb_ntx", 4, 2, DEPTH),
    AMMSpec("lvt", 2, 2, DEPTH),
    AMMSpec("lvt", 4, 3, DEPTH),
    AMMSpec("remap", 2, 2, DEPTH),
    AMMSpec("remap", 2, 4, DEPTH),
]


def ram_oracle(init, ra, wa, wv, wm):
    """Cycle-by-cycle numpy RAM reference: returns (per-cycle reads, mem)."""
    mem = init.copy()
    reads = np.empty(ra.shape, np.uint32)
    for t in range(ra.shape[0]):
        reads[t] = mem[ra[t]]
        for p in range(wa.shape[1]):
            if wm[t, p]:
                mem[wa[t, p]] = wv[t, p]
    return reads, mem


def random_trace(spec, n_cycles, rng):
    from repro.core.amm.replay import make_trace
    return make_trace(spec, n_cycles, rng=rng)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
def test_amm_matches_ram_oracle(spec):
    from repro.core.amm.replay import spec_seed
    rng = np.random.default_rng(spec_seed(spec))
    init = rng.integers(0, 2**32, DEPTH, dtype=np.uint32)
    ra, wa, wv, wm = random_trace(spec, 25, rng)
    want_reads, want_mem = ram_oracle(init, ra, wa, wv, wm)

    sim = make_amm(spec, jnp.asarray(init))
    state, result = sim.replay(sim.state, ra, wa, wv, wm)
    np.testing.assert_array_equal(np.asarray(result.read_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(result.parity_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(sim.peek(state)), want_mem)
    a = int(rng.integers(0, DEPTH))
    assert int(sim.read(state, jnp.int32(a))) == int(want_mem[a])
    assert int(sim.read_parity(state, jnp.int32(a))) == int(want_mem[a])


def _ops_to_arrays(ops):
    ra = np.asarray([reads for reads, _ in ops], np.int32)
    wa = np.asarray([[w[0] for w in writes] for _, writes in ops], np.int32)
    wv = np.asarray([[w[1] for w in writes] for _, writes in ops], np.uint32)
    wm = np.asarray([[w[2] for w in writes] for _, writes in ops], bool)
    return ra, wa, wv, wm


def ops_strategy(spec: AMMSpec, n_steps: int = 12):
    step = st.tuples(
        st.lists(st.integers(0, DEPTH - 1), min_size=spec.n_read,
                 max_size=spec.n_read),
        st.lists(st.tuples(st.integers(0, DEPTH - 1),
                           st.integers(0, 2**32 - 1), st.booleans()),
                 min_size=spec.n_write, max_size=spec.n_write),
    )
    return st.lists(step, min_size=1, max_size=n_steps)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_hb_ntx_hypothesis(data):
    spec = AMMSpec("hb_ntx", 4, 2, DEPTH)
    ra, wa, wv, wm = _ops_to_arrays(data.draw(ops_strategy(spec)))
    want_reads, want_mem = ram_oracle(np.zeros(DEPTH, np.uint32),
                                      ra, wa, wv, wm)
    sim = make_amm(spec)
    state, result = sim.replay(sim.state, ra, wa, wv, wm)
    np.testing.assert_array_equal(np.asarray(result.read_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(result.parity_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(sim.peek(state)), want_mem)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_lvt_hypothesis(data):
    spec = AMMSpec("lvt", 2, 3, DEPTH)
    ra, wa, wv, wm = _ops_to_arrays(data.draw(ops_strategy(spec)))
    want_reads, want_mem = ram_oracle(np.zeros(DEPTH, np.uint32),
                                      ra, wa, wv, wm)
    sim = make_amm(spec)
    state, result = sim.replay(sim.state, ra, wa, wv, wm)
    np.testing.assert_array_equal(np.asarray(result.read_vals), want_reads)
    np.testing.assert_array_equal(np.asarray(sim.peek(state)), want_mem)


def test_spec_formulas():
    s = AMMSpec("h_ntx_rd", 4, 1, 64)
    assert s.leaf_banks() == (9, 16)            # 3^2 leaves, depth N/4
    assert s.storage_bits() == 9 * 16 * 32      # (3/2)^2 overhead
    s = AMMSpec("hb_ntx", 2, 2, 64)
    assert s.leaf_banks() == (9, 16)
    assert AMMSpec("lvt", 3, 2, 64).leaf_banks() == (6, 64)
    assert AMMSpec("remap", 1, 3, 64).leaf_banks() == (4, 64)
    assert AMMSpec("lvt", 2, 4, 64).table_bits() == 64 * 2
    assert AMMSpec("multipump", 2, 2, 64).frequency_factor == 0.5
    assert AMMSpec("hb_ntx", 4, 2, 64).conflict_free
    assert not AMMSpec("banked", 2, 2, 64, n_banks=4).conflict_free


def test_spec_validation():
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 3, 1, 64)           # non-pow2 reads
    with pytest.raises(ValueError):
        AMMSpec("b_ntx_wr", 1, 3, 64)           # B gives exactly 2W
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 2, 1, 63)           # depth not divisible
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 2, 2, 64)           # single write port only


def test_h_step_rejects_multi_write():
    """h_step must not silently drop write ports beyond port 0."""
    from repro.core.amm import ntx
    sim = make_amm(AMMSpec("h_ntx_rd", 2, 1, DEPTH))
    with pytest.raises(ValueError):
        ntx.h_step(sim.state, jnp.zeros((2,), jnp.int32),
                   jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.uint32),
                   jnp.ones((2,), bool))