"""Golden cycle-count regression: the vectorized/prepared scheduler must
be cycle-exact against the seed implementation.

``golden_schedule.json`` was captured from the seed (pre-PreparedTrace)
scheduler over a (bench, design, unroll) matrix.  Both the compiled C
cycle loop and the pure-Python reference loop must reproduce every
cycles / issued / mem_issued / avg_mem_parallelism value bit-exactly.
(``bank_conflict_stalls`` is deliberately NOT pinned: the seed
double-counted multiply-deferred accesses; it now counts unique delayed
accesses.)
"""
import json
import pathlib

import pytest

from repro.core.bench import get_trace
from repro.core.dse.sweep import DesignPoint, _BASE_FU, _spec_for
from repro.core.sim import prepare_trace
from repro.core.sim.scheduler import ScheduleConfig, _schedule_py, schedule

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_schedule.json").read_text())

_DESIGNS = {
    "banked4": DesignPoint("banked", 1, 1, 4),
    "banked32": DesignPoint("banked", 1, 1, 32),
    "multipump-2R2W": DesignPoint("multipump", 2, 2, 1),
    "hb_ntx-2R2W": DesignPoint("hb_ntx", 2, 2, 1),
    "lvt-4R2W": DesignPoint("lvt", 4, 2, 1),
}


def _config(pt, design: str, unroll: int) -> ScheduleConfig:
    dp = _DESIGNS[design]
    specs = {aid: _spec_for(dp, pt.array_depths[aid],
                            pt.trace.word_bytes[aid] * 8)
             for aid in pt.trace.array_names}
    return ScheduleConfig(
        mem=specs,
        fu_counts={k: v * unroll for k, v in _BASE_FU.items()})


def _check(res, g):
    assert res.cycles == g["cycles"], (g, res.cycles)
    assert res.issued == g["issued"]
    assert res.mem_issued == g["mem_issued"]
    assert abs(res.avg_mem_parallelism - g["avg_mem_parallelism"]) < 1e-9


@pytest.mark.parametrize(
    "g", GOLDEN, ids=[f"{g['bench']}-{g['design']}-u{g['unroll']}"
                      for g in GOLDEN])
def test_schedule_matches_seed_golden(g):
    pt = prepare_trace(get_trace(g["bench"]))
    _check(schedule(pt, _config(pt, g["design"], g["unroll"])), g)


@pytest.mark.parametrize(
    "g", GOLDEN[::4], ids=[f"{g['bench']}-{g['design']}-u{g['unroll']}"
                           for g in GOLDEN[::4]])
def test_python_reference_loop_matches_seed_golden(g):
    """The pure-Python fallback loop is pinned too (subset: it is ~50x
    slower than the compiled loop but must stay exact)."""
    pt = prepare_trace(get_trace(g["bench"]))
    _check(_schedule_py(pt, _config(pt, g["design"], g["unroll"])), g)


def test_c_and_python_loops_agree_everywhere():
    """Full ScheduleResult equality (including the stall counter) between
    the compiled and reference loops across the golden matrix subset."""
    from repro.core.sim import _cycle_ext

    if _cycle_ext.load() is None:
        pytest.skip("no C compiler available; python loop is the only path")
    for g in GOLDEN[::3]:
        pt = prepare_trace(get_trace(g["bench"]))
        cfg = _config(pt, g["design"], g["unroll"])
        assert schedule(pt, cfg) == _schedule_py(pt, cfg)
