"""Golden cycle-count regression: every scheduler backend must be
cycle-exact against the pinned golden matrix.

``golden_schedule.json`` holds two generations of rows.  The original
rows were captured from the seed (pre-PreparedTrace) scheduler over a
(bench, design, unroll) matrix; both the compiled C cycle loop and the
pure-Python reference loop must reproduce every cycles / issued /
mem_issued / avg_mem_parallelism value bit-exactly.  Rows added later
(the ``-b4`` leaf-sub-banked DEFAULT_DESIGNS points, the per-kind
coverage across all 12 benches — see ``tools/gen_golden_schedule.py``)
were captured from the agreeing C + pure-py loops and additionally pin
the full stall breakdown (``bank_conflict`` / ``parity_fanout`` /
``write_pair``) plus the parity-path-read and write-pair-RMW event
counters.

The batched JAX backend (``repro.core.sim.jax_cycle``) is pinned
against the same matrix: one ``schedule_batched`` call per bench
evaluates every golden design row of that bench in a single jit call
and must match each row — including the stall breakdown — exactly.
"""
import json
import pathlib

import pytest

from repro.core.bench import get_trace
from repro.core.dse.sweep import DesignPoint, _BASE_FU, _spec_for
from repro.core.sim import prepare_trace
from repro.core.sim.scheduler import ScheduleConfig, _schedule_py, schedule

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_schedule.json").read_text())

_DESIGNS = {
    "banked4": DesignPoint("banked", 1, 1, 4),
    "banked32": DesignPoint("banked", 1, 1, 32),
    "multipump-2R2W": DesignPoint("multipump", 2, 2, 1),
    "hb_ntx-2R2W": DesignPoint("hb_ntx", 2, 2, 1),
    "lvt-4R2W": DesignPoint("lvt", 4, 2, 1),
    # post-seed coverage: remaining kinds + the -b4 sub-banked points
    "ideal-2R2W": DesignPoint("ideal", 2, 2, 1),
    "h_ntx_rd-4R1W": DesignPoint("h_ntx_rd", 4, 1, 1),
    "b_ntx_wr-1R2W": DesignPoint("b_ntx_wr", 1, 2, 1),
    "remap-2R2W": DesignPoint("remap", 2, 2, 1),
    "h_ntx_rd-4R1W-b4": DesignPoint("h_ntx_rd", 4, 1, n_banks=4),
    "hb_ntx-4R2W-b4": DesignPoint("hb_ntx", 4, 2, n_banks=4),
    "lvt-4R2W-b4": DesignPoint("lvt", 4, 2, n_banks=4),
    "remap-4R2W-b4": DesignPoint("remap", 4, 2, n_banks=4),
}

_STALL_FIELDS = ("bank_conflict_stalls", "parity_fanout_stalls",
                 "write_pair_stalls", "parity_path_reads",
                 "write_pair_rmws")


def _config(pt, design: str, unroll: int) -> ScheduleConfig:
    dp = _DESIGNS[design]
    specs = {aid: _spec_for(dp, pt.array_depths[aid],
                            pt.trace.word_bytes[aid] * 8)
             for aid in pt.trace.array_names}
    return ScheduleConfig(
        mem=specs,
        fu_counts={k: v * unroll for k, v in _BASE_FU.items()})


def _check(res, g):
    assert res.cycles == g["cycles"], (g, res.cycles)
    assert res.issued == g["issued"]
    assert res.mem_issued == g["mem_issued"]
    assert abs(res.avg_mem_parallelism - g["avg_mem_parallelism"]) < 1e-9
    for f in _STALL_FIELDS:
        if f in g:
            assert getattr(res, f) == g[f], (f, g, getattr(res, f))


@pytest.mark.parametrize(
    "g", GOLDEN, ids=[f"{g['bench']}-{g['design']}-u{g['unroll']}"
                      for g in GOLDEN])
def test_schedule_matches_seed_golden(g):
    pt = prepare_trace(get_trace(g["bench"]))
    _check(schedule(pt, _config(pt, g["design"], g["unroll"])), g)


@pytest.mark.parametrize(
    "g", GOLDEN[::4], ids=[f"{g['bench']}-{g['design']}-u{g['unroll']}"
                           for g in GOLDEN[::4]])
def test_python_reference_loop_matches_seed_golden(g):
    """The pure-Python fallback loop is pinned too (subset: it is ~50x
    slower than the compiled loop but must stay exact)."""
    pt = prepare_trace(get_trace(g["bench"]))
    _check(_schedule_py(pt, _config(pt, g["design"], g["unroll"])), g)


def test_c_and_python_loops_agree_everywhere():
    """Full ScheduleResult equality (including the stall counter) between
    the compiled and reference loops across the golden matrix subset."""
    from repro.core.sim import _cycle_ext

    if _cycle_ext.load() is None:
        pytest.skip("no C compiler available; python loop is the only path")
    for g in GOLDEN[::3]:
        pt = prepare_trace(get_trace(g["bench"]))
        cfg = _config(pt, g["design"], g["unroll"])
        assert schedule(pt, cfg) == _schedule_py(pt, cfg)


def _bench_rows():
    by_bench: dict[str, list] = {}
    for g in GOLDEN:
        by_bench.setdefault(g["bench"], []).append(g)
    return sorted(by_bench.items())


_BENCH_ROWS = _bench_rows()


@pytest.mark.parametrize("bench,rows", _BENCH_ROWS,
                         ids=[b for b, _ in _BENCH_ROWS])
def test_jax_grid_matches_golden(bench, rows):
    """One batched jit call per bench evaluates every golden design row
    and must match each — cycles AND stall breakdown (ISSUE 5
    acceptance: all benches x all kinds)."""
    from repro.core.sim.jax_cycle import schedule_batched

    pt = prepare_trace(get_trace(bench))
    cfgs = [_config(pt, g["design"], g["unroll"]) for g in rows]
    results = schedule_batched(pt, cfgs)
    for g, res in zip(rows, results):
        _check(res, g)
