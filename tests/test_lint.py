"""tools/lint_repro.py: each rule fires on its target pattern, stays
quiet on the clean form, and the allowlist suppresses by exact key."""
import importlib.util
import pathlib
import textwrap

import pytest

_TOOLS = pathlib.Path(__file__).resolve().parents[1] / "tools"
_spec = importlib.util.spec_from_file_location(
    "lint_repro", _TOOLS / "lint_repro.py")
lint_repro = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(lint_repro)


def _lint(tmp_path, source: str, rel: str = "src/mod.py"):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return lint_repro.lint_file(p, tmp_path)


def _rules(findings):
    return {f.rule for f in findings}


def test_interpret_true_flagged_in_src(tmp_path):
    src = """
        def run(x):
            return pallas_call(kern, interpret=True)(x)
    """
    assert _rules(_lint(tmp_path, src)) == {"interpret-true"}


def test_interpret_true_allowed_in_tests(tmp_path):
    src = """
        def run(x):
            return pallas_call(kern, interpret=True)(x)
    """
    assert _lint(tmp_path, src, rel="tests/test_mod.py") == []


def test_interpret_false_not_flagged(tmp_path):
    src = """
        def run(x, interp):
            return pallas_call(kern, interpret=False)(x)
    """
    assert _lint(tmp_path, src) == []


def test_async_timing_without_block_flagged(tmp_path):
    src = """
        import time
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            y = jnp.dot(x, x)
            t1 = time.perf_counter()
            return t1 - t0
    """
    assert _rules(_lint(tmp_path, src)) == {"missing-block-until-ready"}


def test_timing_with_block_until_ready_clean(tmp_path):
    src = """
        import time
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            y = jnp.dot(x, x).block_until_ready()
            t1 = time.perf_counter()
            return t1 - t0
    """
    assert _lint(tmp_path, src) == []


def test_pure_python_timing_not_flagged(tmp_path):
    src = """
        import time

        def bench(xs):
            t0 = time.perf_counter()
            y = sum(xs)
            t1 = time.perf_counter()
            return t1 - t0
    """
    assert _lint(tmp_path, src) == []


def test_mutable_default_arg_flagged(tmp_path):
    src = """
        def collect(item, acc=[]):
            acc.append(item)
            return acc

        def index(key, table={}):
            return table.setdefault(key, len(table))
    """
    f = _lint(tmp_path, src)
    assert _rules(f) == {"mutable-default-arg"}
    assert len(f) == 2


def test_none_default_not_flagged(tmp_path):
    src = """
        def collect(item, acc=None, n=3, name="x"):
            return (acc or []) + [item]
    """
    assert _lint(tmp_path, src) == []


def test_numpy_inside_lax_scan_body_flagged(tmp_path):
    src = """
        import numpy as np
        from jax import lax

        def body(c, x):
            return c + np.sum(x), None

        def run(xs):
            return lax.scan(body, 0.0, xs)
    """
    assert _rules(_lint(tmp_path, src)) == {"np-in-jax-loop"}


def test_numpy_inside_fori_lambda_flagged(tmp_path):
    src = """
        import numpy as np
        from jax import lax

        def run(xs):
            return lax.fori_loop(0, 4, lambda i, c: c + np.max(xs), 0.0)
    """
    assert _rules(_lint(tmp_path, src)) == {"np-in-jax-loop"}


def test_jnp_inside_loop_body_clean(tmp_path):
    src = """
        import jax.numpy as jnp
        from jax import lax

        def body(c, x):
            return c + jnp.sum(x), None

        def run(xs):
            return lax.scan(body, 0.0, xs)
    """
    assert _lint(tmp_path, src) == []


def test_allowlist_suppresses_by_exact_key(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "tools").mkdir()
    (tmp_path / "src" / "mod.py").write_text(
        "def f(acc=[]):\n    return acc\n")
    findings = lint_repro.lint_file(tmp_path / "src" / "mod.py", tmp_path)
    assert len(findings) == 1
    key = findings[0].key
    assert key == "src/mod.py::mutable-default-arg::f"

    # without an allowlist entry the run fails ...
    assert lint_repro.main(["--root", str(tmp_path)]) == 1
    # ... and the exact key in the default allowlist location clears it
    allow = tmp_path / "tools" / "lint_allowlist.txt"
    allow.write_text("# suppressed on purpose\n" + key + "\n")
    assert lint_repro.main(["--root", str(tmp_path)]) == 0


def test_repo_tree_is_lint_clean():
    root = pathlib.Path(__file__).resolve().parents[1]
    assert lint_repro.main(["--root", str(root)]) == 0
