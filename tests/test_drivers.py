"""End-to-end driver tests: training loop (loss decreases, checkpoint
recovery works), serving loop (tokens come out), gradient compression
path."""
import os

import pytest


def test_train_driver_tiny(tmp_path):
    from repro.launch.train import main
    out = main([
        "--preset", "m100", "--steps", "25", "--batch", "4", "--seq", "64",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
        "--log-every", "10",
    ])
    assert out["final_loss"] < out["first_loss"]


def test_train_driver_crash_recovery(tmp_path):
    from repro.launch.train import main
    out = main([
        "--preset", "m100", "--steps", "16", "--batch", "2", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "50",
        "--simulate-failure", "8", "--log-every", "8",
    ])
    assert out["steps"] >= 16  # re-ran the post-crash steps


def test_train_driver_compressed_grads(tmp_path):
    from repro.launch.train import main
    out = main([
        "--preset", "m100", "--steps", "20", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--compress-grads",
        "--log-every", "10",
    ])
    assert out["final_loss"] < out["first_loss"]


def test_serve_driver_decodes():
    from repro.launch.serve import main
    out = main(["--arch", "qwen3-1.7b", "--preset", "tiny", "--batch", "2",
                "--prompt-len", "16", "--gen", "8"])
    assert out["generated"].shape == (2, 8)
    assert out["tok_per_s"] > 0


def test_serve_driver_mla_absorb():
    from repro.launch.serve import main
    out = main(["--arch", "minicpm3-4b", "--preset", "tiny", "--batch", "2",
                "--prompt-len", "16", "--gen", "4", "--mla-absorb"])
    assert out["generated"].shape == (2, 4)


def test_serve_driver_ssm():
    from repro.launch.serve import main
    out = main(["--arch", "mamba2-130m", "--preset", "tiny", "--batch", "2",
                "--prompt-len", "16", "--gen", "4"])
    assert out["generated"].shape == (2, 4)
