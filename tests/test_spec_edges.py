"""AMMSpec validation edges and the DSE ``_spec_for`` clamps, plus the
empty-family guards and sampling-range semantics of the pareto/ratio
metrics."""
import math

import pytest

from repro.core.amm.spec import AMMSpec
from repro.core.dse.pareto import design_space_expansion, pareto_front
from repro.core.dse.ratio import performance_ratio, spearman_rho
from repro.core.dse.sweep import DesignPoint, DSEPoint, _spec_for


# ----------------------------------------------------------------------
# _spec_for clamps
# ----------------------------------------------------------------------
def test_banked_bank_clamp_to_quarter_depth():
    spec = _spec_for(DesignPoint("banked", n_banks=32), depth=64,
                     width_bits=32)
    assert spec.n_banks == 16                  # min(32, 64 // 4)
    assert spec.n_read == 2 * 16 and spec.n_write == 2 * 16
    tiny = _spec_for(DesignPoint("banked", n_banks=8), depth=2,
                     width_bits=32)
    assert tiny.n_banks == 1                   # max(depth // 4, 1)


def test_amm_depth_floor_is_4x_ports():
    spec = _spec_for(DesignPoint("lvt", 8, 2), depth=16, width_bits=32)
    assert spec.depth == 32                    # max(16, 4 * 8)
    spec = _spec_for(DesignPoint("hb_ntx", 4, 2), depth=8, width_bits=32)
    assert spec.depth == 16
    spec = _spec_for(DesignPoint("lvt", 2, 2), depth=1024, width_bits=32)
    assert spec.depth == 1024                  # floor only lifts


def test_amm_sub_banking_clamped_to_leaf_depth():
    spec = _spec_for(DesignPoint("hb_ntx", 4, 2, n_banks=64), depth=64,
                     width_bits=32)
    # hb 4R: leaves are depth/(2*4) = 8 words -> sub-banking caps at 8
    assert spec.n_banks == 8
    spec = _spec_for(DesignPoint("h_ntx_rd", 4, 1, n_banks=2), depth=64,
                     width_bits=32)
    assert spec.n_banks == 2                   # under the cap: unclamped


# ----------------------------------------------------------------------
# AMMSpec validation
# ----------------------------------------------------------------------
def test_rejects_non_power_of_two_geometry():
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 3, 1, 64)          # read ports must be 2**k
    with pytest.raises(ValueError):
        AMMSpec("hb_ntx", 3, 2, 64)
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 4, 1, 18)          # depth % n_read != 0
    with pytest.raises(ValueError):
        AMMSpec("b_ntx_wr", 1, 2, 63)          # odd depth
    with pytest.raises(ValueError):
        AMMSpec("hb_ntx", 4, 2, 36)            # depth % (2*n_read) != 0
    with pytest.raises(ValueError):
        AMMSpec("lvt", 2, 2, 64, n_banks=3)    # sub-banking must be 2**k


def test_rejects_fixed_port_structure_violations():
    with pytest.raises(ValueError):
        AMMSpec("h_ntx_rd", 2, 2, 64)          # single write port only
    with pytest.raises(ValueError):
        AMMSpec("b_ntx_wr", 1, 3, 64)          # exactly 2 write ports
    with pytest.raises(ValueError):
        AMMSpec("hb_ntx", 4, 1, 64)
    with pytest.raises(ValueError):
        AMMSpec("banked", 2, 2, 64, n_banks=0)
    with pytest.raises(ValueError):
        AMMSpec("ideal", 0, 1, 64)


def test_rejects_bad_geometry_and_oversub_banking():
    with pytest.raises(ValueError):
        AMMSpec("ideal", 1, 1, 0)
    with pytest.raises(ValueError):
        AMMSpec("ideal", 1, 1, 64, 0)          # width
    with pytest.raises(ValueError):
        AMMSpec("hb_ntx", 4, 2, 64, n_banks=16)  # leaf depth is only 8


def test_sub_banked_spec_keeps_storage_and_tables():
    plain = AMMSpec("hb_ntx", 4, 2, 256)
    sub = AMMSpec("hb_ntx", 4, 2, 256, n_banks=4)
    assert sub.storage_bits() == plain.storage_bits()
    assert sub.leaf_banks() == plain.leaf_banks()
    assert "sub=4" in sub.describe()


# ----------------------------------------------------------------------
# empty-family guards (pareto / ratio)
# ----------------------------------------------------------------------
def _pt(design: str, is_amm: bool, t: float, area: float) -> DSEPoint:
    return DSEPoint(bench="b", design=design, is_amm=is_amm, unroll=1,
                    cycles=100, cycle_ns=1.0, time_us=t, area_mm2=area,
                    power_mw=1.0, bank_conflict_stalls=0,
                    parity_fanout_stalls=0, write_pair_stalls=0,
                    avg_mem_parallelism=1.0)


def test_design_space_expansion_empty_family_is_nan():
    amm = [_pt("lvt-2R2W", True, 1.0, 0.1)]
    banked = [_pt("banked4", False, 2.0, 0.1)]
    assert math.isnan(design_space_expansion([], amm))
    assert math.isnan(design_space_expansion(banked, []))
    assert math.isnan(design_space_expansion([], []))
    assert design_space_expansion(banked, amm) == pytest.approx(2.0)


def test_performance_ratio_empty_inputs_are_nan():
    assert math.isnan(performance_ratio([]))
    only_banked = [_pt("banked4", False, 2.0, 0.1)]
    only_amm = [_pt("lvt-2R2W", True, 1.0, 0.1)]
    assert math.isnan(performance_ratio(only_banked))
    assert math.isnan(performance_ratio(only_amm))
    both = only_banked + only_amm
    assert math.isfinite(performance_ratio(both))


def test_pareto_front_empty_is_empty():
    assert pareto_front([]) == []


# ----------------------------------------------------------------------
# performance_ratio sampling range (regression: flat-tail padding)
# ----------------------------------------------------------------------
def test_performance_ratio_clamps_to_common_overlap():
    """Two hand-built fronts whose area advantage is exactly 2x over the
    common reachable range [1, 4]us.  The banking family has one extra
    very slow point at 100us: sampling up to max(maxima) = 100us (the
    old bug) would pad the geomean with both fronts' flat tails and drag
    the result below the true constant 2.0."""
    banking = [_pt("banked1", False, 1.0, 8.0),
               _pt("banked2", False, 2.0, 4.0),
               _pt("banked4", False, 4.0, 2.0),
               _pt("banked8", False, 100.0, 1.0)]
    amm = [_pt("lvt-2R2W", True, 1.0, 4.0),
           _pt("lvt-4R2W", True, 2.0, 2.0),
           _pt("hb_ntx-2R2W", True, 4.0, 1.0)]
    assert performance_ratio(banking + amm) == pytest.approx(2.0)


def test_performance_ratio_disjoint_ranges_use_degenerate_fallback():
    """Families whose reachable time ranges barely overlap fall back to
    a point sample at the slower family's fastest time."""
    banking = [_pt("banked1", False, 4.0, 6.0)]
    amm = [_pt("lvt-2R2W", True, 1.0, 3.0)]
    # overlap degenerates to t_lo == 4.0: banking area 6 vs amm area 3
    assert performance_ratio(banking + amm) == pytest.approx(2.0)


# ----------------------------------------------------------------------
# spearman_rho (the Fig-5 rank-correlation summary)
# ----------------------------------------------------------------------
def test_spearman_monotone_sequences():
    x = [0.1, 0.2, 0.3, 0.5, 0.9]
    assert spearman_rho(x, [2.0, 3.0, 5.0, 7.0, 9.0]) == pytest.approx(1.0)
    assert spearman_rho(x, [9.0, 7.0, 5.0, 3.0, 2.0]) == pytest.approx(-1.0)


def test_spearman_is_rank_based_and_skips_nonfinite():
    # non-linear but monotone -> still exactly -1
    x = [1.0, 2.0, 3.0, 4.0]
    y = [1000.0, 1.0, 0.5, 0.01]
    assert spearman_rho(x, y) == pytest.approx(-1.0)
    # nan pairs are dropped, not propagated
    assert spearman_rho(x + [5.0], y + [float("nan")]) \
        == pytest.approx(-1.0)


def test_spearman_degenerate_inputs_are_nan():
    assert math.isnan(spearman_rho([1.0, 2.0], [3.0, 4.0]))
    assert math.isnan(spearman_rho([1.0, 1.0, 1.0], [1.0, 2.0, 3.0]))
    assert math.isnan(spearman_rho([], []))
