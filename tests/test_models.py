"""Model-zoo tests: per-arch reduced-config smoke tests (deliverable f),
attention equivalences, SSD oracle, decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch, tiny_variant
from repro.configs.base import RuntimeConfig
from repro.models import (decode_step, forward, init_model, loss_fn,
                          make_cache, prefill)
from repro.models.attention import (AttnConfig, flash_attention, gqa_apply,
                                    gqa_init, mla_decode, mla_init,
                                    mla_prefill)
from repro.models.ssm import ssd_chunked, ssd_reference

RT = RuntimeConfig(remat="none")
KEY = jax.random.PRNGKey(0)


def make_batch(arch, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32) * 3,
             "labels": jnp.ones((b, s), jnp.int32) * 5}
    if arch.family == "vlm":
        batch["patches"] = jnp.ones((b, arch.n_patches, arch.vit_dim),
                                    jnp.float32)
    if arch.is_encdec:
        batch["frames"] = jnp.ones((b, s, arch.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_train_step(name):
    """Reduced config of the same family: one forward/train step on CPU,
    output shapes + no NaNs (assignment requirement)."""
    arch = tiny_variant(get_arch(name))
    params = init_model(KEY, arch)
    batch = make_batch(arch)
    logits, aux = jax.jit(lambda p, b: forward(p, arch, b, RT))(params, batch)
    exp_s = batch["tokens"].shape[1] + (
        arch.n_patches if arch.family == "vlm" else 0)
    assert logits.shape == (2, exp_s, arch.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name} logits NaN"
    grads = jax.jit(jax.grad(lambda p, b: loss_fn(p, arch, b, RT)[0]))(
        params, batch)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{name} grad NaN"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_decode_step(name):
    arch = tiny_variant(get_arch(name))
    params = init_model(KEY, arch)
    cache = make_cache(arch, 16, 2)
    toks = jnp.ones((2, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, arch, c, t, RT))(params, cache, toks)
    assert logits.shape == (2, 1, arch.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["len"]) == 1


def test_param_count_estimates_match_published():
    targets = {
        "nemotron-4-340b": 340e9, "mistral-large-123b": 123e9,
        "minicpm3-4b": 4e9, "qwen3-1.7b": 1.7e9, "dbrx-132b": 132e9,
        "mamba2-130m": 130e6, "zamba2-2.7b": 2.7e9,
    }
    for name, want in targets.items():
        est = get_arch(name).param_count_estimate()
        assert 0.8 * want < est < 1.25 * want, (name, est, want)


def test_flash_attention_matches_naive():
    rng = np.random.default_rng(0)
    b, h, s, d = 2, 4, 96, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_kv=32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_gqa_kv_replication_equivalence():
    """kv_repeat must not change the math (Megatron kv replication)."""
    cfg1 = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    cfg2 = dataclasses.replace(cfg1, kv_repeat=2)
    params = gqa_init(KEY, cfg1)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 16, 32)),
                    jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gqa_apply(params, cfg1, x)),
        np.asarray(gqa_apply(params, cfg2, x)), atol=1e-5)


def test_mla_absorb_equivalence():
    """Absorbed (latent-space) decode == expanded decode (the §Perf
    optimization must be exact)."""
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                     attn_type="mla", q_lora_rank=16, kv_lora_rank=8,
                     rope_head_dim=4)
    params = mla_init(KEY, cfg)
    rng = np.random.default_rng(2)
    x_ctx = jnp.asarray(rng.standard_normal((2, 6, 32)), jnp.float32)
    _, (c_kv, k_rope) = mla_prefill(params, cfg, x_ctx)
    pad = 10 - 6
    cache = (jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
             jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))))
    x_new = jnp.asarray(rng.standard_normal((2, 1, 32)), jnp.float32)
    o1, _ = mla_decode(params, cfg, x_new, cache, jnp.int32(6), absorb=False)
    o2, _ = mla_decode(params, cfg, x_new, cache, jnp.int32(6), absorb=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def test_ssd_chunked_matches_reference():
    rng = np.random.default_rng(3)
    b, s, h, p, n = 2, 40, 2, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.4, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, h), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y1, h1 = ssd_reference(x, dt, A, B, C)
    y2, h2 = ssd_chunked(x, dt, A, B, C, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


@pytest.mark.parametrize("name", ["qwen3-1.7b", "minicpm3-4b", "mamba2-130m"])
def test_prefill_decode_matches_forward(name):
    """prefill(ctx) then decode(tok) must reproduce forward(ctx+tok)."""
    arch = tiny_variant(get_arch(name))
    params = init_model(KEY, arch)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(1, arch.vocab - 1, (2, 12)), jnp.int32)
    # full forward over all 12 tokens
    logits_full, _ = forward(params, arch, {"tokens": toks}, RT)
    # prefill on 11, decode token 12
    logits_p, cache = prefill(params, arch, {"tokens": toks[:, :11]}, 16, RT)
    logits_d, _ = decode_step(params, arch, cache, toks[:, 11:12], RT)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_full[:, 11]),
                               atol=2e-2, rtol=2e-2)


def test_loss_decreases_on_repeated_batch():
    arch = tiny_variant(get_arch("qwen3-1.7b"))
    from repro.optim import adamw
    params = init_model(KEY, arch)
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50)
    batch = make_batch(arch)

    @jax.jit
    def step(p, o, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: loss_fn(pp, arch, b, RT), has_aux=True)(p)
        p2, o2, _ = adamw.update(g, o, p, cfg)
        return p2, o2, l

    losses = []
    for _ in range(8):
        params, opt, l = step(params, opt, batch)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5, losses
