"""Fault-injection layer: replay hook semantics, per-cover
classification, and the pinned golden campaigns.

The campaign goldens (tests/golden_faults.json) pin one seeded
campaign per design kind; regenerate deliberately with
``python tools/gen_fault_golden.py`` after any intentional change to
the fault model, the replay hook or the classifier.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.amm import replay as rp
from repro.core.amm.replay import zero_fault
from repro.core.amm.spec import AMMSpec
from repro.core.fault import (COVER, FaultConfig, FaultSpec, build_masks,
                              run_campaign, sample_faults, state_geometry,
                              tile_states)

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_faults.json").read_text())

SPECS = [
    AMMSpec("ideal", 2, 2, 32, 32),
    AMMSpec("banked", 4, 4, 32, 32, n_banks=2),
    AMMSpec("multipump", 2, 2, 32, 32),
    AMMSpec("h_ntx_rd", 4, 1, 64, 32),
    AMMSpec("b_ntx_wr", 1, 2, 32, 32),
    AMMSpec("hb_ntx", 4, 2, 64, 32),
    AMMSpec("lvt", 2, 2, 32, 32),
    AMMSpec("lvt", 4, 2, 32, 32),
    AMMSpec("remap", 2, 2, 32, 32),
]


def _trace_and_init(spec, n_cycles, seed=11, write_prob=0.5):
    rng = np.random.default_rng(seed)
    ops = rp.make_trace(spec, n_cycles, rng=rng, write_prob=write_prob)
    vals = rng.integers(0, 1 << 32, spec.depth, dtype=np.uint32)
    return ops, vals


# ----------------------------------------------------------------------
# replay hook semantics
# ----------------------------------------------------------------------
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
def test_zero_fault_replay_is_bit_exact(spec):
    ops, vals = _trace_and_init(spec, 48)
    st_c, clean = rp.replay(spec, rp.init_flat(spec, vals), *ops)
    st_f, faulty = rp.replay_faulty(spec, rp.init_flat(spec, vals),
                                    zero_fault(spec), *ops)
    assert (np.asarray(clean.read_vals) == np.asarray(faulty.read_vals)).all()
    assert (np.asarray(clean.parity_vals)
            == np.asarray(faulty.parity_vals)).all()
    for k in st_c:
        assert (np.asarray(st_c[k]) == np.asarray(st_f[k])).all()


@pytest.mark.parametrize("spec", SPECS[:4], ids=lambda s: s.describe())
def test_batched_fault_replay_matches_solo(spec):
    ops, vals = _trace_and_init(spec, 40)
    faults = sample_faults(spec, 6, seed=3, n_cycles=40)
    masks = build_masks(spec, faults)
    states = tile_states(spec, vals, len(faults))
    _, batched = rp.replay_faulty_batched(spec, states, masks, *ops,
                                          share_trace=True)
    import jax
    for i in range(len(faults)):
        one = jax.tree.map(lambda a: a[i], masks)
        _, solo = rp.replay_faulty(spec, rp.init_flat(spec, vals), one, *ops)
        assert (np.asarray(batched.read_vals[i])
                == np.asarray(solo.read_vals)).all()


def test_transient_flip_heals_on_overwrite():
    """A bit_flip is live until the word is overwritten, then gone."""
    spec = AMMSpec("ideal", 1, 1, 8, 32)
    T = 6
    ra = np.zeros((T, 1), np.int32)             # read addr 0 every cycle
    wa = np.zeros((T, 1), np.int32)
    wv = np.full((T, 1), 0xABCD, np.uint32)
    wm = np.zeros((T, 1), bool)
    wm[3, 0] = True                             # overwrite at cycle 3
    vals = np.arange(8, dtype=np.uint32) + 100
    masks = build_masks(spec, [FaultSpec("bit_flip", "mem", 0, 0, 4, 0, 1)])
    states = tile_states(spec, vals, 1)
    _, res = rp.replay_faulty_batched(spec, states, masks,
                                      ra, wa, wv, wm, share_trace=True)
    got = np.asarray(res.read_vals)[0, :, 0]
    assert got[0] == 100                        # before injection
    assert got[1] == got[2] == 100 ^ (1 << 4)   # corrupted
    assert (got[4:] == 0xABCD).all()            # healed by the write


def test_stuck_at_defeats_writes():
    """A stuck bit stays stuck through overwrites."""
    spec = AMMSpec("ideal", 1, 1, 8, 32)
    T = 4
    ra = np.zeros((T, 1), np.int32)
    wa = np.zeros((T, 1), np.int32)
    wv = np.full((T, 1), 0xFFFF, np.uint32)
    wm = np.zeros((T, 1), bool)
    wm[1, 0] = True
    masks = build_masks(
        spec, [FaultSpec("stuck_at", "mem", 0, 0, 0, 0, 0)])  # bit0 stuck@0
    states = tile_states(spec, np.full(8, 0xFFFF, np.uint32), 1)
    _, res = rp.replay_faulty_batched(spec, states, masks,
                                      ra, wa, wv, wm, share_trace=True)
    got = np.asarray(res.read_vals)[0, :, 0]
    assert (got == 0xFFFE).all()                # bit0 forced low forever


def test_h_ntx_leaf_loss_is_fully_correctable():
    """Erasing any single leaf never takes out both read paths: for
    every read at least one path still returns the golden word (the
    parity path never contains the direct leaf)."""
    spec = AMMSpec("h_ntx_rd", 4, 1, 64, 32)
    n_leaves = state_geometry(spec)["banks"][0]
    faults = [FaultSpec("bank_loss", "banks", b, 0, 0, 0, 0)
              for b in range(n_leaves)]
    ops, vals = _trace_and_init(spec, 32, write_prob=0.0)
    _, g = rp.replay(spec, rp.init_flat(spec, vals), *ops)
    _, res = rp.replay_faulty_batched(
        spec, tile_states(spec, vals, n_leaves), build_masks(spec, faults),
        *ops, share_trace=True)
    gv = np.asarray(g.read_vals)[None]
    fv, fp = np.asarray(res.read_vals), np.asarray(res.parity_vals)
    assert (fv != gv).any(), "campaign must actually corrupt some reads"
    assert ((fv == gv) | (fp == gv)).all()


# ----------------------------------------------------------------------
# classification per cover
# ----------------------------------------------------------------------
def test_cover_map_is_total():
    from repro.core.amm.spec import AMM_KINDS
    baselines = {"ideal", "banked", "multipump"}
    assert set(COVER) == set(AMM_KINDS) | baselines


def test_lvt_majority_vote_vs_detect_only():
    cfg = FaultConfig(n_faults=16, n_cycles=64, seed=7)
    r4 = run_campaign(AMMSpec("lvt", 4, 2, 64, 32), cfg).resilience
    r2 = run_campaign(AMMSpec("lvt", 2, 2, 64, 32), cfg).resilience
    assert r4.cover == r2.cover == "replica"
    # >=3 replicas: every affected read out-voted; 2: flagged only
    assert r4.affected > 0 and r4.corrected_frac == 1.0 and r4.sdc == 0
    assert r2.affected > 0 and r2.detected_frac == 1.0 and r2.sdc == 0


def test_parity_kinds_have_zero_sdc():
    cfg = FaultConfig(n_faults=16, n_cycles=64, seed=7)
    for spec in (AMMSpec("h_ntx_rd", 4, 1, 64, 32),
                 AMMSpec("hb_ntx", 4, 2, 64, 32)):
        r = run_campaign(spec, cfg).resilience
        assert r.cover == "parity" and r.affected > 0
        assert r.sdc == 0
        assert r.corrected_frac > 0.9


def test_uncovered_kinds_are_pure_sdc():
    cfg = FaultConfig(n_faults=16, n_cycles=64, seed=7)
    for spec in (AMMSpec("banked", 4, 4, 64, 32, n_banks=2),
                 AMMSpec("b_ntx_wr", 1, 2, 64, 32),
                 AMMSpec("remap", 2, 2, 64, 32)):
        r = run_campaign(spec, cfg).resilience
        assert r.cover == "none" and r.affected > 0
        assert r.corrected == r.detected == 0
        assert r.sdc == r.affected and r.det_latency == -1.0


def test_campaign_is_deterministic():
    spec = AMMSpec("h_ntx_rd", 4, 1, 64, 32)
    cfg = FaultConfig(n_faults=8, n_cycles=48, seed=5)
    assert run_campaign(spec, cfg) == run_campaign(spec, cfg)
    other = run_campaign(spec, FaultConfig(n_faults=8, n_cycles=48, seed=6))
    assert other != run_campaign(spec, cfg)


# ----------------------------------------------------------------------
# pinned golden campaigns
# ----------------------------------------------------------------------
def _golden_campaign(row):
    from repro.core.dse.sweep import DEFAULT_DESIGNS, _spec_for
    from repro.core.fault import run_campaign as rc

    by_label = {d.label: d for d in DEFAULT_DESIGNS}
    spec = _spec_for(by_label[row["design"]], 256, 32)
    cfg = FaultConfig(n_faults=32, n_cycles=96, seed=7)
    return rc(spec, cfg)


@pytest.mark.parametrize("row", GOLDEN, ids=lambda r: r["design"])
def test_golden_campaigns_pinned(row):
    res = _golden_campaign(row)
    r = res.resilience
    assert res.spec_label == row["spec"]
    assert r.cover == row["cover"]
    assert (r.n_faults, r.n_reads) == (row["n_faults"], row["n_reads"])
    assert (r.benign, r.corrected, r.detected, r.sdc) == (
        row["benign"], row["corrected"], row["detected"], row["sdc"])
    assert r.sdc_rate == pytest.approx(row["sdc_rate"], abs=1e-9)
    assert r.corrected_frac == pytest.approx(row["corrected_frac"], abs=1e-9)
    assert r.detected_frac == pytest.approx(row["detected_frac"], abs=1e-9)
    assert r.det_latency == pytest.approx(row["det_latency"], abs=1e-9)
    assert list(res.outcomes) == row["outcomes"]


# ----------------------------------------------------------------------
# DSE integration
# ----------------------------------------------------------------------
def test_sweep_attaches_resilience():
    from repro.core.bench import get_trace
    from repro.core.dse import run_sweep
    from repro.core.dse.sweep import DEFAULT_DESIGNS

    designs = [d for d in DEFAULT_DESIGNS
               if d.label in ("banked4", "h_ntx_rd-4R1W", "lvt-4R2W")]
    pts = run_sweep(get_trace("gemm_ncubed"), designs, (1,),
                    faults=FaultConfig(n_faults=8, n_cycles=48, seed=3))
    by = {p.design: p for p in pts}
    assert by["banked4"].res_cover == "none"
    assert by["banked4"].res_corrected == 0.0
    assert by["h_ntx_rd-4R1W"].res_cover == "parity"
    assert by["h_ntx_rd-4R1W"].res_sdc_rate == 0.0
    assert by["lvt-4R2W"].res_cover == "replica"
    # plain sweeps keep the sentinels
    clean = run_sweep(get_trace("gemm_ncubed"), designs, (1,))
    assert all(p.res_cover == "-" and p.res_latency == -1.0 for p in clean)
    # timing fields are identical with and without the campaign
    assert [(p.design, p.cycles, p.time_us) for p in pts] \
        == [(p.design, p.cycles, p.time_us) for p in clean]
