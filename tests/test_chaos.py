"""Chaos harness: the DSE runner must survive infrastructure failures
with bitwise-identical results.

Failure legs (ISSUE 7 tentpole):

* a worker process crashing mid-sweep (``os._exit`` inside a chunk) —
  the broken pool is torn down, rebuilt, and the lost chunks
  re-dispatched;
* a worker hanging past ``chunk_timeout`` — the pool is killed and the
  chunk re-run on a fresh pool;
* torn / corrupted cache entries (truncated JSON, checksum mismatch) —
  read as misses and rewritten, never deserialized;
* a broken shared pool being transparently replaced on next use;
* a broken ``CC`` — the C extension degrades to the pure-Python loop
  with exactly one warning and golden-identical schedules.

The crash/hang injectors are module-level functions: worker processes
are forked (Linux default), so they inherit the monkeypatched runner
module, and the submitted function is pickled by qualified name —
which must resolve in the child.  A sentinel file consumed with an
atomic ``os.unlink`` makes each injected failure fire exactly once
even with several workers racing.
"""
import json
import os
import time
import warnings

import pytest

from repro.core.bench import get_trace
from repro.core.dse import DesignPoint, run_sweep
from repro.core.dse import runner as runner_mod
from repro.core.dse.pareto import pareto_front
from repro.core.dse.runner import SweepCache, point_key, shutdown_pool
from repro.core.sim import prepare_trace

DESIGNS = [DesignPoint("banked", n_banks=4), DesignPoint("lvt", 2, 2),
           DesignPoint("h_ntx_rd", 2, 1), DesignPoint("multipump", 2, 2)]
UNROLLS = (1, 4)

_FLAG_ENV = "REPRO_CHAOS_FLAG"
_ORIG_EVAL = runner_mod._worker_eval_chunk


def _consume_flag() -> bool:
    """Atomically claim the one-shot chaos trigger (fork-safe)."""
    flag = os.environ.get(_FLAG_ENV)
    if not flag:
        return False
    try:
        os.unlink(flag)
    except FileNotFoundError:
        return False
    return True


def _crashy_eval_chunk(fingerprint, tr, chunk, mem_latency, backend="auto"):
    if _consume_flag():
        os._exit(1)                 # simulated OOM-kill / segfault
    return _ORIG_EVAL(fingerprint, tr, chunk, mem_latency, backend)


def _hanging_eval_chunk(fingerprint, tr, chunk, mem_latency, backend="auto"):
    if _consume_flag():
        time.sleep(600)             # simulated wedged worker
    return _ORIG_EVAL(fingerprint, tr, chunk, mem_latency, backend)


@pytest.fixture()
def pt():
    return prepare_trace(get_trace("gemm_ncubed"))


@pytest.fixture()
def chaos_flag(tmp_path, monkeypatch):
    """Arm the one-shot failure trigger before any pool exists, so the
    forked workers inherit the env var."""
    shutdown_pool()
    flag = tmp_path / "chaos.flag"
    flag.write_text("armed")
    monkeypatch.setenv(_FLAG_ENV, str(flag))
    yield flag
    shutdown_pool()


def _front(points):
    return [(p.design, p.unroll, p.cycles, p.time_us, p.area_mm2)
            for p in pareto_front(points)]


# ----------------------------------------------------------------------
# worker crash / hang
# ----------------------------------------------------------------------
def test_worker_crash_mid_sweep_recovers(pt, monkeypatch, chaos_flag):
    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    monkeypatch.setattr(runner_mod, "_worker_eval_chunk", _crashy_eval_chunk)
    serial = run_sweep(pt, DESIGNS, UNROLLS, jobs=1)
    chaotic = run_sweep(pt, DESIGNS, UNROLLS, jobs=2)
    assert not chaos_flag.exists(), "the injected crash never fired"
    assert chaotic == serial
    assert _front(chaotic) == _front(serial)


def test_worker_crash_in_dedicated_pool_recovers(pt, monkeypatch, chaos_flag):
    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    monkeypatch.setattr(runner_mod, "_LARGE_TRACE_NODES", 0)
    monkeypatch.setattr(runner_mod, "_worker_eval_chunk", _crashy_eval_chunk)
    serial = run_sweep(pt, DESIGNS, UNROLLS, jobs=1)
    chaotic = run_sweep(pt, DESIGNS, UNROLLS, jobs=2)
    assert not chaos_flag.exists()
    assert chaotic == serial


def test_worker_hang_hits_timeout_and_recovers(pt, monkeypatch, chaos_flag):
    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    monkeypatch.setattr(runner_mod, "_worker_eval_chunk", _hanging_eval_chunk)
    serial = run_sweep(pt, DESIGNS, UNROLLS, jobs=1)
    t0 = time.monotonic()
    chaotic = run_sweep(pt, DESIGNS, UNROLLS, jobs=2, chunk_timeout=3.0)
    assert time.monotonic() - t0 < 120, "timeout did not interrupt the hang"
    assert not chaos_flag.exists()
    assert chaotic == serial
    assert _front(chaotic) == _front(serial)


def _always_crash_eval_chunk(fingerprint, tr, chunk, mem_latency,
                             backend="auto"):
    os._exit(1)


def test_retries_exhausted_falls_back_to_serial(pt, monkeypatch):
    """With a permanently-crashing worker path, chunk_retries=0 must
    finish the sweep in-process rather than loop or return partials."""
    monkeypatch.setattr(runner_mod, "_worker_eval_chunk",
                        _always_crash_eval_chunk)
    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    shutdown_pool()
    serial = run_sweep(pt, DESIGNS[:2], (1,), jobs=1)
    chaotic = run_sweep(pt, DESIGNS[:2], (1,), jobs=2, chunk_retries=0)
    assert chaotic == serial
    shutdown_pool()


# ----------------------------------------------------------------------
# broken shared pool
# ----------------------------------------------------------------------
def test_broken_pool_is_replaced_on_next_use():
    from concurrent.futures.process import BrokenProcessPool

    shutdown_pool()
    pool = runner_mod._get_pool(2)
    with pytest.raises(BrokenProcessPool):
        pool.submit(os._exit, 1).result()
    assert getattr(pool, "_broken", False)
    pool2 = runner_mod._get_pool(2)
    assert pool2 is not pool
    assert pool2.submit(len, (1, 2, 3)).result() == 3
    shutdown_pool()


def test_sweep_succeeds_after_pool_breakage(pt, monkeypatch):
    from concurrent.futures.process import BrokenProcessPool

    monkeypatch.setattr(runner_mod, "_MIN_PARALLEL_WORK", 0)
    shutdown_pool()
    pool = runner_mod._get_pool(2)
    with pytest.raises(BrokenProcessPool):
        pool.submit(os._exit, 1).result()
    serial = run_sweep(pt, DESIGNS[:2], (1,), jobs=1)
    assert run_sweep(pt, DESIGNS[:2], (1,), jobs=2) == serial
    shutdown_pool()


# ----------------------------------------------------------------------
# torn / corrupted cache entries
# ----------------------------------------------------------------------
def test_torn_cache_write_reads_as_miss(tmp_path, pt):
    cache = SweepCache(tmp_path)
    pts1 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache)
    key = point_key(pt.fingerprint, DESIGNS[0], 1, 2)
    path = cache._path(key)
    full = path.read_text()
    path.write_text(full[:len(full) // 2])          # torn mid-write copy
    cache2 = SweepCache(tmp_path)
    pts2 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache2)
    assert cache2.misses == 1 and pts2 == pts1
    assert json.loads(path.read_text())["point"]["cycles"] == pts1[0].cycles


def test_checksum_mismatch_reads_as_miss(tmp_path, pt):
    """A well-formed entry whose payload was tampered with post-write
    (bit rot, hand edit) must fail the sha256, not deserialize."""
    cache = SweepCache(tmp_path)
    pts1 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache)
    key = point_key(pt.fingerprint, DESIGNS[0], 1, 2)
    path = cache._path(key)
    d = json.loads(path.read_text())
    d["point"]["cycles"] += 1                        # silent corruption
    path.write_text(json.dumps(d))
    cache2 = SweepCache(tmp_path)
    assert cache2.get(key) is None and cache2.misses == 1
    pts2 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache2)
    assert pts2 == pts1
    # entry healed: valid checksum and the true cycle count
    healed = json.loads(path.read_text())
    assert healed["sha256"] == SweepCache._digest(healed["point"])
    assert healed["point"]["cycles"] == pts1[0].cycles


def test_legacy_unchecksummed_entry_reads_as_miss(tmp_path, pt):
    """Pre-v4 bare-dict entries (no envelope) must miss cleanly."""
    cache = SweepCache(tmp_path)
    pts1 = run_sweep(pt, DESIGNS[:1], (1,), cache=cache)
    key = point_key(pt.fingerprint, DESIGNS[0], 1, 2)
    path = cache._path(key)
    path.write_text(json.dumps(json.loads(path.read_text())["point"]))
    cache2 = SweepCache(tmp_path)
    assert cache2.get(key) is None
    assert run_sweep(pt, DESIGNS[:1], (1,), cache=cache2) == pts1


# ----------------------------------------------------------------------
# broken C toolchain
# ----------------------------------------------------------------------
def test_broken_cc_degrades_once_with_golden_results(tmp_path, monkeypatch):
    """CC=/bin/false: the extension must fail to build, warn exactly
    once, and the auto backend must still reproduce pinned golden
    schedules through the pure-Python loop."""
    import pathlib

    import repro.core.sim._cycle_ext as ext
    from test_golden_schedule import GOLDEN, _check, _config

    monkeypatch.setenv("CC", "/bin/false")
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "ccache"))
    monkeypatch.delenv("REPRO_PURE_PY", raising=False)
    monkeypatch.setattr(ext, "_TRIED", False)
    monkeypatch.setattr(ext, "_FN", None)
    monkeypatch.setattr(ext, "_ANALYZE", None)
    monkeypatch.setattr(ext, "_BATCH", None)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert ext.load() is None
        assert ext.load() is None           # latched: no second attempt
        assert ext.load_batch() is None
    relevant = [w for w in caught if "cycle-loop extension" in str(w.message)]
    assert len(relevant) == 1
    assert issubclass(relevant[0].category, RuntimeWarning)
    assert not list(pathlib.Path(tmp_path / "ccache").glob("*.so"))

    from repro.core.sim.scheduler import schedule
    for g in GOLDEN[:6]:
        pt = prepare_trace(get_trace(g["bench"]))
        _check(schedule(pt, _config(pt, g["design"], g["unroll"])), g)
