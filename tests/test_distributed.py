"""Distributed integration tests — run in a subprocess with 8 fake host
devices (tests in THIS process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run a python snippet under a forced device count."""
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_pjit_train_step_matches_single_device():
    """The sharded train step must be numerically identical (up to fp
    noise) to the unsharded one — SPMD correctness."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, tiny_variant
        from repro.configs.base import RuntimeConfig
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import make_train_step
        from repro.models import DTypePolicy, init_model
        from repro.optim import adamw

        arch = tiny_variant(get_arch("qwen3-1.7b"), n_layers=2, vocab=128)
        rt = RuntimeConfig(remat="none")
        policy = DTypePolicy.standard()
        params = init_model(jax.random.PRNGKey(0), arch, policy)
        opt = adamw.init(params, policy)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 127, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 127, (8, 32)), jnp.int32)}
        step = make_train_step(arch, rt, policy)

        # single-device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        # sharded over (4 data, 2 model)
        mesh = make_test_mesh((4, 2), ("data", "model"))
        pps = shd.param_pspecs(jax.eval_shape(lambda: params), mesh)
        psh = shd.to_named(pps, mesh)
        osh = shd.to_named({"m": pps, "v": pps,
                            "step": jax.sharding.PartitionSpec()}, mesh)
        bsh = shd.to_named(shd.input_pspecs(
            jax.eval_shape(lambda: batch), mesh, 8), mesh)
        baxes = shd.batch_axes_for(mesh, 8)
        with shd.activation_sharding(mesh, baxes, False):
            p2, o2, m2 = jax.jit(step, in_shardings=(psh, osh, bsh))(
                params, opt, batch)
        d = max(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print("MAXDIFF", d)
        print("LOSS", float(m1["loss"]), float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
        assert d < 5e-2
    """)
    assert "MAXDIFF" in out


def test_dryrun_mini_mesh_all_families():
    """Lower+compile one small cell per family on an 8-device mesh."""
    out = run_sub("""
        import dataclasses as dc
        import jax, jax.numpy as jnp
        from repro.configs import get_arch, tiny_variant
        from repro.configs.base import SHAPES, RuntimeConfig
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.launch.specs import (abstract_opt_state, abstract_params,
                                        input_specs, policy_for)
        from repro.launch.steps import make_train_step

        mesh = make_test_mesh((4, 2), ("data", "model"))
        for name in ("qwen3-1.7b", "dbrx-132b", "mamba2-130m",
                     "zamba2-2.7b", "internvl2-1b", "seamless-m4t-medium",
                     "minicpm3-4b"):
            arch = tiny_variant(get_arch(name))
            shape = dc.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)
            rt = RuntimeConfig(remat="full", accum_steps=2)
            policy = policy_for(rt)
            pspec = abstract_params(arch, rt)
            pps = shd.param_pspecs(pspec, mesh)
            psh = shd.to_named(pps, mesh)
            ospec = abstract_opt_state(pspec, rt)
            osh = shd.to_named({"m": pps, "v": pps,
                                "step": jax.sharding.PartitionSpec()}, mesh)
            bspec = input_specs(arch, shape, rt)
            bsh = shd.to_named(shd.input_pspecs(bspec, mesh, 8), mesh)
            baxes = shd.batch_axes_for(mesh, 8)
            with shd.activation_sharding(mesh, baxes, True):
                step = make_train_step(arch, rt, policy)
                compiled = jax.jit(step, in_shardings=(psh, osh, bsh)).lower(
                    pspec, ospec, bspec).compile()
            print("COMPILED", name)
    """, timeout=560)
    assert out.count("COMPILED") == 7


def test_elastic_reshard_restore():
    """Checkpoint saved on an 8-device mesh restores onto a 4-device
    mesh (elastic scale-down after failure)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint import CheckpointManager
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.runtime import elastic_mesh_shape

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((8,), jnp.float32)}
        mesh8 = make_test_mesh((4, 2), ("data", "model"))
        sh8 = shd.to_named(jax.tree.map(
            lambda x: jax.sharding.PartitionSpec("data", None)
            if x.ndim == 2 else jax.sharding.PartitionSpec(None), tree), mesh8)
        tree8 = jax.tree.map(jax.device_put, tree, sh8)

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(5, tree8)
            # "lose" half the devices -> new 4-device mesh
            new = elastic_mesh_shape(4, model_parallel=2)
            assert new["shape"] == (2, 2)
            mesh4 = make_test_mesh((2, 2), ("data", "model"))
            sh4 = shd.to_named(jax.tree.map(
                lambda x: jax.sharding.PartitionSpec("data", "model")
                if x.ndim == 2 else jax.sharding.PartitionSpec(None),
                tree), mesh4)
            out = mgr.restore(tree, shardings=sh4)
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(tree["w"]))
            print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


def test_production_mesh_shapes():
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESH_OK", m1.devices.size, m2.devices.size)
    """, n_devices=512)
    assert "MESH_OK 256 512" in out
