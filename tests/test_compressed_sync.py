"""Compressed cross-pod grad sync: numerics + measured wire-byte cut
(subprocess, 8 fake devices in a (2-pod, 4) mesh)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 8, timeout: int = 300) -> str:
    prelude = ("import os\n"
               f"os.environ['XLA_FLAGS'] = "
               f"'--xla_force_host_platform_device_count={n_devices}'\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compressed_sync_accuracy_and_bytes():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.launch.roofline import analyze_hlo
        from repro.runtime.compressed_sync import (compressed_pod_mean,
                                                   uncompressed_pod_mean)

        mesh = make_test_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((256, 64)) * 1e-3,
                              jnp.float32)}

        # numerics: compressed mean ~= exact mean (same g on both pods
        # -> mean == g), error bounded by the int8 step
        got = jax.jit(lambda x: compressed_pod_mean(x, mesh))(g)
        err = float(jnp.abs(got["w"] - g["w"]).max())
        step = float(jnp.max(jnp.abs(g["w"]))) / 127
        print("ERR", err, "STEP", step)
        assert err <= step

        # wire bytes: compressed variant must move <~ half the bytes
        c_ref = jax.jit(lambda x: uncompressed_pod_mean(x, mesh)).lower(g).compile()
        c_cmp = jax.jit(lambda x: compressed_pod_mean(x, mesh)).lower(g).compile()
        b_ref = analyze_hlo(c_ref.as_text())["collective_bytes"]
        b_cmp = analyze_hlo(c_cmp.as_text())["collective_bytes"]
        print("BYTES", b_ref, b_cmp)
        assert b_cmp < 0.6 * b_ref, (b_ref, b_cmp)
    """)
    assert "BYTES" in out
