"""Substrate tests: optimizer, data pipeline, checkpointing, runtime FT,
gradient compression, memory planner."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_arch
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.memory import (AMM_LOCALITY_THRESHOLD, BankedKVCache,
                          banked_embedding_lookup, plan_memory)
from repro.optim import adamw
from repro.runtime import (HeartbeatMonitor, StragglerPolicy,
                           compressed_grad_tree, compress_int8,
                           decompress_int8, elastic_mesh_shape, plan_rescale)


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                            weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, stats = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_clips_gradients():
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    _, _, stats = adamw.update({"w": jnp.full((4,), 1e6)}, state, params, cfg)
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_cosine_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.cosine_lr(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.cosine_lr(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(adamw.cosine_lr(cfg, jnp.asarray(100))) <= 0.11


# ----------------------------------------------------------------------
# data
# ----------------------------------------------------------------------
def test_data_deterministic_and_shaped():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
    it1 = SyntheticCorpus(cfg).batch_iter()
    it2 = SyntheticCorpus(cfg).batch_iter()
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_shards_disjoint():
    a = SyntheticCorpus(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                   n_shards=2, shard_id=0))
    b = SyntheticCorpus(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                   n_shards=2, shard_id=1))
    ba, bb = next(a.batch_iter()), next(b.batch_iter())
    assert ba["tokens"].shape == (4, 16)
    assert not np.array_equal(ba["tokens"], bb["tokens"])


def test_prefetch_loader():
    corpus = SyntheticCorpus(DataConfig(vocab=50, seq_len=8, global_batch=2))
    loader = PrefetchLoader(corpus)
    batches = [next(loader) for _ in range(3)]
    loader.close()
    assert all(b["tokens"].shape == (2, 8) for b in batches)


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(10, tree)
    mgr.save(20, tree)
    mgr.save(30, tree)                      # GC should drop step 10
    assert mgr.steps() == [20, 30]
    out = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    tree = {"w": jnp.zeros((128, 128))}
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    out = mgr.restore(tree)
    assert out["w"].shape == (128, 128)


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((5,))})


# ----------------------------------------------------------------------
# runtime FT
# ----------------------------------------------------------------------
def test_straggler_detection():
    mon = HeartbeatMonitor(8, StragglerPolicy(min_history=4))
    for t in range(8):
        for w in range(8):
            mon.report(w, 1.0 if w != 3 else 5.0)
    assert mon.stragglers() == [3]


def test_dead_worker_detection():
    mon = HeartbeatMonitor(4, dead_after_s=10.0)
    now = 1000.0
    for w in range(4):
        mon.report(w, 1.0, now=now - (20.0 if w == 2 else 1.0))
    assert mon.dead(now=now) == [2]


def test_elastic_mesh_shapes():
    assert elastic_mesh_shape(512, 16)["shape"] == (2, 16, 16)
    assert elastic_mesh_shape(256, 16)["shape"] == (16, 16)
    # lose a host of 8 chips from a 256-pod: 248 = 8 x 31
    m = elastic_mesh_shape(248, 16)
    assert np.prod(m["shape"]) == 248
    plan = plan_rescale(256, 248)
    assert plan.extra_accum_factor >= 1


def test_int8_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((256,)) * 1e-3, jnp.float32)
    err = None
    acc = jnp.zeros_like(g_true)
    for _ in range(64):
        deq, err = compressed_grad_tree(g_true, err)
        acc = acc + deq
    # error feedback: accumulated quantized grads converge to the truth
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g_true),
                               atol=2e-5)


def test_int8_roundtrip_bound():
    g = jnp.asarray(np.random.default_rng(1).standard_normal((1000,)),
                    jnp.float32)
    q, s = compress_int8(g)
    err = jnp.abs(decompress_int8(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


# ----------------------------------------------------------------------
# memory planner (the paper's technique in the LM stack)
# ----------------------------------------------------------------------
def test_planner_embedding_is_low_locality_amm():
    plan = plan_memory(get_arch("qwen3-1.7b"), SHAPES["decode_32k"])
    emb = plan.for_stream("embedding")
    assert emb.locality < AMM_LOCALITY_THRESHOLD and emb.use_amm
    kv = plan.for_stream("kv_pages")
    assert kv is not None and kv.use_amm


def test_planner_ssm_state_is_banked():
    plan = plan_memory(get_arch("mamba2-130m"), SHAPES["train_4k"])
    s = plan.for_stream("ssm_state")
    assert s is not None and not s.use_amm and s.locality > 0.9
    assert "inapplicable" in s.note


def test_banked_embedding_matches_take():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 128, (4, 8)), jnp.int32)
    plan = plan_memory(get_arch("qwen3-1.7b"), SHAPES["decode_32k"])
    got = banked_embedding_lookup(table, ids, plan.for_stream("embedding"))
    want = jnp.take(table, ids.reshape(-1), axis=0).reshape(4, 8, 16)
    assert jnp.array_equal(got, want)


def test_banked_kv_cache_decode():
    cache = BankedKVCache.create(2, 2, 32, 8, dtype=jnp.float32)
    rng = np.random.default_rng(3)
    for _ in range(4):
        cache = cache.append(
            jnp.asarray(rng.standard_normal((2, 2, 1, 8)), jnp.float32),
            jnp.asarray(rng.standard_normal((2, 2, 1, 8)), jnp.float32))
    q = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
    out = cache.decode_read(q)
    from repro.kernels import ref
    want = ref.kv_decode_ref(q, cache.k, cache.v, cache.length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
