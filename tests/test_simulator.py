"""Scheduler, cost-model, locality and DSE invariants."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.amm.spec import AMMSpec
from repro.core.bench import BENCHMARKS
from repro.core.cost import memory_cost, sram_macro
from repro.core.dse import (DesignPoint, evaluate_point, pareto_front,
                            performance_ratio, sweep)
from repro.core.locality import (spatial_locality_jax, spatial_locality_np,
                                 trace_locality)
from repro.core.sim import (LOAD, STORE, ScheduleConfig, Trace, TraceBuilder,
                            schedule)


# ----------------------------------------------------------------------
# locality
# ----------------------------------------------------------------------
def test_locality_stride_one_is_high():
    addrs = np.arange(1000)          # byte stride 1
    assert spatial_locality_np(addrs) > 0.99


def test_locality_stride8_is_eighth():
    addrs = np.arange(0, 8000, 8)
    assert abs(spatial_locality_np(addrs) - 1 / 8) < 1e-6


def test_locality_random_is_low():
    rng = np.random.default_rng(0)
    addrs = rng.integers(0, 1 << 20, 4000)
    assert spatial_locality_np(addrs) < 0.05


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=2, max_size=200))
def test_locality_np_equals_jax(addrs):
    import jax.numpy as jnp
    a = np.asarray(addrs, np.int64)
    np_val = spatial_locality_np(a)
    jx_val = float(spatial_locality_jax(jnp.asarray(a)))
    assert abs(np_val - jx_val) < 1e-5


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=2, max_size=200))
def test_locality_bounded(addrs):
    v = spatial_locality_np(np.asarray(addrs))
    assert 0.0 <= v <= 1.0 + 1e-9


def test_locality_jax_survives_addresses_beyond_int32():
    """Byte addresses above 2**31 must not wrap when jax x64 is disabled
    (regression: the old implementation shipped raw int64 addresses to
    the device, truncating them to int32 garbage strides)."""
    base = np.int64(2) ** 40
    addrs = base + np.arange(0, 8000, 8, dtype=np.int64)
    np_val = spatial_locality_np(addrs)
    assert abs(np_val - 1 / 8) < 1e-6
    assert abs(float(spatial_locality_jax(addrs)) - np_val) < 1e-5


# ----------------------------------------------------------------------
# cost model
# ----------------------------------------------------------------------
def test_sram_monotone_in_size():
    small = sram_macro(1024, 32)
    big = sram_macro(16384, 32)
    assert big.area_mm2 > small.area_mm2
    assert big.access_ns > small.access_ns
    assert big.energy_rd_pj > small.energy_rd_pj


def test_no_eda_support_beyond_two_ports():
    """Paper section I: no memory-compiler support for >2 ports."""
    with pytest.raises(ValueError):
        sram_macro(1024, 32, ports=4)


def test_amm_costs_scale_with_ports():
    base = memory_cost(AMMSpec("h_ntx_rd", 2, 1, 1024))
    more = memory_cost(AMMSpec("h_ntx_rd", 4, 1, 1024))
    assert more.area_mm2 > base.area_mm2


def test_multipump_frequency_penalty():
    mp = memory_cost(AMMSpec("multipump", 2, 2, 1024))
    bk = memory_cost(AMMSpec("banked", 4, 4, 1024, n_banks=2))
    assert mp.max_freq_ghz < bk.max_freq_ghz


def test_table_designs_pay_table_area():
    lvt = memory_cost(AMMSpec("lvt", 2, 2, 1024))
    ideal = memory_cost(AMMSpec("ideal", 2, 2, 1024))
    assert lvt.area_mm2 > 0 and ideal.area_mm2 > 0


# ----------------------------------------------------------------------
# scheduler
# ----------------------------------------------------------------------
def _mem_trace(n_ops: int, n_arrays: int = 1, stride: int = 1) -> Trace:
    tb = TraceBuilder("t")
    arrs = [tb.declare_array(f"a{i}", 4) for i in range(n_arrays)]
    for i in range(n_ops):
        tb.load(arrs[i % n_arrays], (i * stride) % 256)
    return tb.build()


def test_ports_bound_throughput():
    """n independent loads through an rR port config need >= n/r cycles."""
    tr = _mem_trace(64)
    for r in (1, 2, 4):
        cfg = ScheduleConfig(
            mem={0: AMMSpec("lvt", r, 1, 256)},
            fu_counts={"iadd": 8}, mem_latency=1)
        res = schedule(tr, cfg)
        assert res.cycles >= math.ceil(64 / r)
        assert res.cycles <= math.ceil(64 / r) + 4


def test_amm_never_slower_than_banked_same_ports():
    """Conflict-freedom: AMM rR cycles <= banked with r total ports on a
    pathological stride (all accesses to one bank)."""
    tr = _mem_trace(64, stride=8)      # stride 8 words, 8 banks -> 1 bank hit
    amm = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("lvt", 4, 1, 256)}, fu_counts={}))
    banked = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("banked", 4, 4, 256, n_banks=8)},
        fu_counts={}, ports_per_bank=1))
    assert amm.cycles <= banked.cycles
    assert banked.bank_conflict_stalls > 0


def test_dependencies_respected():
    tb = TraceBuilder("chain")
    a = tb.declare_array("a", 4)
    prev = tb.load(a, 0)
    for i in range(1, 20):
        prev = tb.load(a, i, (prev,))
    tr = tb.build()
    res = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("lvt", 8, 8, 64)}, fu_counts={}, mem_latency=2))
    assert res.cycles >= 20 * 2          # serial chain: latency x depth


def test_scheduler_deterministic():
    mod = BENCHMARKS["md_knn"]
    tr = mod.gen_trace(mod.TINY)
    cfg = ScheduleConfig(mem={a: AMMSpec("banked", 8, 8, 4096, n_banks=4)
                              for a in tr.array_names},
                         fu_counts={"fadd": 2, "fmul": 2, "fdiv": 1,
                                    "iadd": 2, "imul": 1, "icmp": 2,
                                    "logic": 2})
    r1, r2 = schedule(tr, cfg), schedule(tr, cfg)
    assert r1.cycles == r2.cycles == schedule(tr, cfg).cycles


# ----------------------------------------------------------------------
# DSE
# ----------------------------------------------------------------------
def test_pareto_front_nondominated():
    mod = BENCHMARKS["gemm_ncubed"]
    pts = sweep(mod.gen_trace(mod.TINY),
                [DesignPoint("banked", n_banks=4),
                 DesignPoint("hb_ntx", 4, 2)], unrolls=(1, 4))
    front = pareto_front(pts)
    for i, p in enumerate(front):
        for q in front:
            assert not (q.time_us < p.time_us and q.area_mm2 < p.area_mm2)


def test_unroll_speeds_up_compute_bound():
    mod = BENCHMARKS["stencil2d"]
    tr = mod.gen_trace(mod.TINY)
    p1 = evaluate_point(tr, DesignPoint("lvt", 4, 2), 1)
    p8 = evaluate_point(tr, DesignPoint("lvt", 4, 2), 8)
    assert p8.cycles < p1.cycles
    assert p8.area_mm2 > p1.area_mm2


def test_paper_locality_correlation():
    """The paper's headline claim (IV-C): AMM performance ratio is higher
    for low-locality benchmarks than for the stride-one benchmark KMP."""
    designs = [DesignPoint("banked", n_banks=2),
               DesignPoint("banked", n_banks=8),
               DesignPoint("banked", n_banks=32),
               DesignPoint("hb_ntx", 4, 2), DesignPoint("lvt", 4, 2),
               DesignPoint("lvt", 8, 2)]
    ratios = {}
    for name in ("kmp", "md_knn", "gemm_ncubed"):
        mod = BENCHMARKS[name]
        pts = sweep(mod.gen_trace(mod.TINY), designs, unrolls=(2, 8))
        ratios[name] = performance_ratio(pts)
    assert ratios["md_knn"] > ratios["kmp"] or \
        ratios["gemm_ncubed"] > ratios["kmp"], ratios
