"""Compiled-vs-interpret-vs-oracle parity harness (ISSUE 8).

Every kernel runs the *same* blocked program through two executors —
the Pallas interpreter and the compiled XLA grid path (`mode="xla"`,
what `mode="compiled"` resolves to on CPU) — and both must agree with
the pure-jnp / replay-backed oracles in ``kernels/ref.py``:

  * integer paths (amm_gather XOR reconstruction) are bit-exact,
  * float accumulation paths (kv_decode, ssd_chunk) are bit-exact
    between executors (identical op sequence per block) and tight
    allclose against the dense oracles (different reduction order).

The grid covers shape classes, bank counts (odd / non-pow2 / single),
ragged sequence lengths (incl. empty rows), and both parity paths of
the XOR gather.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import amm_gather, kv_decode, ref, ssd_chunk
from repro.kernels.lowering import resolve_mode, supports_pallas_lowering

RNG = np.random.default_rng(42)
MODES = ("interpret", "xla")


# ----------------------------------------------------------------- amm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,nb,n,bn", [
    (64, 8, 2, 16, 8), (128, 16, 4, 64, 32), (256, 32, 8, 128, 128),
    (96, 8, 3, 48, 16),          # odd bank count
    (250, 8, 5, 50, 25),         # non-pow2 table depth and banks
    (64, 8, 1, 32, 32),          # single-bank degenerate geometry
])
def test_amm_gather_parity(dtype, v, d, nb, n, bn):
    table = jnp.asarray(RNG.standard_normal((v, d)), dtype)
    idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    want = ref.amm_gather_ref(table, idx)
    outs = {m: amm_gather(table, idx, n_banks=nb, mode=m, block_n=bn)
            for m in MODES}
    for m, got in outs.items():
        assert jnp.array_equal(got, want), f"{m} != oracle"
    assert jnp.array_equal(outs["interpret"], outs["xla"])


def test_amm_gather_replay_oracle_parity():
    """Both executors must match the replay-backed functional-model
    oracle (H-NTX-Rd direct/parity paths) bit-for-bit."""
    table = jnp.asarray(RNG.standard_normal((128, 16)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 128, 64), jnp.int32)
    want = ref.amm_gather_replay_ref(table, idx)
    for m in MODES:
        got = amm_gather(table, idx, n_banks=4, mode=m)
        assert jnp.array_equal(got, want), f"{m} != replay oracle"


def test_amm_gather_block_autoselect():
    """Any request count runs: the dispatcher re-legalizes the tuned
    block size against the actual shape (incl. primes)."""
    table = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    for n in (1, 7, 63, 97, 128):
        idx = jnp.asarray(RNG.integers(0, 64, n), jnp.int32)
        for m in MODES:
            got = amm_gather(table, idx, n_banks=4, mode=m)
            assert jnp.array_equal(got, ref.amm_gather_ref(table, idx))


# ------------------------------------------------------------------ kv
_KV_SHAPES = [
    # b, hq, hkv, s, d, nb
    (2, 4, 2, 64, 16, 4),
    (1, 8, 8, 128, 32, 8),
    (3, 6, 2, 96, 8, 3),         # odd bank count
    (4, 8, 4, 64, 16, 1),        # single bank
]


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("b,hq,hkv,s,d,nb", _KV_SHAPES)
def test_kv_decode_parity(dtype, tol, b, hq, hkv, s, d, nb):
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    lens = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)
    want = ref.kv_decode_ref(q, k, v, lens)
    outs = {}
    group = hq // hkv
    for m in MODES:
        for bh in sorted({1, group}):
            got = kv_decode(q, k, v, lens, n_banks=nb, mode=m, block_h=bh)
            outs[(m, bh)] = np.asarray(got, np.float32)
            np.testing.assert_allclose(outs[(m, bh)],
                                       np.asarray(want, np.float32),
                                       atol=tol, rtol=tol,
                                       err_msg=f"{m} bh={bh}")
    # same block program, same ops: executors agree bit-exactly
    for bh in sorted({1, group}):
        np.testing.assert_array_equal(outs[("interpret", bh)],
                                      outs[("xla", bh)])


@pytest.mark.parametrize("lens", [
    [0, 5, 33, 64],              # empty row + mid-bank + bank boundary + full
    [1, 1, 16, 17],              # bank-boundary straddle (SB=16 at nb=4)
    [0, 0, 0, 0],                # fully-empty batch
])
def test_kv_decode_ragged_masking(lens):
    """Per-row seq_len < padded S: masked reference equality, empty rows
    decode to zeros, and padded K/V content never leaks into outputs."""
    b, hq, hkv, s, d, nb = 4, 4, 2, 64, 16, 4
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    L = jnp.asarray(lens, jnp.int32)
    want = np.asarray(ref.kv_decode_ref(q, k, v, L))
    assert not np.isnan(want).any(), "masked reference must be NaN-free"
    kp, vp = k, v
    for i, n in enumerate(lens):     # poison the padded tail of each row
        kp = kp.at[i, :, n:, :].set(1e4)
        vp = vp.at[i, :, n:, :].set(-1e4)
    for m in MODES:
        got = np.asarray(kv_decode(q, k, v, L, n_banks=nb, mode=m))
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
        for i, n in enumerate(lens):
            if n == 0:
                assert np.all(got[i] == 0.0), "empty row must decode to 0"
        # padded content must never leak into the output
        got2 = np.asarray(kv_decode(q, kp, vp, L, n_banks=nb, mode=m))
        np.testing.assert_allclose(got2, got, atol=1e-6)


# ----------------------------------------------------------------- ssd
@pytest.mark.parametrize("bt,h,q,p,n", [(1, 2, 8, 4, 4), (2, 4, 16, 8, 8),
                                        (2, 3, 12, 8, 6)])
def test_ssd_chunk_parity(bt, h, q, p, n):
    x = jnp.asarray(RNG.standard_normal((bt, h, q, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (bt, h, q)), jnp.float32)
    la = -dt * jnp.asarray(RNG.uniform(0.5, 2.0, (1, h, 1)), jnp.float32)
    cum = jnp.cumsum(la, axis=-1)
    B = jnp.asarray(RNG.standard_normal((bt, q, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((bt, q, n)), jnp.float32)
    h_in = jnp.asarray(RNG.standard_normal((bt, h, p, n)), jnp.float32)
    y_ref, h_ref = ref.ssd_chunk_ref(x, dt, cum, B, C, h_in)
    outs = {}
    for m in MODES:
        for bh in sorted({1, h}):
            y, hout = ssd_chunk(x, dt, cum, B, C, h_in, mode=m, block_h=bh)
            outs[(m, bh)] = (np.asarray(y), np.asarray(hout))
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       atol=1e-4, err_msg=f"{m} bh={bh}")
            np.testing.assert_allclose(np.asarray(hout), np.asarray(h_ref),
                                       atol=1e-4, err_msg=f"{m} bh={bh}")
    for bh in sorted({1, h}):
        np.testing.assert_array_equal(outs[("interpret", bh)][0],
                                      outs[("xla", bh)][0])
        np.testing.assert_array_equal(outs[("interpret", bh)][1],
                                      outs[("xla", bh)][1])


# ------------------------------------------------------ mode dispatch
def test_resolve_mode_defaults():
    assert resolve_mode(True, None) == "interpret"
    compiled = resolve_mode(False, None)
    assert compiled == ("pallas" if supports_pallas_lowering() else "xla")
    assert resolve_mode(None, None) == compiled
    assert resolve_mode(None, "compiled") == compiled
    assert resolve_mode(True, "xla") == "xla"   # explicit mode wins
    with pytest.raises(ValueError):
        resolve_mode(None, "nope")


def test_env_override_is_default_only(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    assert resolve_mode(None, None) == "interpret"
    assert resolve_mode(False, None) == "interpret"
    assert resolve_mode(None, "xla") == "xla"   # explicit mode still wins


def test_compiled_executes_with_interpret_false():
    """The acceptance bullet: kernels execute with interpret=False on
    CPU — resolved through the interpreter-bypass path."""
    table = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 64, 32), jnp.int32)
    got = amm_gather(table, idx, n_banks=4, interpret=False)
    assert jnp.array_equal(got, ref.amm_gather_ref(table, idx))
