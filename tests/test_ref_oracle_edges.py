"""Edge-case coverage for the ``kernels/ref.py`` oracles (ISSUE 8):
odd / non-pow2 bank counts, single-bank degenerate geometry, and
parity-path request slots landing exactly on bank boundaries."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import amm_gather, pack_amm_banks, ref

RNG = np.random.default_rng(11)


# --------------------------------------------------- pack_amm_banks
@pytest.mark.parametrize("nb", [1, 2, 3, 5, 6, 8])
def test_parity_invariant_any_bank_count(nb):
    """parity == XOR of all banks for pow2, odd and composite counts."""
    v = 24 * nb        # always divisible, non-pow2 depth for nb != 8
    table = jnp.asarray(RNG.integers(0, 2**31, (v, 4)), jnp.uint32)
    banks, parity = pack_amm_banks(table.view(jnp.float32), nb)
    acc = banks[0]
    for j in range(1, nb):
        acc = acc ^ banks[j]
    assert jnp.array_equal(acc, parity)
    assert banks.shape == (nb, v // nb, 4)


def test_single_bank_parity_is_the_bank():
    """nb=1: the parity bank degenerates to a copy of the single data
    bank, and the reconstruction path must still return the row."""
    table = jnp.asarray(RNG.standard_normal((32, 8)), jnp.float32)
    banks, parity = pack_amm_banks(table, 1)
    assert jnp.array_equal(banks[0], parity)
    idx = jnp.asarray(RNG.integers(0, 32, 16), jnp.int32)
    for m in ("interpret", "xla"):
        got = amm_gather(table, idx, n_banks=1, mode=m)
        assert jnp.array_equal(got, ref.amm_gather_ref(table, idx))


def test_pack_rejects_indivisible_depth():
    table = jnp.asarray(RNG.standard_normal((30, 4)), jnp.float32)
    with pytest.raises(AssertionError):
        pack_amm_banks(table, 4)


# ----------------------------------------------- bank-boundary slots
@pytest.mark.parametrize("nb", [2, 3, 4, 8])
def test_parity_path_at_bank_boundaries(nb):
    """Force the *parity* path (odd request slots) onto the first and
    last offset of every bank: the XOR reconstruction must be bit-exact
    exactly where bank geometry transitions."""
    v, d = 8 * nb, 8
    rows = v // nb
    table = jnp.asarray(RNG.integers(0, 2**31, (v, d)), jnp.uint32).view(
        jnp.float32)
    edges = []
    for b in range(nb):
        edges += [b * rows, b * rows + rows - 1]    # first/last row of bank b
    # even slots = direct path on the same addresses, odd slots = parity
    idx = jnp.asarray(np.repeat(edges, 2), jnp.int32)
    bits = lambda a: jax.lax.bitcast_convert_type(a, jnp.uint32)
    want = ref.amm_gather_ref(table, idx)
    for m in ("interpret", "xla"):
        got = amm_gather(table, idx, n_banks=nb, mode=m)
        # compare bit patterns: random words include NaN payloads, which
        # float equality would reject even when reconstruction is exact
        assert jnp.array_equal(bits(got), bits(want))
    # and the replay-backed functional oracle agrees on the same trace
    assert jnp.array_equal(bits(ref.amm_gather_replay_ref(table, idx)),
                           bits(want))


# ------------------------------------------- replay-backed oracle
@pytest.mark.parametrize("n", [1, 2, 7, 63])
def test_replay_oracle_odd_request_counts(n):
    """The replay oracle pads odd request counts to full 2-port cycles;
    the pad must never leak into the returned rows."""
    table = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 64, n), jnp.int32)
    want = ref.amm_gather_ref(table, idx)
    got = ref.amm_gather_replay_ref(table, idx)
    assert got.shape == want.shape
    assert jnp.array_equal(got, want)


def test_replay_oracle_uint_roundtrip_bf16():
    """bf16 payloads bitcast through uint16 lanes must round-trip."""
    table = jnp.asarray(RNG.standard_normal((64, 8)), jnp.bfloat16)
    idx = jnp.asarray(RNG.integers(0, 64, 32), jnp.int32)
    assert jnp.array_equal(ref.amm_gather_replay_ref(table, idx),
                           ref.amm_gather_ref(table, idx))


# --------------------------------------------------- kv masked oracle
def test_kv_ref_empty_row_is_zero_and_nan_free():
    b, hq, hkv, s, d = 3, 4, 2, 32, 8
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    out = np.asarray(ref.kv_decode_ref(q, k, v, jnp.asarray([0, 1, 32])))
    assert not np.isnan(out).any()
    assert np.all(out[0] == 0.0)
    # a length-1 row is just v[0] broadcast through softmax(single)
    np.testing.assert_allclose(
        out[1].reshape(hkv, hq // hkv, d),
        np.broadcast_to(np.asarray(v)[1, :, 0][:, None, :],
                        (hkv, hq // hkv, d)), atol=1e-6)
