"""Surrogate-predictor regression suite (PR 6).

Pins three layers of the surrogate-guided DSE path:

* **accuracy** — the analytic cycle predictor against the calibrated
  312-row subset of the pinned golden matrix (the 12 MachSuite benches
  x 13 designs x {1,4}; the serving benches' 78 rows are conformance
  pins, not fit data): median / max relative error and per-bench
  Spearman rank correlation must not regress past the fit tool's own
  gates;
* **soundness** — the pruned sweep (``prune="surrogate"``) must return
  the exact exhaustive Pareto front on every TINY bench at
  ``DEFAULT_MARGIN`` (for uncalibrated trace families — the serving
  benches — by auto-falling back to the exhaustive grid), and the in-C
  front caps may only suppress points that are provably off the front;
* **plumbing** — the batched-C evaluator equals the per-point path
  bitwise, and the sweep-cache manifest fast path serves a fully
  cached benchmark without ever generating its trace.
"""
import json
import pathlib

import pytest

from repro.core.bench import BENCHMARKS, SERVING, get_trace, trace_cache_key
from repro.core.dse import spearman_rho
from repro.core.dse.pareto import pareto_front
from repro.core.dse.runner import (SweepCache, point_key, run_sweep,
                                   run_sweep_bench)
from repro.core.dse.surrogate import (CALIBRATED_BENCHES,
                                      CALIBRATION_DESIGNS,
                                      CALIBRATION_UNROLLS,
                                      CALIBRATED_MEM_LATENCY,
                                      DEFAULT_MARGIN, TraceFeatures,
                                      grid_predictions, predict,
                                      select_band)
from repro.core.dse.sweep import (DEFAULT_DESIGNS, DEFAULT_UNROLLS,
                                  evaluate_point, evaluate_points)
from repro.core.sim import prepare_trace

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_schedule.json").read_text())

_PREPARED: dict = {}


def _pt(bench: str):
    if bench not in _PREPARED:
        _PREPARED[bench] = prepare_trace(get_trace(bench))
    return _PREPARED[bench]


def _golden_by_bench() -> dict:
    out: dict = {}
    for g in GOLDEN:
        out.setdefault(g["bench"], []).append(g)
    return out


# ----------------------------------------------------------------------
# calibration matrix stays in sync with the golden matrix
# ----------------------------------------------------------------------
def test_calibration_matrix_matches_golden_rows():
    """The golden matrix covers all 15 benches (390 rows); the surrogate
    is fitted against exactly its calibrated 312-row MachSuite subset —
    the serving benches carry conformance rows but no calibration, and
    the pruned sweep falls back to exhaustive for them."""
    assert len(GOLDEN) == 390
    assert {g["design"] for g in GOLDEN} == set(CALIBRATION_DESIGNS)
    assert tuple(sorted({g["unroll"] for g in GOLDEN})) == CALIBRATION_UNROLLS
    assert {g["bench"] for g in GOLDEN} == set(BENCHMARKS)
    assert CALIBRATED_BENCHES == set(BENCHMARKS) - set(SERVING)
    n_cal = sum(g["bench"] in CALIBRATED_BENCHES for g in GOLDEN)
    assert n_cal == 312


def test_calibration_designs_match_golden_test_matrix():
    """Same DesignPoints as tests/test_golden_schedule.py pins."""
    from tests.test_golden_schedule import _DESIGNS

    assert dict(CALIBRATION_DESIGNS) == dict(_DESIGNS)


# ----------------------------------------------------------------------
# predictor accuracy against the 312 calibrated golden rows
# ----------------------------------------------------------------------
def test_cycle_predictor_accuracy_pins():
    """Median/max relative cycle error and per-bench rank correlation
    against every *calibrated* golden row (same gates as
    tools/fit_surrogate.py; serving-bench rows are excluded because the
    pruned sweep never consults the surrogate for them)."""
    rel_all = []
    for bench, rows in sorted(_golden_by_bench().items()):
        if bench not in CALIBRATED_BENCHES:
            continue
        pt = _pt(bench)
        feats = TraceFeatures(pt)
        preds, truths = [], []
        for g in rows:
            dp = CALIBRATION_DESIGNS[g["design"]]
            p = predict(pt, dp, g["unroll"], feats)
            preds.append(p.cycles)
            truths.append(g["cycles"])
            rel_all.append(abs(p.cycles - g["cycles"]) / g["cycles"])
        rho = spearman_rho(truths, preds)
        # constant-truth benches (every design equally fast) have no
        # defined rank correlation; spearman_rho returns nan there
        if rho == rho:
            assert rho >= 0.9, (bench, rho)
    rel_all.sort()
    assert rel_all[len(rel_all) // 2] <= 0.06, rel_all[len(rel_all) // 2]
    assert rel_all[-1] <= 0.25, rel_all[-1]


def test_stall_predictions_gated_by_kind():
    """Stall mechanisms that a kind does not have must predict zero,
    and no stall prediction may go negative."""
    pt = _pt("gemm_ncubed")
    feats = TraceFeatures(pt)
    for label, dp in CALIBRATION_DESIGNS.items():
        p = predict(pt, dp, 4, feats)
        assert p.bank_conflict_stalls >= 0.0, label
        assert p.parity_fanout_stalls >= 0.0, label
        assert p.write_pair_stalls >= 0.0, label
        if dp.kind not in ("h_ntx_rd", "b_ntx_wr", "hb_ntx"):
            assert p.parity_fanout_stalls == 0.0, label
            assert p.write_pair_stalls == 0.0, label
        if dp.kind in ("ideal", "multipump", "lvt"):
            assert p.bank_conflict_stalls == 0.0, label


# ----------------------------------------------------------------------
# band pruning soundness
# ----------------------------------------------------------------------
def test_band_keeps_every_true_front_point_on_all_tiny_benches():
    """select_band at DEFAULT_MARGIN never drops a true-front point of
    the default 20-design x 4-unroll grid (the ranking-safety property
    DEFAULT_MARGIN is sized for).  The serving benches are included
    even though run_sweep falls back to exhaustive for them: the band
    property happens to hold there too, and this pins it in case they
    ever join the calibration set."""
    for bench in BENCHMARKS:
        pt = _pt(bench)
        preds = grid_predictions(pt, DEFAULT_DESIGNS, DEFAULT_UNROLLS)
        keep = select_band(preds, DEFAULT_MARGIN)
        res = evaluate_points(pt, [(g.design, g.unroll) for g in preds])
        front = {(p.design, p.unroll) for p in pareto_front(res)}
        kept = {(g.design.label, g.unroll)
                for g, k in zip(preds, keep) if k}
        assert front <= kept, (bench, front - kept)


def test_pruned_front_equals_exhaustive_front_on_all_tiny_benches():
    for bench in BENCHMARKS:
        pt = _pt(bench)
        exh = run_sweep(pt, DEFAULT_DESIGNS, DEFAULT_UNROLLS)
        prn = run_sweep(pt, DEFAULT_DESIGNS, DEFAULT_UNROLLS,
                        prune="surrogate")
        fe = {(p.design, p.unroll) for p in pareto_front(exh)}
        fp = {(p.design, p.unroll) for p in pareto_front(prn)}
        assert fe == fp, (bench, fe ^ fp)
        # pruned results are a designs-major subsequence of the grid
        # with bitwise-equal rows
        by_key = {(p.design, p.unroll): p for p in exh}
        for p in prn:
            assert p == by_key[(p.design, p.unroll)]


def test_unknown_prune_mode_raises():
    with pytest.raises(ValueError, match="prune"):
        run_sweep(_pt("gemm_ncubed"), DEFAULT_DESIGNS[:2], (1,),
                  prune="magic")


def test_prune_falls_back_on_uncalibrated_trace_family(capsys):
    """Serving traces are not in the calibration set: the pruned sweep
    must silently run the full exhaustive grid for them (exactness
    pinned by construction, no reliance on band soundness)."""
    designs = DEFAULT_DESIGNS[::3]
    for bench in SERVING:
        pt = _pt(bench)
        prn = run_sweep(pt, designs, (1, 4), prune="surrogate",
                        verbose=True)
        assert "not in the surrogate calibration set" in \
            capsys.readouterr().err
        exh = run_sweep(pt, designs, (1, 4))
        assert prn == exh
        assert len(prn) == len(designs) * 2


def test_prune_falls_back_off_calibration_latency():
    """The surrogate is only calibrated at mem_latency=2: any other
    latency must silently run the exhaustive sweep (full grid back)."""
    pt = _pt("gemm_ncubed")
    designs = DEFAULT_DESIGNS[:4]
    assert CALIBRATED_MEM_LATENCY == 2
    prn = run_sweep(pt, designs, (1, 4), mem_latency=3, prune="surrogate")
    exh = run_sweep(pt, designs, (1, 4), mem_latency=3)
    assert prn == exh
    assert len(prn) == len(designs) * 2


# ----------------------------------------------------------------------
# batched-C evaluator
# ----------------------------------------------------------------------
def test_batch_evaluator_equals_per_point():
    pt = _pt("fft_strided")
    points = [(dp, u) for dp in list(CALIBRATION_DESIGNS.values())
              for u in (1, 4)]
    batch = evaluate_points(pt, points)
    for (dp, u), got in zip(points, batch):
        assert got == evaluate_point(pt, dp, u)


def test_front_cap_suppresses_only_off_front_points():
    """front_cap=True may return None only for points that are provably
    off the exhaustive front; completed points stay bitwise equal."""
    pt = _pt("fft_strided")
    points = [(dp, u) for dp in list(CALIBRATION_DESIGNS.values())
              for u in (1, 4)]
    exact = evaluate_points(pt, points)
    capped = evaluate_points(pt, points, front_cap=True)
    front = {(p.design, p.unroll) for p in pareto_front(exact)}
    assert len(capped) == len(exact)
    n_capped = 0
    for full, got in zip(exact, capped):
        if got is None:
            n_capped += 1
            assert (full.design, full.unroll) not in front
        else:
            assert got == full
    # the cap must actually fire on this bench, else the test is vacuous
    assert n_capped > 0
    survivors = [p for p in capped if p is not None]
    assert {(p.design, p.unroll) for p in pareto_front(survivors)} == front


# ----------------------------------------------------------------------
# sweep-cache manifest fast path
# ----------------------------------------------------------------------
def test_manifest_fast_path_skips_trace_generation(tmp_path, monkeypatch):
    bench = "gemm_ncubed"
    designs = DEFAULT_DESIGNS[:3]
    unrolls = (1, 4)
    cache = SweepCache(tmp_path)
    stats: dict = {}
    cold = run_sweep_bench(bench, designs, unrolls, cache=cache,
                           stats=stats)
    assert stats["fast_path"] is False
    assert cache.manifest_get(trace_cache_key(bench)) is not None

    calls = {"n": 0}
    real = get_trace

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr("repro.core.bench.get_trace", counting)
    stats = {}
    warm = run_sweep_bench(bench, designs, unrolls, cache=cache,
                           stats=stats)
    assert stats["fast_path"] is True
    assert calls["n"] == 0
    assert warm == cold


def test_manifest_partial_cache_falls_through(tmp_path):
    """A manifest hit with missing grid points must re-run the sweep
    (and still return the full grid)."""
    bench = "gemm_ncubed"
    designs = DEFAULT_DESIGNS[:3]
    cache = SweepCache(tmp_path)
    cold = run_sweep_bench(bench, designs, (1,), cache=cache)
    # wider grid: manifest hits, but the u=4 points are not cached yet
    stats: dict = {}
    wide = run_sweep_bench(bench, designs, (1, 4), cache=cache,
                           stats=stats)
    assert stats["fast_path"] is False
    assert len(wide) == 2 * len(designs)
    assert [p for p in wide if p.unroll == 1] == cold


# ----------------------------------------------------------------------
# runner observability
# ----------------------------------------------------------------------
def test_verbose_progress_lines_on_stderr(capsys):
    run_sweep(_pt("gemm_ncubed"), DEFAULT_DESIGNS[:3], (1, 4),
              verbose=True)
    err = capsys.readouterr().err
    assert "[sweep]" in err

    run_sweep(_pt("gemm_ncubed"), DEFAULT_DESIGNS[:3], (1, 4),
              prune="surrogate", verbose=True)
    err = capsys.readouterr().err
    assert "[sweep]" in err and "band" in err
