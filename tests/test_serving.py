"""Serving workload family (ISSUE 10).

Three layers:

* **KV-cache bugfix regressions** — ``BankedKVCache.append`` must drop
  (not silently overwrite) at capacity and clamp ``length``;
  ``BankedKVCache.create`` must round a non-power-of-two bank plan to
  the largest divisor of ``max_len`` (not collapse it to one bank) and
  reject non-positive plans.
* **serving-trace properties** — the three serving benches generate
  deterministic (fingerprint-stable) traces whose measured spatial
  locality lands below every dense MachSuite bench, the precondition
  for extending the paper's Fig-5 claim to LLM-serving workloads.
* **backend identity** — each serving bench runs through ``run_sweep``
  on all three scheduler backends with bitwise-identical results.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bench import BENCHMARKS, SERVING, get_trace
from repro.core.bench import kv_decode as KD
from repro.core.bench import moe_route as MR
from repro.core.bench import paged_kv as PK
from repro.core.locality import trace_locality
from repro.kernels import ref
from repro.memory import BankedKVCache, StreamPlan


def _plan(nb: int) -> StreamPlan:
    return StreamPlan(stream="kv", locality=0.1, use_amm=True, n_banks=nb,
                      n_read_ports=2, est_area_mm2=0.0)


def _rand_kv(rng, b, h, d):
    return (jnp.asarray(rng.standard_normal((b, h, 1, d)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, h, 1, d)), jnp.float32))


# ----------------------------------------------------------------------
# bugfix 1: append at capacity
# ----------------------------------------------------------------------
def test_append_at_capacity_drops_write_and_clamps_length():
    """A full row's append is dropped: k/v bitwise untouched, length
    pinned at max_len.  (The old behavior let JAX clamp the OOB scatter
    onto the last slot — silently replacing the newest token — while
    length grew past the cache size.)"""
    rng = np.random.default_rng(5)
    cache = BankedKVCache.create(2, 2, 4, 8, dtype=jnp.float32)
    for _ in range(4):
        cache = cache.append(*_rand_kv(rng, 2, 2, 8))
    np.testing.assert_array_equal(np.asarray(cache.length), [4, 4])
    k_full, v_full = cache.k, cache.v

    over = cache.append(*_rand_kv(rng, 2, 2, 8))
    np.testing.assert_array_equal(np.asarray(over.length), [4, 4])
    assert jnp.array_equal(over.k, k_full)
    assert jnp.array_equal(over.v, v_full)

    # and decode after the over-append still matches the dense
    # reference on the pre-overflow contents
    q = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(over.decode_read(q)),
        np.asarray(ref.kv_decode_ref(q, k_full, v_full, over.length)),
        atol=1e-5)


def test_append_ragged_full_row_drops_open_row_writes():
    """Mixed-length batch with one row at capacity: the full row drops,
    the open row still lands its token at its own length."""
    rng = np.random.default_rng(6)
    cache = BankedKVCache.create(2, 1, 4, 4, dtype=jnp.float32)
    for _ in range(2):
        cache = cache.append(*_rand_kv(rng, 2, 1, 4))
    cache = dataclasses.replace(
        cache, length=jnp.asarray([4, 2], jnp.int32))      # row 0 full

    kn, vn = _rand_kv(rng, 2, 1, 4)
    out = cache.append(kn, vn)
    np.testing.assert_array_equal(np.asarray(out.length), [4, 3])
    assert jnp.array_equal(out.k[0], cache.k[0])           # row 0 untouched
    np.testing.assert_array_equal(np.asarray(out.k[1, :, 2]),
                                  np.asarray(kn[1, :, 0]))
    np.testing.assert_array_equal(np.asarray(out.v[1, :, 2]),
                                  np.asarray(vn[1, :, 0]))


# ----------------------------------------------------------------------
# bugfix 2: bank-plan rounding
# ----------------------------------------------------------------------
def test_create_rounds_to_largest_divisor_not_single_bank():
    """nb=6 over S=64 must give 4 banks (largest divisor <= 6); the old
    halving loop walked 6 -> 3 -> 1 and dropped all banking."""
    assert BankedKVCache.create(1, 1, 64, 8, plan=_plan(6)).n_banks == 4
    assert BankedKVCache.create(1, 1, 48, 8, plan=_plan(3)).n_banks == 3
    assert BankedKVCache.create(1, 1, 40, 8, plan=_plan(12)).n_banks == 10
    assert BankedKVCache.create(1, 1, 32, 8, plan=_plan(8)).n_banks == 8
    # nb > max_len clamps to max_len first
    assert BankedKVCache.create(1, 1, 4, 8, plan=_plan(64)).n_banks == 4


@pytest.mark.parametrize("nb", (0, -2))
def test_create_rejects_nonpositive_bank_plan(nb):
    with pytest.raises(ValueError, match="n_banks"):
        BankedKVCache.create(1, 1, 32, 8, plan=_plan(nb))


def test_create_odd_bank_plan_round_trips_decode():
    """An odd bank count (3 banks over S=48) must survive create and
    decode bit-for-bit against the dense masked reference."""
    rng = np.random.default_rng(7)
    cache = BankedKVCache.create(2, 2, 48, 8, dtype=jnp.float32,
                                 plan=_plan(3))
    assert cache.n_banks == 3
    for _ in range(5):
        cache = cache.append(*_rand_kv(rng, 2, 2, 8))
    q = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(cache.decode_read(q)),
        np.asarray(ref.kv_decode_ref(q, cache.k, cache.v, cache.length)),
        atol=1e-5)


# ----------------------------------------------------------------------
# serving benches: references
# ----------------------------------------------------------------------
def test_kv_decode_jax_matches_np():
    i = KD.make_inputs(KD.TINY)
    got = np.asarray(KD.run_jax(jnp.asarray(i["q"]), jnp.asarray(i["k"]),
                                jnp.asarray(i["v"]),
                                jnp.asarray(i["lengths"])))
    want = KD.run_np(i["q"], i["k"], i["v"], i["lengths"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # mixed lengths: the batch must actually be ragged
    assert len(set(i["lengths"].tolist())) > 1


def test_paged_kv_jax_matches_np_and_pool_is_fragmented():
    p = PK.TINY
    i = PK.make_inputs(p)
    got = np.asarray(PK.run_jax(jnp.asarray(i["block_table"]),
                                jnp.asarray(i["lengths"]),
                                jnp.asarray(i["kv_pool"]),
                                jnp.asarray(i["weights"]), p.page_size))
    want = PK.run_np(i["block_table"], i["lengths"], i["kv_pool"],
                     i["weights"], p.page_size)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # interleaved growth: some request's pages must be non-contiguous
    bt = i["block_table"]
    frag = any((np.diff(row[row >= 0]) != 1).any()
               for row in bt if (row >= 0).sum() > 1)
    assert frag, bt


def test_moe_route_jax_matches_np_with_capacity_overflow():
    p = MR.TINY
    i = MR.make_inputs(p)
    got = np.asarray(MR.run_jax(jnp.asarray(i["logits"]),
                                jnp.asarray(i["x"]),
                                jnp.asarray(i["w_exp"]),
                                p.top_k, p.capacity_factor))
    want = MR.run_np(i["logits"], i["x"], i["w_exp"],
                     p.top_k, p.capacity_factor)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # the capacity-drop path must actually fire at TINY size
    _, top_e = MR._route_np(i["logits"], p.top_k)
    counts = np.bincount(top_e.reshape(-1), minlength=p.n_experts)
    assert (counts > MR.capacity(p)).any(), counts


# ----------------------------------------------------------------------
# serving benches: trace properties
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", SERVING)
def test_serving_trace_fingerprint_stable(name):
    from repro.core.sim.prepared import trace_fingerprint

    mod = BENCHMARKS[name]
    assert trace_fingerprint(mod.gen_trace(mod.TINY)) == \
        trace_fingerprint(mod.gen_trace(mod.TINY))


def test_serving_locality_below_dense_benches():
    """Fig-5 precondition: all three serving traces sit below the dense
    byte-oriented/windowed MachSuite benches on the locality axis, and
    the lockstep KV-decode burst lands at the very bottom (below even
    GEMM's column walks)."""
    L = {}
    for name in SERVING + ("kmp", "aes", "stencil2d", "gemm_ncubed"):
        mod = BENCHMARKS[name]
        tr = mod.gen_trace(mod.TINY)
        addrs, aids = tr.mem_addrs_and_arrays()
        L[name] = trace_locality(addrs, aids)
    for s in SERVING:
        for dense in ("kmp", "aes", "stencil2d"):
            assert L[s] < L[dense], (s, dense, L)
    assert L["kv_decode"] < L["gemm_ncubed"], L


# ----------------------------------------------------------------------
# serving benches: 3-backend sweep identity (acceptance criterion)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", SERVING)
def test_serving_sweep_identical_across_backends(name):
    """run_sweep on py / C / jax returns bitwise-identical DSE points
    (cycles, stall breakdowns, derived metrics) on every serving bench."""
    from repro.core.dse.pareto import pareto_front
    from repro.core.dse.runner import run_sweep
    from repro.core.dse.sweep import DEFAULT_DESIGNS
    from repro.core.sim import prepare_trace

    pt = prepare_trace(get_trace(name))
    designs = DEFAULT_DESIGNS[::4]
    res_c = run_sweep(pt, designs, (1, 4), backend="c")
    res_py = run_sweep(pt, designs, (1, 4), backend="py")
    assert res_py == res_c
    res_jax = run_sweep(pt, designs, (1, 4), backend="jax")
    assert res_jax == res_c
    assert pareto_front(res_c)
