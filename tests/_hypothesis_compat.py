"""Import hypothesis, or fall back to a deterministic mini-shim.

The seed test suite failed collection outright when hypothesis was not
installed.  Tests import ``given``/``settings``/``st`` from this module
instead: with hypothesis present (see requirements-dev.txt) they get
full property testing; without it they get a small deterministic
replacement that draws seeded pseudo-random examples through the same
strategy API, so every property still runs against real inputs.

The shim implements only what the suite uses: ``st.integers``,
``st.booleans``, ``st.lists``, ``st.tuples``, ``st.data``, ``@given``
and ``@settings(max_examples=..., deadline=...)``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random
    import types

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng: random.Random):
            return self._draw_fn(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng):
            return [elements.draw(rng)
                    for _ in range(rng.randint(min_size, hi))]
        return _Strategy(draw)

    def _tuples(*elements):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))

    class _DataObject:
        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    _DATA_MARKER = _Strategy(None)  # sentinel resolved by @given

    def _data():
        return _DATA_MARKER

    st = types.SimpleNamespace(
        integers=_integers, booleans=_booleans, lists=_lists,
        tuples=_tuples, data=_data)

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n_examples = getattr(wrapper, "_shim_max_examples", 10)

                def resolve(strategy, rng):
                    if strategy is _DATA_MARKER:
                        return _DataObject(rng)
                    return strategy.draw(rng)

                for example in range(n_examples):
                    rng = random.Random(0xA11CE + 7919 * example)
                    drawn = [resolve(s, rng) for s in arg_strategies]
                    drawn_kw = {k: resolve(s, rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # Hide the strategy-filled parameters from pytest, which
            # would otherwise look for fixtures with those names.
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values()
                      if p.name not in kw_strategies]
            if arg_strategies:
                params = params[:-len(arg_strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco
