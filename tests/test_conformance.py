"""Three-way differential conformance fuzz (ISSUE 5 satellite).

The scheduler has three cycle-loop backends — pure-Python reference,
compiled C, batched JAX — that must agree *decision for decision*: same
cycle counts, same stall breakdown, same parity/RMW event counters,
same per-array access totals.  Hand-pinned goldens only cover the
benchmark traces; this suite drives all three loops with
hypothesis-generated DDGs (random dependency structure, every design
kind including leaf sub-banking, mixed FU budgets / memory latencies /
ports-per-bank) and asserts full ``ScheduleResult`` equality.

On failure the shrunk counterexample is serialized to
``tests/conformance_failures/repro_<test>.json`` (trace ops, per-array
specs, config) so it can be replayed without hypothesis:

    python - <<'PY'
    from tests.test_conformance import replay_repro
    replay_repro("tests/conformance_failures/repro_<test>.json")
    PY

Two op-less "shape anchor" arrays ride along in every config to pin the
design-derived padding buckets (NTX key space, remap banks, table
depth, parity fan-out) to their maxima, so jit signatures do not vary
with the drawn design mix.  Trace-derived buckets (node-count pow2,
pred fan-in) and the ports-per-bank-dependent scan-slot bucket still
vary, so the suite compiles a small handful of kernels rather than
exactly one.
"""
import json
import pathlib

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.amm.spec import AMMSpec
from repro.core.sim import _cycle_ext
from repro.core.sim.scheduler import (ScheduleConfig, _schedule_c,
                                      _schedule_py)
from repro.core.sim.trace import (FADD, FDIV, FMUL, IADD, ICMP, IMUL, LOGIC,
                                  TraceBuilder)

FAIL_DIR = pathlib.Path(__file__).parent / "conformance_failures"

_FU_KINDS = (FADD, FMUL, FDIV, IADD, IMUL, ICMP, LOGIC)
_DEPTH = 64          # pow2: satisfies every kind's divisibility rule

# (kind, n_read, n_write, sub) legal design templates; sub > 1 only
# where the leaf depth allows it at _DEPTH
_DESIGN_SPACE = (
    ("ideal", 2, 2, 1),
    ("ideal", 4, 1, 1),
    ("banked", 2, 2, 1), ("banked", 4, 4, 2), ("banked", 8, 8, 4),
    ("multipump", 2, 2, 1), ("multipump", 4, 4, 1),
    ("h_ntx_rd", 2, 1, 1), ("h_ntx_rd", 4, 1, 1), ("h_ntx_rd", 4, 1, 2),
    ("b_ntx_wr", 1, 2, 1), ("b_ntx_wr", 2, 2, 2),
    ("hb_ntx", 2, 2, 1), ("hb_ntx", 4, 2, 1), ("hb_ntx", 4, 2, 2),
    ("lvt", 2, 2, 1), ("lvt", 4, 2, 1),
    ("remap", 2, 2, 1), ("remap", 4, 3, 1),
)

# op-less arrays appended to every trace: their specs max out the
# device-padding buckets (scan slots, NTX key space, remap banks, table
# depth, parity fan-out) so all fuzz cases share one compiled kernel
_ANCHOR_SPECS = (
    AMMSpec("hb_ntx", 4, 2, _DEPTH, n_banks=2),
    AMMSpec("remap", 4, 3, _DEPTH),
)


def gen_case(draw):
    """Draw one (trace-recipe, config-recipe) case as a plain dict."""
    n_arrays = draw(st.integers(1, 2))
    n_ops = draw(st.integers(6, 48))
    ops = []
    for i in range(n_ops):
        is_mem = draw(st.booleans())
        n_deps = draw(st.integers(0, min(2, i)))
        deps = sorted({draw(st.integers(0, i - 1)) for _ in range(n_deps)})
        if is_mem:
            ops.append({
                "mem": True,
                "load": draw(st.booleans()),
                "array": draw(st.integers(0, n_arrays - 1)),
                "index": draw(st.integers(0, _DEPTH - 1)),
                "deps": deps,
            })
        else:
            ops.append({
                "mem": False,
                "fu": draw(st.integers(0, len(_FU_KINDS) - 1)),
                "deps": deps,
            })
    designs = [draw(st.integers(0, len(_DESIGN_SPACE) - 1))
               for _ in range(n_arrays)]
    fu_counts = {name: draw(st.integers(1, 6))
                 for name in ("fadd", "fmul", "fdiv", "iadd", "imul",
                              "icmp", "logic")}
    return {
        "n_arrays": n_arrays,
        "ops": ops,
        "designs": designs,
        "fu_counts": fu_counts,
        "mem_latency": draw(st.integers(1, 3)),
        "ports_per_bank": draw(st.integers(1, 2)),
    }


def build_case(case):
    """Materialize a drawn case into ``(Trace, ScheduleConfig)``."""
    tb = TraceBuilder("fuzz")
    for aid in range(case["n_arrays"]):
        tb.declare_array(f"a{aid}", 4)
    anchor_base = case["n_arrays"]
    for k in range(len(_ANCHOR_SPECS)):
        tb.declare_array(f"anchor{k}", 4)
    for op in case["ops"]:
        deps = tuple(op["deps"])
        if op["mem"]:
            if op["load"]:
                tb.load(op["array"], op["index"], deps)
            else:
                tb.store(op["array"], op["index"], deps)
        else:
            tb.op(_FU_KINDS[op["fu"]], *deps)
    tr = tb.build()
    mem = {}
    for aid, di in enumerate(case["designs"]):
        kind, rd, wr, sub = _DESIGN_SPACE[di]
        nb = sub if kind == "banked" else 1
        if kind in ("h_ntx_rd", "b_ntx_wr", "hb_ntx", "lvt", "remap"):
            nb = sub
        mem[aid] = AMMSpec(kind, rd, wr, _DEPTH, n_banks=nb)
    for k, spec in enumerate(_ANCHOR_SPECS):
        mem[anchor_base + k] = spec
    cfg = ScheduleConfig(
        mem=mem, fu_counts=dict(case["fu_counts"]),
        mem_latency=case["mem_latency"],
        ports_per_bank=case["ports_per_bank"])
    return tr, cfg


def replay_repro(path):
    """Re-run a serialized counterexample through all three backends."""
    case = json.loads(pathlib.Path(path).read_text())
    _assert_conformance(case, repro_name=None)


def _dump_repro(case, name: str) -> pathlib.Path:
    FAIL_DIR.mkdir(exist_ok=True)
    path = FAIL_DIR / f"repro_{name}.json"
    path.write_text(json.dumps(case, indent=1, sort_keys=True))
    return path


def _assert_conformance(case, repro_name: "str | None"):
    from repro.core.sim.jax_cycle import schedule_jax
    from repro.core.sim.prepared import prepare_trace

    tr, cfg = build_case(case)
    tr = prepare_trace(tr)
    try:
        py = _schedule_py(tr, cfg)
        jx = schedule_jax(tr, cfg)
        assert jx == py, f"jax vs python loop:\n  jax: {jx}\n  py : {py}"
        fast = _cycle_ext.load()
        if fast is not None:
            cc = _schedule_c(fast, tr, cfg)
            assert cc == py, f"C vs python loop:\n  C : {cc}\n  py: {py}"
    except AssertionError as e:
        if repro_name is not None:
            path = _dump_repro(case, repro_name)
            raise AssertionError(
                f"{e}\n(counterexample serialized to {path}; replay with "
                f"tests.test_conformance.replay_repro)") from None
        raise


@settings(max_examples=120, deadline=None)
@given(st.data())
def test_three_backends_agree_on_random_ddgs(data):
    """py / C / jax loops agree on cycles + stall breakdown + event
    counters for arbitrary small DDGs over the full design space."""
    _assert_conformance(gen_case(data.draw), "random_ddgs")


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_three_backends_agree_on_mem_storms(data):
    """Memory-only bursts (every op a load/store, dense same-array
    traffic) maximize arbitration pressure: parity fan-out, write
    pairing, steering conflicts, deferral-scan caps."""
    case = gen_case(data.draw)
    for i, op in enumerate(case["ops"]):
        if not op["mem"]:
            case["ops"][i] = {"mem": True, "load": i % 3 != 0,
                              "array": i % case["n_arrays"],
                              "index": (7 * i) % _DEPTH,
                              "deps": op["deps"]}
    _assert_conformance(case, "mem_storms")


def test_repro_files_replay_clean():
    """Any committed counterexample repro must now pass (regression
    lock: a fixed divergence stays fixed)."""
    if not FAIL_DIR.exists():
        pytest.skip("no serialized counterexamples")
    files = sorted(FAIL_DIR.glob("repro_*.json"))
    if not files:
        pytest.skip("no serialized counterexamples")
    for f in files:
        replay_repro(f)
