"""Independent legality checker: clean passes + seeded-mutation kills.

Three layers:

* clean runs — for every AMM kind and every backend the recorded event
  log must validate with zero violations, and the py/C logs must be
  bit-identical;
* seeded mutations — each known hazard class is injected into a clean
  event log (or result) and the checker must detect it AND classify it
  under the right rule;
* static bounds — every golden row's measured cycles must sit at or
  above every provable lower bound, with at least one bound tight
  somewhere (certificates that can never bind certify nothing).
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core.amm.spec import AMMSpec
from repro.core.sim import trace as T
from repro.core.sim.arbiter import STALL_KEYS
from repro.core.sim.events import (PATH_BROADCAST, PATH_DIRECT,
                                   PATH_PAIR_RMW, PATH_PARITY,
                                   PATH_STEERED)
from repro.core.sim.prepared import FU_ORDER, prepare_trace
from repro.core.sim.scheduler import (ScheduleConfig, schedule,
                                      schedule_events)
from repro.core.verify import (LegalityError, RULE_CLASSES, check_schedule,
                               static_bounds, verify_events, verify_result)

SPECS = {
    "ideal": AMMSpec(kind="ideal", n_read=4, n_write=2, depth=64),
    "banked": AMMSpec(kind="banked", n_read=4, n_write=2, depth=64,
                      n_banks=4),
    "multipump": AMMSpec(kind="multipump", n_read=2, n_write=2, depth=64),
    "lvt": AMMSpec(kind="lvt", n_read=2, n_write=2, depth=64),
    "h_ntx_rd": AMMSpec(kind="h_ntx_rd", n_read=4, n_write=1, depth=64),
    "b_ntx_wr": AMMSpec(kind="b_ntx_wr", n_read=1, n_write=2, depth=64),
    "hb_ntx": AMMSpec(kind="hb_ntx", n_read=4, n_write=2, depth=64),
    "remap": AMMSpec(kind="remap", n_read=2, n_write=2, depth=64),
}
_FU = {k: 2 for k in FU_ORDER}


def _build_trace():
    tb = T.TraceBuilder("verify")
    a = tb.declare_array("a", 4)
    b = tb.declare_array("b", 4)
    rng = np.random.default_rng(7)
    prev = ()
    for i in range(48):
        x = tb.load(a, int(rng.integers(0, 64)), prev)
        y = tb.load(a, int(rng.integers(0, 64)), ())
        z = tb.op(T.FADD, x, y)
        w = tb.op(T.FMUL, z, z)
        tb.store(b, int(rng.integers(0, 64)), (w,))
        tb.store(a, int(rng.integers(0, 64)), (w,))
        prev = (w,) if i % 7 == 0 else ()
    return tb.build()


@pytest.fixture(scope="module")
def pt():
    return prepare_trace(_build_trace())


def _cfg(kind: str) -> ScheduleConfig:
    return ScheduleConfig(mem={0: SPECS[kind], 1: SPECS["ideal"]},
                          fu_counts=dict(_FU))


def _clean(pt, kind: str):
    """A verified-clean (cfg, result, event-log) triple for one kind."""
    cfg = _cfg(kind)
    res, ev = schedule_events(pt, cfg, backend="py")
    assert verify_events(pt, cfg, res, ev) == []
    return cfg, res, ev


def _classes(pt, cfg, res, ev) -> set:
    return {v.rule for v in verify_events(pt, cfg, res, ev)}


# ----------------------------------------------------------------------
# clean logs: all kinds x all backends validate, py == C bit-exact
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kind", sorted(SPECS))
def test_clean_event_logs_validate(pt, kind):
    cfg = _cfg(kind)
    res_py, ev_py = schedule_events(pt, cfg, backend="py")
    rep = verify_result(pt, cfg, res_py, ev_py, backend="py")
    assert rep.ok, rep.violations
    assert all(res_py.cycles >= b for b in rep.bounds.values()), rep.bounds

    from repro.core.sim import _cycle_ext

    if _cycle_ext.load() is not None:
        res_c, ev_c = schedule_events(pt, cfg, backend="c")
        assert res_c == res_py
        assert ev_c == ev_py


@pytest.mark.parametrize("kind", ("hb_ntx", "remap", "banked"))
def test_jax_event_log_matches_python(pt, kind):
    cfg = _cfg(kind)
    res_py, ev_py = schedule_events(pt, cfg, backend="py")
    res_jx, ev_jx = schedule_events(pt, cfg, backend="jax")
    assert res_jx == res_py
    assert ev_jx == ev_py
    assert verify_events(pt, cfg, res_jx, ev_jx) == []


def test_schedule_check_flag_passes_and_matches(pt):
    cfg = _cfg("hb_ntx")
    assert schedule(pt, cfg, check=True) == schedule(pt, cfg)


# ----------------------------------------------------------------------
# seeded mutations: every hazard class detected AND correctly classified
# ----------------------------------------------------------------------
def test_mutation_dropped_event_is_completeness(pt):
    cfg, res, ev = _clean(pt, "ideal")
    ev.cycle[5] = -1
    assert "completeness" in _classes(pt, cfg, res, ev)


def test_mutation_issue_beyond_horizon_is_completeness(pt):
    cfg, res, ev = _clean(pt, "ideal")
    ev.cycle[5] = res.cycles + 7
    assert "completeness" in _classes(pt, cfg, res, ev)


def test_mutation_dependence_reorder_detected(pt):
    cfg, res, ev = _clean(pt, "ideal")
    # issue some consumer in the same cycle as its producer: no
    # producer latency is zero, so this always breaks the dataflow
    counts = np.diff(pt.succ_ptr)
    src = int(np.flatnonzero(counts)[0])
    dst = int(pt.succ_idx[pt.succ_ptr[src]])
    ev.cycle[dst] = ev.cycle[src]
    assert "dependence" in _classes(pt, cfg, res, ev)


def test_mutation_fu_overissue_detected(pt):
    cfg, res, ev = _clean(pt, "ideal")
    fadd = np.flatnonzero(pt.klass_np == pt.n_arrays
                          + FU_ORDER.index("fadd"))[:3]
    c = int(ev.cycle[fadd].max())
    ev.cycle[fadd] = c          # 3 fadds in one cycle vs a budget of 2
    ev.slot[fadd] = [0, 1, 2]
    assert "fu_budget" in _classes(pt, cfg, res, ev)


def test_mutation_duplicate_slot_is_slot_collision(pt):
    cfg, res, ev = _clean(pt, "ideal")
    mem = np.flatnonzero((pt.klass_np == 0) & (ev.slot >= 1))
    node = int(mem[0])
    ev.slot[node] = 0           # collides with that cycle's slot 0
    assert "slot_collision" in _classes(pt, cfg, res, ev)


def test_mutation_banked_wrong_bank_is_bank_conflict(pt):
    cfg, res, ev = _clean(pt, "banked")
    node = int(np.flatnonzero((pt.klass_np == 0)
                              & pt.is_load_np.astype(bool))[0])
    ev.resource[node] = (ev.resource[node] + 1) % SPECS["banked"].n_banks
    assert "bank_conflict" in _classes(pt, cfg, res, ev)


def test_mutation_multipump_slot_overflow_is_slot_budget(pt):
    cfg, res, ev = _clean(pt, "multipump")
    acc = np.flatnonzero(pt.klass_np == 0)[:5]
    c = int(ev.cycle[acc].max())
    ev.cycle[acc] = c           # 5 pumped accesses vs 2x2 slots
    ev.slot[acc] = np.arange(5)
    assert "slot_budget" in _classes(pt, cfg, res, ev)


def test_mutation_ntx_wrong_leaf_port_is_parity_fanout(pt):
    cfg, res, ev = _clean(pt, "h_ntx_rd")
    direct = np.flatnonzero((pt.klass_np == 0)
                            & pt.is_load_np.astype(bool)
                            & (ev.path == PATH_DIRECT))
    node = int(direct[0])
    ev.resource[node] += 1      # claims a leaf that is not its direct path
    assert "parity_fanout" in _classes(pt, cfg, res, ev)


def test_mutation_ntx_duplicate_leaf_claim_is_parity_fanout(pt):
    cfg, res, ev = _clean(pt, "h_ntx_rd")
    direct = np.flatnonzero((pt.klass_np == 0)
                            & pt.is_load_np.astype(bool)
                            & (ev.path == PATH_DIRECT))
    # two direct reads of the same word forced into the same cycle:
    # they would need the same leaf port twice
    words = pt.word_index_np[direct] % 64
    uniq, inv, cnt = np.unique(words, return_inverse=True,
                               return_counts=True)
    grp = int(np.flatnonzero(cnt[inv] > 1)[0])
    pair = direct[inv == inv[grp]][:2]
    ev.cycle[pair[1]] = ev.cycle[pair[0]]
    assert "parity_fanout" in _classes(pt, cfg, res, ev)


def test_mutation_double_pair_rmw_is_write_pair(pt):
    cfg, res, ev = _clean(pt, "hb_ntx")
    pairs = np.flatnonzero(ev.path == PATH_PAIR_RMW)
    assert pairs.size, "trace exercises the write-pair path"
    other = np.flatnonzero((pt.klass_np == 0)
                           & ~pt.is_load_np.astype(bool)
                           & (ev.path != PATH_PAIR_RMW))
    node = int(other[0])
    ev.path[node] = PATH_PAIR_RMW       # second RMW flow in that cycle
    ev.cycle[node] = ev.cycle[int(pairs[0])]
    assert "write_pair" in _classes(pt, cfg, res, ev)


def test_mutation_lvt_plain_write_is_path_kind(pt):
    cfg, res, ev = _clean(pt, "lvt")
    node = int(np.flatnonzero(ev.path == PATH_BROADCAST)[0])
    ev.path[node] = PATH_DIRECT     # LVT write must replicate to banks
    assert "path_kind" in _classes(pt, cfg, res, ev)


def test_mutation_remap_missteered_write_is_steering(pt):
    cfg, res, ev = _clean(pt, "remap")
    node = int(np.flatnonzero(ev.path == PATH_STEERED)[0])
    nb = SPECS["remap"].n_write + 1
    ev.resource[node] = (ev.resource[node] + 1) % nb
    assert "steering" in _classes(pt, cfg, res, ev)


def test_mutation_remap_wrong_read_bank_is_bank_conflict(pt):
    cfg, res, ev = _clean(pt, "remap")
    node = int(np.flatnonzero((pt.klass_np == 0)
                              & pt.is_load_np.astype(bool))[0])
    nb = SPECS["remap"].n_write + 1
    ev.resource[node] = (ev.resource[node] + 1) % nb
    assert "bank_conflict" in _classes(pt, cfg, res, ev)


def test_mutation_corrupt_counter_detected(pt):
    cfg, res, ev = _clean(pt, "ideal")
    res2 = dataclasses.replace(res, issued=res.issued + 1)
    assert "counter" in _classes(pt, cfg, res2, ev)


def test_mutation_cycles_below_bound_is_static_bound(pt):
    cfg, res, ev = _clean(pt, "ideal")
    res2 = dataclasses.replace(res, cycles=1)
    rep = verify_result(pt, cfg, res2, ev, backend="py")
    assert "static_bound" in {v.rule for v in rep.violations}
    with pytest.raises(LegalityError):
        rep.raise_if_failed()


def test_all_emitted_rules_are_in_the_vocabulary(pt):
    """Every mutation above classified into the declared rule set."""
    assert set(STALL_KEYS) < set(RULE_CLASSES)


# ----------------------------------------------------------------------
# golden matrix: zero violations + sound-and-somewhere-tight bounds
# ----------------------------------------------------------------------
from test_golden_schedule import GOLDEN, _config  # noqa: E402

_BY_BENCH: dict = {}
for _g in GOLDEN:
    _BY_BENCH.setdefault(_g["bench"], []).append(_g)


@pytest.mark.parametrize(
    "g", GOLDEN[::6], ids=[f"{g['bench']}-{g['design']}-u{g['unroll']}"
                           for g in GOLDEN[::6]])
def test_golden_rows_check_clean(g):
    from repro.core.bench import get_trace

    gpt = prepare_trace(get_trace(g["bench"]))
    cfg = _config(gpt, g["design"], g["unroll"])
    rep = check_schedule(gpt, cfg)
    assert rep.ok, rep.violations
    assert rep.result.cycles == g["cycles"]


def test_static_bounds_sound_on_all_golden_rows_and_tight_somewhere():
    from repro.core.bench import get_trace

    tight = 0
    for bench, rows in sorted(_BY_BENCH.items()):
        gpt = prepare_trace(get_trace(bench))
        for g in rows:
            cfg = _config(gpt, g["design"], g["unroll"])
            bounds = static_bounds(gpt, cfg)
            for kind, b in bounds.items():
                assert b <= g["cycles"], (
                    f"{bench}/{g['design']}@u{g['unroll']}: {kind} bound "
                    f"{b} exceeds measured {g['cycles']}")
            tight += any(b == g["cycles"] for b in bounds.values())
    assert tight > 0, "no certificate is ever tight — they bind nothing"


def test_jax_batched_events_check_clean_per_bench():
    from repro.core.bench import get_trace
    from repro.core.sim.jax_cycle import schedule_batched

    bench = "gemm_ncubed"
    rows = _BY_BENCH[bench]
    gpt = prepare_trace(get_trace(bench))
    cfgs = [_config(gpt, g["design"], g["unroll"]) for g in rows]
    results, events = schedule_batched(gpt, cfgs, collect_events=True)
    for g, cfg, res, ev in zip(rows, cfgs, results, events):
        assert res.cycles == g["cycles"]
        rep = verify_result(gpt, cfg, res, ev, backend="jax")
        assert rep.ok, (g["design"], g["unroll"], rep.violations)


def test_conformance_corpus_replays_clean_through_checker():
    """Any committed differential-fuzz counterexample must also pass
    the independent checker on every backend."""
    fail_dir = pathlib.Path(__file__).parent / "conformance_failures"
    files = sorted(fail_dir.glob("repro_*.json")) if fail_dir.exists() \
        else []
    if not files:
        pytest.skip("no serialized counterexamples")
    from test_conformance import build_case

    for f in files:
        tr, cfg = build_case(json.loads(f.read_text()))
        cpt = prepare_trace(tr)
        for be in ("py", "auto"):
            rep = check_schedule(cpt, cfg, backend=be)
            assert rep.ok, (f.name, be, rep.violations)
