"""Roofline HLO-parser unit tests on synthetic HLO text."""
from repro.launch.roofline import (HloAnalysis, RooflineReport, analyze_hlo,
                                   model_flops)

_SYNTH = """\
HloModule test

%loop_body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  %a = f32[4,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} parameter(1)
  %d = f32[4,8]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,8]{1,0} all-reduce(%d), channel_id=1, replica_groups=[2,2]<=[4]
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4,8]) tuple(%i, %ar)
}

%loop_cond (q: (s32[], f32[4,8])) -> pred[] {
  %q = (s32[], f32[4,8]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}

ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8]{1,0} parameter(0)
  %init = (s32[], f32[4,8]) tuple(%x, %x)
  %wh = (s32[], f32[4,8]) while(%init), condition=%loop_cond, body=%loop_body, backend_config={"known_trip_count":{"n":"3"},"known_init_step":{"init":"0","step":"1"}}
  %ag = f32[8,8]{1,0} all-gather(%x), channel_id=2, replica_groups=[2,2]<=[4]
  ROOT %out = f32[4,8]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_trip_count_multiplies_loop_body():
    r = analyze_hlo(_SYNTH, f32_as_bf16=False)
    # dot: 2 * (4*8) * 8 = 512 flops, x3 trips
    assert r["flops"] == 3 * 512
    # all-reduce payload 4*8*4B = 128B x ring factor 2 x 3 trips,
    # plus the one-shot all-gather 8*8*4 = 256B x 1
    assert r["collectives"]["all-reduce"] == 3 * 2 * 128
    assert r["collectives"]["all-gather"] == 256


def test_f32_as_bf16_halves_payloads():
    r = analyze_hlo(_SYNTH, f32_as_bf16=True)
    assert r["collectives"]["all-reduce"] == 3 * 2 * 64


def test_report_terms_and_dominant():
    rep = RooflineReport(
        arch="a", shape="s", mesh="m", chips=256,
        flops_per_device=197e12,          # exactly 1 s of compute
        hbm_bytes_per_device=819e9 / 2,   # 0.5 s memory
        collective_bytes_per_device=50e9 * 2,  # 2 s collective
        collectives={}, model_flops_global=197e12 * 256 * 0.5)
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert rep.dominant == "collective"
    assert abs(rep.step_s - 2.0) < 1e-9
    assert abs(rep.mfu - 0.25) < 1e-9
    assert abs(rep.useful_flops_ratio - 0.5) < 1e-9


def test_model_flops():
    assert model_flops(1e9, 1000, "train") == 6e12
    assert model_flops(1e9, 128, "decode") == 2 * 1e9 * 128
    assert model_flops(10e9, 128, "decode", active_params=int(3e9)) \
        == 2 * 3e9 * 128
