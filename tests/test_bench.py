"""Benchmark implementations vs references + trace sanity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bench import (BENCHMARKS, aes, fft_strided, gemm_ncubed, kmp,
                              md_knn, sort_merge, stencil2d)
from repro.core.locality import trace_locality


def test_fft_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(128) + 1j * rng.standard_normal(128)
    got = np.asarray(fft_strided.spectrum(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x), rtol=1e-4, atol=1e-4)


def test_aes_fips197_vector():
    key = np.arange(16, dtype=np.uint8)
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8)
    want = bytes.fromhex("69c4e0d86a7b0430d8cdb78070b4c55a")
    assert aes.encrypt_np(pt[None], key)[0].tobytes() == want
    assert np.asarray(aes.run_jax(jnp.asarray(pt[None]), key))[0].tobytes() \
        == want


def test_kmp_jax_matches_np():
    p = kmp.Params(n=1500, seed=3)
    text = kmp.make_text(p)
    assert kmp.run_np(text) == int(kmp.run_jax(jnp.asarray(text)))
    assert kmp.run_np(text) > 0


def test_md_knn_forces_finite_and_symmetric_scale():
    inp = md_knn.make_inputs(md_knn.Params(n_atoms=32))
    f = md_knn.run_jax(jnp.asarray(inp["position"]),
                       jnp.asarray(inp["neighbor_list"]))
    assert bool(jnp.isfinite(f).all())
    assert f.shape == (32, 3)


def test_stencil_matches_manual():
    inp = stencil2d.make_inputs(stencil2d.TINY)
    got = np.asarray(stencil2d.run_jax(jnp.asarray(inp["orig"]),
                                       jnp.asarray(inp["filter"])))
    o, f = inp["orig"], inp["filter"]
    r, c = o.shape
    want = np.zeros((r - 2, c - 2), np.float32)
    for i in range(r - 2):
        for j in range(c - 2):
            want[i, j] = float((o[i:i + 3, j:j + 3] * f).sum())
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_sort_trace_runs_and_jax_sorts():
    x = sort_merge.make_input(sort_merge.TINY)
    got = np.asarray(sort_merge.run_jax(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x))


def test_gemm():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((8, 8))
    b = rng.standard_normal((8, 8))
    np.testing.assert_allclose(
        np.asarray(gemm_ncubed.run_jax(jnp.asarray(a), jnp.asarray(b))),
        a @ b, rtol=1e-5)


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_traces_are_wellformed(name):
    mod = BENCHMARKS[name]
    tr = mod.gen_trace(mod.TINY)
    assert tr.n_nodes > 50
    assert tr.n_mem > 10
    # topological: every dep references an earlier node
    assert (tr.pred_idx < np.repeat(
        np.arange(tr.n_nodes), np.diff(tr.pred_ptr))).all()
    m = tr.mem_mask()
    assert (tr.addrs[m] >= 0).all()


def test_locality_ordering_matches_paper():
    """Paper Fig 5: byte-oriented KMP/AES high; FFT/GEMM/MD-KNN low."""
    L = {}
    for name in ("kmp", "aes", "fft_strided", "gemm_ncubed", "md_knn"):
        mod = BENCHMARKS[name]
        tr = mod.gen_trace(mod.TINY)
        addrs, aids = tr.mem_addrs_and_arrays()
        L[name] = trace_locality(addrs, aids)
    assert L["kmp"] > 0.3 and L["aes"] > 0.3
    for low in ("fft_strided", "gemm_ncubed", "md_knn"):
        assert L[low] < 0.3, (low, L[low])
        assert L[low] < L["kmp"]
