"""Benchmark implementations vs references + trace sanity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bench import (BENCHMARKS, aes, bfs_queue, fft_strided,
                              gemm_ncubed, kmp, md_knn, nw, radix_sort,
                              sort_merge, spmv_crs, stencil2d, viterbi)
from repro.core.locality import trace_locality


def test_fft_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(128) + 1j * rng.standard_normal(128)
    got = np.asarray(fft_strided.spectrum(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x), rtol=1e-4, atol=1e-4)


def test_aes_fips197_vector():
    key = np.arange(16, dtype=np.uint8)
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8)
    want = bytes.fromhex("69c4e0d86a7b0430d8cdb78070b4c55a")
    assert aes.encrypt_np(pt[None], key)[0].tobytes() == want
    assert np.asarray(aes.run_jax(jnp.asarray(pt[None]), key))[0].tobytes() \
        == want


def test_kmp_jax_matches_np():
    p = kmp.Params(n=1500, seed=3)
    text = kmp.make_text(p)
    assert kmp.run_np(text) == int(kmp.run_jax(jnp.asarray(text)))
    assert kmp.run_np(text) > 0


def test_md_knn_forces_finite_and_symmetric_scale():
    inp = md_knn.make_inputs(md_knn.Params(n_atoms=32))
    f = md_knn.run_jax(jnp.asarray(inp["position"]),
                       jnp.asarray(inp["neighbor_list"]))
    assert bool(jnp.isfinite(f).all())
    assert f.shape == (32, 3)


def test_stencil_matches_manual():
    inp = stencil2d.make_inputs(stencil2d.TINY)
    got = np.asarray(stencil2d.run_jax(jnp.asarray(inp["orig"]),
                                       jnp.asarray(inp["filter"])))
    o, f = inp["orig"], inp["filter"]
    r, c = o.shape
    want = np.zeros((r - 2, c - 2), np.float32)
    for i in range(r - 2):
        for j in range(c - 2):
            want[i, j] = float((o[i:i + 3, j:j + 3] * f).sum())
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_sort_trace_runs_and_jax_sorts():
    x = sort_merge.make_input(sort_merge.TINY)
    got = np.asarray(sort_merge.run_jax(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x))


def test_gemm():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((8, 8))
    b = rng.standard_normal((8, 8))
    np.testing.assert_allclose(
        np.asarray(gemm_ncubed.run_jax(jnp.asarray(a), jnp.asarray(b))),
        a @ b, rtol=1e-5)


# ----------------------------------------------------------------------
# irregular / low-spatial-locality suite (Fig-5 expansion)
# ----------------------------------------------------------------------
def test_spmv_jax_matches_np():
    inp = spmv_crs.make_inputs(spmv_crs.TINY)
    got = np.asarray(spmv_crs.run_jax(
        jnp.asarray(inp["vals"]), jnp.asarray(inp["cols"]),
        inp["row_ptr"], jnp.asarray(inp["vec"])))
    want = spmv_crs.run_np(inp["vals"], inp["cols"], inp["row_ptr"],
                           inp["vec"])
    # jax accumulates in float32 when x64 is disabled: same headroom as
    # test_gemm, not the float64 tolerance
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_bfs_jax_matches_np_queue_traversal():
    p = bfs_queue.TINY
    inp = bfs_queue.make_inputs(p)
    got = np.asarray(bfs_queue.run_jax(inp["edge_ptr"],
                                       jnp.asarray(inp["edges"]),
                                       p.n_nodes))
    want = bfs_queue.run_np(inp["edge_ptr"], inp["edges"], p.n_nodes)
    np.testing.assert_array_equal(got, want)
    # the random digraph must actually be traversed, not degenerate
    assert 2 < int((want < p.n_nodes).sum()) <= p.n_nodes
    assert int(want[want < p.n_nodes].max()) >= 2        # >= 3 BFS levels


def test_nw_jax_matches_np():
    inp = nw.make_inputs(nw.TINY)
    mj, pj = nw.run_jax(jnp.asarray(inp["seq_a"]), jnp.asarray(inp["seq_b"]))
    mn, pn = nw.run_np(inp["seq_a"], inp["seq_b"])
    np.testing.assert_array_equal(np.asarray(mj), mn)
    np.testing.assert_array_equal(np.asarray(pj), pn)


def test_viterbi_jax_matches_np():
    inp = viterbi.make_inputs(viterbi.TINY)
    got = np.asarray(viterbi.run_jax(
        jnp.asarray(inp["obs"]), jnp.asarray(inp["init"]),
        jnp.asarray(inp["transition"]), jnp.asarray(inp["emission"])))
    want = viterbi.run_np(inp["obs"], inp["init"], inp["transition"],
                          inp["emission"])
    np.testing.assert_array_equal(got, want)


def test_radix_jax_matches_np_and_sorts():
    p = radix_sort.TINY
    a = radix_sort.make_input(p)
    got = np.asarray(radix_sort.run_jax(jnp.asarray(a), p.value_bits))
    want = radix_sort.run_np(a, p.value_bits)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(want, np.sort(a))


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_trace_generation_is_deterministic(name):
    """Same params -> bit-identical trace (and therefore one fingerprint,
    the key of the DSE result cache)."""
    from repro.core.sim.prepared import trace_fingerprint

    mod = BENCHMARKS[name]
    t1 = mod.gen_trace(mod.TINY)
    t2 = mod.gen_trace(mod.TINY)
    assert trace_fingerprint(t1) == trace_fingerprint(t2)


@pytest.mark.parametrize("name",
                         ("spmv_crs", "bfs_queue", "nw", "viterbi",
                          "radix_sort", "kv_decode", "paged_kv",
                          "moe_route"))
def test_trace_disk_cache_round_trip(name, tmp_path, monkeypatch):
    """get_trace's on-disk npz cache must reload the new traces exactly
    (array contents, names and word sizes)."""
    import repro.core.bench as B
    from repro.core.sim.prepared import trace_fingerprint

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_NO_TRACE_CACHE", raising=False)
    monkeypatch.setattr(B, "_TRACE_MEMO", {})
    fresh = B.get_trace(name)                  # generates + writes npz
    monkeypatch.setattr(B, "_TRACE_MEMO", {})
    cached = B.get_trace(name)                 # must come back from disk
    assert cached is not fresh
    assert trace_fingerprint(cached) == trace_fingerprint(fresh)
    assert cached.word_bytes == fresh.word_bytes
    assert cached.array_names == fresh.array_names


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_traces_are_wellformed(name):
    mod = BENCHMARKS[name]
    tr = mod.gen_trace(mod.TINY)
    assert tr.n_nodes > 50
    assert tr.n_mem > 10
    # topological: every dep references an earlier node
    assert (tr.pred_idx < np.repeat(
        np.arange(tr.n_nodes), np.diff(tr.pred_ptr))).all()
    m = tr.mem_mask()
    assert (tr.addrs[m] >= 0).all()


def test_locality_ordering_matches_paper():
    """Paper Fig 5: byte-oriented KMP/AES high; FFT/GEMM/MD-KNN low."""
    L = {}
    for name in ("kmp", "aes", "fft_strided", "gemm_ncubed", "md_knn"):
        mod = BENCHMARKS[name]
        tr = mod.gen_trace(mod.TINY)
        addrs, aids = tr.mem_addrs_and_arrays()
        L[name] = trace_locality(addrs, aids)
    assert L["kmp"] > 0.3 and L["aes"] > 0.3
    for low in ("fft_strided", "gemm_ncubed", "md_knn"):
        assert L[low] < 0.3, (low, L[low])
        assert L[low] < L["kmp"]


def test_irregular_suite_locality_ordering():
    """The new irregular kernels populate the low/mid end of the Fig-5
    locality axis: all of them score clearly below the byte-oriented
    KMP/AES pair and below stencil2d's windowed streams, and the graph
    traversal (whose node records, edge bursts and level gathers are all
    discovery-order driven) scores below even GEMM.

    spmv_crs sits *above* GEMM by design of the metric, not by accident:
    the per-array-weighted Weinberg score gives spmv's stride-one
    val/cols streams a 1/8-1/4 floor, while GEMM's B matrix is walked
    down columns at ~zero locality for a third of its accesses.
    """
    L = {}
    for name in ("kmp", "aes", "stencil2d", "gemm_ncubed",
                 "spmv_crs", "bfs_queue", "nw", "viterbi", "radix_sort"):
        mod = BENCHMARKS[name]
        tr = mod.gen_trace(mod.TINY)
        addrs, aids = tr.mem_addrs_and_arrays()
        L[name] = trace_locality(addrs, aids)
    for irregular in ("spmv_crs", "bfs_queue", "viterbi", "radix_sort"):
        assert L[irregular] < L["stencil2d"], (irregular, L)
        assert L[irregular] < L["kmp"] and L[irregular] < L["aes"]
    assert L["bfs_queue"] < L["gemm_ncubed"], L
    # NW's DP wavefront keeps a byte-oriented sequence scan: mid-spread,
    # between the streaming and the byte-oriented benchmarks
    assert L["stencil2d"] < L["nw"] < L["aes"], L
