"""PreparedTrace invariants: the vectorized one-time analysis must match
the seed's per-call Python-loop recurrences on arbitrary DAGs."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bench import get_trace
from repro.core.sim import (LOAD, STORE, Trace, TraceBuilder, prepare_trace,
                            trace_fingerprint)
from repro.core.sim import trace as T
from repro.core.sim.prepared import (dependency_depths, schedule_heights,
                                     successor_csr)


# ---- seed reference implementations (verbatim recurrences) -----------
def _ref_succ_lists(tr):
    n = tr.n_nodes
    counts = np.zeros(n, np.int64)
    np.add.at(counts, tr.pred_idx, 1)
    ptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    idx = np.empty(int(ptr[-1]), np.int64)
    fill = ptr[:-1].copy()
    for i in range(n):
        lo, hi = tr.pred_ptr[i], tr.pred_ptr[i + 1]
        for p in tr.pred_idx[lo:hi]:
            idx[fill[p]] = i
            fill[p] += 1
    return ptr, idx


def _ref_heights(tr, succ_ptr, succ_idx):
    n = tr.n_nodes
    h = np.zeros(n, np.int64)
    for i in range(n - 1, -1, -1):
        lo, hi = succ_ptr[i], succ_ptr[i + 1]
        if hi > lo:
            h[i] = h[succ_idx[lo:hi]].max() + T.LATENCY[int(tr.kinds[i])]
    return h


def _ref_depths(tr):
    n = tr.n_nodes
    depth = np.zeros(n, np.int32)
    ptr, idx = tr.pred_ptr, tr.pred_idx
    for i in range(n):
        lo, hi = ptr[i], ptr[i + 1]
        if hi > lo:
            depth[i] = depth[idx[lo:hi]].max() + 1
    return depth


def _random_trace(seed: int, n_ops: int = 120) -> Trace:
    rng = np.random.default_rng(seed)
    tb = TraceBuilder(f"rand{seed}")
    arrs = [tb.declare_array(f"a{i}", 4) for i in range(3)]
    nodes = []
    for i in range(n_ops):
        deps = tuple(int(d) for d in
                     rng.choice(i, size=min(i, int(rng.integers(0, 3))),
                                replace=False)) if i else ()
        roll = rng.random()
        if roll < 0.4:
            nodes.append(tb.load(arrs[i % 3], int(rng.integers(0, 64)), deps))
        elif roll < 0.55:
            nodes.append(tb.store(arrs[i % 3], int(rng.integers(0, 64)), deps))
        else:
            kind = int(rng.choice([T.FADD, T.FMUL, T.IADD, T.ICMP]))
            nodes.append(tb.add(kind, deps))
    return tb.build()


@pytest.mark.parametrize("seed", range(8))
def test_vectorized_analysis_matches_reference(seed):
    tr = _random_trace(seed)
    sp, si = successor_csr(tr.pred_ptr, tr.pred_idx, tr.n_nodes)
    rp, ri = _ref_succ_lists(tr)
    np.testing.assert_array_equal(sp, rp)
    np.testing.assert_array_equal(si, ri)
    np.testing.assert_array_equal(
        schedule_heights(tr.kinds, tr.pred_ptr, tr.pred_idx, sp, si),
        _ref_heights(tr, sp, si))
    np.testing.assert_array_equal(
        dependency_depths(tr.pred_ptr, tr.pred_idx, sp, si), _ref_depths(tr))


@pytest.mark.parametrize("bench", ["gemm_ncubed", "kmp", "md_knn"])
def test_prepared_fields_match_reference_on_benchmarks(bench):
    tr = get_trace(bench)
    pt = prepare_trace(tr)
    sp, si = _ref_succ_lists(tr)
    np.testing.assert_array_equal(pt.succ_ptr, sp)
    np.testing.assert_array_equal(pt.succ_idx, si)
    np.testing.assert_array_equal(pt.height, _ref_heights(tr, sp, si))
    np.testing.assert_array_equal(pt.depth, _ref_depths(tr))
    np.testing.assert_array_equal(pt.indegree,
                                  tr.pred_ptr[1:] - tr.pred_ptr[:-1])
    # trace.depths() delegates to the prepared analysis
    np.testing.assert_array_equal(tr.depths(), pt.depth)


def test_prepare_trace_is_memoized_and_idempotent():
    tr = _random_trace(99)
    pt1 = prepare_trace(tr)
    assert prepare_trace(tr) is pt1
    assert prepare_trace(pt1) is pt1


def test_array_depths_match_seed_formula():
    tr = get_trace("gemm_ncubed")
    pt = prepare_trace(tr)
    m = tr.mem_mask()
    for aid in tr.array_names:
        sel = (tr.array_ids == aid) & m
        max_idx = int(tr.addrs[sel].max()) // tr.word_bytes[aid]
        assert pt.array_depths[aid] == max(16, 1 << (max_idx + 1).bit_length())


def test_fingerprint_sensitive_to_content():
    a, b = _random_trace(1), _random_trace(2)
    assert trace_fingerprint(a) != trace_fingerprint(b)
    assert trace_fingerprint(a) == trace_fingerprint(_random_trace(1))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=2, max_size=60))
def test_chain_heights_equal_chain_latency_sums(idxs):
    """Serial load chain: height telescopes to the latency-weighted
    distance from each node to the sink."""
    tb = TraceBuilder("chain")
    a = tb.declare_array("a", 4)
    prev = tb.load(a, idxs[0])
    for ix in idxs[1:]:
        prev = tb.load(a, ix, (prev,))
    pt = prepare_trace(tb.build())
    n = len(idxs)
    want = [(n - 1 - i) * T.LATENCY[LOAD] for i in range(n)]
    np.testing.assert_array_equal(pt.height, want)
    np.testing.assert_array_equal(pt.depth, np.arange(n))
