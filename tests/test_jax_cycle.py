"""Batched JAX cycle-loop unit tests (ISSUE 5).

The broad decision-for-decision equality against the C / pure-Python
loops lives in ``tests/test_conformance.py`` (fuzz) and
``tests/test_golden_schedule.py`` (pinned matrix).  This file pins the
pieces with bespoke contracts:

* the kernel's remap write steering against the functional replay
  engine's ``_remap_step`` scan rule (PR 3 cross-validated the *python*
  arbiter; this closes the triangle for the jax engine), including the
  "no two live writes share a bank" invariant on scheduler-issued
  writes;
* the DeviceViews padding contract (pads are inert, the permutation is
  heap order);
* the error surfaces (unconfigured array, max_cycles) that the
  reference loops raise from inside the cycle loop.
"""
import numpy as np
import pytest

from repro.core.amm.spec import AMMSpec
from repro.core.sim import prepare_trace
from repro.core.sim.prepared import FU_ORDER
from repro.core.sim.scheduler import ScheduleConfig, _schedule_py
from repro.core.sim.trace import IADD, TraceBuilder


# ----------------------------------------------------------------------
# remap steering == functional replay steering
# ----------------------------------------------------------------------
def test_remap_write_step_matches_replay_scan_rule():
    from repro.core.amm import replay as rp
    from repro.core.sim.jax_cycle import remap_write_step

    spec = AMMSpec("remap", 2, 3, 64)
    nb = spec.n_write + 1
    n_cycles = 200
    rng = np.random.default_rng(23)
    wa = rng.integers(0, spec.depth, (n_cycles, spec.n_write)).astype(np.int32)
    wv = rng.integers(0, 2**32, (n_cycles, spec.n_write), dtype=np.uint32)
    wm = rng.random((n_cycles, spec.n_write)) < 0.8
    ra = np.zeros((n_cycles, spec.n_read), np.int32)

    state, res = rp.replay(spec, rp.init_flat(spec), ra, wa, wv, wm)
    live = np.zeros(spec.depth, np.int32)
    for t in range(n_cycles):
        ruse = np.zeros(nb, np.int32)
        wuse = np.zeros(nb, np.int32)
        banks_this_cycle = []
        for p in range(spec.n_write):
            if not wm[t, p]:
                continue
            ok, bank, live, ruse, wuse = remap_write_step(
                live, ruse, wuse, int(wa[t, p]), nb, ppb=2)
            assert bool(ok), (t, p)
            assert int(bank) == int(res.write_banks[t, p]), (t, p)
            banks_this_cycle.append(int(bank))
        # no two live writes share a bank within one cycle
        assert len(set(banks_this_cycle)) == len(banks_this_cycle), t
        live = np.asarray(live)
    np.testing.assert_array_equal(live, np.asarray(state["map"]))


def test_scheduler_issued_remap_writes_match_replay_final_map():
    """End-to-end: a store-burst trace whose waves issue one per cycle
    in program order.  The batched engine's final live map must equal
    the functional replay of the same write stream, pinning the
    *scheduler-issued* steering (not just the isolated step rule)."""
    from repro.core.amm import replay as rp
    from repro.core.sim.jax_cycle import schedule_batched

    spec = AMMSpec("remap", 2, 2, 64)
    n_waves, W = 40, spec.n_write
    rng = np.random.default_rng(5)
    wa = rng.integers(0, spec.depth, (n_waves, W)).astype(np.int32)

    tb = TraceBuilder("remap_waves")
    aid = tb.declare_array("a", 4)
    prev = [()] * W
    for t in range(n_waves):
        prev = [(tb.store(aid, int(wa[t, p]), prev[p]),) for p in range(W)]
    pt = prepare_trace(tb.build())

    cfg = ScheduleConfig(mem={aid: spec}, fu_counts={})
    results, maps = schedule_batched(pt, [cfg], return_maps=True)
    assert results[0] == _schedule_py(pt, cfg)
    # every wave issues in full: W writes/cycle always steer in nb=W+1
    assert results[0].mem_issued == n_waves * W
    assert results[0].bank_conflict_stalls == 0

    wv = np.zeros((n_waves, W), np.uint32)
    wm = np.ones((n_waves, W), bool)
    ra = np.zeros((n_waves, spec.n_read), np.int32)
    state, res = rp.replay(spec, rp.init_flat(spec), ra, wa, wv, wm)
    np.testing.assert_array_equal(maps[0, 0, :spec.depth],
                                  np.asarray(state["map"]))
    # scheduler-issued writes never share a bank within a cycle
    banks = np.asarray(res.write_banks)
    assert all(len(set(row.tolist())) == W for row in banks)


# ----------------------------------------------------------------------
# DeviceViews padding contract
# ----------------------------------------------------------------------
def test_device_views_padding_and_heap_order():
    tb = TraceBuilder("dv")
    a = tb.declare_array("a", 4)
    n0 = tb.load(a, 0)
    n1 = tb.load(a, 5, (n0,))
    n2 = tb.op(IADD, n1)
    tb.store(a, 1, (n2,))
    pt = prepare_trace(tb.build())
    dv = pt.device_views()

    assert dv.n_pad >= pt.n_nodes and dv.n_pad & (dv.n_pad - 1) == 0
    assert dv.a_pad >= pt.n_arrays
    # pad nodes gate on themselves: never ready
    for i in range(dv.n_real, dv.n_pad):
        assert dv.preds_pad[i, 0] == i
    # perm is a permutation; class segments ordered arrays -> FU -> pads
    assert sorted(dv.perm.tolist()) == list(range(dv.n_pad))
    assert (np.diff(dv.gid_perm) >= 0).all()
    # within a class, perm is heap-pop order: height desc, node id asc
    mem_slice = dv.perm[dv.seg_start[0]:dv.seg_start[1]]
    heights = pt.height[mem_slice]
    keys = [(-int(h), int(n)) for h, n in zip(heights, mem_slice)]
    assert keys == sorted(keys)
    # FU segment budgets line up with FU_ORDER ids
    assert dv.seg_start.shape == (dv.a_pad + len(FU_ORDER) + 1,)


# ----------------------------------------------------------------------
# error surfaces match the reference loops
# ----------------------------------------------------------------------
def test_jax_unconfigured_array_raises_keyerror():
    from repro.core.sim.jax_cycle import schedule_jax

    tb = TraceBuilder("nospec")
    a = tb.declare_array("a", 4)
    b = tb.declare_array("b", 4)
    tb.load(a, 0)
    tb.load(b, 0)
    pt = prepare_trace(tb.build())
    cfg = ScheduleConfig(mem={a: AMMSpec("ideal", 2, 2, 64)}, fu_counts={})
    with pytest.raises(KeyError):
        schedule_jax(pt, cfg)
    with pytest.raises(KeyError):
        _schedule_py(pt, cfg)


def test_jax_max_cycles_raises_runtimeerror():
    from repro.core.sim.jax_cycle import schedule_jax

    tb = TraceBuilder("longchain")
    a = tb.declare_array("a", 4)
    prev = ()
    for i in range(64):
        prev = (tb.load(a, i % 16, prev),)
    pt = prepare_trace(tb.build())
    cfg = ScheduleConfig(mem={a: AMMSpec("ideal", 1, 1, 64)}, fu_counts={},
                         max_cycles=5)
    with pytest.raises(RuntimeError, match="exceeded"):
        schedule_jax(pt, cfg)
    with pytest.raises(RuntimeError, match="exceeded"):
        _schedule_py(pt, cfg)
