"""Arbitration-layer cross-validation (ISSUE 3 tentpole).

Three pins:
  1. the arbiter's leaf-path tables are identical to the functional
     replay engine's ``h_tables`` (same geometry -> same hardware);
  2. the arbiter's per-cycle decisions reproduce the functional models'
     observed behavior on shared address traces — remap bank steering
     equals ``replay`` write_banks / final map, and B/HB write-pair RMW
     activations equal the models' conflict condition;
  3. the compiled C cycle loop and the pure-Python loop agree on the
     full ``ScheduleResult`` for every design kind (the goldens pin
     ideal/banked against the seed; this pins the new kinds against
     each other).
"""
import numpy as np
import pytest

from repro.core.amm.spec import AMMSpec
from repro.core.bench import get_trace
from repro.core.dse.sweep import _BASE_FU, DesignPoint, _spec_for
from repro.core.sim import prepare_trace
from repro.core.sim.arbiter import (PortArbiter, compile_spec, ntx_tables)
from repro.core.sim.scheduler import (ScheduleConfig, _schedule_c,
                                      _schedule_py, schedule)
from repro.core.sim import _cycle_ext
from repro.core.sim.trace import TraceBuilder


def _arb(spec: AMMSpec, ports_per_bank: int = 2) -> PortArbiter:
    return PortArbiter(compile_spec(spec, ports_per_bank), ports_per_bank)


# ----------------------------------------------------------------------
# 1. path tables == functional replay tables
# ----------------------------------------------------------------------
@pytest.mark.parametrize("depth,levels", [(64, 0), (64, 1), (64, 2),
                                          (256, 3), (96, 1)])
def test_ntx_tables_match_replay_htables(depth, levels):
    from repro.core.amm.replay import h_tables

    direct, offset, parity = ntx_tables(depth, levels)
    tb = h_tables(depth, levels)
    np.testing.assert_array_equal(direct, tb.direct.astype(np.int64))
    np.testing.assert_array_equal(offset, tb.offset.astype(np.int64))
    np.testing.assert_array_equal(parity, tb.parity_paths.astype(np.int64))


# ----------------------------------------------------------------------
# 2. descriptor compilation
# ----------------------------------------------------------------------
def test_descriptor_per_kind_fields():
    d = compile_spec(AMMSpec("multipump", 2, 2, 64))
    assert (d.rd, d.wr, d.clock_ratio, d.slots) == (2, 2, 2, 4)

    d = compile_spec(AMMSpec("lvt", 4, 2, 64))
    assert d.write_broadcast == 4 and d.slots == 6

    d = compile_spec(AMMSpec("remap", 4, 2, 64))
    assert d.n_banks == 3                      # n_write + 1 steering banks

    d = compile_spec(AMMSpec("hb_ntx", 4, 2, 64))
    assert (d.levels, d.n_leaves, d.half, d.tree_depth) == (2, 9, 32, 32)

    d = compile_spec(AMMSpec("h_ntx_rd", 4, 1, 64, n_banks=4))
    assert (d.levels, d.n_leaves, d.tree_depth, d.sub) == (2, 9, 64, 4)

    # seed max_failed formula must survive for the golden-pinned kinds
    d = compile_spec(AMMSpec("banked", 8, 8, 256, n_banks=8), 2)
    assert d.max_failed == 4 * 8 * 2 + 8
    d = compile_spec(AMMSpec("ideal", 2, 2, 64), 2)
    assert d.max_failed == 4 * 1 * 2 + 8


# ----------------------------------------------------------------------
# 3. remap steering == functional replay steering
# ----------------------------------------------------------------------
def test_remap_steering_matches_replay():
    from repro.core.amm import replay as rp

    spec = AMMSpec("remap", 2, 2, 64)
    n_cycles, n_write = 300, spec.n_write
    rng = np.random.default_rng(7)
    wa = rng.integers(0, spec.depth, (n_cycles, n_write)).astype(np.int32)
    wv = rng.integers(0, 2**32, (n_cycles, n_write), dtype=np.uint32)
    wm = np.ones((n_cycles, n_write), bool)
    ra = np.zeros((n_cycles, spec.n_read), np.int32)

    state, res = rp.replay(spec, rp.init_flat(spec), ra, wa, wv, wm)
    arb = _arb(spec)
    for t in range(n_cycles):
        arb.begin_cycle()
        for p in range(n_write):
            bank = arb.write(int(wa[t, p]))
            assert bank is not None, (t, p)
            assert bank == int(res.write_banks[t, p]), (t, p)
    np.testing.assert_array_equal(np.asarray(arb.map),
                                  np.asarray(state["map"]))


def test_remap_no_two_writes_share_a_bank():
    spec = AMMSpec("remap", 2, 3, 64)
    arb = _arb(spec)
    rng = np.random.default_rng(3)
    for _ in range(200):
        arb.begin_cycle()
        banks = [arb.write(int(a)) for a in rng.integers(0, 64, 3)]
        assert None not in banks
        assert len(set(banks)) == 3            # steering keeps banks disjoint


def test_remap_reads_serialize_on_live_bank():
    """All words start live in bank 0: a 4R config only gets
    ports_per_bank reads per cycle until writes spread the map."""
    tb = TraceBuilder("remap_reads")
    a = tb.declare_array("a", 4)
    for i in range(16):
        tb.load(a, i)
    tr = tb.build()
    res = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("remap", 4, 2, 64)}, fu_counts={}))
    assert res.cycles >= 8                     # 2 ports on the live bank
    assert res.bank_conflict_stalls > 0
    ideal = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("ideal", 4, 2, 64)}, fu_counts={}))
    assert ideal.cycles < res.cycles


# ----------------------------------------------------------------------
# 4. write pairing == functional conflict condition
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kind,n_read", [("b_ntx_wr", 1), ("hb_ntx", 4)])
def test_write_pair_rmws_match_functional_conflicts(kind, n_read):
    spec = AMMSpec(kind, n_read, 2, 64)
    half = spec.depth // 2
    rng = np.random.default_rng(11)
    wa = rng.integers(0, spec.depth, (400, 2))
    arb = _arb(spec)
    for t in range(wa.shape[0]):
        arb.begin_cycle()
        assert arb.write(int(wa[t, 0])) is not None
        assert arb.write(int(wa[t, 1])) is not None  # pairs never stall
    # the models' conflict condition: both writes land in the same half
    expected = int(np.sum((wa[:, 0] >= half) == (wa[:, 1] >= half)))
    assert arb.write_pair_rmws == expected


def test_pair_rmw_blocked_by_ref_read():
    """The Ref re-pointing flow reads the other bank + Ref; a datapath
    read holding the Ref read port this cycle stalls the pair."""
    spec = AMMSpec("b_ntx_wr", 1, 2, 64)
    arb = _arb(spec)
    arb.begin_cycle()
    assert arb.read(3)                         # half 0: uses s0 + ref ports
    assert arb.write(5) == 0                   # plain write, half 0
    assert arb.write(9) is None                # pair needs ref read: busy
    arb.begin_cycle()
    assert arb.write(5) == 0
    assert arb.write(9) == 0                   # no read -> re-point succeeds
    assert arb.write_pair_rmws == 1


# ----------------------------------------------------------------------
# 5. parity-path fan-out
# ----------------------------------------------------------------------
def test_h_ntx_parity_fanout_and_stall():
    spec = AMMSpec("h_ntx_rd", 2, 1, 64)       # k=1: 3 leaves
    arb = _arb(spec)
    arb.begin_cycle()
    assert arb.read(0)                         # direct leaf b0
    assert arb.read(1)                         # same leaf -> parity {b1,ref}
    assert arb.parity_path_reads == 1
    assert not arb.read(2)                     # direct & parity both busy
    arb.begin_cycle()
    assert arb.read(0) and arb.read(40)        # different leaves: both direct
    assert arb.parity_path_reads == 1          # unchanged


def test_sub_banking_relaxes_leaf_conflicts():
    plain = AMMSpec("h_ntx_rd", 2, 1, 64)
    sub = AMMSpec("h_ntx_rd", 2, 1, 64, n_banks=4)
    a_plain, a_sub = _arb(plain), _arb(sub)
    a_plain.begin_cycle()
    a_sub.begin_cycle()
    # addresses 0 and 1 share the direct leaf but not the sub-bank
    assert a_plain.read(0) and a_plain.read(1)
    assert a_plain.parity_path_reads == 1      # served via parity fan-out
    assert a_sub.read(0) and a_sub.read(1)
    assert a_sub.parity_path_reads == 0        # both direct


def test_hb_sub_banking_reduces_parity_stalls_in_schedule():
    pt = prepare_trace(get_trace("gemm_ncubed"))

    def run(dp):
        specs = {aid: _spec_for(dp, pt.array_depths[aid],
                                pt.trace.word_bytes[aid] * 8)
                 for aid in pt.trace.array_names}
        return schedule(pt, ScheduleConfig(
            mem=specs, fu_counts={k: v * 4 for k, v in _BASE_FU.items()}))

    plain = run(DesignPoint("hb_ntx", 4, 2))
    banked = run(DesignPoint("hb_ntx", 4, 2, n_banks=4))
    assert banked.parity_fanout_stalls < plain.parity_fanout_stalls
    assert banked.cycles <= plain.cycles


# ----------------------------------------------------------------------
# 6. multipump pumped-slot semantics
# ----------------------------------------------------------------------
def test_multipump_delivers_advertised_ports_only():
    tb = TraceBuilder("mp")
    a = tb.declare_array("a", 4)
    for i in range(16):
        tb.load(a, i)
    tr = tb.build()
    mp = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("multipump", 2, 2, 64)}, fu_counts={},
        mem_latency=1))
    ideal = schedule(tr, ScheduleConfig(
        mem={0: AMMSpec("ideal", 2, 2, 64)}, fu_counts={}, mem_latency=1))
    assert mp.cycles == ideal.cycles           # 2R2W, not the seed's 4R4W
    assert mp.cycles >= 8                      # 16 loads / 2 read ports


# ----------------------------------------------------------------------
# 7. C and Python cycle loops agree on every kind
# ----------------------------------------------------------------------
_ALL_KINDS = (
    DesignPoint("ideal", 2, 2),
    DesignPoint("banked", 1, 1, 8),
    DesignPoint("multipump", 2, 2),
    DesignPoint("h_ntx_rd", 4, 1),
    DesignPoint("h_ntx_rd", 4, 1, n_banks=4),
    DesignPoint("b_ntx_wr", 1, 2),
    DesignPoint("hb_ntx", 2, 2),
    DesignPoint("hb_ntx", 4, 2, n_banks=4),
    DesignPoint("lvt", 4, 2),
    DesignPoint("remap", 4, 2),
)


@pytest.mark.parametrize("bench", ["gemm_ncubed", "md_knn"])
def test_c_and_python_loops_agree_on_all_kinds(bench):
    fast = _cycle_ext.load()
    if fast is None:
        pytest.skip("no C compiler available; python loop is the only path")
    pt = prepare_trace(get_trace(bench))
    for dp in _ALL_KINDS:
        specs = {aid: _spec_for(dp, pt.array_depths[aid],
                                pt.trace.word_bytes[aid] * 8)
                 for aid in pt.trace.array_names}
        for unroll in (1, 4):
            cfg = ScheduleConfig(
                mem=specs,
                fu_counts={k: v * unroll for k, v in _BASE_FU.items()})
            assert _schedule_c(fast, pt, cfg) == _schedule_py(pt, cfg), \
                (bench, dp.label, unroll)


def test_schedule_is_deterministic_across_paths():
    """Public schedule() (whatever path it picks) equals the reference."""
    pt = prepare_trace(get_trace("stencil2d"))
    dp = DesignPoint("remap", 2, 2)
    specs = {aid: _spec_for(dp, pt.array_depths[aid],
                            pt.trace.word_bytes[aid] * 8)
             for aid in pt.trace.array_names}
    cfg = ScheduleConfig(mem=specs, fu_counts=_BASE_FU)
    assert schedule(pt, cfg) == _schedule_py(pt, cfg)
