"""AdamW with global-norm clipping, decoupled weight decay and
configurable moment dtypes (bf16 moments for the >=100B 'lean' presets
so optimizer state fits HBM — see DESIGN.md §4).

Functional: ``init -> state``, ``update(grads, state, params) ->
(new_params, new_state, stats)``.  The state pytree mirrors the param
pytree, so the launcher shards it with the same PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.common import DTypePolicy, Params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params: Params, policy: DTypePolicy | None = None) -> Params:
    policy = policy or DTypePolicy.standard()
    zeros = lambda p: jnp.zeros_like(p, dtype=policy.moments)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _decayable(path: tuple) -> bool:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    # no decay on norms / biases / 1-D tensors handled via rank at call site
    return not any(n in ("scale", "bias", "A_log", "D", "dt_bias")
                   for n in names)


def update(grads: Params, state: Params, params: Params,
           cfg: AdamWConfig, policy: DTypePolicy | None = None
           ) -> tuple[Params, Params, dict]:
    policy = policy or DTypePolicy.standard()
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decayable(path) and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(policy.moments), v32.astype(policy.moments)

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
