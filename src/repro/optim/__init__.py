from repro.optim.adamw import AdamWConfig, cosine_lr, global_norm, init, update

__all__ = ["AdamWConfig", "init", "update", "cosine_lr", "global_norm"]
