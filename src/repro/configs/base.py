"""Architecture + shape configuration system.

``ArchConfig`` captures everything needed to build one of the assigned
architectures; one ``configs/<id>.py`` per arch instantiates it with the
exact published numbers.  ``ShapeConfig`` captures the assigned input
shapes (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False
    attn_type: str = "gqa"         # gqa | mla
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MLA (minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    shared_attn_every: int = 0     # hybrid: shared attn block cadence
    # --- enc-dec (audio) ---
    enc_layers: int = 0            # >0 -> encoder-decoder
    cross_len_frac: int = 8        # encoder len = seq_len // frac at decode
    # --- VLM ---
    vit_dim: int = 0               # stub patch-embedding dim
    n_patches: int = 256
    # --- technique hooks (the paper's AMM planner) ---
    sub_quadratic: bool = False    # can run long_500k
    vocab_pad_multiple: int = 128  # TPU lane alignment + mesh divisibility

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def param_count_estimate(self) -> int:
        """Analytic parameter count (sanity-checked in tests)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        total = V * d                       # embedding
        if not self.tie_embeddings:
            total += V * d                  # head
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            if self.attn_type == "mla":
                per_layer += d * self.q_lora_rank
                per_layer += self.q_lora_rank * self.n_heads * (hd + self.rope_head_dim)
                per_layer += d * (self.kv_lora_rank + self.rope_head_dim)
                per_layer += self.kv_lora_rank * self.n_heads * hd * 2
                per_layer += self.n_heads * hd * d
            else:
                per_layer += d * self.n_heads * hd
                per_layer += 2 * d * self.n_kv_heads * hd
                per_layer += self.n_heads * hd * d
            if self.family == "moe":
                ff_mults = 3 if self.gated_mlp else 2
                per_layer += d * self.n_experts          # router
                per_layer += self.n_experts * ff_mults * d * self.d_ff
            else:
                ff_mults = 3 if self.gated_mlp else 2
                per_layer += ff_mults * d * self.d_ff
        elif self.family in ("ssm", "hybrid"):
            di = self.ssm_expand * d
            h = di // self.ssm_head_dim
            per_layer += d * (2 * di + 2 * self.ssm_state + h)   # in_proj
            per_layer += di * d                                   # out_proj
        total += L * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            # one shared attention+mlp block (+ concat projector)
            total += 4 * d * self.n_heads * hd + (3 if self.gated_mlp else 2) * d * self.d_ff
            total += 2 * d * d
        if self.is_encdec:
            # encoder blocks + decoder cross-attention
            enc_per = 2 * d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + (3 if self.gated_mlp else 2) * d * self.d_ff
            total += self.enc_layers * enc_per
            total += self.n_layers * (2 * d * self.n_heads * hd)  # cross kv/q approx
        if self.family == "vlm":
            total += self.vit_dim * d
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (skip for pure
    full-attention archs, run for SSM/hybrid — per the assignment)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "pure full-attention arch: 500k decode is quadratic-cost; skipped per assignment"
    return True, ""


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Per (arch x shape) runtime knobs, resolved by the launcher."""
    dtype_preset: str = "standard"     # standard | lean | ultra_lean
    accum_steps: int = 1
    seq_shard_acts: bool = False       # Megatron-SP boundary activations
    kv_shard: str = "heads"            # heads | seq
    mla_absorb: bool = False
    remat: str = "full"                # full | none
    axis_profile: str = "tp"           # tp (Megatron) | dp (pure FSDP-256)
