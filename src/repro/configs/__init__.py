"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (FAMILIES, SHAPES, ArchConfig, RuntimeConfig,
                                ShapeConfig, shape_applicable)

_MODULES = {
    "nemotron-4-340b": "nemotron_4_340b",
    "mistral-large-123b": "mistral_large_123b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen3-1.7b": "qwen3_1_7b",
    "dbrx-132b": "dbrx_132b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mamba2-130m": "mamba2_130m",
    "internvl2-1b": "internvl2_1b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.ARCH


def tiny_variant(arch: ArchConfig, **overrides) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    import dataclasses
    small = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(arch.n_kv_heads, 2)),
        d_ff=128,
        vocab=256,
        head_dim=16,
    )
    if arch.attn_type == "mla":
        small.update(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                     head_dim=16, n_kv_heads=4)
    if arch.family == "moe":
        small.update(n_experts=4, top_k=2, d_ff=32)
    if arch.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if arch.family == "hybrid":
        small.update(shared_attn_every=2, n_heads=4, head_dim=16,
                     n_kv_heads=4)
    if arch.is_encdec:
        small.update(enc_layers=2)
    if arch.family == "vlm":
        small.update(vit_dim=32, n_patches=8)
    small.update(overrides)
    return dataclasses.replace(arch, **small)


__all__ = [
    "ArchConfig", "ShapeConfig", "RuntimeConfig", "SHAPES", "FAMILIES",
    "ARCH_NAMES", "get_arch", "tiny_variant", "shape_applicable",
]
