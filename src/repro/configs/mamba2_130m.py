"""mamba2-130m [ssm] — SSD, attention-free (arXiv:2405.21060)."""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True, sub_quadratic=True,
)
