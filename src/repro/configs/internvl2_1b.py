"""internvl2-1b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + Qwen2-0.5B LM backbone (arXiv:2404.16821)."""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, head_dim=64,
    vit_dim=1024, n_patches=256,
    act="silu", gated_mlp=True, tie_embeddings=True,
    rope_theta=1000000.0,
)
