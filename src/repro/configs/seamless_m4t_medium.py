"""seamless-m4t-medium [audio] — enc-dec; speech frontend is a STUB
(input_specs provides precomputed frame embeddings) (arXiv:2308.11596)."""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    enc_layers=12, act="gelu", gated_mlp=False,
)
