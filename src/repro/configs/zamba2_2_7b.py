"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block every
6 layers (arXiv:2411.15242)."""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    shared_attn_every=6, tie_embeddings=True, sub_quadratic=True,
)
