"""minicpm3-4b [dense] — MLA attention (hf:openbmb/MiniCPM3-4B)."""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448, head_dim=64,
    attn_type="mla", q_lora_rank=768, kv_lora_rank=256, rope_head_dim=32,
    act="silu", gated_mlp=True, tie_embeddings=True,
)
