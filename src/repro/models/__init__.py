from repro.models.common import DTypePolicy, count_params
from repro.models.lm import (decode_step, forward, init_model, loss_fn,
                             make_cache, prefill)

__all__ = [
    "DTypePolicy", "count_params", "init_model", "forward", "loss_fn",
    "make_cache", "prefill", "decode_step",
]
