"""Shared model primitives: norms, RoPE, initializers, dtype policy.

All models are pure-functional: ``init(key, cfg) -> params`` (nested
dicts of jnp arrays) and ``apply(params, ...) -> out``.  Layer stacks
are created pre-stacked on a leading [L, ...] axis and consumed with
``lax.scan`` so that compile time and HLO size stay O(1) in depth —
essential for the 96-layer dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy. ``lean`` presets drop the fp32 master copy
    for >=100B-param archs so optimizer state fits 16 GB/chip HBM."""
    params: Any = jnp.float32
    compute: Any = jnp.bfloat16
    moments: Any = jnp.float32

    @staticmethod
    def standard() -> "DTypePolicy":
        return DTypePolicy(jnp.float32, jnp.bfloat16, jnp.float32)

    @staticmethod
    def lean() -> "DTypePolicy":
        return DTypePolicy(jnp.float32, jnp.bfloat16, jnp.bfloat16)

    @staticmethod
    def ultra_lean() -> "DTypePolicy":
        """bf16 params + bf16 moments: 6 bytes/param optimizer footprint."""
        return DTypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.bfloat16)


def truncated_normal_init(key: jax.Array, shape: tuple[int, ...],
                          scale: float, dtype=jnp.float32) -> jax.Array:
    stddev = scale / max(1.0, (shape[0] if shape else 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    return truncated_normal_init(key, (d_in, d_out), 1.0, dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_init(d: int) -> Params:
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Activations
# ----------------------------------------------------------------------
def squared_relu(x: jax.Array) -> jax.Array:
    """Nemotron-4's squared ReLU."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": squared_relu,
    "relu": jax.nn.relu,
}


def stack_layer_init(layer_init: Callable[[jax.Array], Params],
                     key: jax.Array, n_layers: int) -> Params:
    """Initialize L layers pre-stacked on axis 0 (for lax.scan)."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(layer_init)(keys)


def count_params(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
