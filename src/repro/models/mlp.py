"""Feed-forward variants: gated (SwiGLU) and plain (squared-ReLU etc.)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, Params, dense_init


def mlp_init(key: jax.Array, d_model: int, d_ff: int, gated: bool) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "w_up": dense_init(ks[0], d_model, d_ff),
        "w_down": dense_init(ks[1], d_ff, d_model),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def mlp_apply(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    f = ACTIVATIONS[act]
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        up = f(x @ params["w_gate"].astype(x.dtype)) * up
    else:
        up = f(up)
    return up @ params["w_down"].astype(x.dtype)
