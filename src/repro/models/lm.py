"""Unified language-model assembly for all assigned architectures.

One functional model covering the six families (dense / moe / ssm /
hybrid / vlm / audio-encdec).  Layers are pre-stacked and consumed with
``lax.scan`` (+ per-layer remat), so HLO size and compile time are O(1)
in depth — required for 96-layer, 340B-parameter dry-runs.

Public entry points:
  init_model(key, arch, policy)                  -> params
  forward(params, arch, batch, rt)               -> logits (train/prefill)
  loss_fn(params, arch, batch, rt)               -> (loss, metrics)
  make_cache(arch, shape, batch, policy)         -> decode cache pytree
  prefill(params, arch, batch, rt)               -> (logits, cache)
  decode_step(params, arch, cache, tokens, rt)   -> (logits, cache)

Activation-sharding hooks go through ``repro.launch.sharding.constrain``
so the model code stays mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RuntimeConfig, ShapeConfig
from repro.launch.sharding import constrain
from repro.models.attention import (AttnConfig, flash_attention, gqa_apply,
                                    gqa_decode, gqa_init, gqa_prefill,
                                    mla_apply, mla_decode, mla_init,
                                    mla_prefill)
from repro.models.common import (DTypePolicy, Params, dense_init, norm_init,
                                 rms_norm, truncated_normal_init)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import MoEConfig, aux_load_balance_loss, moe_apply, moe_init
from repro.models.ssm import SSMConfig, mamba2_apply, mamba2_decode, mamba2_init

# ======================================================================
# Config adapters
# ======================================================================
def attn_config(arch: ArchConfig, causal: bool = True) -> AttnConfig:
    from repro.launch.sharding import tp_hint
    tp = tp_hint()
    rep = 1
    if tp > 1 and arch.n_kv_heads < tp and tp % arch.n_kv_heads == 0 \
            and arch.n_heads % tp == 0:
        rep = tp // arch.n_kv_heads        # Megatron kv replication
    return AttnConfig(
        d_model=arch.d_model,
        n_heads=arch.n_heads,
        n_kv_heads=arch.n_kv_heads,
        head_dim=arch.resolved_head_dim,
        qk_norm=arch.qk_norm,
        rope_theta=arch.rope_theta,
        causal=causal,
        attn_type=arch.attn_type,
        q_lora_rank=arch.q_lora_rank,
        kv_lora_rank=arch.kv_lora_rank,
        rope_head_dim=arch.rope_head_dim,
        kv_repeat=rep,
    )


def moe_config(arch: ArchConfig) -> MoEConfig:
    return MoEConfig(
        d_model=arch.d_model, d_ff_expert=arch.d_ff,
        n_experts=arch.n_experts, top_k=arch.top_k,
        capacity_factor=arch.moe_capacity_factor,
        act=arch.act, gated=arch.gated_mlp,
    )


def ssm_config(arch: ArchConfig) -> SSMConfig:
    return SSMConfig(
        d_model=arch.d_model, d_state=arch.ssm_state,
        head_dim=arch.ssm_head_dim, expand=arch.ssm_expand,
        chunk=arch.ssm_chunk,
    )


# ======================================================================
# Per-layer blocks
# ======================================================================
def _attn_block_init(key, arch: ArchConfig) -> Params:
    acfg = attn_config(arch)
    init = mla_init if arch.attn_type == "mla" else gqa_init
    return {"attn": init(key, acfg), "ln": norm_init(arch.d_model)}


def _decoder_layer_init(key, arch: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = _attn_block_init(k1, arch)
    p["ln2"] = norm_init(arch.d_model)
    if arch.family == "moe":
        p["moe"] = moe_init(k2, moe_config(arch))
    else:
        p["mlp"] = mlp_init(k2, arch.d_model, arch.d_ff, arch.gated_mlp)
    return p


def _ssm_layer_init(key, arch: ArchConfig) -> Params:
    return {"mamba": mamba2_init(key, ssm_config(arch)),
            "ln": norm_init(arch.d_model)}


def _encoder_layer_init(key, arch: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    acfg = attn_config(arch, causal=False)
    return {"attn": gqa_init(k1, acfg), "ln": norm_init(arch.d_model),
            "mlp": mlp_init(k2, arch.d_model, arch.d_ff, arch.gated_mlp),
            "ln2": norm_init(arch.d_model)}


def _cross_decoder_layer_init(key, arch: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = _decoder_layer_init(jax.random.fold_in(k1, 0), arch)
    p["cross"] = gqa_init(k2, attn_config(arch, causal=False))
    p["ln_cross"] = norm_init(arch.d_model)
    return p


def _shared_block_init(key, arch: ArchConfig) -> Params:
    """zamba2-style shared attention block, fed concat(h, emb0)."""
    k1, k2, k3 = jax.random.split(key, 3)
    p = _decoder_layer_init(k1, arch)
    p["w_cat"] = dense_init(k2, 2 * arch.d_model, arch.d_model)
    return p


def _layer_apply_full(p: Params, arch: ArchConfig, h: jax.Array,
                      rt: RuntimeConfig) -> tuple[jax.Array, jax.Array]:
    """Full-sequence decoder layer (train / prefill w/o cache).
    Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    # Megatron-SP: sub-block outputs are constrained to the seq-sharded
    # "hidden" layout BEFORE the residual add, so the TP partial-sum
    # lowers to a reduce-scatter and the residual add stays local
    # (otherwise GSPMD all-gathers the residual at every add —
    # measured ~7 hidden-sized gathers/layer on mistral, §Perf it.4).
    if arch.family in ("ssm", "hybrid"):
        x = constrain(rms_norm(h, p["ln"]["scale"]), "tp_in", rt)
        h = h + constrain(mamba2_apply(p["mamba"], ssm_config(arch), x),
                          "hidden", rt)
        return constrain(h, "hidden", rt), aux
    acfg = attn_config(arch)
    x = constrain(rms_norm(h, p["ln"]["scale"]), "tp_in", rt)
    attn = mla_apply if arch.attn_type == "mla" else gqa_apply
    h = h + constrain(attn(p["attn"], acfg, x), "hidden", rt)
    x2 = constrain(rms_norm(h, p["ln2"]["scale"]), "tp_in", rt)
    if arch.family == "moe":
        h = h + constrain(moe_apply(p["moe"], moe_config(arch), x2),
                          "hidden", rt)
        aux = aux_load_balance_loss(p["moe"], moe_config(arch), x2)
    else:
        h = h + constrain(mlp_apply(p["mlp"], x2, arch.act), "hidden", rt)
    return constrain(h, "hidden", rt), aux


def _shared_block_apply(p: Params, arch: ArchConfig, h: jax.Array,
                        emb0: jax.Array, rt: RuntimeConfig) -> jax.Array:
    z = jnp.concatenate([h, emb0.astype(h.dtype)], axis=-1)
    z = z @ p["w_cat"].astype(h.dtype)
    acfg = attn_config(arch)
    x = rms_norm(z, p["ln"]["scale"])
    z = z + gqa_apply(p["attn"], acfg, x)
    x2 = rms_norm(z, p["ln2"]["scale"])
    z = z + mlp_apply(p["mlp"], x2, arch.act)
    return h + z


# ======================================================================
# Model init
# ======================================================================
def init_model(key: jax.Array, arch: ArchConfig,
               policy: DTypePolicy | None = None) -> Params:
    policy = policy or DTypePolicy.standard()
    ks = jax.random.split(key, 8)
    d = arch.d_model
    params: Params = {
        # vocab padded to a multiple of 128 (TPU lanes + mesh divisibility)
        "embed": truncated_normal_init(ks[0], (arch.padded_vocab, d), 1.0),
        "final_norm": norm_init(d),
    }
    if not arch.tie_embeddings:
        params["head"] = dense_init(ks[1], d, arch.padded_vocab)

    if arch.family in ("ssm", "hybrid"):
        layer_init = partial(_ssm_layer_init, arch=arch)
    elif arch.is_encdec:
        layer_init = partial(_cross_decoder_layer_init, arch=arch)
    else:
        layer_init = partial(_decoder_layer_init, arch=arch)
    params["blocks"] = jax.vmap(lambda k: layer_init(k))(
        jax.random.split(ks[2], arch.n_layers))

    if arch.family == "hybrid" and arch.shared_attn_every:
        params["shared"] = _shared_block_init(ks[3], arch)
    if arch.is_encdec:
        params["enc_blocks"] = jax.vmap(
            lambda k: _encoder_layer_init(k, arch))(
            jax.random.split(ks[4], arch.enc_layers))
        params["enc_norm"] = norm_init(d)
    if arch.family == "vlm":
        params["patch_proj"] = dense_init(ks[5], arch.vit_dim, d)

    return jax.tree.map(
        lambda x: x.astype(policy.params)
        if x.dtype == jnp.float32 else x, params)


# ======================================================================
# Forward (train / prefill), scan over stacked layers
# ======================================================================
def _cast_blocks(blocks: Params, dtype) -> Params:
    """Cast stacked weights to compute dtype ONCE, outside the layer
    scan, so FSDP all-gathers move bf16 (not f32) bytes.  Norm scales
    etc. are 1-D and stay f32 (rms_norm computes in f32 anyway)."""
    return jax.tree.map(
        lambda x: x.astype(dtype) if (x.ndim >= 2 and
                                      x.dtype == jnp.float32) else x,
        blocks)


def _scan_layers(params: Params, arch: ArchConfig, h: jax.Array,
                 rt: RuntimeConfig) -> tuple[jax.Array, jax.Array]:
    emb0 = h
    every = arch.shared_attn_every

    def one_layer(carry, xs):
        hh = carry
        bp, idx = xs
        hh, aux = _layer_apply_full(bp, arch, hh, rt)
        if arch.family == "hybrid" and every:
            hh = jax.lax.cond(
                (idx % every) == 0,
                lambda v: _shared_block_apply(params["shared"], arch, v,
                                              emb0, rt),
                lambda v: v,
                hh,
            )
        return hh, aux

    layer = one_layer
    if rt.remat == "full":
        layer = jax.checkpoint(
            one_layer, policy=jax.checkpoint_policies.nothing_saveable)
    blocks = _cast_blocks(params["blocks"], h.dtype)
    h, auxs = jax.lax.scan(
        layer, h, (blocks, jnp.arange(arch.n_layers)))
    return h, jnp.sum(auxs)


def _encoder_forward(params: Params, arch: ArchConfig, frames: jax.Array,
                     rt: RuntimeConfig) -> jax.Array:
    acfg = attn_config(arch, causal=False)

    def one_layer(h, bp):
        x = rms_norm(h, bp["ln"]["scale"])
        h = h + gqa_apply(bp["attn"], acfg, x)
        x2 = rms_norm(h, bp["ln2"]["scale"])
        h = h + mlp_apply(bp["mlp"], x2, arch.act)
        return constrain(h, "hidden", rt), None

    layer = one_layer
    if rt.remat == "full":
        layer = jax.checkpoint(
            one_layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, frames, params["enc_blocks"])
    return rms_norm(h, params["enc_norm"]["scale"])


def _cross_decoder_forward(params: Params, arch: ArchConfig, h: jax.Array,
                           enc_out: jax.Array, rt: RuntimeConfig
                           ) -> tuple[jax.Array, jax.Array]:
    acfg = attn_config(arch)
    xcfg = attn_config(arch, causal=False)

    def one_layer(hh, bp):
        x = rms_norm(hh, bp["ln"]["scale"])
        hh = hh + gqa_apply(bp["attn"], acfg, x)
        xc = rms_norm(hh, bp["ln_cross"]["scale"])
        # cross attention: q from decoder, k/v from encoder output
        b, s, _ = xc.shape
        hd = xcfg.head_dim
        q = (xc @ bp["cross"]["wq"].astype(xc.dtype)).reshape(
            b, s, xcfg.n_heads, hd)
        k = (enc_out.astype(xc.dtype) @ bp["cross"]["wk"].astype(xc.dtype)
             ).reshape(b, -1, xcfg.n_kv_heads, hd)
        v = (enc_out.astype(xc.dtype) @ bp["cross"]["wv"].astype(xc.dtype)
             ).reshape(b, -1, xcfg.n_kv_heads, hd)
        o = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=False)
        o = jnp.swapaxes(o, 1, 2).reshape(b, s, xcfg.n_heads * hd)
        hh = hh + o @ bp["cross"]["wo"].astype(xc.dtype)
        x2 = rms_norm(hh, bp["ln2"]["scale"])
        hh = hh + mlp_apply(bp["mlp"], x2, arch.act)
        return constrain(hh, "hidden", rt), jnp.zeros((), jnp.float32)

    layer = one_layer
    if rt.remat == "full":
        layer = jax.checkpoint(
            one_layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, auxs = jax.lax.scan(layer, h, params["blocks"])
    return h, jnp.sum(auxs)


def embed_tokens(params: Params, arch: ArchConfig, tokens: jax.Array,
                 rt: RuntimeConfig, compute_dtype) -> jax.Array:
    e = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    return constrain(e * jnp.sqrt(arch.d_model).astype(compute_dtype),
                     "hidden", rt)


def forward(params: Params, arch: ArchConfig, batch: dict[str, jax.Array],
            rt: RuntimeConfig | None = None,
            policy: DTypePolicy | None = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss).

    batch keys: "tokens" [B,S]; vlm: + "patches" [B,P,vit_dim];
    audio: + "frames" [B,S_enc,d_model]."""
    rt = rt or RuntimeConfig()
    policy = policy or DTypePolicy.standard()
    cd = policy.compute
    tokens = batch["tokens"]
    h = embed_tokens(params, arch, tokens, rt, cd)

    if arch.family == "vlm":
        prefix = (batch["patches"].astype(cd)
                  @ params["patch_proj"].astype(cd))
        h = jnp.concatenate([prefix, h], axis=1)

    if arch.is_encdec:
        enc_out = _encoder_forward(params, arch,
                                   batch["frames"].astype(cd), rt)
        h, aux = _cross_decoder_forward(params, arch, h, enc_out, rt)
    else:
        h, aux = _scan_layers(params, arch, h, rt)

    h = rms_norm(h, params["final_norm"]["scale"])
    head = params.get("head", None)
    w = (params["embed"].T if head is None else head).astype(cd)
    logits = h @ w
    return constrain(logits, "logits", rt), aux


def loss_fn(params: Params, arch: ArchConfig, batch: dict[str, jax.Array],
            rt: RuntimeConfig | None = None,
            policy: DTypePolicy | None = None) -> tuple[jax.Array, dict]:
    """Next-token cross entropy (+ MoE aux + z-loss)."""
    logits, aux = forward(params, arch, batch, rt, policy)
    labels = batch["labels"]
    if arch.family == "vlm":  # logits cover [patches + tokens]
        logits = logits[:, -labels.shape[1]:, :]
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    shifted = lg - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    # gold logit via masked reduce (fuses under SPMD; take_along_axis over
    # the vocab-sharded axis would all-gather the full logits tensor)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == jnp.maximum(labels, 0)[..., None], lg, 0.0),
        axis=-1)
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    z_loss = 1e-4 * jnp.sum(jnp.square(lse) * mask) / denom
    aux_w = 0.01 * aux
    loss = ce + z_loss + aux_w
    return loss, {"ce": ce, "z_loss": z_loss, "aux": aux_w,
                  "tokens": jnp.sum(mask)}


# ======================================================================
# Decode caches
# ======================================================================
def make_cache(arch: ArchConfig, seq_len: int, batch: int,
               policy: DTypePolicy | None = None) -> Params:
    """Allocate (or shape-spec, under eval_shape) the decode cache."""
    policy = policy or DTypePolicy.standard()
    cd = policy.compute
    hd = arch.resolved_head_dim
    L, B = arch.n_layers, batch
    cache: Params = {"len": jnp.zeros((), jnp.int32)}
    if arch.family in ("dense", "moe", "vlm", "audio"):
        if arch.attn_type == "mla":
            cache["c_kv"] = jnp.zeros((L, B, seq_len, arch.kv_lora_rank), cd)
            cache["k_rope"] = jnp.zeros((L, B, seq_len, arch.rope_head_dim), cd)
        else:
            cache["k"] = jnp.zeros((L, B, arch.n_kv_heads, seq_len, hd), cd)
            cache["v"] = jnp.zeros((L, B, arch.n_kv_heads, seq_len, hd), cd)
    if arch.is_encdec:
        s_enc = max(seq_len // arch.cross_len_frac, 16)
        cache["cross_k"] = jnp.zeros((L, B, arch.n_kv_heads, s_enc, hd), cd)
        cache["cross_v"] = jnp.zeros((L, B, arch.n_kv_heads, s_enc, hd), cd)
    if arch.family in ("ssm", "hybrid"):
        scfg = ssm_config(arch)
        cache["ssm_h"] = jnp.zeros(
            (L, B, scfg.n_heads, scfg.head_dim, scfg.d_state), jnp.float32)
        cache["ssm_conv"] = jnp.zeros(
            (L, B, scfg.conv_width - 1, scfg.conv_channels), cd)
    if arch.family == "hybrid" and arch.shared_attn_every:
        n_uses = -(-arch.n_layers // arch.shared_attn_every)
        cache["shared_k"] = jnp.zeros(
            (n_uses, B, arch.n_kv_heads, seq_len, hd), cd)
        cache["shared_v"] = jnp.zeros(
            (n_uses, B, arch.n_kv_heads, seq_len, hd), cd)
    return cache


# ======================================================================
# Decode step
# ======================================================================
def _cross_attn_decode(bp: Params, arch: ArchConfig, x: jax.Array,
                       ck: jax.Array, cv: jax.Array) -> jax.Array:
    b = x.shape[0]
    hd = arch.resolved_head_dim
    q = (x @ bp["cross"]["wq"].astype(x.dtype)).reshape(
        b, arch.n_heads, hd)
    g = arch.n_heads // arch.n_kv_heads
    qg = q.reshape(b, arch.n_kv_heads, g, hd)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", w, cv.astype(jnp.float32))
    o = o.reshape(b, 1, arch.n_heads * hd).astype(x.dtype)
    return o @ bp["cross"]["wo"].astype(x.dtype)


def decode_step(params: Params, arch: ArchConfig, cache: Params,
                tokens: jax.Array, rt: RuntimeConfig | None = None,
                policy: DTypePolicy | None = None
                ) -> tuple[jax.Array, Params]:
    """One decode step.  tokens: [B, 1] new token ids."""
    rt = rt or RuntimeConfig()
    policy = policy or DTypePolicy.standard()
    cd = policy.compute
    h = embed_tokens(params, arch, tokens, rt, cd)
    pos = cache["len"]
    acfg = attn_config(arch)
    emb0 = h

    if arch.family in ("dense", "moe", "vlm") or arch.is_encdec:
        if arch.attn_type == "mla":
            xs = (params["blocks"], cache["c_kv"], cache["k_rope"])

            def layer(carry, x):
                hh = carry
                bp, ck, kr = x
                xn = rms_norm(hh, bp["ln"]["scale"])
                o, (ck, kr) = mla_decode(bp["attn"], acfg, xn, (ck, kr),
                                         pos, absorb=rt.mla_absorb)
                hh = hh + o
                x2 = rms_norm(hh, bp["ln2"]["scale"])
                if arch.family == "moe":
                    hh = hh + moe_apply(bp["moe"], moe_config(arch), x2)
                else:
                    hh = hh + mlp_apply(bp["mlp"], x2, arch.act)
                return hh, (ck, kr)

            h, (ckv, krope) = jax.lax.scan(layer, h, xs)
            cache = {**cache, "c_kv": ckv, "k_rope": krope}
        else:
            if arch.is_encdec:
                xs = (params["blocks"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"])
            else:
                xs = (params["blocks"], cache["k"], cache["v"])

            def layer(carry, x):
                hh = carry
                if arch.is_encdec:
                    bp, kc, vc, xk, xv = x
                else:
                    bp, kc, vc = x
                xn = rms_norm(hh, bp["ln"]["scale"])
                o, (kc, vc) = gqa_decode(bp["attn"], acfg, xn, (kc, vc), pos)
                hh = hh + o
                if arch.is_encdec:
                    xc = rms_norm(hh, bp["ln_cross"]["scale"])
                    hh = hh + _cross_attn_decode(bp, arch, xc[:, 0], xk, xv)
                x2 = rms_norm(hh, bp["ln2"]["scale"])
                if arch.family == "moe":
                    hh = hh + moe_apply(bp["moe"], moe_config(arch), x2)
                else:
                    hh = hh + mlp_apply(bp["mlp"], x2, arch.act)
                return hh, (kc, vc)

            h, (kc, vc) = jax.lax.scan(layer, h, xs)
            cache = {**cache, "k": kc, "v": vc}
    else:  # ssm / hybrid
        scfg = ssm_config(arch)
        every = arch.shared_attn_every
        sk = cache.get("shared_k")
        sv = cache.get("shared_v")

        def layer(carry, x):
            hh, sk, sv = carry
            bp, hc, cc, idx = x
            xn = rms_norm(hh, bp["ln"]["scale"])
            o, (hc, cc) = mamba2_decode(bp["mamba"], scfg, xn, (hc, cc))
            hh = hh + o

            if arch.family == "hybrid" and every:
                u = idx // every

                def do_shared(args):
                    hh, sk, sv = args
                    sp = params["shared"]
                    z = jnp.concatenate([hh, emb0.astype(hh.dtype)], -1)
                    z = z @ sp["w_cat"].astype(hh.dtype)
                    xn2 = rms_norm(z, sp["ln"]["scale"])
                    ku, vu = sk[u], sv[u]
                    o2, (ku, vu) = gqa_decode(sp["attn"], acfg, xn2,
                                              (ku, vu), pos)
                    z = z + o2
                    x2 = rms_norm(z, sp["ln2"]["scale"])
                    z = z + mlp_apply(sp["mlp"], x2, arch.act)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, ku, u, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, vu, u, 0)
                    return hh + z, sk, sv

                hh, sk, sv = jax.lax.cond(
                    (idx % every) == 0, do_shared, lambda a: a, (hh, sk, sv))
            return (hh, sk, sv), (hc, cc)

        if sk is None:
            sk = jnp.zeros((1,), jnp.float32)
            sv = jnp.zeros((1,), jnp.float32)
        (h, sk, sv), (hc, cc) = jax.lax.scan(
            layer, (h, sk, sv),
            (params["blocks"], cache["ssm_h"], cache["ssm_conv"],
             jnp.arange(arch.n_layers)))
        cache = {**cache, "ssm_h": hc, "ssm_conv": cc}
        if arch.family == "hybrid" and every:
            cache = {**cache, "shared_k": sk, "shared_v": sv}

    h = rms_norm(h, params["final_norm"]["scale"])
    head = params.get("head", None)
    w = (params["embed"].T if head is None else head).astype(cd)
    logits = h @ w
    cache = {**cache, "len": cache["len"] + 1}
    return constrain(logits, "logits", rt), cache


def prefill(params: Params, arch: ArchConfig, batch: dict[str, jax.Array],
            cache_len: int, rt: RuntimeConfig | None = None,
            policy: DTypePolicy | None = None) -> tuple[jax.Array, Params]:
    """Run the full-sequence forward and populate a decode cache of
    capacity ``cache_len`` (>= prompt length)."""
    rt = rt or RuntimeConfig()
    policy = policy or DTypePolicy.standard()
    cd = policy.compute
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = make_cache(arch, cache_len, b, policy)
    h = embed_tokens(params, arch, tokens, rt, cd)
    acfg = attn_config(arch)

    if arch.is_encdec:
        # encoder once; decoder prefill caches self-KV + per-layer cross-KV
        enc_out = _encoder_forward(params, arch,
                                   batch["frames"].astype(cd), rt)
        hd = arch.resolved_head_dim
        xcfg = attn_config(arch, causal=False)

        def layer(hh, bp):
            xn = rms_norm(hh, bp["ln"]["scale"])
            o, (kc, vc) = gqa_prefill(bp["attn"], acfg, xn)
            hh = hh + o
            xc = rms_norm(hh, bp["ln_cross"]["scale"])
            be, se, _ = enc_out.shape
            q = (xc @ bp["cross"]["wq"].astype(cd)).reshape(
                be, -1, xcfg.n_heads, hd)
            xk = (enc_out.astype(cd) @ bp["cross"]["wk"].astype(cd)
                  ).reshape(be, se, xcfg.n_kv_heads, hd)
            xv = (enc_out.astype(cd) @ bp["cross"]["wv"].astype(cd)
                  ).reshape(be, se, xcfg.n_kv_heads, hd)
            o2 = flash_attention(jnp.swapaxes(q, 1, 2),
                                 jnp.swapaxes(xk, 1, 2),
                                 jnp.swapaxes(xv, 1, 2), causal=False)
            o2 = o2.swapaxes(1, 2).reshape(be, -1, xcfg.n_heads * hd)
            hh = hh + o2 @ bp["cross"]["wo"].astype(cd)
            x2 = rms_norm(hh, bp["ln2"]["scale"])
            hh = hh + mlp_apply(bp["mlp"], x2, arch.act)
            return constrain(hh, "hidden", rt), (
                kc, vc, jnp.swapaxes(xk, 1, 2), jnp.swapaxes(xv, 1, 2))

        h, (kc, vc, xk, xv) = jax.lax.scan(layer, h, params["blocks"])
        pad = ((0, 0), (0, 0), (0, 0), (0, cache_len - s), (0, 0))
        cache["k"] = jnp.pad(kc.astype(cd), pad)
        cache["v"] = jnp.pad(vc.astype(cd), pad)
        s_enc = cache["cross_k"].shape[3]
        cache["cross_k"] = xk[:, :, :, :s_enc].astype(cd)
        cache["cross_v"] = xv[:, :, :, :s_enc].astype(cd)
    elif arch.family in ("dense", "moe", "vlm"):
        if arch.attn_type == "mla":
            def layer(hh, bp):
                xn = rms_norm(hh, bp["ln"]["scale"])
                o, (ckv, kr) = mla_prefill(bp["attn"], acfg, xn)
                hh = hh + o
                x2 = rms_norm(hh, bp["ln2"]["scale"])
                if arch.family == "moe":
                    hh = hh + moe_apply(bp["moe"], moe_config(arch), x2)
                else:
                    hh = hh + mlp_apply(bp["mlp"], x2, arch.act)
                return constrain(hh, "hidden", rt), (ckv, kr)

            h, (ckv, kr) = jax.lax.scan(layer, h, params["blocks"])
            cache["c_kv"] = jnp.pad(
                ckv.astype(cd), ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
            cache["k_rope"] = jnp.pad(
                kr.astype(cd), ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
        else:
            def layer(hh, bp):
                xn = rms_norm(hh, bp["ln"]["scale"])
                o, (kc, vc) = gqa_prefill(bp["attn"], acfg, xn)
                hh = hh + o
                x2 = rms_norm(hh, bp["ln2"]["scale"])
                if arch.family == "moe":
                    hh = hh + moe_apply(bp["moe"], moe_config(arch), x2)
                else:
                    hh = hh + mlp_apply(bp["mlp"], x2, arch.act)
                return constrain(hh, "hidden", rt), (kc, vc)

            h, (kc, vc) = jax.lax.scan(layer, h, params["blocks"])
            pad = ((0, 0), (0, 0), (0, 0), (0, cache_len - s), (0, 0))
            cache["k"] = jnp.pad(kc.astype(cd), pad)
            cache["v"] = jnp.pad(vc.astype(cd), pad)
    elif arch.family in ("ssm", "hybrid"):
        def layer(hh, bp):
            xn = rms_norm(hh, bp["ln"]["scale"])
            o, (hf, conv_tail) = mamba2_apply(
                bp["mamba"], ssm_config(arch), xn, return_state=True)
            return constrain(hh + o, "hidden", rt), (hf, conv_tail)

        # Note: prefill for hybrid ignores the shared attention block's
        # cache population here for brevity of the driver; serving tests
        # exercise decode_step from a zero cache instead.
        h, (hf, conv_tail) = jax.lax.scan(layer, h, params["blocks"])
        cache["ssm_h"] = hf
        cache["ssm_conv"] = conv_tail.astype(cd)
    h = rms_norm(h, params["final_norm"]["scale"])
    head = params.get("head", None)
    w = (params["embed"].T if head is None else head).astype(cd)
    logits = h[:, -1:, :] @ w
    cache = {**cache, "len": jnp.asarray(s, jnp.int32)}
    return logits, cache
