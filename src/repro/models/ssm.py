"""Mamba2 / SSD (state-space duality, arXiv:2405.21060).

The selective state space  h_t = a_t h_{t-1} + dt_t B_t x_t^T,
y_t = C_t h_t + D x_t  is evaluated with the *chunked SSD* algorithm:
within a chunk of Q tokens the quadratic "attention-like" form is used
(dual form, matmul-friendly -> MXU), across chunks the linear state
recurrence is carried by ``lax.scan``.  A naive per-token recurrence
oracle (``ssd_reference``) validates it, and the Pallas kernel in
``repro.kernels.ssd_scan`` is its TPU twin.

Shapes: x [B,S,H,P] (H heads of headdim P), dt [B,S,H], B/C [B,S,N]
(single group shared across heads), state h [B,H,P,N].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, rms_norm


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.d_state


# ----------------------------------------------------------------------
# Core SSD math
# ----------------------------------------------------------------------
def ssd_reference(x, dt, A, B, C, h0=None):
    """Naive per-token recurrence (oracle).  x:[b,s,h,p] dt:[b,s,h]
    A:[h] B,C:[b,s,n] -> y:[b,s,h,p], h_final:[b,h,p,n]."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hprev, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt.astype(jnp.float32) * A)            # [b,h]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
                         Bt.astype(jnp.float32), xt.astype(jnp.float32))
        hnew = a[..., None, None] * hprev + dBx
        y = jnp.einsum("bn,bhpn->bhp", Ct.astype(jnp.float32), hnew)
        return hnew, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    hf, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hf


def ssd_chunked(x, dt, A, B, C, h0=None, chunk: int = 256):
    """Chunked SSD (the paper's efficient dual form)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)

    la = dtc * A                                   # log a_t  [b,nc,q,h]
    cum = jnp.cumsum(la, axis=2)                   # [b,nc,q,h]

    def chunk_step(hprev, inp):
        xq, dtq, Bq, Cq, laq, cumq = inp
        # intra-chunk dual (quadratic) term; mask the *exponent* (not the
        # product) so the upper triangle never produces inf -> NaN grads
        mask = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
        diff = cumq[:, :, None, :] - cumq[:, None, :, :]            # [b,i,j,h]
        decay = jnp.exp(jnp.where(mask, diff, -1e30))
        scores = jnp.einsum("bin,bjn->bij", Cq, Bq)[..., None] * decay
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", scores, dtq,
                             xq.astype(jnp.float32))
        # contribution of the inbound state
        state_decay = jnp.exp(cumq)                               # [b,q,h]
        y_inter = jnp.einsum("bin,bih,bhpn->bihp", Cq, state_decay, hprev)
        # outbound state
        tail = jnp.exp(cumq[:, -1:, :] - cumq)                    # [b,q,h]
        dBx = jnp.einsum("bjh,bjn,bjhp->bhpn", tail * dtq, Bq,
                         xq.astype(jnp.float32))
        hnew = jnp.exp(cumq[:, -1, :])[..., None, None] * hprev + dBx
        return hnew, y_intra + y_inter

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0),
          jnp.moveaxis(la, 1, 0), jnp.moveaxis(cum, 1, 0))
    hf, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * q, h, p)[:, :s]
    return y, hf


# ----------------------------------------------------------------------
# Mamba2 block
# ----------------------------------------------------------------------
def mamba2_init(key: jax.Array, cfg: SSMConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    proj_out = 2 * di + 2 * cfg.d_state + h
    return {
        "in_proj": dense_init(ks[0], d, proj_out),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width,
                                             cfg.conv_channels)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((cfg.conv_channels,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.zeros((di,), jnp.float32)},
        "out_proj": dense_init(ks[2], di, d),
    }


def _split_proj(cfg: SSMConfig, proj: jax.Array):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + cfg.conv_channels]
    dt = proj[..., di + cfg.conv_channels:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. xbc: [B,S,C]; w: [W,C]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) * w[i]
    return jax.nn.silu(out + bias).astype(xbc.dtype)


def mamba2_apply(params: Params, cfg: SSMConfig, x: jax.Array,
                 h0=None, conv0=None, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: [B,S,D]."""
    b, s, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :di].reshape(b, s, h, p)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, hf = ssd_chunked(xs, dt, A, B, C, h0=h0, chunk=cfg.chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm"]["scale"])
    out = y @ params["out_proj"].astype(x.dtype)
    if return_state:
        conv_tail = None
        if cfg.conv_width > 1:
            # last (W-1) pre-conv inputs for decode continuation
            proj_tail = proj[:, -(cfg.conv_width - 1):, :]
            _, xbc_tail, _ = _split_proj(cfg, proj_tail)
            conv_tail = xbc_tail
        return out, (hf, conv_tail)
    return out


def mamba2_decode(params: Params, cfg: SSMConfig, x: jax.Array,
                  state: tuple[jax.Array, jax.Array]):
    """Single-token decode.  x: [B,1,D]; state = (h [b,h,p,n],
    conv_buf [b,W-1,C])."""
    b = x.shape[0]
    di, n, h, p, w = (cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim,
                      cfg.conv_width)
    hprev, conv_buf = state
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc_new, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([conv_buf.astype(x.dtype), xbc_new], axis=1)
    acc = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     params["conv_w"])
    xbc = jax.nn.silu(acc + params["conv_b"])[:, None, :].astype(x.dtype)
    xt = xbc[..., :di].reshape(b, 1, h, p)[:, 0]
    B = xbc[..., di:di + n][:, 0]
    C = xbc[..., di + n:][:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)[:, 0] + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                         # [b,h]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B.astype(jnp.float32),
                     xt.astype(jnp.float32))
    hnew = a[..., None, None] * hprev + dBx
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), hnew)
    y = y + params["D"][None, :, None] * xt.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm"]["scale"])
    out = y @ params["out_proj"].astype(x.dtype)
    conv_buf = window[:, 1:, :]
    return out, (hnew, conv_buf)
