"""Mixture-of-Experts layer with sort-free capacity dispatch.

Routing: top-k softmax gating.  Dispatch builds, per expert, a dense
[E, C] table of token slots (C = capacity) via cumulative positions —
no [T, E, C] one-hot tensor is ever materialized (that einsum dominates
memory at 32k tokens x 64 experts).  Expert FFNs run as a batched
einsum over the expert axis, which shards cleanly over the "model" mesh
axis (expert parallelism); XLA inserts the token all-to-all.

This is also where the paper's lens applies at cluster scale: expert
banks are a multi-ported memory, tokens are read requests, and top-k
routing of a skewed token distribution is exactly a low-spatial-locality
multi-port access pattern (see repro.memory.planner).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, Params, dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"
    gated: bool = True


def moe_init(key: jax.Array, cfg: MoEConfig) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert

    def expert_stack(k, d_in, d_out):
        return jax.vmap(lambda kk: dense_init(kk, d_in, d_out))(
            jax.random.split(k, e))

    p: Params = {
        "router": dense_init(ks[0], d, e),
        "w_up": expert_stack(ks[1], d, f),
        "w_down": expert_stack(ks[2], f, d),
    }
    if cfg.gated:
        p["w_gate"] = expert_stack(ks[3], d, f)
    return p


def moe_apply(params: Params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """x: [B, S, D] -> [B, S, D].  Tokens over capacity are dropped
    (standard capacity-based MoE; the residual path carries them).

    Dispatch is *per sequence*: each batch row computes its own expert
    queue positions (cumsum along S*K only).  This keeps the dispatch
    math batch-local, so with batch sharded over "data" and experts over
    "model" the only cross-device movement is the token all-to-all —
    a global cumsum over the sharded token axis would serialize across
    devices (measured collective-bound dbrx/moonshot baselines,
    EXPERIMENTS.md §Perf iteration 3)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * s * k / e), 1)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                    # [B, S, E]
    top_g, top_e = jax.lax.top_k(gates, k)                     # [B, S, K]
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's per-row queue
    flat_e = top_e.reshape(b, s * k)                           # [B, S*K]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # [B, S*K, E]
    pos = jnp.cumsum(onehot, axis=1) - 1                       # row-local
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = slot < cap

    # scatter row-token ids into the per-row dispatch table [B, E, C]
    dest = jnp.where(keep, flat_e * cap + slot, e * cap)       # overflow bin
    token_ids = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :]
    table = jnp.full((b, e * cap + 1), s, jnp.int32)           # s = pad token
    table = jax.vmap(lambda t_, d_, i_: t_.at[d_].set(i_, mode="drop"))(
        table, dest, jnp.broadcast_to(token_ids, dest.shape))
    table = table[:, :-1].reshape(b, e, cap)                   # [B, E, C]

    # gather expert inputs; pad row s reads zeros
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad[:, :, None, :], table.reshape(b, e * cap, 1, 1), axis=1
    ).reshape(b, e, cap, d)

    f = ACTIVATIONS[cfg.act]
    up = jnp.einsum("becd,edf->becf", xe, params["w_up"].astype(x.dtype))
    if cfg.gated:
        up = f(jnp.einsum("becd,edf->becf", xe,
                          params["w_gate"].astype(x.dtype))) * up
    else:
        up = f(up)
    ye = jnp.einsum("becf,efd->becd", up, params["w_down"].astype(x.dtype))

    # combine back with gate weights (row-local scatter-add)
    gate_tbl = jax.vmap(lambda d_, g_: jnp.zeros(
        (e * cap + 1,), jnp.float32).at[d_].set(g_, mode="drop"))(
        dest, top_g.reshape(b, s * k))[:, :-1].reshape(b, e, cap)
    contrib = (ye * gate_tbl[..., None].astype(ye.dtype)
               ).reshape(b, e * cap, d).astype(jnp.float32)
    y = jax.vmap(lambda t_, c_: jnp.zeros((s + 1, d), jnp.float32)
                 .at[t_].add(c_))(table.reshape(b, e * cap), contrib)
    return y[:, :s].astype(x.dtype)


def aux_load_balance_loss(params: Params, cfg: MoEConfig,
                          x: jax.Array) -> jax.Array:
    """Switch-style load balance loss: E * sum_e f_e * p_e."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(gates, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.n_experts, dtype=jnp.float32),
                    axis=0)
    prob = jnp.mean(gates, axis=0)
    return cfg.n_experts * jnp.sum(frac * prob)
