"""Attention variants: GQA (w/ optional qk-norm) and MLA (multi-head
latent attention, MiniCPM3/DeepSeek-V2 style).

Full-sequence attention is computed *blockwise* over KV chunks with an
online-softmax accumulator (flash-attention recurrence in pure JAX via
``lax.scan``) so the [S, S] score matrix is never materialized — at
prefill_32k a materialized score tensor would be O(S^2) HBM and the
dry-run would not fit.  The Pallas TPU kernel in ``repro.kernels`` is
the hardware-target twin of this reference.

Decode (single new token against a cached KV of length S) is a separate
path; with ``kv_seq_shard`` the cache's length axis is sharded over the
"model" mesh axis and XLA inserts the partial-softmax reduction
(baseline) — the shard_map flash-decode in lm.py is the optimized form.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (Params, apply_rope, dense_init, norm_init,
                                 rms_norm)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    # MLA (attn_type == "mla")
    attn_type: str = "gqa"            # "gqa" | "mla"
    q_lora_rank: int = 0              # 0 = full-rank q projection
    kv_lora_rank: int = 0
    rope_head_dim: int = 0            # decoupled rope dims (MLA)
    block_q: int = 512
    block_kv: int = 1024
    # kv replication factor: full-seq paths repeat kv heads so that the
    # head axis divides the TP degree exactly (Megatron kv replication)
    kv_repeat: int = 1


# ======================================================================
# Blockwise (flash-style) attention core
# ======================================================================
def _flash_block_scan(q, k, v, causal: bool, q_offset, block_kv: int,
                      bias=None):
    """Online-softmax attention.

    q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D]; returns [B, Hq, Sq, D].
    Group-query: Hq is a multiple of Hkv; handled by reshaping q into
    [B, Hkv, G, Sq, D] so each KV head serves G query heads.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    n_blocks = -(-skv // block_kv)
    pad = n_blocks * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, n_blocks, block_kv, d)
    vb = v.reshape(b, hkv, n_blocks, block_kv, d)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kv_i, k_i, v_i = xs
        kv_pos = kv_i * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                       k_i.astype(jnp.float32)) * scale
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((sq, block_kv), bool)
        mask = jnp.logical_and(mask, (kv_pos < skv)[None, :])
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    kb_t = jnp.moveaxis(kb, 2, 0)   # [n_blocks, B, Hkv, bk, D]
    vb_t = jnp.moveaxis(vb, 2, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_blocks), kb_t, vb_t))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def flash_attention(q, k, v, *, causal=True, q_offset=0, block_kv=1024):
    return _flash_block_scan(q, k, v, causal, q_offset, block_kv)


# ======================================================================
# GQA
# ======================================================================
def gqa_init(key: jax.Array, cfg: AttnConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.head_dim
    p: Params = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def _project_qkv(params: Params, cfg: AttnConfig, x: jax.Array,
                 positions: jax.Array):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"]["scale"])
        k = rms_norm(k, params["k_norm"]["scale"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _replicate_kv(cfg: AttnConfig, k: jax.Array, v: jax.Array):
    """Repeat kv heads so the head axis divides TP exactly (Megatron kv
    replication).  GQA math is unchanged — property-tested."""
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    return k, v


def gqa_apply(params: Params, cfg: AttnConfig, x: jax.Array,
              positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence (train / prefill) GQA."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, cfg, x, positions)
    k, v = _replicate_kv(cfg, k, v)
    out = flash_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=cfg.causal, block_kv=cfg.block_kv)
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype)


def gqa_prefill(params: Params, cfg: AttnConfig, x: jax.Array,
                positions: jax.Array | None = None):
    """Returns (attn_out, (k_cache, v_cache)) with caches [B, Hkv, S, D]."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, cfg, x, positions)
    kc, vc = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)  # cache: real heads
    kr, vr = _replicate_kv(cfg, k, v)
    out = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(kr, 1, 2),
                          jnp.swapaxes(vr, 1, 2),
                          causal=cfg.causal, block_kv=cfg.block_kv)
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype), (kc, vc)


def gqa_decode(params: Params, cfg: AttnConfig, x: jax.Array,
               cache: tuple[jax.Array, jax.Array], cache_len: jax.Array):
    """One-token decode. x: [B, 1, D_model]; cache [B, Hkv, S_max, D]."""
    b = x.shape[0]
    hd = cfg.head_dim
    positions = jnp.full((1,), cache_len, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, positions)
    kc, vc = cache
    kc = jax.lax.dynamic_update_slice_in_dim(
        kc, jnp.swapaxes(k, 1, 2).astype(kc.dtype), cache_len, axis=2)
    vc = jax.lax.dynamic_update_slice_in_dim(
        vc, jnp.swapaxes(v, 1, 2).astype(vc.dtype), cache_len, axis=2)
    s_max = kc.shape[2]
    qh = jnp.swapaxes(q, 1, 2)                       # [B, Hq, 1, D]
    g = cfg.n_heads // cfg.n_kv_heads
    qg = qh.reshape(b, cfg.n_kv_heads, g, hd)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) / jnp.sqrt(hd)
    valid = jnp.arange(s_max)[None, None, None, :] <= cache_len
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, vc.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), (kc, vc)


# ======================================================================
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2)
# ======================================================================
def mla_init(key: jax.Array, cfg: AttnConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, hd, r = cfg.d_model, cfg.head_dim, cfg.rope_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    p: Params = {
        # q: d -> q_lora -> heads*(nope+rope)
        "wq_a": dense_init(ks[0], d, qr),
        "q_a_norm": norm_init(qr),
        "wq_b": dense_init(ks[1], qr, cfg.n_heads * (hd + r)),
        # kv: d -> kv_lora (+ shared k_rope)
        "wkv_a": dense_init(ks[2], d, kvr + r),
        "kv_a_norm": norm_init(kvr),
        # up-projections from the latent
        "wk_b": dense_init(ks[3], kvr, cfg.n_heads * hd),
        "wv_b": dense_init(ks[4], kvr, cfg.n_heads * hd),
        "wo": dense_init(ks[5], cfg.n_heads * hd, d),
    }
    return p


def _mla_qkv_full(params: Params, cfg: AttnConfig, x: jax.Array,
                  positions: jax.Array):
    b, s, _ = x.shape
    hd, r, kvr = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    qa = rms_norm(x @ params["wq_a"].astype(x.dtype),
                  params["q_a_norm"]["scale"])
    q = (qa @ params["wq_b"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd + r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"].astype(x.dtype)                  # [B,S,kvr+r]
    c_kv = rms_norm(kv[..., :kvr], params["kv_a_norm"]["scale"])
    k_rope = apply_rope(kv[..., kvr:][:, :, None, :], positions,
                        cfg.rope_theta)                        # [B,S,1,r]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params: Params, cfg: AttnConfig, x: jax.Array,
              positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence MLA: expand the latent to per-head K/V, then flash."""
    b, s, _ = x.shape
    hd, r = cfg.head_dim, cfg.rope_head_dim
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_full(params, cfg, x, positions)
    k_nope = (c_kv @ params["wk_b"].astype(x.dtype)).reshape(
        b, s, cfg.n_heads, hd)
    v = (c_kv @ params["wv_b"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    # fold the decoupled rope part into the head dim (shared k_rope per head)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, s, cfg.n_heads, r))], axis=-1)
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, r)))
    out = flash_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v_pad, 1, 2), causal=cfg.causal, block_kv=cfg.block_kv)
    out = jnp.swapaxes(out, 1, 2)[..., :hd].reshape(b, s, cfg.n_heads * hd)
    return out @ params["wo"].astype(x.dtype)


def mla_prefill(params: Params, cfg: AttnConfig, x: jax.Array,
                positions: jax.Array | None = None):
    """Cache only the latent (c_kv) + shared rope key — MLA's memory win."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    out = mla_apply(params, cfg, x, positions)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_full(params, cfg, x, positions)
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(params: Params, cfg: AttnConfig, x: jax.Array,
               cache: tuple[jax.Array, jax.Array], cache_len: jax.Array,
               absorb: bool = False):
    """One-token MLA decode against latent cache (c_kv [B,S,kvr],
    k_rope [B,S,r]).

    absorb=False (baseline): expand latent to per-head K/V each step.
    absorb=True (optimized): score/accumulate in latent space — the
    W_UK/W_UV absorption trick; O(S*kvr) instead of O(S*H*hd) bytes.
    """
    b = x.shape[0]
    hd, r, kvr = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    positions = jnp.full((1,), cache_len, jnp.int32)
    q_nope, q_rope, c_new, k_rope_new = _mla_qkv_full(params, cfg, x, positions)
    c_cache, r_cache = cache
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        c_cache, c_new.astype(c_cache.dtype), cache_len, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        r_cache, k_rope_new[:, :, 0, :].astype(r_cache.dtype), cache_len, axis=1)
    s_max = c_cache.shape[1]
    valid = (jnp.arange(s_max)[None, None, :] <= cache_len)

    q_nope_h = q_nope[:, 0]                       # [B, H, hd]
    q_rope_h = q_rope[:, 0]                       # [B, H, r]
    scale = 1.0 / jnp.sqrt(hd + r)

    if absorb:
        wk = params["wk_b"].reshape(kvr, cfg.n_heads, hd)
        q_lat = jnp.einsum("bhd,khd->bhk", q_nope_h.astype(jnp.float32),
                           wk.astype(jnp.float32))            # [B,H,kvr]
        s_lat = jnp.einsum("bhk,bsk->bhs", q_lat,
                           c_cache.astype(jnp.float32))
        s_rope = jnp.einsum("bhr,bsr->bhs", q_rope_h.astype(jnp.float32),
                            r_cache.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        scores = jnp.where(valid, scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhs,bsk->bhk", w, c_cache.astype(jnp.float32))
        wv = params["wv_b"].reshape(kvr, cfg.n_heads, hd)
        out = jnp.einsum("bhk,khd->bhd", ctx_lat, wv.astype(jnp.float32))
    else:
        k_nope = jnp.einsum("bsk,kD->bsD", c_cache.astype(jnp.float32),
                            params["wk_b"].astype(jnp.float32)).reshape(
            b, s_max, cfg.n_heads, hd)
        v_full = jnp.einsum("bsk,kD->bsD", c_cache.astype(jnp.float32),
                            params["wv_b"].astype(jnp.float32)).reshape(
            b, s_max, cfg.n_heads, hd)
        s_nope = jnp.einsum("bhd,bshd->bhs", q_nope_h.astype(jnp.float32),
                            k_nope)
        s_rope = jnp.einsum("bhr,bsr->bhs", q_rope_h.astype(jnp.float32),
                            r_cache.astype(jnp.float32))
        scores = (s_nope + s_rope) * scale
        scores = jnp.where(valid, scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhs,bshd->bhd", w, v_full)

    out = out.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), (c_cache, r_cache)
