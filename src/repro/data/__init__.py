from repro.data.pipeline import DataConfig, PrefetchLoader, SyntheticCorpus

__all__ = ["DataConfig", "SyntheticCorpus", "PrefetchLoader"]
