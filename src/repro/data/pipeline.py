"""Synthetic data pipeline with *tunable spatial locality*.

The corpus is a deterministic order-1 Markov token stream whose
stationary distribution is Zipf(alpha).  alpha controls how skewed the
embedding-gather address stream is — the knob the AMM MemoryPlanner
(repro.memory.planner) reads when deciding bank/port configs, mirroring
the paper's locality-driven design choice.

Host sharding: every (process, data-shard) pair derives a disjoint
deterministic key, so the pipeline scales to multi-host without any
coordination.  A background prefetch thread keeps ``prefetch`` batches
ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    zipf_alpha: float = 1.2
    markov_order_strength: float = 0.7   # prob of following the chain
    seed: int = 1234
    n_shards: int = 1
    shard_id: int = 0
    prefetch: int = 2


class SyntheticCorpus:
    """Deterministic, learnable synthetic LM corpus."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.local_batch = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self.stationary = p / p.sum()
        # sparse deterministic "grammar": each token has one likely successor
        self.successor = rng.permutation(v).astype(np.int64)

    def batch_iter(self) -> Iterator[dict[str, np.ndarray]]:
        cfg = self.cfg
        step = 0
        while True:
            rng = np.random.default_rng(
                (cfg.seed, cfg.shard_id, step))
            b, s = self.local_batch, cfg.seq_len
            follow = rng.random((b, s)) < cfg.markov_order_strength
            fresh = rng.choice(cfg.vocab, size=(b, s), p=self.stationary)
            toks = np.empty((b, s + 1), np.int32)
            toks[:, 0] = fresh[:, 0]
            for t in range(1, s + 1):
                nxt = self.successor[toks[:, t - 1]]
                toks[:, t] = np.where(follow[:, t - 1], nxt, fresh[:, t - 1])
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
            step += 1

    def embedding_trace(self, n_tokens: int = 8192) -> np.ndarray:
        """Byte-address stream of the embedding gathers this corpus
        generates — consumed by the AMM planner / locality metric."""
        it = self.batch_iter()
        out = []
        while sum(x.size for x in out) < n_tokens:
            out.append(next(it)["tokens"].reshape(-1))
        ids = np.concatenate(out)[:n_tokens]
        return ids.astype(np.int64) * 4          # 4-byte table rows


class PrefetchLoader:
    """Runs the corpus iterator in a daemon thread."""

    def __init__(self, corpus: SyntheticCorpus) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=corpus.cfg.prefetch)
        self._stop = threading.Event()

        def worker() -> None:
            for batch in corpus.batch_iter():
                if self._stop.is_set():
                    return
                self._q.put(batch)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
