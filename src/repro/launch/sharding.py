"""Sharding rules engine.

Maps every parameter / activation / cache tensor to a PartitionSpec over
the production mesh axes ("pod", "data", "model"):

  * TP (Megatron): attention heads, FFN hidden, experts, vocab -> "model"
  * FSDP/ZeRO: the other matrix dim of every weight        -> "data"
  * DP: batch -> ("pod", "data")   (pod is pure DP; grads all-reduce)
  * SP (optional, rt.seq_shard_acts): boundary activations' sequence
    axis -> "model" (Megatron sequence parallelism)

Model code calls :func:`constrain` with a *role* string; outside a
launcher context it is the identity, so models stay mesh-agnostic and
unit tests see no sharding machinery.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# ----------------------------------------------------------------------
# Activation-sharding context
# ----------------------------------------------------------------------
_SHARDER: contextvars.ContextVar[Callable | None] = contextvars.ContextVar(
    "activation_sharder", default=None)
_TP_HINT: contextvars.ContextVar[int] = contextvars.ContextVar(
    "tp_hint", default=1)


def tp_hint() -> int:
    """Tensor-parallel degree the launcher is lowering for (1 = none).
    Models use it to replicate GQA kv heads up to a multiple of TP so
    the head axis shards exactly (kv replication, standard Megatron)."""
    return _TP_HINT.get()


def constrain(x: jax.Array, role: str, rt: Any = None) -> jax.Array:
    fn = _SHARDER.get()
    if fn is None:
        return x
    return fn(x, role, rt)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_axes: tuple[str, ...],
                        seq_shard_acts: bool = False,
                        axis_profile: str = "tp"):
    """Install the launcher's activation sharder."""
    vocab_axis = "model" if axis_profile == "tp" else None

    def sharder(x: jax.Array, role: str, rt: Any = None) -> jax.Array:
        if x.ndim < 2:
            return x
        bspec = batch_axes if batch_axes else None
        seq = None
        if role == "hidden":
            if (seq_shard_acts and x.ndim == 3
                    and x.shape[1] % mesh.shape["model"] == 0
                    and x.shape[1] > 1):
                seq = "model"
            spec = P(bspec, seq, *([None] * (x.ndim - 2)))
        elif role == "tp_in":
            # explicit SP -> TP transition: activations enter the
            # tensor-parallel matmuls seq-UNsharded, so the weights'
            # "model" sharding survives (otherwise GSPMD all-gathers
            # full weight matrices per layer — measured 48x collective
            # blow-up on mistral-123b, see EXPERIMENTS.md §Perf)
            spec = P(bspec, *([None] * (x.ndim - 1)))
        elif role == "logits":
            spec = P(bspec, None, vocab_axis)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    tok = _SHARDER.set(sharder)
    tok2 = _TP_HINT.set(int(mesh.shape.get("model", 1))
                        if axis_profile == "tp" else 1)
    try:
        yield
    finally:
        _SHARDER.reset(tok)
        _TP_HINT.reset(tok2)


# ----------------------------------------------------------------------
# Batch axes
# ----------------------------------------------------------------------
def batch_axes_for(mesh: Mesh, global_batch: int,
                   include_model: bool = False) -> tuple[str, ...]:
    """Largest prefix of (pod, data[, model]) whose product divides the
    batch.  include_model=True is the pure-DP profile (no TP): the model
    axis becomes extra data parallelism."""
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes: list[str] = []
    prod = 1
    for name in names:
        if name in mesh.shape:
            n = mesh.shape[name]
            if global_batch % (prod * n) == 0:
                axes.append(name)
                prod *= n
    # prefer ("data",) alone if pod doesn't fit but data does
    if not axes and "data" in mesh.shape and \
            global_batch % mesh.shape["data"] == 0:
        axes = ["data"]
    return tuple(axes)


# ----------------------------------------------------------------------
# Parameter rules: leaf-name -> PartitionSpec of the *unstacked* tensor.
# A leading layer-stack axis (rank == len(spec)+1) gets None prepended.
# ----------------------------------------------------------------------
_PARAM_RULES: dict[str, P] = {
    # embeddings / head
    "embed": P("model", "data"),
    "head": P("data", "model"),
    "patch_proj": P(None, "data"),
    # attention (gqa)
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    # attention (mla)
    "wq_a": P("data", None),
    "wq_b": P(None, "model"),
    "wkv_a": P("data", None),
    "wk_b": P(None, "model"),
    "wv_b": P(None, "model"),
    # mlp
    "w_up": P("data", "model"),
    "w_gate": P("data", "model"),
    "w_down": P("model", "data"),
    # moe (expert-stacked: E D F / E F D)
    "router": P("data", None),
    # mamba2
    "in_proj": P("data", "model"),
    "out_proj": P("model", "data"),
    "conv_w": P(None, "model"),
    # hybrid shared block
    "w_cat": P("data", "model"),
}

# expert-stacked MoE weights carry an [E, ...] axis -> experts on "model"
_MOE_EXPERT_RULES: dict[str, P] = {
    "w_up": P("model", "data", None),
    "w_gate": P("model", "data", None),
    "w_down": P("model", None, "data"),
}


def _fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh | None) -> P:
    """Drop axes whose size does not divide the dimension (e.g. mamba
    in_proj's 2*d_inner + 2*state + H tail dim)."""
    if mesh is None:
        return spec
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        prod = 1
        for a in axes:
            prod *= mesh.shape.get(a, 1)
        out.append(axis if dim % prod == 0 else None)
    return P(*out)


def _to_dp_profile(spec: P) -> P:
    """Pure-FSDP profile: no tensor parallelism — the 'data' dim of each
    weight is sharded over BOTH mesh axes, 'model' dims replicate."""
    out = []
    for axis in spec:
        if axis == "data":
            out.append(("data", "model"))
        elif axis == "model":
            out.append(None)
        else:
            out.append(axis)
    return P(*out)


def _spec_for_path(path: tuple, leaf: Any, mesh: Mesh | None,
                   axis_profile: str) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    stacked = names[0] in ("blocks", "enc_blocks")
    in_moe = "moe" in names
    if in_moe and name in _MOE_EXPERT_RULES:
        spec = _MOE_EXPERT_RULES[name]
    elif name in _PARAM_RULES:
        spec = _PARAM_RULES[name]
    else:
        spec = None  # norms, biases, A_log, scales... -> replicated
    rank = len(leaf.shape)
    if spec is None:
        return P(*([None] * rank))
    if axis_profile == "dp" and not in_moe:
        spec = _to_dp_profile(spec)
    if stacked and rank == len(spec) + 1:
        spec = P(None, *spec)
    elif rank != len(spec):
        # rank mismatch (e.g. tiny test config) -> replicate
        return P(*([None] * rank))
    return _fit_spec(spec, leaf.shape, mesh)


def param_pspecs(params_shape: Any, mesh: Mesh | None = None,
                 axis_profile: str = "tp") -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.  With a
    mesh, axes that don't divide the dim are dropped (replicated)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _spec_for_path(p, l, mesh, axis_profile), params_shape)


def cache_pspecs(cache_shape: Any, mesh: Mesh, global_batch: int,
                 kv_shard: str = "auto") -> Any:
    """Decode-cache specs.  KV caches [L, B, Hkv, S, D]: batch on
    (pod,data) when divisible; heads on "model" when divisible, else the
    sequence axis (flash-decode over sharded KV length)."""
    baxes = batch_axes_for(mesh, global_batch)
    bspec = baxes if baxes else None
    m = mesh.shape.get("model", 1)

    def spec(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        rank = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v", "shared_k", "shared_v"):
            L, B, H, S, D = leaf.shape
            if kv_shard == "heads" or (kv_shard == "auto" and H % m == 0):
                return P(None, bspec if B % _prod(mesh, baxes) == 0 else None,
                         "model" if H % m == 0 else None, None, None)
            return P(None, bspec if B % _prod(mesh, baxes) == 0 else None,
                     None, "model" if S % m == 0 else None, None)
        if name in ("c_kv", "k_rope"):
            L, B, S, D = leaf.shape
            return P(None, bspec if B % _prod(mesh, baxes) == 0 else None,
                     "model" if S % m == 0 else None, None)
        if name == "ssm_h":
            L, B, H, Pd, N = leaf.shape
            return P(None, bspec if B % _prod(mesh, baxes) == 0 else None,
                     "model" if H % m == 0 else None, None, None)
        if name == "ssm_conv":
            L, B, W, C = leaf.shape
            return P(None, bspec if B % _prod(mesh, baxes) == 0 else None,
                     None, "model" if C % m == 0 else None)
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def _prod(mesh: Mesh, axes: tuple[str, ...]) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return max(out, 1)


def input_pspecs(batch_shape: Any, mesh: Mesh, global_batch: int,
                 batch_axes: tuple[str, ...] | None = None) -> Any:
    baxes = batch_axes_for(mesh, global_batch) if batch_axes is None \
        else batch_axes
    bspec = baxes if baxes else None

    def spec(path, leaf) -> P:
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        if leaf.shape[0] == global_batch and global_batch % _prod(mesh, baxes) == 0:
            return P(bspec, *([None] * (rank - 1)))
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def to_named(tree_spec: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec,
                        is_leaf=lambda x: isinstance(x, P))
