"""End-to-end training driver (example application + FT demonstration).

Trains a reduced-config model on the synthetic corpus with the full
production substrate: jitted train step (grad accum + AdamW), periodic
checkpoints, straggler monitoring, optional int8 gradient compression
(error feedback), and crash-restart recovery (--simulate-failure).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --preset tiny --steps 50
  PYTHONPATH=src python -m repro.launch.train --preset m100 --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_arch, tiny_variant
from repro.configs.base import ArchConfig, RuntimeConfig
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models import DTypePolicy, count_params, init_model
from repro.optim import adamw
from repro.runtime import (HeartbeatMonitor, compressed_grad_tree)

M100 = ArchConfig(
    name="m100", family="dense", n_layers=12, d_model=640, n_heads=10,
    n_kv_heads=5, d_ff=2560, vocab=16384, head_dim=64, qk_norm=True,
    act="silu", gated_mlp=True, tie_embeddings=True)


def build_arch(args) -> ArchConfig:
    if args.preset == "m100":
        return M100
    base = get_arch(args.arch)
    if args.preset == "tiny":
        return tiny_variant(base)
    return base


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCH_NAMES))
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "m100", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="crash (and auto-restart once) at this step")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = build_arch(args)
    rt = RuntimeConfig(accum_steps=args.accum, remat="none")
    policy = DTypePolicy.standard()
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    params = init_model(key, arch, policy)
    opt_state = adamw.init(params, policy)
    print(f"arch={arch.name} params={count_params(params)/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    corpus = SyntheticCorpus(DataConfig(
        vocab=arch.vocab, seq_len=args.seq, global_batch=args.batch))
    loader = PrefetchLoader(corpus)

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2, async_save=True)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    base_step = make_train_step(arch, rt, policy, opt_cfg)

    if args.compress_grads:
        # wrap: grads quantized int8 with error feedback before the update
        def step_fn(params, opt_state, err, batch):
            def micro(p, b):
                from repro.models.lm import loss_fn
                return loss_fn(p, arch, b, rt, policy)
            (loss, _), grads = jax.value_and_grad(micro, has_aux=True)(
                params, batch)
            grads, err = compressed_grad_tree(grads, err)
            new_p, new_o, stats = adamw.update(grads, opt_state, params,
                                               opt_cfg, policy)
            return new_p, new_o, err, {"loss": loss, **stats}
        step = jax.jit(step_fn)
        err_state = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
    else:
        step = jax.jit(base_step)
        err_state = None

    monitor = HeartbeatMonitor(n_workers=1)
    losses = []
    crashed = False
    i = start
    while i < args.steps:
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        t0 = time.time()
        if args.compress_grads:
            params, opt_state, err_state, stats = step(
                params, opt_state, err_state, batch)
        else:
            params, opt_state, stats = step(params, opt_state, batch)
        stats["loss"].block_until_ready()
        monitor.report(0, time.time() - t0)
        losses.append(float(stats["loss"]))
        i += 1
        if args.simulate_failure and i == args.simulate_failure and not crashed:
            print(f"!! simulated node failure at step {i}; restoring")
            crashed = True
            ckpt.save(i, {"params": params, "opt": opt_state}, blocking=True)
            # crash: lose live state
            params = opt_state = None
            state = ckpt.restore(
                {"params": jax.eval_shape(lambda: init_model(key, arch, policy)),
                 "opt": None} if False else
                {"params": init_model(key, arch, policy),
                 "opt": adamw.init(init_model(key, arch, policy), policy)})
            params, opt_state = state["params"], state["opt"]
            i = ckpt.latest_step()
            print(f"recovered at step {i}")
        if i % args.ckpt_every == 0:
            ckpt.save(i, {"params": params, "opt": opt_state})
        if i % args.log_every == 0 or i == args.steps:
            print(f"step {i:5d} loss={losses[-1]:.4f} "
                  f"lr={float(stats['lr']):.2e} "
                  f"gnorm={float(stats['grad_norm']):.2f} "
                  f"dt={time.time()-t0:.3f}s")
    ckpt.wait()
    loader.close()
    out = {"first_loss": losses[0], "final_loss": losses[-1],
           "steps": len(losses)}
    print(f"done: loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    assert out["final_loss"] < out["first_loss"], "training failed to learn"
    return out


if __name__ == "__main__":
    main()
