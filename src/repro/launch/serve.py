"""Serving driver: prefill a batch of prompts, then decode with the
banked KV cache (example application for the inference shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --preset tiny --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch, tiny_variant
from repro.configs.base import RuntimeConfig
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.memory import plan_memory
from repro.configs.base import SHAPES
from repro.models import DTypePolicy, init_model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCH_NAMES))
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mla-absorb", action="store_true")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.preset == "tiny":
        arch = tiny_variant(arch)
    rt = RuntimeConfig(remat="none", mla_absorb=args.mla_absorb)
    policy = DTypePolicy.standard()

    # the paper's planner: pick the memory layout for this serving shape
    plan = plan_memory(arch, SHAPES["decode_32k"])
    print("memory plan:")
    for s in plan.streams:
        print(f"  {s.stream:12s} L={s.locality:5.3f} "
              f"{'AMM' if s.use_amm else 'banked'} banks={s.n_banks}  ({s.note})")

    params = init_model(jax.random.PRNGKey(0), arch, policy)
    cache_len = args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, arch.vocab, (args.batch, args.prompt_len)), jnp.int32)
    batch = {"tokens": tokens}
    if arch.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, arch.n_patches, arch.vit_dim)),
            jnp.float32)

    if arch.family in ("hybrid",) or arch.is_encdec:
        # drivers for these families decode from an empty cache
        from repro.models import make_cache
        cache = make_cache(arch, cache_len, args.batch, policy)
        if arch.is_encdec:
            print("enc-dec: decoding against zero cross-cache (driver demo)")
        last = tokens[:, :1]
    else:
        prefill_step = jax.jit(make_prefill_step(arch, rt, policy, cache_len))
        t0 = time.time()
        logits, cache = jax.block_until_ready(prefill_step(params, batch))
        t_prefill = time.time() - t0
        print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill:.3f}s")
        last = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)

    decode = jax.jit(make_decode_step(arch, rt, policy))
    outs = []
    t0 = time.time()
    for i in range(args.gen):
        last, logits, cache = decode(params, cache, last)
        outs.append(np.asarray(last))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    toks = args.gen * args.batch
    print(f"decode: {toks} tokens in {dt:.3f}s -> {toks/dt:.1f} tok/s")
    gen = np.concatenate(outs, axis=1)
    print("sample continuation ids:", gen[0, :16].tolist())
    return {"tok_per_s": toks / dt, "generated": gen}


if __name__ == "__main__":
    main()
