"""Step builders: the jitted SPMD programs the launcher lowers.

``make_train_step`` builds loss -> grad -> AdamW update with optional
microbatch gradient accumulation (lax.scan over the split batch, grads
accumulated in the policy's moment dtype to bound HBM).  ``make_*_step``
variants for serving build prefill and single-token decode.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RuntimeConfig
from repro.models.common import DTypePolicy
from repro.models.lm import decode_step, loss_fn, prefill
from repro.optim import adamw


def make_train_step(arch: ArchConfig, rt: RuntimeConfig,
                    policy: DTypePolicy,
                    opt_cfg: adamw.AdamWConfig | None = None) -> Callable:
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def micro_loss(params, mb):
        loss, metrics = loss_fn(params, arch, mb, rt, policy)
        return loss, metrics

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        a = rt.accum_steps
        if a <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape(a, x.shape[0] // a, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, policy.moments), params)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (l, _), g = grad_fn(params, mb)
                g_sum = jax.tree.map(
                    lambda s, gi: s + gi.astype(policy.moments), g_sum, g)
                return (g_sum, l_sum + l), None

            (g_sum, l_sum), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / a, g_sum)
            loss = l_sum / a
            metrics = {}
        new_params, new_opt, stats = adamw.update(
            grads, opt_state, params, opt_cfg, policy)
        return new_params, new_opt, {"loss": loss, **stats}

    return train_step


def make_prefill_step(arch: ArchConfig, rt: RuntimeConfig,
                      policy: DTypePolicy, cache_len: int) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, arch, batch, cache_len, rt, policy)

    return prefill_step


def make_decode_step(arch: ArchConfig, rt: RuntimeConfig,
                     policy: DTypePolicy) -> Callable:
    def serve_step(params, cache, tokens):
        logits, cache = decode_step(params, arch, cache, tokens, rt, policy)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), logits, cache

    return serve_step
