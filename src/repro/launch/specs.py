"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell —
weak-type-correct, shardable, never allocated (dry-run pattern).

Also resolves the per-cell RuntimeConfig (dtype preset, accumulation,
activation sequence-sharding, kv sharding) — the launcher-side knobs
that make the big cells fit 16 GB/chip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, RuntimeConfig, ShapeConfig)
from repro.models.common import DTypePolicy
from repro.models.lm import make_cache

I32 = jnp.int32
BF16 = jnp.bfloat16


def resolve_runtime(arch: ArchConfig, shape: ShapeConfig,
                    n_data_shards: int = 16,
                    profile: str = "baseline") -> RuntimeConfig:
    """Per-cell runtime knobs (see DESIGN.md §4).

    profile="baseline": paper-faithful uniform Megatron TP-16 + blanket
    accumulation rules — the §Roofline baseline.
    profile="opt": the §Perf hillclimbed configuration — accumulation
    chosen by activation-budget math (in-scan collective traffic scales
    linearly with accum, so accum is minimized subject to HBM), and
    small archs trade TP for pure-FSDP over all chips (their TP psum
    cost exceeds their compute).
    """
    n = arch.param_count_estimate()
    big = n >= 60e9
    huge = n >= 200e9
    accum = 1
    if shape.kind == "train":
        # n_data_shards should be the product of ALL batch axes (incl. pod)
        per_dev_seqs = max(shape.global_batch // n_data_shards, 1)
        if profile == "opt":
            # boundary activations (post-SP) must fit ~6 GB HBM:
            # act_bytes = L * S * d_model * 2 / TP16 per sequence
            act_per_seq = arch.n_layers * shape.seq_len * arch.d_model * 2 / 16
            budget = 6e9
            need = act_per_seq * per_dev_seqs / budget
            accum = 1
            while accum < per_dev_seqs and need > accum:
                accum *= 2
        else:
            if huge:
                accum = per_dev_seqs
            elif big:
                accum = max(per_dev_seqs // 2, 1)
            elif arch.d_model >= 2048:
                accum = max(per_dev_seqs // 8, 1)
    preset = "standard"
    if big:
        preset = "lean"
    if huge:
        preset = "ultra_lean" if shape.kind != "train" else "lean"
    axis_profile = "tp"
    # dp profile: small archs trade TP for pure FSDP; _fit_spec degrades
    # weight sharding gracefully when dims don't divide 256 (replication
    # is affordable exactly because these models are small)
    if profile == "opt" and shape.kind == "train" and n < 8e9:
        axis_profile = "dp"
    return RuntimeConfig(
        dtype_preset=preset,
        accum_steps=accum,
        seq_shard_acts=(arch.d_model >= 6144 or shape.seq_len >= 32768)
        and axis_profile == "tp",
        kv_shard="auto",
        mla_absorb=profile == "opt",
        remat="full" if shape.kind == "train" else "none",
        axis_profile=axis_profile,
    )


def policy_for(rt: RuntimeConfig) -> DTypePolicy:
    return {"standard": DTypePolicy.standard(),
            "lean": DTypePolicy.lean(),
            "ultra_lean": DTypePolicy.ultra_lean()}[rt.dtype_preset]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                rt: RuntimeConfig | None = None) -> dict:
    """Step inputs for the cell.

    train/prefill: token batch (+ modality stubs).  decode: one new
    token per sequence (+ the cache spec via ``cache_specs``)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((b, 1), I32)}
    batch: dict = {}
    if arch.family == "vlm":
        s_text = s - arch.n_patches
        batch["patches"] = _sds((b, arch.n_patches, arch.vit_dim), BF16)
        batch["tokens"] = _sds((b, s_text), I32)
        if shape.kind == "train":
            batch["labels"] = _sds((b, s_text), I32)
        return batch
    if arch.is_encdec:
        batch["frames"] = _sds((b, s, arch.d_model), BF16)
    batch["tokens"] = _sds((b, s), I32)
    if shape.kind == "train":
        batch["labels"] = _sds((b, s), I32)
    return batch


def cache_specs(arch: ArchConfig, shape: ShapeConfig,
                rt: RuntimeConfig | None = None) -> dict:
    rt = rt or resolve_runtime(arch, shape)
    policy = policy_for(rt)
    return jax.eval_shape(
        lambda: make_cache(arch, shape.seq_len, shape.global_batch, policy))


def abstract_params(arch: ArchConfig, rt: RuntimeConfig | None = None):
    from repro.models.lm import init_model
    rt = rt or RuntimeConfig()
    policy = policy_for(rt)
    return jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), arch, policy))


def abstract_opt_state(params_spec, rt: RuntimeConfig | None = None):
    from repro.optim import adamw
    rt = rt or RuntimeConfig()
    policy = policy_for(rt)
    return jax.eval_shape(lambda: adamw.init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_spec),
        policy))
