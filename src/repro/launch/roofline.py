"""Roofline analysis from compiled dry-run artifacts (no hardware).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = dot_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

Methodology (documented in EXPERIMENTS.md §Roofline): plain
``compiled.cost_analysis()`` counts every while (lax.scan) body ONCE —
with scan-over-layers + microbatch accumulation that under-counts flops
by ~L x accum (verified empirically: 9x on qwen3).  We therefore walk
the post-optimization HLO text ourselves:

  * computations are parsed into a call graph; ``while`` ops carry
    ``backend_config known_trip_count`` which we use as multipliers
    (conditional branches contribute their max; fusions are traversed);
  * compute = 2 * prod(result_dims) * prod(contracted lhs dims) summed
    over every ``dot`` (matmul-only — elementwise flops are noise at
    these scales);
  * memory  = operand + result bytes of every ``dot`` plus result bytes
    of ``gather``/``reduce`` ops.  CPU HLO materializes elementwise
    chains a TPU would fuse, so counting every instruction massively
    overstates HBM traffic; matmul operands/results and table gathers
    are the traffic that cannot fuse away.  The launcher adds analytic
    optimizer-update traffic (pure elementwise, invisible to this
    counter) on top for train cells;
  * collective = result-shape payload of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (the partitioned
    module's shapes are per-device, so terms are per-chip directly),
    scaled by the ring cost factor per kind (all-reduce moves
    2(n-1)/n ~ 2x its payload per device; gather/scatter/a2a ~ 1x).

dtype correction: XLA:CPU legalizes bf16 dots to f32, so the dry-run
HLO shows f32 activations/collectives that are bf16 on TPU.  With
``f32_as_bf16=True`` (the launcher default) f32 payloads are counted at
2 bytes — matching the TPU execution our dtype policy produces (bf16
compute, bf16 grad accumulation/reduction; fp32 master weights never
cross chips).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

HW = {
    "peak_flops": 197e12,
    "hbm_bw": 819e9,
    "link_bw": 50e9,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(?P<dt>(?:f|bf|s|u|c)[0-9]+(?:e[0-9]+m[0-9]+\w*)?|pred)"
    r"\[(?P<dims>[0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%(?P<name>[\w\.\-]+)\s*=\s*"
                       r"(?P<rest>.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\(.*\{")
_OPNAME_RE = re.compile(
    r"^(?P<shape>(?:\([^)]*\)|[\w\[\],\{\}\s\/\*]+?))\s+"
    r"(?P<op>[\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?\{?%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%([\w\.\-]+)")


# per-device ring traffic per byte of payload
_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_list_bytes(text: str, f32_as_bf16: bool = False) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group("dims").split(","):
            if d:
                n *= int(d)
        nbytes = _DTYPE_BYTES[dt]
        if f32_as_bf16 and dt == "f32":
            nbytes = 2   # CPU-legalized bf16 (see module docstring)
        total += n * nbytes
    return total


def _shape_dims(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group("dims").split(",") if d]
        out.append((m.group("dt"), dims))
    return out


@dataclasses.dataclass
class _Comp:
    flops: float = 0.0
    bytes_rw: float = 0.0
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    # (kind, callee, multiplier); kind in {"while","call","branch","fusion"}
    calls: list[tuple[str, str, float]] = dataclasses.field(
        default_factory=list)
    branch_groups: list[list[str]] = dataclasses.field(default_factory=list)


_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "constant", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "iota",
}


class HloAnalysis:
    """Trip-count-aware flops/bytes/collectives from HLO text."""

    def __init__(self, hlo_text: str, f32_as_bf16: bool = False) -> None:
        self.comps: dict[str, _Comp] = {}
        self.entry: str | None = None
        self._f32bf16 = f32_as_bf16
        self._parse(hlo_text)
        self._memo: dict[str, tuple[float, float, dict]] = {}

    def _b(self, text: str) -> int:
        return _shape_list_bytes(text, self._f32bf16)

    # ------------------------------------------------------------------
    def _parse(self, txt: str) -> None:
        cur: _Comp | None = None
        cur_name = None
        shapes: dict[str, str] = {}
        for raw in txt.splitlines():
            if raw.startswith("}"):
                cur = None
                continue
            h = _HEADER_RE.match(raw)
            if h and not raw.startswith(" "):
                cur_name = h.group("name")
                cur = _Comp()
                self.comps[cur_name] = cur
                shapes = {}
                if raw.startswith("ENTRY"):
                    self.entry = cur_name
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(raw)
            if not mi:
                continue
            name, rest = mi.group("name"), mi.group("rest")
            om = _OPNAME_RE.match(rest)
            if not om:
                continue
            shape_txt, op = om.group("shape").strip(), om.group("op")
            shapes[name] = shape_txt

            if op in _COLLECTIVES or any(
                    op == c + sfx for c in _COLLECTIVES
                    for sfx in ("-start",)):
                base = op[:-6] if op.endswith("-start") else op
                cur.coll[base] += _RING_FACTOR[base] * self._b(shape_txt)
                cur.bytes_rw += self._b(shape_txt)
                continue
            if op == "while":
                b = _BODY_RE.search(rest)
                t = _TRIP_RE.search(rest)
                if b:
                    cur.calls.append(
                        ("while", b.group(1), float(t.group(1)) if t else 1.0))
                continue
            if op == "conditional":
                br = _BRANCHES_RE.search(rest)
                group = []
                if br:
                    group = re.findall(r"%([\w\.\-]+)", br.group(1))
                else:
                    group = _TF_RE.findall(rest)
                if group:
                    cur.branch_groups.append(group)
                continue
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "sort", "scatter", "reduce-window", "select-and-scatter"):
                cm = _CALLS_RE.search(rest)
                if cm and op in ("fusion", "call", "map"):
                    cur.calls.append(("call", cm.group(1), 1.0))
                if op in ("scatter", "sort", "reduce"):
                    cur.bytes_rw += 2 * self._b(shape_txt)
                continue
            if op == "dot":
                # operands: dot(%a, %b); resolve shapes from symbol table
                args = re.findall(r"%([\w\.\-]+)", rest.split("dot(", 1)[1]
                                  .split(")", 1)[0])
                res_dims = _shape_dims(shape_txt)
                res_n = 1
                for _, dims in res_dims[:1]:
                    for d in dims:
                        res_n *= d
                k = 1
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                if args and args[0] in shapes and mcd:
                    lhs_dims = _shape_dims(shapes[args[0]])
                    if lhs_dims:
                        dims = lhs_dims[0][1]
                        for idx in mcd.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                k *= dims[int(idx)]
                cur.flops += 2.0 * res_n * k
                # HBM traffic: both operands + the result
                cur.bytes_rw += self._b(shape_txt)
                for a in args[:2]:
                    if a in shapes:
                        cur.bytes_rw += self._b(shapes[a])
                continue
            if op in ("gather", "dynamic-slice"):
                cur.bytes_rw += 2 * self._b(shape_txt)

    # ------------------------------------------------------------------
    def _total(self, name: str, depth: int = 0
               ) -> tuple[float, float, dict[str, float]]:
        if name in self._memo:
            return self._memo[name]
        if name not in self.comps or depth > 64:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}
        c = self.comps[name]
        fl, by = c.flops, c.bytes_rw
        co = dict(c.coll)
        for kind, callee, mult in c.calls:
            f2, b2, c2 = self._total(callee, depth + 1)
            fl += mult * f2
            by += mult * b2
            for k in co:
                co[k] += mult * c2[k]
        for group in c.branch_groups:
            totals = [self._total(g, depth + 1) for g in group]
            if totals:
                best = max(totals, key=lambda t: t[0] + t[1])
                fl += best[0]
                by += best[1]
                for k in co:
                    co[k] += best[2][k]
        self._memo[name] = (fl, by, co)
        return self._memo[name]

    def totals(self) -> tuple[float, float, dict[str, float]]:
        if self.entry is None:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}
        return self._total(self.entry)


def analyze_hlo(hlo_text: str, f32_as_bf16: bool = True) -> dict:
    fl, by, co = HloAnalysis(hlo_text, f32_as_bf16).totals()
    return {"flops": fl, "bytes": by, "collectives": co,
            "collective_bytes": sum(co.values())}


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Trip-count-aware per-kind collective payload bytes."""
    _, _, co = HloAnalysis(hlo_text).totals()
    return {k: int(v) for k, v in co.items()}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collectives: dict[str, float]
    model_flops_global: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / HW["peak_flops"]

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HW["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / HW["link_bw"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s, 1e-12)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops x chips) — remat/redundancy waste."""
        hw = self.flops_per_device * self.chips
        return self.model_flops_global / hw if hw else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline-bound step time."""
        denom = self.step_s * self.chips * HW["peak_flops"]
        return self.model_flops_global / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "useful_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu,
            "collectives": self.collectives,
        }


def model_flops(arch_params: int, tokens: int, kind: str,
                active_params: int | None = None) -> float:
    """6*N*D for training, 2*N_active per generated token otherwise."""
    n = active_params or arch_params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens
