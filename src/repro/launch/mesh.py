"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi-pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(see launch/dryrun.py)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU integration tests (subprocess sets device count)."""
    import numpy as np
    n = int(np.prod(shape))
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes)
