import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may touch jax ---------------------------------------
import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, get_arch, shape_applicable  # noqa: E402
from repro.launch import sharding as shd                                  # noqa: E402
from repro.launch.mesh import make_production_mesh                        # noqa: E402
from repro.launch.roofline import (RooflineReport, analyze_hlo,           # noqa: E402
                                   model_flops)
from repro.launch.specs import (abstract_opt_state, abstract_params,      # noqa: E402
                                cache_specs, input_specs, policy_for,
                                resolve_runtime)
from repro.launch.steps import (make_decode_step, make_prefill_step,      # noqa: E402
                                make_train_step)

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, lower + compile the step
program against the production mesh — 16x16 single-pod and 2x16x16
multi-pod — using ShapeDtypeStruct inputs (no allocation), then print
memory_analysis() and cost_analysis() and emit the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out dryrun.jsonl
"""


def _tree_bytes_sharded(spec_tree, pspec_tree, mesh) -> int:
    """Analytic per-device bytes of a sharded pytree."""
    total = 0
    for spec, ps in zip(jax.tree.leaves(spec_tree),
                        jax.tree.leaves(pspec_tree,
                                        is_leaf=lambda x: isinstance(
                                            x, jax.sharding.PartitionSpec))):
        n = 1
        for d in spec.shape:
            n *= d
        shards = 1
        for axis in ps:
            if axis is None:
                continue
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                shards *= mesh.shape[a]
        total += n * spec.dtype.itemsize // max(shards, 1)
    return total


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             rt_overrides: dict | None = None, verbose: bool = True,
             profile: str = "baseline") -> dict:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    n_batch_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
    rt = resolve_runtime(arch, shape, n_data_shards=n_batch_shards,
                         profile=profile)
    if rt_overrides:
        import dataclasses as dc
        rt = dc.replace(rt, **rt_overrides)
    policy = policy_for(rt)

    t0 = time.time()
    params_spec = abstract_params(arch, rt)
    param_ps = shd.param_pspecs(params_spec, mesh, rt.axis_profile)
    param_sh = shd.to_named(param_ps, mesh)
    batch_spec = input_specs(arch, shape, rt)
    baxes = shd.batch_axes_for(mesh, shape.global_batch,
                               include_model=rt.axis_profile == "dp")
    batch_ps = shd.input_pspecs(batch_spec, mesh, shape.global_batch,
                                batch_axes=baxes)
    batch_sh = shd.to_named(batch_ps, mesh)

    import math
    n_params = sum(math.prod(l.shape) for l in jax.tree.leaves(params_spec))
    active = None
    if arch.family == "moe":
        # active = non-expert params + top_k/n_experts of expert params
        e_params = arch.n_layers * arch.n_experts * arch.d_model * \
            arch.d_ff * (3 if arch.gated_mlp else 2)
        active = n_params - e_params + e_params * arch.top_k // arch.n_experts

    with shd.activation_sharding(mesh, baxes, rt.seq_shard_acts,
                                 rt.axis_profile):
        if shape.kind == "train":
            opt_spec = abstract_opt_state(params_spec, rt)
            opt_ps = {"m": param_ps, "v": param_ps,
                      "step": jax.sharding.PartitionSpec()}
            opt_sh = shd.to_named(opt_ps, mesh)
            step = make_train_step(arch, rt, policy)
            jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh))
            lowered = jitted.lower(params_spec, opt_spec, batch_spec)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            step = make_prefill_step(arch, rt, policy, shape.seq_len)
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_spec, batch_spec)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            cache_spec = cache_specs(arch, shape, rt)
            cache_ps = shd.cache_pspecs(cache_spec, mesh, shape.global_batch,
                                        rt.kv_shard)
            cache_sh = shd.to_named(cache_ps, mesh)
            step = make_decode_step(arch, rt, policy)
            jitted = jax.jit(step, in_shardings=(
                param_sh, cache_sh, batch_sh["tokens"]))
            lowered = jitted.lower(params_spec, cache_spec,
                                   batch_spec["tokens"])
            tokens = shape.global_batch
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses ----
    mem_line = ""
    try:
        ma = compiled.memory_analysis()
        mem_line = str(ma)
    except Exception as e:  # CPU backend may not implement it
        mem_line = f"(memory_analysis unavailable on this backend: {e})"
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = dict(ca) if ca else {}
    except Exception as e:
        cost = {"error": str(e)}
    hlo = compiled.as_text()
    # trip-count-aware walk (plain cost_analysis counts scan bodies once)
    hm = analyze_hlo(hlo)
    coll = {k: int(v) for k, v in hm["collectives"].items()}

    # analytic per-device state bytes (params + opt for train; + cache)
    param_bytes = _tree_bytes_sharded(params_spec, param_ps, mesh)
    state_bytes = param_bytes
    opt_traffic = 0.0
    if shape.kind == "train":
        moment_bytes = param_bytes * policy.moments.dtype.itemsize // \
            jax.tree.leaves(params_spec)[0].dtype.itemsize
        state_bytes += 2 * moment_bytes
        # optimizer update: read p,m,v,g + write p,m,v (pure elementwise —
        # invisible to the dot-based HLO byte counter)
        opt_traffic = 4.0 * param_bytes + 4.0 * moment_bytes
    if shape.kind == "decode":
        state_bytes += _tree_bytes_sharded(cache_spec, cache_ps, mesh)

    rep = RooflineReport(
        arch=arch_name, shape=shape_name,
        mesh="pod2x16x16" if multi_pod else "pod16x16",
        chips=chips,
        flops_per_device=float(hm["flops"]),
        hbm_bytes_per_device=float(hm["bytes"]) + opt_traffic,
        collective_bytes_per_device=float(hm["collective_bytes"]),
        collectives=coll,
        model_flops_global=model_flops(n_params, tokens, shape.kind, active),
    )
    result = {
        "arch": arch_name, "shape": shape_name, "mesh": rep.mesh,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "n_params": n_params,
        "state_bytes_per_device": state_bytes,
        "rt": {"preset": rt.dtype_preset, "accum": rt.accum_steps,
               "seq_shard_acts": rt.seq_shard_acts,
               "axis_profile": rt.axis_profile, "profile": profile},
        "memory_analysis": mem_line[:400],
        "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "hlo_flops": rep.flops_per_device,
        "hlo_bytes": rep.hbm_bytes_per_device,
        "collective_bytes": rep.collective_bytes_per_device,
        "collectives": coll,
        "roofline": rep.row(),
    }
    if verbose:
        print(json.dumps(result, indent=1)[:2000])
        print(f"[{arch_name} x {shape_name} x {rep.mesh}] OK  "
              f"compile={t_compile:.0f}s  state/dev="
              f"{state_bytes/2**30:.2f}GiB  dominant={rep.dominant}  "
              f"terms=({rep.compute_s*1e3:.1f}, {rep.memory_s*1e3:.1f}, "
              f"{rep.collective_s*1e3:.1f})ms  mfu_bound={rep.mfu:.3f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "opt"])
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                try:
                    res = run_cell(a, s, mp, profile=args.profile)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": a, "shape": s,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": str(e)[:500]}
                    failures += 1
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(res) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
