"""Checkpointing: npy-per-leaf with a JSON manifest, atomic renames,
optional async writes, keep-last-k GC, and reshard-on-restore.

Restore never assumes the saving mesh: leaves come back as host numpy
arrays and are ``device_put`` with whatever sharding the *current* mesh
dictates — that is what makes elastic re-scaling (runtime/elastic.py)
work after losing a pod.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in leaves:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path)
        named.append((name, leaf))
    return named, treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3
    async_save: bool = False

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("ckpt_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool | None = None) -> str:
        """Atomic save; returns the checkpoint path."""
        named, _ = _flatten(tree)
        # np.load round-trips ml_dtypes (bfloat16 etc.) as raw void — we
        # store such leaves as a uint view and record the logical dtype.
        host = []
        for n, x in named:
            arr = np.asarray(jax.device_get(x))
            logical = str(arr.dtype)
            if arr.dtype.kind not in "fiub c":
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            host.append((n, arr, logical))

        def write() -> None:
            final = self._step_dir(step)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for name, arr, logical in host:
                fname = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append({
                    "name": name, "file": fname,
                    "shape": list(arr.shape), "dtype": logical,
                })
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking is None:
            blocking = not self.async_save
        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return self._step_dir(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``.  ``shardings``
        (optional pytree of NamedSharding) re-shards onto the current
        mesh — the saving mesh is irrelevant."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        by_name = {leaf["name"]: leaf for leaf in manifest["leaves"]}

        named, treedef = _flatten(template)
        shard_named = None
        if shardings is not None:
            shard_named, _ = _flatten(shardings)
        out = []
        import ml_dtypes  # registers bfloat16 & friends with numpy

        for i, (name, tmpl) in enumerate(named):
            if name not in by_name:
                raise KeyError(f"checkpoint {d} missing leaf {name!r}")
            leaf = by_name[name]
            arr = np.load(os.path.join(d, leaf["file"]))
            want = np.dtype(leaf["dtype"])
            if arr.dtype != want:
                arr = arr.view(want)       # undo the uint storage view
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"leaf {name}: saved {arr.shape} != template {tmpl.shape}")
            if shard_named is not None:
                out.append(jax.device_put(arr.astype(tmpl.dtype),
                                          shard_named[i][1]))
            else:
                out.append(jax.numpy.asarray(arr.astype(tmpl.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out)
