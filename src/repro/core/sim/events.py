"""Issue-event log shared by the three scheduler backends.

Every backend (``scheduler._schedule_py``, ``_cycle_loop.c`` via
``_cycle_ext``, ``jax_cycle``) can optionally record, for every issued
op, *where* the access landed and *how*: the cycle it issued, the
per-class port slot it occupied, the bank/leaf it touched and the path
kind it took.  The log is the raw material of the independent legality
checker in :mod:`repro.core.verify` — the checker re-derives what each
event was *allowed* to do straight from the :class:`AMMSpec` and
cross-examines the recorded resources, sharing none of the arbitration
code that produced them.

Because the list scheduler issues every trace op exactly once, the log
is node-indexed fixed-shape arrays rather than an append stream: entry
``i`` describes node ``i``.  That keeps recording allocation-free in
the C loop and fixed-shape in the JAX loop, and makes the three
backends' logs directly comparable (they are pinned equal by
``tests/test_verify.py``).

Path kinds (shared with the C enum in ``_cycle_loop.c``):

=================  ====================================================
``PATH_COMPUTE``   functional-unit op (no memory resource)
``PATH_DIRECT``    plain access: ideal/multipump port, banked bank,
                   NTX direct leaf, LVT read, remap live-bank read,
                   NTX plain (first-per-half / dedicated-port) write
``PATH_PARITY``    NTX read served by the full 2**k parity path
``PATH_STEERED``   remap write steered to a conflict-free bank
``PATH_PAIR_RMW``  B/HB-NTX same-half write pair through the Ref unit
``PATH_BROADCAST`` LVT write replicated into every read-port bank
=================  ====================================================

``resource`` is the structure the event occupied: the bank index for
banked accesses, the live/steered bank for remap, the packed
``(tree * n_leaves + leaf) * sub + sub_offset`` port key for NTX
direct reads, and ``-1`` where the kind has no single arbitrated
resource (ideal/LVT/multipump ports, parity fan-outs, pair RMWs —
their resource *sets* are re-derived by the checker).  ``slot`` is the
0-based issue ordinal within the op's resource class that cycle.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# path-kind codes; keep in sync with the P_* enum in _cycle_loop.c
PATH_COMPUTE = 0
PATH_DIRECT = 1
PATH_PARITY = 2
PATH_STEERED = 3
PATH_PAIR_RMW = 4
PATH_BROADCAST = 5

PATH_NAMES: dict[int, str] = {
    PATH_COMPUTE: "compute",
    PATH_DIRECT: "direct",
    PATH_PARITY: "parity",
    PATH_STEERED: "steered",
    PATH_PAIR_RMW: "pair_rmw",
    PATH_BROADCAST: "broadcast",
}


@dataclasses.dataclass
class EventLog:
    """Node-indexed issue events of one schedule run.

    All arrays have length ``n_nodes``; un-issued slots (only possible
    in a corrupted log) hold ``-1`` everywhere.
    """

    cycle: np.ndarray       # [n] int64 issue cycle
    path: np.ndarray        # [n] int64 PATH_* code
    resource: np.ndarray    # [n] int64 bank / leaf key, -1 if n/a
    slot: np.ndarray        # [n] int64 per-class issue ordinal in-cycle

    @classmethod
    def empty(cls, n: int) -> "EventLog":
        return cls(cycle=np.full(n, -1, np.int64),
                   path=np.full(n, -1, np.int64),
                   resource=np.full(n, -1, np.int64),
                   slot=np.full(n, -1, np.int64))

    @classmethod
    def from_packed(cls, packed: np.ndarray) -> "EventLog":
        """From the C loop's ``[n, 4]`` (cycle, path, resource, slot)."""
        packed = packed.reshape(-1, 4)
        return cls(cycle=packed[:, 0].copy(), path=packed[:, 1].copy(),
                   resource=packed[:, 2].copy(), slot=packed[:, 3].copy())

    @property
    def n_nodes(self) -> int:
        return int(self.cycle.shape[0])

    def copy(self) -> "EventLog":
        return EventLog(self.cycle.copy(), self.path.copy(),
                        self.resource.copy(), self.slot.copy())

    def __eq__(self, other) -> bool:
        if not isinstance(other, EventLog):
            return NotImplemented
        return (np.array_equal(self.cycle, other.cycle)
                and np.array_equal(self.path, other.path)
                and np.array_equal(self.resource, other.resource)
                and np.array_equal(self.slot, other.slot))
