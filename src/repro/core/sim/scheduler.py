"""Port-constrained cycle-accurate list scheduler (paper III-C).

'The cycle-accurate simulator schedules the data flow graph [...] The
DAG allows multiple accesses and the scheduler then issues the number of
accesses requested, accordingly from the read-write port configurations
and port width defined by the user.'

Resource model per cycle:
  * per-array memory ports — for conflict-free designs (AMM / ideal):
    ``n_read`` loads + ``n_write`` stores may issue per cycle, any
    addresses;
  * for ``banked``: each bank is an independent dual-port macro; an
    access issues only if its bank has a port left this cycle — the
    bank-conflict serialization the paper contrasts AMMs against;
  * for ``multipump``: 2x ports per external cycle (internally double
    clocked; the frequency penalty is applied by the cost composition);
  * functional units — ``fu_counts[kind]`` parallel units, as produced
    by Aladdin's loop unrolling ('multi-issue ALUs may be constructed by
    loop unrolling').

The scheduler is event-driven over the trace's DDG: priority = longest
path to sink (critical path first), standard list scheduling.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.amm.spec import AMMSpec
from repro.core.sim import trace as T


@dataclasses.dataclass
class ScheduleConfig:
    mem: dict[int, AMMSpec]                 # per-array memory design
    fu_counts: dict[str, int]               # parallel FUs per class
    mem_latency: int = 2                    # issue-to-data cycles for loads
    ports_per_bank: int = 2                 # dual-port leaf macros
    max_cycles: int = 50_000_000


@dataclasses.dataclass
class ScheduleResult:
    cycles: int
    issued: int
    mem_issued: int
    bank_conflict_stalls: int               # accesses delayed >=1 cycle by banking
    per_array_accesses: dict[int, int]
    avg_mem_parallelism: float

    def summary(self) -> dict:
        return dataclasses.asdict(self)


def _succ_lists(tr: T.Trace) -> tuple[np.ndarray, np.ndarray]:
    """CSR successor lists from the predecessor CSR."""
    n = tr.n_nodes
    counts = np.zeros(n, np.int64)
    np.add.at(counts, tr.pred_idx, 1)
    ptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    idx = np.empty(int(ptr[-1]), np.int64)
    fill = ptr[:-1].copy()
    for i in range(n):
        lo, hi = tr.pred_ptr[i], tr.pred_ptr[i + 1]
        for p in tr.pred_idx[lo:hi]:
            idx[fill[p]] = i
            fill[p] += 1
    return ptr, idx


def _heights(tr: T.Trace, succ_ptr: np.ndarray, succ_idx: np.ndarray) -> np.ndarray:
    """Longest path to any sink (list-scheduling priority)."""
    n = tr.n_nodes
    h = np.zeros(n, np.int64)
    for i in range(n - 1, -1, -1):
        lo, hi = succ_ptr[i], succ_ptr[i + 1]
        if hi > lo:
            h[i] = h[succ_idx[lo:hi]].max() + T.LATENCY[int(tr.kinds[i])]
    return h


def schedule(tr: T.Trace, cfg: ScheduleConfig) -> ScheduleResult:
    n = tr.n_nodes
    succ_ptr, succ_idx = _succ_lists(tr)
    height = _heights(tr, succ_ptr, succ_idx)
    n_preds = (tr.pred_ptr[1:] - tr.pred_ptr[:-1]).astype(np.int64).copy()

    # ready heaps per resource class: ("mem", array_id) or ("fu", class)
    ready: dict[tuple, list] = {}

    def klass(i: int) -> tuple:
        k = int(tr.kinds[i])
        if k <= T.STORE:
            return ("mem", int(tr.array_ids[i]))
        return ("fu", T.FU_CLASS[k])

    def push(i: int) -> None:
        ready.setdefault(klass(i), []).append((-int(height[i]), i))

    for i in np.nonzero(n_preds == 0)[0]:
        push(int(i))
    for h in ready.values():
        heapq.heapify(h)

    inflight: list[tuple[int, int]] = []   # (finish_cycle, node)
    cycle = 0
    issued = mem_issued = conflict_stalls = 0
    per_array: dict[int, int] = {a: 0 for a in tr.array_names}
    mem_cycles_used = 0
    remaining = n

    specs = cfg.mem

    while remaining > 0:
        if cycle > cfg.max_cycles:
            raise RuntimeError(f"scheduler exceeded {cfg.max_cycles} cycles")

        # ---- retire ----
        while inflight and inflight[0][0] <= cycle:
            _, node = heapq.heappop(inflight)
            remaining -= 1
            lo, hi = succ_ptr[node], succ_ptr[node + 1]
            for s in succ_idx[lo:hi]:
                n_preds[s] -= 1
                if n_preds[s] == 0:
                    cls = klass(int(s))
                    heapq.heappush(ready.setdefault(cls, []), (-int(height[s]), int(s)))

        # ---- issue ----
        any_mem_this_cycle = 0
        for cls, heap in list(ready.items()):
            if not heap:
                continue
            if cls[0] == "fu":
                budget = cfg.fu_counts.get(cls[1], 1)
                while heap and budget > 0:
                    _, node = heapq.heappop(heap)
                    lat = T.LATENCY[int(tr.kinds[node])]
                    heapq.heappush(inflight, (cycle + lat, node))
                    issued += 1
                    budget -= 1
            else:
                aid = cls[1]
                spec = specs[aid]
                rd_budget = spec.n_read
                wr_budget = spec.n_write
                if spec.kind == "multipump":
                    rd_budget, wr_budget = rd_budget * 2, wr_budget * 2
                bank_use: dict[int, int] = {}
                deferred: list[tuple[int, int]] = []
                # Bound the scan: once every bank is saturated (or we have
                # burned a generous number of failed pops) nothing further
                # in this array's heap can issue this cycle.  Without the
                # cap the deferral loop is O(ready) per cycle -> quadratic.
                failed_pops = 0
                max_failed = 4 * spec.n_banks * cfg.ports_per_bank + 8
                saturated_banks = 0
                while heap and (rd_budget > 0 or wr_budget > 0):
                    if spec.kind == "banked" and (
                        saturated_banks >= spec.n_banks or failed_pops >= max_failed
                    ):
                        break
                    pr, node = heapq.heappop(heap)
                    is_load = int(tr.kinds[node]) == T.LOAD
                    if is_load and rd_budget <= 0:
                        deferred.append((pr, node))
                        failed_pops += 1
                        if failed_pops >= max_failed:
                            break
                        continue
                    if not is_load and wr_budget <= 0:
                        deferred.append((pr, node))
                        failed_pops += 1
                        if failed_pops >= max_failed:
                            break
                        continue
                    if spec.kind == "banked":
                        word = tr.word_bytes[aid]
                        bank = (int(tr.addrs[node]) // word) % spec.n_banks
                        if bank_use.get(bank, 0) >= cfg.ports_per_bank:
                            deferred.append((pr, node))
                            conflict_stalls += 1
                            failed_pops += 1
                            continue
                        bank_use[bank] = bank_use.get(bank, 0) + 1
                        if bank_use[bank] == cfg.ports_per_bank:
                            saturated_banks += 1
                    lat = cfg.mem_latency if is_load else T.LATENCY[T.STORE]
                    heapq.heappush(inflight, (cycle + lat, node))
                    issued += 1
                    mem_issued += 1
                    any_mem_this_cycle += 1
                    per_array[aid] = per_array.get(aid, 0) + 1
                    if is_load:
                        rd_budget -= 1
                    else:
                        wr_budget -= 1
                for item in deferred:
                    heapq.heappush(heap, item)
        if any_mem_this_cycle:
            mem_cycles_used += 1

        cycle += 1
        if not inflight and all(not h for h in ready.values()) and remaining > 0:
            raise RuntimeError("deadlock: nodes remain but nothing ready/inflight")

    return ScheduleResult(
        cycles=cycle,
        issued=issued,
        mem_issued=mem_issued,
        bank_conflict_stalls=conflict_stalls,
        per_array_accesses=per_array,
        avg_mem_parallelism=mem_issued / max(mem_cycles_used, 1),
    )
