"""Port-constrained cycle-accurate list scheduler (paper III-C).

'The cycle-accurate simulator schedules the data flow graph [...] The
DAG allows multiple accesses and the scheduler then issues the number of
accesses requested, accordingly from the read-write port configurations
and port width defined by the user.'

Resource model per cycle (see ``repro.core.sim.arbiter`` for the full
per-kind rules):
  * ``ideal`` / ``lvt`` — ``n_read`` loads + ``n_write`` stores, any
    addresses (LVT's replica broadcast is a cost effect, not timing);
  * ``banked`` — each bank is an independent dual-port macro; an access
    issues only if its bank has a port left this cycle — the
    bank-conflict serialization the paper contrasts AMMs against;
  * ``multipump`` — the advertised ports, delivered from an internally
    double-clocked dual-port macro (at most ``ports_per_bank * 2``
    total accesses per external cycle; the frequency penalty is applied
    by the cost composition);
  * ``h_ntx_rd`` / ``b_ntx_wr`` / ``hb_ntx`` — leaf-bank arbitration:
    reads take their direct leaf or fan out over the whole parity path;
    same-half write pairs go through the single Ref re-pointing unit;
  * ``remap`` — reads must hit the live bank from the steering table;
    writes are steered to a conflict-free bank and update the table;
  * functional units — ``fu_counts[kind]`` parallel units, as produced
    by Aladdin's loop unrolling ('multi-issue ALUs may be constructed by
    loop unrolling').

The scheduler is event-driven over the trace's DDG: priority = longest
path to sink (critical path first), standard list scheduling.

``schedule()`` accepts a raw :class:`Trace` or a :class:`PreparedTrace`
(see ``repro.core.sim.prepared``).  All trace-only analysis — successor
CSR, heights, per-node classes — lives in the prepared layer and is
computed once per trace; a ``schedule()`` call pays only for the cycle
loop, which is what makes shared-trace DSE sweeps cheap.
"""
from __future__ import annotations

import dataclasses
import heapq

from repro.core.amm.spec import AMMSpec
from repro.core.sim import _cycle_ext
from repro.core.sim import trace as T
from repro.core.sim.arbiter import (EV_PAIR_RMW, EV_PARITY_READ, KIND_BANKED,
                                    KIND_LVT, KIND_REMAP, N_FIELDS,
                                    STALL_BANK, STALL_KEYS, STALL_PARITY,
                                    PortArbiter, _NTX_KINDS,
                                    compile_descriptors, descriptor_matrix)
from repro.core.sim.events import (PATH_BROADCAST, PATH_COMPUTE, PATH_DIRECT,
                                   PATH_PAIR_RMW, PATH_PARITY, PATH_STEERED,
                                   EventLog)
from repro.core.sim.prepared import FU_ORDER, PreparedTrace, prepare_trace

# C fallback guard: the compiled loop uses fixed-size path buffers
_MAX_C_PARITY_PATHS = 128


@dataclasses.dataclass
class ScheduleConfig:
    mem: dict[int, AMMSpec]                 # per-array memory design
    fu_counts: dict[str, int]               # parallel FUs per class
    mem_latency: int = 2                    # issue-to-data cycles for loads
    ports_per_bank: int = 2                 # dual-port leaf macros
    max_cycles: int = 50_000_000


@dataclasses.dataclass
class ScheduleResult:
    cycles: int
    issued: int
    mem_issued: int
    bank_conflict_stalls: int               # unique accesses delayed >=1 cycle
                                            #   by bank/steering conflicts
    parity_fanout_stalls: int               # NTX reads with direct leaf AND
                                            #   parity path busy
    write_pair_stalls: int                  # B/HB-NTX same-half write pairs
                                            #   blocked on the Ref RMW path
    parity_path_reads: int                  # reads served via XOR parity path
    write_pair_rmws: int                    # successful Ref re-pointing flows
    per_array_accesses: dict[int, int]
    avg_mem_parallelism: float

    def stall_breakdown(self) -> dict[str, int]:
        """Per-cause unique-access stall counts (paper Sec. II timing)."""
        return {k: getattr(self, f"{k}_stalls") for k in STALL_KEYS}

    def summary(self) -> dict:
        return dataclasses.asdict(self)


def schedule(tr: "T.Trace | PreparedTrace", cfg: ScheduleConfig,
             backend: str = "auto", *, check: bool = False) -> ScheduleResult:
    """Run the port-constrained list scheduler on one trace.

    With ``check=True`` the run is re-executed with issue-event logging
    and the independent legality checker (``repro.core.verify``)
    validates every recorded event against rules compiled straight from
    the ``AMMSpec``s, plus the static hazard lower bounds; a
    ``repro.core.verify.LegalityError`` is raised on any violation.

    Three cycle-exact execution backends implement the same decision
    procedure (pinned against each other by ``tests/test_arbiter.py``,
    ``tests/test_golden_schedule.py`` and the differential fuzz suite in
    ``tests/test_conformance.py``):

    * ``"auto"`` — the compiled C loop when a compiler is available
      (``repro.core.sim._cycle_ext``), else the Python loop;
    * ``"c"`` — the compiled loop, *required*: raises ``RuntimeError``
      when the extension cannot be built, so C-labeled timings are
      never silently Python timings.  (Designs beyond the fixed
      ``_MAX_C_PARITY_PATHS`` path buffers still fall back to the
      identical-result Python loop — that limit is structural, not
      environmental.);
    * ``"py"`` — the pure-Python reference loop below;
    * ``"jax"`` — the batched fixed-shape loop in
      ``repro.core.sim.jax_cycle`` (one design per call here; use
      ``jax_cycle.schedule_batched`` to evaluate a whole grid per jit
      call).
    """
    pt = prepare_trace(tr)
    if check:
        from repro.core.verify import check_schedule
        report = check_schedule(pt, cfg, backend=backend)
        report.raise_if_failed()
        return report.result
    if backend == "jax":
        from repro.core.sim.jax_cycle import schedule_jax
        return schedule_jax(pt, cfg)
    if backend == "py":
        return _schedule_py(pt, cfg)
    if backend not in ("auto", "c"):
        raise ValueError(f"unknown scheduler backend {backend!r}")
    fast = _cycle_ext.load()
    if fast is None and backend == "c":
        raise RuntimeError(
            "backend='c' requested but the compiled cycle loop is "
            "unavailable (no C compiler / REPRO_PURE_PY set); use "
            "backend='auto' for silent pure-Python fallback")
    if fast is not None:
        res = _schedule_c(fast, pt, cfg)
        if res is not None:
            return res
    return _schedule_py(pt, cfg)


def schedule_events(tr: "T.Trace | PreparedTrace", cfg: ScheduleConfig,
                    backend: str = "auto",
                    ) -> "tuple[ScheduleResult, EventLog]":
    """Run :func:`schedule` with issue-event logging enabled.

    Returns the (unchanged — recording never influences an arbitration
    decision) :class:`ScheduleResult` plus the node-indexed
    :class:`~repro.core.sim.events.EventLog`.  All three backends emit
    bit-identical logs for the same config.
    """
    pt = prepare_trace(tr)
    n = pt.trace.n_nodes
    if backend == "jax":
        from repro.core.sim.jax_cycle import schedule_batched
        res_list, ev_list = schedule_batched(pt, [cfg], collect_events=True)
        return res_list[0], ev_list[0]
    if backend == "py":
        ev = EventLog.empty(n)
        return _schedule_py(pt, cfg, events=ev), ev
    if backend not in ("auto", "c"):
        raise ValueError(f"unknown scheduler backend {backend!r}")
    fast = _cycle_ext.load()
    if fast is None and backend == "c":
        raise RuntimeError(
            "backend='c' requested but the compiled cycle loop is "
            "unavailable (no C compiler / REPRO_PURE_PY set); use "
            "backend='auto' for silent pure-Python fallback")
    if fast is not None:
        ev = EventLog.empty(n)
        res = _schedule_c(fast, pt, cfg, events=ev)
        if res is not None:
            return res, ev
    ev = EventLog.empty(n)
    return _schedule_py(pt, cfg, events=ev), ev


def _descriptors(pt: PreparedTrace, cfg: ScheduleConfig):
    return compile_descriptors(cfg.mem, pt.n_arrays, cfg.ports_per_bank)


def _c_stall_kwargs(out, offsets=(3, 5, 6)) -> dict[str, int]:
    """Stall fields from a C ``out`` block, in STALL_KEYS order."""
    return {f"{k}_stalls": int(out[i]) for k, i in zip(STALL_KEYS, offsets)}


def _schedule_c(fast, pt: PreparedTrace, cfg: ScheduleConfig,
                events: "EventLog | None" = None) -> "ScheduleResult | None":
    import ctypes

    import numpy as np

    trace = pt.trace
    n = trace.n_nodes
    n_arrays = pt.n_arrays
    n_classes = n_arrays + len(FU_ORDER)

    descs = _descriptors(pt, cfg)
    for d in descs:
        if d is not None and d.kind in _NTX_KINDS \
                and (1 << d.levels) > _MAX_C_PARITY_PATHS:
            return None                    # exceeds C path buffers: fall back
    desc_mat = descriptor_matrix(descs)

    fu_budgets = np.asarray(
        [cfg.fu_counts.get(name, 1) for name in FU_ORDER], np.int64)

    out = np.zeros(9 + n_arrays, np.int64)
    i64p = ctypes.POINTER(ctypes.c_longlong)
    u8p = ctypes.POINTER(ctypes.c_ubyte)

    def ip(a):
        return a.ctypes.data_as(i64p)

    def up(a):
        return a.ctypes.data_as(u8p)

    if events is not None:
        ev_buf = np.full(4 * max(n, 1), -1, np.int64)
        ev_ptr = ip(ev_buf)
    else:
        ev_buf = None
        ev_ptr = None                      # NULL: recording compiled out

    rc = fast(
        n, n_arrays, n_classes,
        ip(pt.succ_ptr), ip(pt.succ_idx), ip(pt.indegree), ip(pt.height),
        up(pt.is_load_np), ip(pt.latency_np), ip(pt.word_index_np),
        ip(pt.klass_np),
        ip(fu_budgets), ip(desc_mat),
        cfg.mem_latency, cfg.ports_per_bank, cfg.max_cycles,
        ip(out), ev_ptr)
    if rc == -1:
        raise RuntimeError(f"scheduler exceeded {cfg.max_cycles} cycles")
    if rc == -2:
        raise RuntimeError("deadlock: nodes remain but nothing ready/inflight")
    if rc == -3:
        raise KeyError("memory op on array without a ScheduleConfig.mem spec")
    if rc != 0:
        return None                        # allocation failure: fall back
    if events is not None and n:
        packed = ev_buf[:4 * n].reshape(n, 4)
        events.cycle[:] = packed[:, 0]
        events.path[:] = packed[:, 1]
        events.resource[:] = packed[:, 2]
        events.slot[:] = packed[:, 3]
    return ScheduleResult(
        cycles=int(out[0]),
        issued=int(out[1]),
        mem_issued=int(out[2]),
        **_c_stall_kwargs(out),
        parity_path_reads=int(out[7]),
        write_pair_rmws=int(out[8]),
        per_array_accesses={a: int(out[9 + a]) for a in trace.array_names},
        avg_mem_parallelism=int(out[2]) / max(int(out[4]), 1),
    )


def schedule_batch(tr: "T.Trace | PreparedTrace", cfgs: "list[ScheduleConfig]",
                   *, areas: "list[float] | None" = None,
                   cycle_ns: "list[float] | None" = None,
                   front_cap: bool = False) -> "list[ScheduleResult | None]":
    """Evaluate many configs against one resident trace in a single C call.

    The per-trace analysis (successor CSR, heights, classes) is paid once
    and every config reuses the resident arrays; only the per-config
    descriptor matrices and FU budgets are marshalled.  Results are
    cycle-exact and identical to per-point :func:`schedule` calls.

    With ``front_cap=True`` (requires ``areas`` and ``cycle_ns``, one per
    config, ideally in ascending-area order), the C loop abandons a
    config once its elapsed time provably exceeds the best completed time
    of a strictly cheaper config — such a point cannot be on the
    time/area Pareto front (the front keeps a point only if *no* cheaper
    point is at least as fast).  Abandoned configs return ``None`` in the
    result list; completed configs are exact.

    Falls back to the per-point Python loop when the compiled batch entry
    is unavailable or a config exceeds the C path buffers (then no
    capping happens: every slot gets an exact result).
    """
    pt = prepare_trace(tr)
    if not cfgs:
        return []
    if front_cap and (areas is None or cycle_ns is None):
        raise ValueError("front_cap=True requires areas and cycle_ns")
    bt = _cycle_ext.load_batch()
    if bt is not None:
        res = _schedule_c_batch(bt, pt, cfgs, areas=areas,
                                cycle_ns=cycle_ns, front_cap=front_cap)
        if res is not None:
            return res
    return [_schedule_py(pt, c) for c in cfgs]


def _schedule_c_batch(bt, pt: PreparedTrace, cfgs, *, areas, cycle_ns,
                      front_cap) -> "list[ScheduleResult | None] | None":
    import ctypes

    import numpy as np

    trace = pt.trace
    n = trace.n_nodes
    n_arrays = pt.n_arrays
    n_classes = n_arrays + len(FU_ORDER)
    n_cfg = len(cfgs)

    ports_per_bank = cfgs[0].ports_per_bank
    max_cycles = cfgs[0].max_cycles
    if any(c.ports_per_bank != ports_per_bank or c.max_cycles != max_cycles
           for c in cfgs):
        return None                        # mixed globals: caller's problem

    # Per-config descriptor matrices; configs beyond the fixed C path
    # buffers are evaluated by the (identical-result) Python loop.
    batch_idx: list[int] = []
    desc_rows: list = [None] * n_cfg
    for i, cfg in enumerate(cfgs):
        descs = _descriptors(pt, cfg)
        if any(d is not None and d.kind in _NTX_KINDS
               and (1 << d.levels) > _MAX_C_PARITY_PATHS for d in descs):
            continue
        batch_idx.append(i)
        desc_rows[i] = descriptor_matrix(descs)

    results: "list[ScheduleResult | None]" = [None] * n_cfg
    py_idx = [i for i in range(n_cfg) if desc_rows[i] is None]

    nb = len(batch_idx)
    if nb:
        desc_all = np.ascontiguousarray(
            np.stack([desc_rows[i] for i in batch_idx]), np.int64)
        fu_all = np.asarray(
            [[cfgs[i].fu_counts.get(name, 1) for name in FU_ORDER]
             for i in batch_idx], np.int64)
        lat_all = np.asarray([cfgs[i].mem_latency for i in batch_idx],
                             np.int64)
        if front_cap:
            area_all = np.asarray([areas[i] for i in batch_idx], np.float64)
            ns_all = np.asarray([cycle_ns[i] for i in batch_idx], np.float64)
        else:
            area_all = np.zeros(nb, np.float64)
            ns_all = np.ones(nb, np.float64)
        status = np.zeros(nb, np.int64)
        out_all = np.zeros(nb * (9 + n_arrays), np.int64)

        i64p = ctypes.POINTER(ctypes.c_longlong)
        u8p = ctypes.POINTER(ctypes.c_ubyte)
        f64p = ctypes.POINTER(ctypes.c_double)

        def ip(a):
            return a.ctypes.data_as(i64p)

        bt(n, n_arrays, n_classes, nb,
           ip(pt.succ_ptr), ip(pt.succ_idx), ip(pt.indegree), ip(pt.height),
           pt.is_load_np.ctypes.data_as(u8p), ip(pt.latency_np),
           ip(pt.word_index_np), ip(pt.klass_np),
           ip(fu_all), ip(desc_all), ip(lat_all),
           ports_per_bank, max_cycles, 1 if front_cap else 0,
           area_all.ctypes.data_as(f64p), ns_all.ctypes.data_as(f64p),
           ip(status), ip(out_all))

        stride = 9 + n_arrays
        for j, i in enumerate(batch_idx):
            st = int(status[j])
            if st == 1:
                continue                   # front-capped: stays None
            if st == -1:
                raise RuntimeError(
                    f"scheduler exceeded {max_cycles} cycles")
            if st == -2:
                raise RuntimeError(
                    "deadlock: nodes remain but nothing ready/inflight")
            if st == -3:
                raise KeyError(
                    "memory op on array without a ScheduleConfig.mem spec")
            if st != 0:
                py_idx.append(i)           # allocation failure: fall back
                continue
            out = out_all[j * stride:(j + 1) * stride]
            results[i] = ScheduleResult(
                cycles=int(out[0]),
                issued=int(out[1]),
                mem_issued=int(out[2]),
                **_c_stall_kwargs(out),
                parity_path_reads=int(out[7]),
                write_pair_rmws=int(out[8]),
                per_array_accesses={a: int(out[9 + a])
                                    for a in trace.array_names},
                avg_mem_parallelism=int(out[2]) / max(int(out[4]), 1),
            )

    for i in py_idx:
        results[i] = _schedule_py(pt, cfgs[i])
    return results


def _schedule_py(pt: PreparedTrace, cfg: ScheduleConfig,
                 events: "EventLog | None" = None) -> ScheduleResult:
    trace = pt.trace
    n = trace.n_nodes

    # optional issue-event recording (repro.core.sim.events).  Recording
    # is strictly observational: every write happens after the issue
    # decision and touches no scheduler state, so logged and unlogged
    # runs are cycle-identical (pinned by tests/test_verify.py).
    rec = events is not None
    if rec:
        ev_cycle = events.cycle
        ev_path = events.path
        ev_res = events.resource
        ev_slot = events.slot

    # shared, read-only per-trace state (plain lists: no numpy boxing in
    # the cycle loop; built lazily — the C loop never needs them)
    mir = pt.py_mirrors()
    succ = mir.succ_lists
    is_load = mir.is_load
    node_lat = mir.latency_list             # FU latency; == STORE latency for stores
    word_idx = mir.word_index
    kid = mir.klass_id                      # resource class per node
    n_arrays = pt.n_arrays
    prio = mir.packed_prio                  # packed (neg_height, node) per node
    heappush, heappop, heapify = heapq.heappush, heapq.heappop, heapq.heapify

    # per-call mutable state; one ready heap per resource class id
    # (array ids, then FU classes — see prepared.FU_ORDER).  Heap entries
    # are packed ints: ready heaps hold prio[i], the inflight heap holds
    # finish_cycle * n + node — both order exactly like the seed tuples.
    n_preds = pt.indegree.tolist()
    heaps: list[list] = [[] for _ in range(n_arrays + len(FU_ORDER))]
    active: set[int] = set()                # class ids with a nonempty heap
    for i in mir.roots:
        c = kid[i]
        heaps[c].append(prio[i])
        active.add(c)
    for c in active:
        heapify(heaps[c])

    # per-class config, resolved once: FU issue widths and per-array
    # arbitration descriptors (see repro.core.sim.arbiter).  Simple and
    # banked kinds keep the seed-exact inline paths; the NTX kinds and
    # remap get a stateful PortArbiter.
    fu_budgets = [cfg.fu_counts.get(name, 1) for name in FU_ORDER]
    ports_per_bank = cfg.ports_per_bank
    descs = _descriptors(pt, cfg)
    mem_info: list = [None] * n_arrays
    arbiters: list = [None] * n_arrays
    # event-log path kinds resolved per array: writes on LVT broadcast
    # into every read replica; remap writes are steered
    write_path: list = [PATH_DIRECT] * n_arrays
    for aid, d in enumerate(descs):
        if d is None:
            continue                        # KeyError only if ops ever ready
        if d.kind == KIND_BANKED:
            mem_info[aid] = ("B", d.rd, d.wr, d.n_banks, d.max_failed)
        elif d.kind in _NTX_KINDS or d.kind == KIND_REMAP:
            arbiters[aid] = PortArbiter(d, ports_per_bank)
            mem_info[aid] = ("A", d.rd, d.wr, d.max_failed)
            if d.kind == KIND_REMAP:
                write_path[aid] = PATH_STEERED
        else:
            mem_info[aid] = ("S", d.rd, d.wr, d.slots, d.max_failed)
            if d.kind == KIND_LVT:
                write_path[aid] = PATH_BROADCAST

    inflight: list[int] = []               # finish_cycle * n + node
    cycle = 0
    issued = mem_issued = conflict_stalls = 0
    parity_stalls = pair_stalls = 0
    per_array: dict[int, int] = {a: 0 for a in trace.array_names}
    mem_cycles_used = 0
    remaining = n
    delayed = bytearray(n)                 # nodes already counted as stalled
    mem_latency = cfg.mem_latency
    max_cycles = cfg.max_cycles

    while remaining > 0:
        if cycle > max_cycles:
            raise RuntimeError(f"scheduler exceeded {max_cycles} cycles")

        # ---- retire ----
        retire_limit = cycle * n + n - 1   # packed entries with finish <= cycle
        while inflight and inflight[0] <= retire_limit:
            node = heappop(inflight) % n
            remaining -= 1
            for s in succ[node]:
                n_preds[s] -= 1
                if n_preds[s] == 0:
                    c = kid[s]
                    heappush(heaps[c], prio[s])
                    active.add(c)

        # ---- issue ----
        any_mem_this_cycle = 0
        for c in list(active):
            heap = heaps[c]
            if c >= n_arrays:
                budget = fu_budgets[c - n_arrays]
                fu_slot = 0
                while heap and budget > 0:
                    node = heappop(heap) % n
                    heappush(inflight, (cycle + node_lat[node]) * n + node)
                    issued += 1
                    budget -= 1
                    if rec:
                        ev_cycle[node] = cycle
                        ev_path[node] = PATH_COMPUTE
                        ev_slot[node] = fu_slot
                    fu_slot += 1
            else:
                info = mem_info[c]
                if info is None:
                    raise KeyError(c)      # memory op on an unconfigured array
                tag = info[0]
                if tag == "B":
                    # banked: seed-exact bank-port serialization
                    _, rd_budget, wr_budget, n_banks, max_failed = info
                    bank_use: dict[int, int] = {}
                    deferred: list[int] = []
                    # Bound the scan: once every bank is saturated (or we
                    # have burned a generous number of failed pops) nothing
                    # further in this array's heap can issue this cycle.
                    # Without the cap the deferral loop is O(ready) per
                    # cycle -> quadratic.
                    failed_pops = 0
                    saturated_banks = 0
                    mem_slot = 0
                    while heap and (rd_budget > 0 or wr_budget > 0):
                        if (saturated_banks >= n_banks
                                or failed_pops >= max_failed):
                            break
                        item = heappop(heap)
                        node = item % n
                        ld = is_load[node]
                        if ld and rd_budget <= 0:
                            deferred.append(item)
                            failed_pops += 1
                            if failed_pops >= max_failed:
                                break
                            continue
                        if not ld and wr_budget <= 0:
                            deferred.append(item)
                            failed_pops += 1
                            if failed_pops >= max_failed:
                                break
                            continue
                        bank = word_idx[node] % n_banks
                        used = bank_use.get(bank, 0)
                        if used >= ports_per_bank:
                            deferred.append(item)
                            if not delayed[node]:
                                delayed[node] = 1
                                conflict_stalls += 1
                            failed_pops += 1
                            continue
                        bank_use[bank] = used + 1
                        if used + 1 == ports_per_bank:
                            saturated_banks += 1
                        lat = mem_latency if ld else node_lat[node]
                        heappush(inflight, (cycle + lat) * n + node)
                        issued += 1
                        mem_issued += 1
                        any_mem_this_cycle += 1
                        per_array[c] += 1
                        if rec:
                            ev_cycle[node] = cycle
                            ev_path[node] = PATH_DIRECT
                            ev_res[node] = bank
                            ev_slot[node] = mem_slot
                        mem_slot += 1
                        if ld:
                            rd_budget -= 1
                        else:
                            wr_budget -= 1
                    for item in deferred:
                        heappush(heap, item)
                elif tag == "S":
                    # ideal / lvt / multipump: port budgets plus the shared
                    # pumped-slot budget (binding for multipump only)
                    _, rd_budget, wr_budget, slots, max_failed = info
                    deferred = []
                    failed_pops = 0
                    mem_slot = 0
                    wpath_c = write_path[c]
                    while heap and (rd_budget > 0 or wr_budget > 0) \
                            and slots > 0:
                        item = heappop(heap)
                        node = item % n
                        ld = is_load[node]
                        if ld and rd_budget <= 0:
                            deferred.append(item)
                            failed_pops += 1
                            if failed_pops >= max_failed:
                                break
                            continue
                        if not ld and wr_budget <= 0:
                            deferred.append(item)
                            failed_pops += 1
                            if failed_pops >= max_failed:
                                break
                            continue
                        lat = mem_latency if ld else node_lat[node]
                        heappush(inflight, (cycle + lat) * n + node)
                        issued += 1
                        mem_issued += 1
                        any_mem_this_cycle += 1
                        per_array[c] += 1
                        if rec:
                            ev_cycle[node] = cycle
                            ev_path[node] = PATH_DIRECT if ld else wpath_c
                            ev_slot[node] = mem_slot
                        mem_slot += 1
                        slots -= 1
                        if ld:
                            rd_budget -= 1
                        else:
                            wr_budget -= 1
                    for item in deferred:
                        heappush(heap, item)
                else:
                    # NTX kinds / remap: structural arbitration per access
                    _, rd_budget, wr_budget, max_failed = info
                    arb = arbiters[c]
                    arb.begin_cycle()
                    deferred = []
                    failed_pops = 0
                    mem_slot = 0
                    wpath_c = write_path[c]
                    while heap and (rd_budget > 0 or wr_budget > 0):
                        if failed_pops >= max_failed:
                            break
                        item = heappop(heap)
                        node = item % n
                        ld = is_load[node]
                        if ld and rd_budget <= 0:
                            deferred.append(item)
                            failed_pops += 1
                            continue
                        if not ld and wr_budget <= 0:
                            deferred.append(item)
                            failed_pops += 1
                            continue
                        ok, cause, _ev = arb.access(ld, word_idx[node])
                        if not ok:
                            deferred.append(item)
                            if not delayed[node]:
                                delayed[node] = 1
                                if cause == STALL_BANK:
                                    conflict_stalls += 1
                                elif cause == STALL_PARITY:
                                    parity_stalls += 1
                                else:
                                    pair_stalls += 1
                            failed_pops += 1
                            continue
                        lat = mem_latency if ld else node_lat[node]
                        heappush(inflight, (cycle + lat) * n + node)
                        issued += 1
                        mem_issued += 1
                        any_mem_this_cycle += 1
                        per_array[c] += 1
                        if rec:
                            ev_cycle[node] = cycle
                            if _ev == EV_PARITY_READ:
                                ev_path[node] = PATH_PARITY
                            elif _ev == EV_PAIR_RMW:
                                ev_path[node] = PATH_PAIR_RMW
                            elif ld:
                                ev_path[node] = PATH_DIRECT
                            else:
                                ev_path[node] = wpath_c
                            ev_res[node] = arb.last_res
                            ev_slot[node] = mem_slot
                        mem_slot += 1
                        if ld:
                            rd_budget -= 1
                        else:
                            wr_budget -= 1
                    for item in deferred:
                        heappush(heap, item)
            if not heap:
                active.discard(c)
        if any_mem_this_cycle:
            mem_cycles_used += 1

        cycle += 1
        if not active:
            if not inflight:
                if remaining > 0:
                    raise RuntimeError(
                        "deadlock: nodes remain but nothing ready/inflight")
            else:
                next_finish = inflight[0] // n
                if next_finish > cycle:
                    # Nothing can issue or retire until the next in-flight
                    # op completes; skipping the idle cycles is cycle-exact.
                    cycle = next_finish

    parity_reads = sum(a.parity_path_reads for a in arbiters if a is not None)
    pair_rmws = sum(a.write_pair_rmws for a in arbiters if a is not None)
    return ScheduleResult(
        cycles=cycle,
        issued=issued,
        mem_issued=mem_issued,
        bank_conflict_stalls=conflict_stalls,
        parity_fanout_stalls=parity_stalls,
        write_pair_stalls=pair_stalls,
        parity_path_reads=parity_reads,
        write_pair_rmws=pair_rmws,
        per_array_accesses=per_array,
        avg_mem_parallelism=mem_issued / max(mem_cycles_used, 1),
    )
