from repro.core.sim.arbiter import (STALL_KEYS, ArbDescriptor, PortArbiter,
                                    compile_spec, ntx_tables)
from repro.core.sim.events import (PATH_BROADCAST, PATH_COMPUTE, PATH_DIRECT,
                                   PATH_NAMES, PATH_PAIR_RMW, PATH_PARITY,
                                   PATH_STEERED, EventLog)
from repro.core.sim.prepared import (PreparedTrace, prepare_trace,
                                     trace_fingerprint)
from repro.core.sim.scheduler import (ScheduleConfig, ScheduleResult,
                                      schedule, schedule_events)
from repro.core.sim.trace import (FADD, FDIV, FMUL, IADD, ICMP, IMUL, LOAD,
                                  LOGIC, STORE, Trace, TraceBuilder)

__all__ = [
    "Trace", "TraceBuilder", "schedule", "ScheduleConfig", "ScheduleResult",
    "schedule_events", "EventLog", "STALL_KEYS",
    "PATH_COMPUTE", "PATH_DIRECT", "PATH_PARITY", "PATH_STEERED",
    "PATH_PAIR_RMW", "PATH_BROADCAST", "PATH_NAMES",
    "ArbDescriptor", "PortArbiter", "compile_spec", "ntx_tables",
    "PreparedTrace", "prepare_trace", "trace_fingerprint",
    "LOAD", "STORE", "FADD", "FMUL", "FDIV", "IADD", "IMUL", "ICMP", "LOGIC",
]
