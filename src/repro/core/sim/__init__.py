from repro.core.sim.arbiter import (ArbDescriptor, PortArbiter, compile_spec,
                                    ntx_tables)
from repro.core.sim.prepared import (PreparedTrace, prepare_trace,
                                     trace_fingerprint)
from repro.core.sim.scheduler import ScheduleConfig, ScheduleResult, schedule
from repro.core.sim.trace import (FADD, FDIV, FMUL, IADD, ICMP, IMUL, LOAD,
                                  LOGIC, STORE, Trace, TraceBuilder)

__all__ = [
    "Trace", "TraceBuilder", "schedule", "ScheduleConfig", "ScheduleResult",
    "ArbDescriptor", "PortArbiter", "compile_spec", "ntx_tables",
    "PreparedTrace", "prepare_trace", "trace_fingerprint",
    "LOAD", "STORE", "FADD", "FMUL", "FDIV", "IADD", "IMUL", "ICMP", "LOGIC",
]
