"""Batched JAX cycle loop: the third execution backend of the scheduler.

The port-constrained list scheduler exists as a pure-Python reference
loop and a compiled C twin (``scheduler._schedule_py`` /
``_cycle_loop.c``).  Both evaluate one design point per call, so a
Fig-4 grid is a host-side loop over designs.  This module reformulates
the *same* decision procedure as fixed-shape array ops — a
``lax.while_loop`` over cycles whose body issues ready nodes by masked
priority — so that ``jax.vmap`` batches the whole grid into a single
compiled call (and, on an accelerator, a single device launch).

Exactness contract
------------------
``schedule_batched`` is pinned decision-for-decision against the other
two loops (``tests/test_conformance.py``, ``tests/test_golden_schedule``):

* ready nodes are scanned in exact heap order per resource class — the
  class-grouped, ``(-height, node)``-sorted ``DeviceViews.perm`` makes
  the per-cycle candidate list a masked prefix of a static permutation;
* the per-kind arbitration rules (banked bank ports, multipump pumped
  slots, NTX direct/parity leaf paths and Ref write pairing, remap
  live-bank steering) replicate :class:`~repro.core.sim.arbiter.
  PortArbiter` branch for branch, driven by the same numeric
  ``ArbDescriptor`` fields and the same ``ntx_tables`` geometry;
* deferral-scan caps (``max_failed``), first-deferral stall attribution
  and the idle-cycle jump are carried over unchanged.

Shapes are static per :class:`StaticCfg` (padded to power-of-two
buckets), so traces and design grids of similar size share one
compiled kernel.  State that differs per design — descriptor rows,
FU budgets, leaf-path tables, the remap live map — is batched along
the leading design axis; trace tensors are broadcast.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import TYPE_CHECKING, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sim.arbiter import (F_CONFIGURED, F_DEPTH, F_HALF, F_KIND,
                                    F_LEVELS, F_MAXFAIL, F_NBANKS, F_NLEAVES,
                                    F_RD, F_SLOTS, F_SUB, F_WR, KIND_BANKED,
                                    KIND_H_NTX, KIND_LVT, KIND_REMAP,
                                    N_FIELDS, STALL_BANK, STALL_KEYS,
                                    STALL_PAIR, STALL_PARITY, _NTX_KINDS,
                                    compile_descriptors,
                                    descriptor_device_tables,
                                    descriptor_matrix, device_limits)
from repro.core.sim.events import (PATH_BROADCAST, PATH_COMPUTE, PATH_DIRECT,
                                   PATH_PAIR_RMW, PATH_PARITY, PATH_STEERED,
                                   EventLog)
from repro.core.sim.prepared import (FU_ORDER, PreparedTrace, _next_pow2,
                                     prepare_trace)

if TYPE_CHECKING:
    from repro.core.sim.scheduler import ScheduleConfig, ScheduleResult
    from repro.core.sim.trace import Trace

I32 = jnp.int32
_INT32_INF = np.int32(2**31 - 1)

# error codes surfaced from the device loop (host raises to match the
# reference loops' exceptions)
ERR_NONE, ERR_MAX_CYCLES, ERR_DEADLOCK, ERR_UNCONFIGURED = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class StaticCfg:
    """Hashable static-shape key: one compiled kernel per value.

    Only padded dimensions appear here — everything trace- or
    design-specific (class segment layout, descriptors, leaf tables,
    the real node count) travels as device data, so similarly-sized
    traces and any design grid share one compiled kernel.
    """

    n_pad: int
    n_preds_max: int
    a_pad: int                  # array-axis bucket
    scan_slots: int             # S: per-cycle candidate slots per array
    key_space: int              # U: NTX port-key ids per array
    bank_slots: int             # NB: bank-usage counters per array
    table_depth: int            # D: per-word state (NTX tables, remap map)
    parity_paths: int           # PP: widest NTX parity fan-out


def _steer(wuse_o: jax.Array, ruse_o: jax.Array, valid: jax.Array,
           ppb) -> tuple[jax.Array, jax.Array]:
    """Remap write steering: first free bank in live-map scan order.

    ``wuse_o``/``ruse_o`` are this cycle's per-bank usage gathered in
    scan order (starting from the word's live bank); a bank is free when
    it has no write yet and a port left — exactly the
    ``replay._remap_step`` / ``PortArbiter._remap`` rule.  Returns
    ``(any_free, position)`` along the last axis.
    """
    free = (wuse_o == 0) & (ruse_o < ppb) & valid
    return jnp.any(free, axis=-1), jnp.argmax(free, axis=-1).astype(I32)


def remap_write_step(live_map, ruse, wuse, addr, n_banks: int, ppb: int):
    """One remap write-steering decision, the ``jax_cycle`` rule.

    Single-array view of the kernel's batched steering (same
    :func:`_steer` core), exposed for the property tests that pin it
    against ``repro.core.amm.replay._remap_step``.  Returns
    ``(issued, bank, live_map, ruse, wuse)`` with state untouched when
    the write stalls.
    """
    live_map = jnp.asarray(live_map, I32)
    ruse = jnp.asarray(ruse, I32)
    wuse = jnp.asarray(wuse, I32)
    order = (live_map[addr] + jnp.arange(n_banks, dtype=I32)) % n_banks
    ok, pos = _steer(wuse[order], ruse[order], jnp.ones(n_banks, bool), ppb)
    bank = order[pos]
    tgt = jnp.where(ok, bank, n_banks)          # n_banks = trash slot
    ruse = jnp.concatenate([ruse, jnp.zeros(1, I32)]).at[tgt].add(1)[:-1]
    wuse = jnp.concatenate([wuse, jnp.zeros(1, I32)]).at[tgt].set(1)[:-1]
    live_map = live_map.at[jnp.where(ok, addr, live_map.shape[0] - 1)].set(
        jnp.where(ok, bank, live_map[live_map.shape[0] - 1]))
    return ok, jnp.where(ok, bank, -1), live_map, ruse, wuse


def _make_lane_fn(sc: StaticCfg, record: bool = False):
    """Single-design cycle loop for one trace shape (vmapped by caller).

    With ``record=True`` the carry grows four ``(NPAD + 2,)`` int32
    event arrays (cycle / path / resource / slot per node, the
    :mod:`repro.core.sim.events` log) written through the same
    trash-slot scatters the schedule state already uses, and the lane
    returns a fifth ``[4, NPAD]`` output.  The default lane is
    byte-identical to before — recording costs nothing when off.

    The per-cycle issue phase is two fused stages instead of a Python
    loop over resource classes: one segmented cumulative-rank pass over
    the whole priority permutation (top-``budget`` selection for every
    FU class at once) and one segmented prefix-scatter that lays each
    array's ready candidates into its scan slots.  The deferral scan
    then advances every array one pop per ``while_loop`` step — its
    trip count is the *actual* maximum pop count this cycle, not the
    worst-case ``scan_slots`` bound.  Class segments arrive as device
    data (``gid_perm``/``seg_start``), not compile-time constants.
    """
    NPAD, A = sc.n_pad, sc.a_pad
    S = max(sc.scan_slots, 1)
    U = max(sc.key_space, 1)
    NB = max(sc.bank_slots, 1)
    D = max(sc.table_depth, 1)
    PP = max(sc.parity_paths, 1)
    TRASH = NPAD + 1                       # NPAD is the always-retired pred
    arA = jnp.arange(A)

    def lane(desc, fu_budgets, mem_latency, ppb, max_cycles,
             direct_t, offset_t, parity_t,
             n_real, preds_pad, lat, is_load, word_idx, perm, gid_perm,
             seg_start):
        lat_p = lat[perm]
        budget_of = jnp.concatenate(
            [jnp.zeros((A,), I32), fu_budgets.astype(I32),
             jnp.zeros((1,), I32)])        # mem / FU / pad segment budgets
        kind = desc[:, F_KIND]
        is_lvt = kind == KIND_LVT
        configured = desc[:, F_CONFIGURED] > 0
        n_banks = jnp.maximum(desc[:, F_NBANKS], 1)
        depth = jnp.maximum(desc[:, F_DEPTH], 1)
        levels = desc[:, F_LEVELS]
        half = jnp.maximum(desc[:, F_HALF], 0)
        sub = jnp.maximum(desc[:, F_SUB], 1)
        max_failed = desc[:, F_MAXFAIL]
        nl = jnp.maximum(desc[:, F_NLEAVES], 1)
        is_h = kind == KIND_H_NTX
        is_ntx = ((kind == _NTX_KINDS[0]) | (kind == _NTX_KINDS[1])
                  | (kind == _NTX_KINDS[2]))
        is_banked = kind == KIND_BANKED
        is_remap = kind == KIND_REMAP
        is_simple = ~(is_ntx | is_banked | is_remap)
        npaths = jnp.left_shift(jnp.int32(1), levels)
        pcols = jnp.arange(PP, dtype=I32)[None, :]

        def _top(rd, wr, slots, failed, saturated):
            have = (rd > 0) | (wr > 0)
            return jnp.where(
                is_banked,
                have & (saturated < n_banks) & (failed < max_failed),
                jnp.where(is_simple, have & (slots > 0),
                          have & (failed < max_failed)))

        def body(c):
            (cycle, remaining, finish, issued, delayed, maps, cnt,
             per_array, err) = c[:9]
            if record:
                ev_cycle, ev_path, ev_res, ev_slot = c[9:]
            err = jnp.where((err == ERR_NONE) & (cycle > max_cycles),
                            jnp.int32(ERR_MAX_CYCLES), err)
            # ---- retire: a node is retired once issued & finish <= cycle
            finish_r, issued_r = finish[:NPAD], issued[:NPAD]
            retired = issued_r & (finish_r <= cycle)
            remaining = n_real - jnp.sum(retired, dtype=I32)
            ready = (~issued_r) & jnp.all(finish[preds_pad] <= cycle, axis=1)
            ready_p = ready[perm]

            # ---- one segmented rank pass over the whole priority perm:
            # top-`budget` issue for every FU class, prefix positions for
            # every memory class (mem/pad segments carry budget 0)
            cs0 = jnp.concatenate([jnp.zeros((1,), I32),
                                   jnp.cumsum(ready_p.astype(I32))])
            rank = cs0[1:] - cs0[seg_start[gid_perm]]
            take = ready_p & (rank <= budget_of[gid_perm])
            tgt = jnp.where(take, perm, TRASH)
            finish = finish.at[tgt].set(cycle + lat_p)
            issued = issued.at[tgt].set(True)
            if record:
                ev_cycle = ev_cycle.at[tgt].set(cycle)
                ev_path = ev_path.at[tgt].set(jnp.int32(PATH_COMPUTE))
                ev_slot = ev_slot.at[tgt].set((rank - 1).astype(I32))
            fu_issue_n = jnp.sum(take, dtype=I32)

            # ---- memory classes: segmented prefix -> per-array scan slots
            pos = rank - 1
            slot = jnp.where((gid_perm < A) & ready_p & (pos < S),
                             gid_perm * (S + 1) + pos, A * (S + 1))
            cand = jnp.zeros((A * (S + 1) + 1,), I32).at[slot].set(perm)
            cand = cand[:A * (S + 1)].reshape(A, S + 1)[:, :S]
            n_ready = cs0[seg_start[1:A + 1]] - cs0[seg_start[:A]]
            ncand = jnp.minimum(n_ready, S)
            err = jnp.where(
                (err == ERR_NONE) & jnp.any((n_ready > 0) & ~configured),
                jnp.int32(ERR_UNCONFIGURED), err)

            # ---- deferral scan: every array advances one pop per step,
            # exactly the reference loops' pop/defer/issue procedure
            def icond(st):
                j, rd, wr, slots, failed, saturated, stop = st[:7]
                return jnp.any((j < ncand) & ~stop & configured
                               & _top(rd, wr, slots, failed, saturated))

            def istep(st):
                (j, rd, wr, slots, failed, saturated, stop, pair_used,
                 wr_half, ruse, wuse, use, amap, finish, issued, delayed,
                 mem_pa, conflict_n, parity_n, pair_n, pr_n, rmw_n) = st[:22]
                if record:
                    ev_cycle, ev_path, ev_res, ev_slot = st[22:]
                act = ((j < ncand) & ~stop & configured
                       & _top(rd, wr, slots, failed, saturated))
                node = lax.dynamic_index_in_dim(cand, j, axis=1,
                                                keepdims=False)
                ld = is_load[node]
                w = word_idx[node]
                dir_defer = jnp.where(ld, rd <= 0, wr <= 0)
                att = act & ~dir_defer
                a = w % depth
                # NTX geometry: tree / in-tree address / leaf / sub-bank
                tree = jnp.where(is_h, 0, (a >= half).astype(I32))
                ta = jnp.minimum(a - tree * half, D - 1)
                leaf = direct_t[arA, ta]
                soff = offset_t[arA, ta] % sub
                key1 = (tree * nl + leaf) * sub + soff
                key2 = (2 * nl + leaf) * sub + soff
                key_other = ((1 - tree) * nl + leaf) * sub + soff
                u2 = use[arA, key2]
                direct_free = ~use[arA, key1] & (is_h | ~u2)
                pl = parity_t[arA, ta]                         # [A, PP]
                pvalid = pcols < npaths[:, None]
                pk_t = (tree[:, None] * nl[:, None] + pl) * sub[:, None] \
                    + soff[:, None]
                pk_r = (2 * nl[:, None] + pl) * sub[:, None] + soff[:, None]
                p_busy = use[arA[:, None], pk_t] \
                    | (~is_h[:, None] & use[arA[:, None], pk_r])
                parity_free = ~jnp.any(pvalid & p_busy, axis=1)
                tree01 = jnp.minimum(tree, 1)
                first_w = wr_half[arA, tree01] == 0
                pair_ok = ~pair_used & ~use[arA, key_other] & ~u2
                ntx_ok = jnp.where(ld, direct_free | parity_free,
                                   is_h | first_w | pair_ok)
                # banked
                bankb = w % n_banks
                used_b = ruse[arA, bankb]
                banked_ok = used_b < ppb
                # remap: live-bank read, first-free-bank write steering
                mb = amap[arA, jnp.minimum(a, D - 1)]
                r_ok = ruse[arA, mb] < ppb
                worder = (mb[:, None] + jnp.arange(NB, dtype=I32)[None, :]) \
                    % n_banks[:, None]
                any_wf, wpos = _steer(
                    wuse[arA[:, None], worder], ruse[arA[:, None], worder],
                    jnp.arange(NB)[None, :] < n_banks[:, None], ppb)
                wbank = worder[arA, wpos]
                remap_ok = jnp.where(ld, r_ok, any_wf)
                ok = jnp.where(is_banked, banked_ok,
                               jnp.where(is_remap, remap_ok,
                                         jnp.where(is_ntx, ntx_ok, True)))
                issue = att & ok
                defer = att & ~ok
                cause = jnp.where(is_ntx & ld, STALL_PARITY,
                                  jnp.where(is_ntx, STALL_PAIR, STALL_BANK))
                # budgets / scan caps
                rd = rd - (issue & ld).astype(I32)
                wr = wr - (issue & ~ld).astype(I32)
                slots = slots - (issue & is_simple).astype(I32)
                failed = failed + ((act & dir_defer) | defer).astype(I32)
                stop = stop | (is_simple & act & dir_defer
                               & (failed >= max_failed))
                # per-kind structural state (one scatter per state array)
                bsel = issue & is_banked
                saturated = saturated + (bsel & (used_b + 1 == ppb)) \
                    .astype(I32)
                rd_direct = issue & is_ntx & ld & direct_free
                rd_parity = issue & is_ntx & ld & ~direct_free
                ntx_w = issue & is_ntx & ~ld & ~is_h
                w_pair = ntx_w & ~first_w
                pm = rd_parity[:, None] & pvalid
                kidx = jnp.concatenate(
                    [key1[:, None], key2[:, None], key_other[:, None],
                     pk_t, pk_r], axis=1)
                kmsk = jnp.concatenate(
                    [rd_direct[:, None],
                     ((rd_direct & ~is_h) | w_pair)[:, None],
                     w_pair[:, None], pm, pm & ~is_h[:, None]], axis=1)
                use = use.at[arA[:, None], jnp.where(kmsk, kidx, U)].set(True)
                wr_half = wr_half.at[arA, jnp.where(ntx_w, tree01, 2)].add(1)
                pair_used = pair_used | w_pair
                rm_rd = issue & is_remap & ld
                rm_wr = issue & is_remap & ~ld
                ridx = jnp.where(bsel, bankb,
                                 jnp.where(rm_rd, mb,
                                           jnp.where(rm_wr, wbank, NB)))
                ruse = ruse.at[arA, ridx].add(1)
                wuse = wuse.at[arA, jnp.where(rm_wr, wbank, NB)].set(1)
                amap = amap.at[arA, jnp.where(rm_wr, a, D)].set(
                    jnp.where(rm_wr, wbank, 0))
                # apply issues to the global schedule state
                latv = jnp.where(ld, mem_latency, lat[node])
                tgt = jnp.where(issue, node, TRASH)
                finish = finish.at[tgt].set(cycle + latv)
                issued = issued.at[tgt].set(True)
                if record:
                    # path kind / resource / slot of each issue, exactly
                    # the reference loops' recording rules (events.py)
                    pathv = jnp.where(
                        rd_parity, PATH_PARITY,
                        jnp.where(w_pair, PATH_PAIR_RMW,
                                  jnp.where(rm_wr, PATH_STEERED,
                                            jnp.where(issue & ~ld & is_lvt,
                                                      PATH_BROADCAST,
                                                      PATH_DIRECT))))
                    resv = jnp.where(
                        bsel, bankb,
                        jnp.where(rm_rd, mb,
                                  jnp.where(rm_wr, wbank,
                                            jnp.where(rd_direct, key1, -1))))
                    ev_cycle = ev_cycle.at[tgt].set(cycle)
                    ev_path = ev_path.at[tgt].set(pathv.astype(I32))
                    ev_res = ev_res.at[tgt].set(resv.astype(I32))
                    ev_slot = ev_slot.at[tgt].set(mem_pa)
                first = defer & ~delayed[node]
                delayed = delayed.at[jnp.where(first, node, TRASH)].set(True)
                mem_pa = mem_pa + issue.astype(I32)
                conflict_n = conflict_n + jnp.sum(
                    first & (cause == STALL_BANK), dtype=I32)
                parity_n = parity_n + jnp.sum(
                    first & (cause == STALL_PARITY), dtype=I32)
                pair_n = pair_n + jnp.sum(
                    first & (cause == STALL_PAIR), dtype=I32)
                pr_n = pr_n + jnp.sum(rd_parity, dtype=I32)
                rmw_n = rmw_n + jnp.sum(w_pair, dtype=I32)
                nxt = (j + 1, rd, wr, slots, failed, saturated, stop,
                       pair_used, wr_half, ruse, wuse, use, amap, finish,
                       issued, delayed, mem_pa, conflict_n, parity_n,
                       pair_n, pr_n, rmw_n)
                if record:
                    nxt = nxt + (ev_cycle, ev_path, ev_res, ev_slot)
                return nxt

            zA = jnp.zeros((A,), I32)
            z = jnp.int32(0)
            st0 = (jnp.int32(0),
                   desc[:, F_RD].astype(I32), desc[:, F_WR].astype(I32),
                   desc[:, F_SLOTS].astype(I32), zA, zA,
                   jnp.zeros((A,), bool), jnp.zeros((A,), bool),
                   jnp.zeros((A, 3), I32),
                   jnp.zeros((A, NB + 1), I32), jnp.zeros((A, NB + 1), I32),
                   jnp.zeros((A, U + 1), bool), maps, finish, issued,
                   delayed, zA, z, z, z, z, z)
            if record:
                st0 = st0 + (ev_cycle, ev_path, ev_res, ev_slot)
            st = lax.while_loop(icond, istep, st0)
            maps, finish, issued, delayed = st[12:16]
            mem_pa, conflict_add, parity_add, pair_add, pr_add, rmw_add = \
                st[16:22]
            if record:
                ev_cycle, ev_path, ev_res, ev_slot = st[22:]
            mem_add = jnp.sum(mem_pa, dtype=I32)
            per_array = per_array + mem_pa
            any_mem = (mem_add > 0).astype(I32)

            # ---- advance the clock (idle-cycle jump is cycle-exact)
            issued_r = issued[:NPAD]
            finish_r = finish[:NPAD]
            still_ready = jnp.any(ready & ~issued_r)
            inflight = issued_r & (finish_r > cycle)
            any_inflight = jnp.any(inflight)
            next_finish = jnp.min(jnp.where(inflight, finish_r, _INT32_INF))
            ncycle = cycle + 1
            ncycle = jnp.where(
                ~still_ready & any_inflight & (next_finish > ncycle),
                next_finish, ncycle)
            err = jnp.where(
                (err == ERR_NONE) & ~still_ready & ~any_inflight
                & (remaining > 0),
                jnp.int32(ERR_DEADLOCK), err)
            cnt = cnt + jnp.stack(
                [fu_issue_n + mem_add, mem_add, conflict_add, parity_add,
                 pair_add, pr_add, rmw_add, any_mem])
            nxt = (ncycle, remaining, finish, issued, delayed, maps, cnt,
                   per_array, err)
            if record:
                nxt = nxt + (ev_cycle, ev_path, ev_res, ev_slot)
            return nxt

        finish0 = jnp.concatenate([
            jnp.full((NPAD,), _INT32_INF, I32),
            jnp.asarray([-1, _INT32_INF], I32)])     # pred sentinel + trash
        carry0 = (jnp.int32(0), n_real, finish0,
                  jnp.zeros((NPAD + 2,), bool), jnp.zeros((NPAD + 2,), bool),
                  jnp.zeros((A, D + 1), I32), jnp.zeros((8,), I32),
                  jnp.zeros((A,), I32), jnp.int32(ERR_NONE))
        if record:
            carry0 = carry0 + tuple(
                jnp.full((NPAD + 2,), -1, I32) for _ in range(4))

        def cond(c):
            return (c[1] > 0) & (c[8] == ERR_NONE)

        out = lax.while_loop(cond, body, carry0)
        cycle, _, _, _, _, maps, cnt, per_array, err = out[:9]
        if record:
            events = jnp.stack([e[:NPAD] for e in out[9:]])
            return cycle, cnt, per_array, err, maps[:, :D], events
        return cycle, cnt, per_array, err, maps[:, :D]

    return lane


@lru_cache(maxsize=32)
def _compiled(sc: StaticCfg, record: bool = False):
    lane = _make_lane_fn(sc, record)
    return jax.jit(jax.vmap(lane, in_axes=(0,) * 8 + (None,) * 8))


def _bucket_limits(limits: "Sequence[tuple]") -> tuple[int, int, int, int, int]:
    """Pow-2 buckets of the per-design device limits (jit-cache reuse)."""
    s, u, nb, d, pp = (max(col) for col in zip(*limits))
    return (_next_pow2(max(s, 1)), _next_pow2(max(u, 1)),
            _next_pow2(max(nb, 1)), _next_pow2(max(d, 1)),
            _next_pow2(max(pp, 1)))


def schedule_batched(
    tr: "Trace | PreparedTrace",
    cfgs: "Sequence[ScheduleConfig]",
    *,
    return_maps: bool = False,
    collect_events: bool = False,
):
    """Run the cycle-accurate scheduler for many designs in one jit call.

    Every ``cfg`` is one design point over the *same* trace (the DSE
    grid axis); the batch is vmapped, so cost grows with the widest
    lane, not the lane count.  Returns ``list[ScheduleResult]`` in
    ``cfgs`` order — each element exactly equal to what
    ``scheduler.schedule`` computes for that config.  With
    ``return_maps=True`` also returns the final remap live maps
    ``[batch, n_arrays, table_depth]`` (property-test hook).  With
    ``collect_events=True`` the recording kernel variant runs instead
    and a list of per-config :class:`~repro.core.sim.events.EventLog`
    is appended to the return tuple (bit-equal to the py/C logs).
    """
    from repro.core.sim.scheduler import ScheduleResult

    pt = prepare_trace(tr)
    dv = pt.device_views()
    cfgs = list(cfgs)
    if not cfgs:
        empty: tuple = ([],)
        if return_maps:
            empty = empty + (np.zeros((0, 0, 0), np.int32),)
        if collect_events:
            empty = empty + ([],)
        return empty if len(empty) > 1 else empty[0]

    all_descs = [compile_descriptors(c.mem, pt.n_arrays, c.ports_per_bank)
                 for c in cfgs]
    S, U, NB, D, PP = _bucket_limits([device_limits(d) for d in all_descs])
    A = dv.a_pad
    sc = StaticCfg(
        n_pad=dv.n_pad, n_preds_max=dv.n_preds_max, a_pad=A,
        scan_slots=S, key_space=U, bank_slots=NB, table_depth=D,
        parity_paths=PP)

    B = len(cfgs)
    desc = np.zeros((B, A, N_FIELDS), np.int32)
    direct = np.zeros((B, A, D), np.int32)
    offset = np.zeros((B, A, D), np.int32)
    parity = np.zeros((B, A, D, PP), np.int32)
    fu_budgets = np.zeros((B, len(FU_ORDER)), np.int32)
    mem_latency = np.zeros((B,), np.int32)
    ppb = np.zeros((B,), np.int32)
    max_cycles = np.zeros((B,), np.int32)
    for b, (cfg, descs) in enumerate(zip(cfgs, all_descs)):
        mat = descriptor_matrix(descs)
        desc[b, :mat.shape[0]] = mat.astype(np.int32)
        dt, ot, pt_ = descriptor_device_tables(descs, A, D, PP)
        direct[b], offset[b], parity[b] = dt, ot, pt_
        fu_budgets[b] = [cfg.fu_counts.get(name, 1) for name in FU_ORDER]
        mem_latency[b] = cfg.mem_latency
        ppb[b] = cfg.ports_per_bank
        max_cycles[b] = min(cfg.max_cycles, int(_INT32_INF) - 64)

    lane_out = _compiled(sc, collect_events)(
        desc, fu_budgets, mem_latency, ppb, max_cycles,
        direct, offset, parity,
        np.int32(dv.n_real), dv.preds_pad, dv.lat, dv.is_load,
        dv.word_idx, dv.perm, dv.gid_perm, dv.seg_start)
    cycles, cnt, per_array, err, maps = lane_out[:5]
    ev_dev = np.asarray(lane_out[5]) if collect_events else None
    cycles = np.asarray(cycles)
    cnt = np.asarray(cnt)
    per_array = np.asarray(per_array)
    err = np.asarray(err)

    for b, cfg in enumerate(cfgs):
        if err[b] == ERR_MAX_CYCLES:
            raise RuntimeError(
                f"scheduler exceeded {cfg.max_cycles} cycles")
        if err[b] == ERR_DEADLOCK:
            raise RuntimeError(
                "deadlock: nodes remain but nothing ready/inflight")
        if err[b] == ERR_UNCONFIGURED:
            raise KeyError(
                "memory op on array without a ScheduleConfig.mem spec")

    names = pt.trace.array_names
    results = [
        ScheduleResult(
            cycles=int(cycles[b]),
            issued=int(cnt[b, 0]),
            mem_issued=int(cnt[b, 1]),
            **{f"{k}_stalls": int(cnt[b, i])
               for k, i in zip(STALL_KEYS, (2, 3, 4))},
            parity_path_reads=int(cnt[b, 5]),
            write_pair_rmws=int(cnt[b, 6]),
            per_array_accesses={a: int(per_array[b, a]) for a in names},
            avg_mem_parallelism=int(cnt[b, 1]) / max(int(cnt[b, 7]), 1),
        )
        for b in range(len(cfgs))
    ]
    ret: tuple = (results,)
    if return_maps:
        ret = ret + (np.asarray(maps),)
    if collect_events:
        n = pt.trace.n_nodes
        ret = ret + ([EventLog(cycle=ev_dev[b, 0, :n].astype(np.int64),
                               path=ev_dev[b, 1, :n].astype(np.int64),
                               resource=ev_dev[b, 2, :n].astype(np.int64),
                               slot=ev_dev[b, 3, :n].astype(np.int64))
                      for b in range(len(cfgs))],)
    return ret if len(ret) > 1 else ret[0]


def schedule_jax(tr: "Trace | PreparedTrace",
                 cfg: "ScheduleConfig") -> "ScheduleResult":
    """Single-design convenience wrapper over :func:`schedule_batched`."""
    return schedule_batched(tr, [cfg])[0]
