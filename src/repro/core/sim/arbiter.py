"""Per-kind memory-arbitration layer (paper II + III-C).

The port-constrained scheduler used to model every conflict-free design
as an ideal ``n_read x n_write`` multiport and only serialized
``banked``.  That erases exactly the cycle-level structure the paper's
AMM families differ in: NTX parity-path reads fan out across internal
leaf banks, B-NTX pairs same-bank writes through the Ref re-pointing
flow, LVT broadcasts every write to its read replicas, remap steers
writes under a no-two-writes-share-a-bank constraint, and multipumping
buys its ports from an internally doubled clock rather than real wiring.

This module compiles an :class:`~repro.core.amm.spec.AMMSpec` into a
compact numeric :class:`ArbDescriptor` consumed by **both** cycle loops
(``scheduler._schedule_py`` and ``_cycle_loop.c``), plus a pure-Python
:class:`PortArbiter` that implements the per-cycle issue rules for the
stateful kinds.  The two loops make bit-identical decisions: the C code
recomputes the same leaf paths from the same geometry.

Per-kind issue rules (one external cycle)
-----------------------------------------
``ideal`` / ``lvt``
    ``n_read`` loads + ``n_write`` stores, any addresses.  LVT is
    conflict-free because every write-port bank is replicated per read
    port (the broadcast is a cost/energy effect, not a timing one).
``banked``
    each of ``n_banks`` banks is a dual-port macro serving up to
    ``ports_per_bank`` accesses; conflicts serialize (seed semantics,
    pinned by the seed goldens).
``multipump``
    the advertised ``n_read``/``n_write`` ports are delivered by an
    internally double-clocked dual-port macro: per external cycle at
    most ``ports_per_bank * clock_ratio`` total accesses, capped per
    direction by the advertised port counts.  (The seed granted
    ``2*n_read`` reads *and* ``2*n_write`` writes — double-counting the
    pumping that already pays for the advertised ports.)
``h_ntx_rd``
    ``3**k`` leaf banks, one read port per (leaf, sub-bank).  A read
    takes its direct leaf if free, else the whole ``2**k``-leaf parity
    path (all leaves must be free) — else it stalls
    (``parity_fanout_stalls``).  The single write port always issues
    (the invariant-maintaining XOR scatter has dedicated write ports).
``b_ntx_wr`` / ``hb_ntx``
    two data structures (address halves) plus a Ref structure, each an
    ``h_ntx``-style tree (``k == 0`` for plain B-NTX).  A read consumes
    the direct (or parity) leaves of its data tree *and* of the Ref
    tree.  The first write per half issues plainly; a second write into
    an already-written half is the paper's pair-conflict flow: it needs
    the single Ref re-pointing unit plus read access to the *other*
    data tree and the Ref tree at its offset — if any of those leaf
    read ports were consumed this cycle the write stalls
    (``write_pair_stalls``); successful re-points are counted as
    ``write_pair_rmws`` (cross-validated against the functional models'
    conflict condition in ``core/amm/replay``).
``remap``
    ``n_write + 1`` full-depth banks and a live-map table.  A read must
    hit the bank currently holding its word (``map[word]``); a bank
    serves ``ports_per_bank`` accesses per cycle.  A write is steered to
    the first bank — scanning from the word's current bank, exactly the
    ``replay._remap_step`` rule — that has no write this cycle and a
    port left; the map is updated to the chosen bank.  Both read
    over-subscription and failed steering count as
    ``bank_conflict_stalls``.

AMM leaf sub-banking (``AMMSpec.n_banks`` on AMM kinds) splits every
leaf macro into ``n_banks`` word-interleaved sub-banks with independent
ports: two accesses to the same leaf no longer conflict unless they
also share ``offset % n_banks``.  For LVT/remap the sub-banking is a
cost/frequency effect only (their arbitration is bank-granular).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from repro.core.amm.spec import AMMSpec

# kind ids shared with _cycle_loop.c — keep both tables in sync.
KIND_IDEAL, KIND_BANKED, KIND_MULTIPUMP = 0, 1, 2
KIND_H_NTX, KIND_B_NTX, KIND_HB_NTX = 3, 4, 5
KIND_LVT, KIND_REMAP = 6, 7

KIND_IDS: dict[str, int] = {
    "ideal": KIND_IDEAL, "banked": KIND_BANKED, "multipump": KIND_MULTIPUMP,
    "h_ntx_rd": KIND_H_NTX, "b_ntx_wr": KIND_B_NTX, "hb_ntx": KIND_HB_NTX,
    "lvt": KIND_LVT, "remap": KIND_REMAP,
}

_NTX_KINDS = (KIND_H_NTX, KIND_B_NTX, KIND_HB_NTX)

# descriptor field layout (row per array) shared with _cycle_loop.c
F_KIND, F_RD, F_WR, F_SLOTS, F_NBANKS, F_DEPTH, F_LEVELS, F_HALF, \
    F_SUB, F_MAXFAIL, F_CONFIGURED, F_NLEAVES, F_TREE_DEPTH = range(13)
N_FIELDS = 13

# stall / event causes reported by PortArbiter
STALL_NONE, STALL_BANK, STALL_PARITY, STALL_PAIR = 0, 1, 2, 3
EV_NONE, EV_PARITY_READ, EV_PAIR_RMW = 0, 1, 2

# The canonical stall taxonomy, in STALL_BANK/STALL_PARITY/STALL_PAIR
# order.  Every consumer — ``ScheduleResult.stall_breakdown``, the C and
# JAX backend wrappers, the DSE CSV schema, the surrogate feature lists
# and the legality checker's violation classes — derives its key set
# from this tuple; the per-backend re-declarations it replaces drifted
# once already.
STALL_KEYS: tuple[str, ...] = ("bank_conflict", "parity_fanout",
                               "write_pair")


@dataclasses.dataclass(frozen=True)
class ArbDescriptor:
    """Compact numeric arbitration descriptor for one array's memory.

    Attributes mirror the C-side descriptor row: ``rd``/``wr`` are the
    per-external-cycle datapath budgets (multipump folded in), ``slots``
    the shared port-slot budget (binding for multipump only),
    ``n_banks`` the internal bank count (banked / remap), ``levels`` the
    NTX read-tree height ``k``, ``n_leaves`` = ``3**k`` leaves per tree,
    ``tree_depth`` the words per tree (full depth for h_ntx, the half
    for b/hb), ``half`` the top-level split point, ``sub`` the leaf
    sub-banking factor, and ``max_failed`` the deferral-scan cap.
    """

    kind: int
    rd: int
    wr: int
    slots: int
    n_banks: int
    depth: int
    levels: int
    half: int
    sub: int
    max_failed: int
    n_leaves: int
    tree_depth: int
    write_broadcast: int        # LVT: replicas each write lands in (cost)
    clock_ratio: int            # multipump: internal clock multiple

    def row(self) -> list[int]:
        """Descriptor row in the ``F_*`` layout for the C cycle loop."""
        out = [0] * N_FIELDS
        out[F_KIND] = self.kind
        out[F_RD] = self.rd
        out[F_WR] = self.wr
        out[F_SLOTS] = self.slots
        out[F_NBANKS] = self.n_banks
        out[F_DEPTH] = self.depth
        out[F_LEVELS] = self.levels
        out[F_HALF] = self.half
        out[F_SUB] = self.sub
        out[F_MAXFAIL] = self.max_failed
        out[F_CONFIGURED] = 1
        out[F_NLEAVES] = self.n_leaves
        out[F_TREE_DEPTH] = self.tree_depth
        return out


def compile_spec(spec: AMMSpec, ports_per_bank: int = 2) -> ArbDescriptor:
    """Compile one memory design into its arbitration descriptor."""
    kind = KIND_IDS[spec.kind]
    rd, wr = spec.n_read, spec.n_write
    k = spec.read_tree_levels
    clock_ratio = 2 if kind == KIND_MULTIPUMP else 1
    slots = (ports_per_bank * clock_ratio if kind == KIND_MULTIPUMP
             else rd + wr)
    n_banks = 1
    levels = half = 0
    n_leaves = tree_depth = 0
    sub = 1
    if kind == KIND_BANKED:
        n_banks = spec.n_banks
    elif kind == KIND_REMAP:
        n_banks = spec.n_write + 1
    elif kind == KIND_H_NTX:
        levels, n_leaves, tree_depth = k, 3 ** k, spec.depth
        sub = max(spec.n_banks, 1)
    elif kind in (KIND_B_NTX, KIND_HB_NTX):
        levels = k if kind == KIND_HB_NTX else 0
        n_leaves, tree_depth = 3 ** levels, spec.depth // 2
        half = spec.depth // 2
        sub = max(spec.n_banks, 1)
    # deferral-scan cap: seed formula for seed kinds (goldens), scaled to
    # the internal structure for the new ones
    if kind in _NTX_KINDS:
        trees = 1 if kind == KIND_H_NTX else 3
        max_failed = 4 * trees * n_leaves * sub * ports_per_bank + 8
    elif kind == KIND_REMAP:
        max_failed = 4 * n_banks * ports_per_bank + 8
    else:
        max_failed = 4 * spec.n_banks * ports_per_bank + 8
    return ArbDescriptor(
        kind=kind, rd=rd, wr=wr, slots=slots, n_banks=n_banks,
        depth=spec.depth, levels=levels, half=half, sub=sub,
        max_failed=max_failed, n_leaves=n_leaves, tree_depth=tree_depth,
        write_broadcast=spec.n_read if kind == KIND_LVT else 1,
        clock_ratio=clock_ratio,
    )


# ----------------------------------------------------------------------
# NTX leaf-path tables (numpy mirror of replay.h_tables, jax-free)
# ----------------------------------------------------------------------
@lru_cache(maxsize=None)
def ntx_tables(tree_depth: int, levels: int):
    """``(direct, offset, parity)`` leaf-path tables for one tree.

    Same construction as ``repro.core.amm.replay.h_tables`` (pinned
    equal by ``tests/test_arbiter.py``) but numpy-only so the scheduler
    never imports jax: ``direct[a]`` is the leaf the direct read path
    lands in, ``offset[a]`` the word offset inside every path leaf, and
    ``parity[a]`` the ``2**k`` leaves whose XOR reconstructs the word.
    """
    k = levels
    addrs = np.arange(tree_depth, dtype=np.int64)
    off = addrs.copy()
    bits = np.zeros((tree_depth, k), np.int64)
    cur = tree_depth
    for lvl in range(k):
        half = cur // 2
        hi = (off >= half).astype(np.int64)
        bits[:, lvl] = hi
        off -= hi * half
        cur = half
    w3 = 3 ** np.arange(k - 1, -1, -1, dtype=np.int64)
    direct = (bits @ w3) if k else np.zeros(tree_depth, np.int64)
    n_paths = 1 << k
    parity = np.zeros((tree_depth, n_paths), np.int64)
    for j in range(n_paths):
        c = np.asarray([(j >> (k - 1 - lvl)) & 1 for lvl in range(k)],
                       np.int64)
        parity[:, j] = (np.where(c, 2, 1 - bits) @ w3) if k else 0
    return (direct.astype(np.int64), off.astype(np.int64), parity)


# ----------------------------------------------------------------------
# pure-Python per-cycle arbiter (reference; twin of the C branches)
# ----------------------------------------------------------------------
class PortArbiter:
    """Stateful per-array arbiter for the ntx kinds and remap.

    The scheduler calls :meth:`begin_cycle` once per cycle and then
    :meth:`access` once per candidate op in heap-priority order; the
    C cycle loop replays exactly the same decision procedure.  The
    object also works standalone (tests drive it with per-cycle address
    lists and compare against ``core/amm/replay``).
    """

    def __init__(self, desc: ArbDescriptor,
                 ports_per_bank: int = 2) -> None:
        self.desc = desc
        self.ports_per_bank = ports_per_bank
        self.kind = desc.kind
        if self.kind in _NTX_KINDS:
            self.direct, self.offset, self.parity = ntx_tables(
                desc.tree_depth, desc.levels)
            self._use: set[int] = set()
        elif self.kind == KIND_REMAP:
            self.map = [0] * desc.depth
            self._ruse = [0] * desc.n_banks
            self._wuse = [0] * desc.n_banks
        else:
            raise ValueError(f"kind {desc.kind} needs no PortArbiter")
        self.parity_path_reads = 0
        self.write_pair_rmws = 0
        self._wr_half = [0, 0]
        self._pair_used = 0
        # resource touched by the last successful access: the direct
        # leaf-port key for NTX direct reads, the live/steered bank for
        # remap, -1 where the access has no single resource (parity
        # fan-outs, pair RMWs, plain writes).  Consumed by the
        # event-log recording path in the scheduler.
        self.last_res = -1

    # -- cycle lifecycle ------------------------------------------------
    def begin_cycle(self) -> None:
        if self.kind == KIND_REMAP:
            nb = self.desc.n_banks
            self._ruse = [0] * nb
            self._wuse = [0] * nb
        else:
            self._use.clear()
        self._wr_half[0] = self._wr_half[1] = 0
        self._pair_used = 0

    # -- key helpers ----------------------------------------------------
    def _key(self, tree: int, leaf: int, sub: int) -> int:
        return (tree * self.desc.n_leaves + leaf) * self.desc.sub + sub

    # -- the decision procedure ----------------------------------------
    def access(self, is_load: bool, word: int) -> tuple[bool, int, int]:
        """Arbitrate one access; returns ``(issued, stall_cause, event)``.

        Port-count budgets are enforced by the caller; this decides only
        the kind-specific structural constraints.
        """
        if self.kind == KIND_REMAP:
            return self._remap(is_load, word)
        return self._ntx(is_load, word)

    def _ntx(self, is_load: bool, word: int) -> tuple[bool, int, int]:
        d = self.desc
        a = word % d.depth
        if d.kind == KIND_H_NTX:
            tree, ta = 0, a
        else:
            tree = 1 if a >= d.half else 0
            ta = a - (d.half if tree else 0)
        if not is_load:
            self.last_res = -1
            if d.kind == KIND_H_NTX:
                return True, STALL_NONE, EV_NONE     # single dedicated port
            if self._wr_half[tree] == 0:
                self._wr_half[tree] = 1
                return True, STALL_NONE, EV_NONE     # plain write
            if self._pair_used:
                return False, STALL_PAIR, EV_NONE    # one re-point per cycle
            leaf = int(self.direct[ta])
            s = int(self.offset[ta]) % d.sub
            k_other = self._key(1 - tree, leaf, s)
            k_ref = self._key(2, leaf, s)
            if k_other in self._use or k_ref in self._use:
                return False, STALL_PAIR, EV_NONE    # Ref RMW read path busy
            self._use.add(k_other)
            self._use.add(k_ref)
            self._pair_used = 1
            self._wr_half[tree] += 1
            self.write_pair_rmws += 1
            return True, STALL_NONE, EV_PAIR_RMW
        # read: direct path, else the full parity path
        leaf = int(self.direct[ta])
        s = int(self.offset[ta]) % d.sub
        keys = [self._key(tree, leaf, s)]
        if d.kind != KIND_H_NTX:
            keys.append(self._key(2, leaf, s))
        if all(k not in self._use for k in keys):
            self._use.update(keys)
            self.last_res = keys[0]
            return True, STALL_NONE, EV_NONE
        self.last_res = -1
        pkeys = []
        for pl in self.parity[ta]:
            pkeys.append(self._key(tree, int(pl), s))
            if d.kind != KIND_H_NTX:
                pkeys.append(self._key(2, int(pl), s))
        if all(k not in self._use for k in pkeys):
            self._use.update(pkeys)
            self.parity_path_reads += 1
            return True, STALL_NONE, EV_PARITY_READ
        return False, STALL_PARITY, EV_NONE

    def _remap(self, is_load: bool, word: int) -> tuple[bool, int, int]:
        d = self.desc
        a = word % d.depth
        nb, ppb = d.n_banks, self.ports_per_bank
        if is_load:
            bank = self.map[a]
            if self._ruse[bank] >= ppb:
                return False, STALL_BANK, EV_NONE
            self._ruse[bank] += 1
            self.last_res = bank
            return True, STALL_NONE, EV_NONE
        start = self.map[a]
        for i in range(nb):
            b = (start + i) % nb
            if not self._wuse[b] and self._ruse[b] < ppb:
                self._wuse[b] = 1
                self._ruse[b] += 1
                self.map[a] = b
                self.last_res = b
                return True, STALL_NONE, EV_NONE
        return False, STALL_BANK, EV_NONE

    # -- convenience for standalone (test) driving ----------------------
    def read(self, word: int) -> bool:
        ok, _, _ = self.access(True, word)
        return ok

    def write(self, word: int) -> "int | None":
        """Issue a write; returns the steered bank (remap), 0, or None."""
        ok, _, _ = self.access(False, word)
        if not ok:
            return None
        if self.kind == KIND_REMAP:
            return self.map[word % self.desc.depth]
        return 0


# ----------------------------------------------------------------------
# scheduler glue
# ----------------------------------------------------------------------
def compile_descriptors(mem: "dict[int, AMMSpec]", n_arrays: int,
                        ports_per_bank: int) -> "list[ArbDescriptor | None]":
    """Per-array descriptors (``None`` where no spec is configured)."""
    out: "list[ArbDescriptor | None]" = [None] * n_arrays
    for aid in range(n_arrays):
        spec = mem.get(aid)
        if spec is not None:
            out[aid] = compile_spec(spec, ports_per_bank)
    return out


def descriptor_matrix(descs: "list[ArbDescriptor | None]") -> np.ndarray:
    """``[n_arrays, N_FIELDS]`` int64 matrix for the C cycle loop."""
    n = max(len(descs), 1)
    mat = np.zeros((n, N_FIELDS), np.int64)
    for aid, d in enumerate(descs):
        if d is not None:
            mat[aid] = d.row()
    return np.ascontiguousarray(mat)


# ----------------------------------------------------------------------
# device-tensor export (batched JAX cycle loop)
# ----------------------------------------------------------------------
def device_limits(descs: "list[ArbDescriptor | None]",
                  ) -> tuple[int, int, int, int, int]:
    """Fixed-shape bounds one design's descriptors need on device.

    Returns ``(scan_slots, key_space, bank_slots, table_depth,
    parity_paths)``:

    * ``scan_slots`` — max candidates one array's per-cycle deferral
      scan can pop: every pop either issues (``rd + wr`` cap) or defers
      (``max_failed`` cap), so the scan never looks further;
    * ``key_space`` — NTX (tree, leaf, sub-bank) port-key ids,
      ``3 * n_leaves * sub``;
    * ``bank_slots`` — banked/remap per-cycle bank-usage counters;
    * ``table_depth`` — words addressed by per-word state (NTX path
      tables are per ``tree_depth`` word, the remap live map per
      ``depth`` word);
    * ``parity_paths`` — widest NTX parity fan-out ``2**levels``.
    """
    slots = keys = banks = depth = paths = 0
    for d in descs:
        if d is None:
            continue
        slots = max(slots, d.rd + d.wr + d.max_failed)
        banks = max(banks, d.n_banks)
        if d.kind in _NTX_KINDS:
            keys = max(keys, 3 * d.n_leaves * d.sub)
            depth = max(depth, d.tree_depth)
            paths = max(paths, 1 << d.levels)
        elif d.kind == KIND_REMAP:
            depth = max(depth, d.depth)
    return slots, keys, banks, depth, paths


def descriptor_device_tables(
    descs: "list[ArbDescriptor | None]", n_arrays: int, table_depth: int,
    parity_paths: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Padded per-array NTX leaf-path tables for the JAX cycle loop.

    Returns ``(direct, offset, parity)`` of shapes ``[n_arrays,
    table_depth]`` / ``[n_arrays, table_depth, parity_paths]`` (int32,
    zero where an array is not an NTX kind or beyond its tree depth) —
    the same :func:`ntx_tables` geometry both reference loops use.
    """
    a = max(n_arrays, 1)
    d_pad = max(table_depth, 1)
    p_pad = max(parity_paths, 1)
    direct = np.zeros((a, d_pad), np.int32)
    offset = np.zeros((a, d_pad), np.int32)
    parity = np.zeros((a, d_pad, p_pad), np.int32)
    for aid, d in enumerate(descs):
        if d is None or d.kind not in _NTX_KINDS:
            continue
        dr, off, par = ntx_tables(d.tree_depth, d.levels)
        direct[aid, :d.tree_depth] = dr
        offset[aid, :d.tree_depth] = off
        parity[aid, :d.tree_depth, :par.shape[1]] = par
    return direct, offset, parity
