/* Port-constrained list-scheduler cycle loop (C twin of scheduler._schedule_py).
 *
 * Compiled on demand by repro.core.sim._cycle_ext into a cached shared
 * object and called through ctypes.  The algorithm is a 1:1 port of the
 * pure-Python cycle loop; every heap holds distinct packed int64 keys,
 * so pop order — and therefore the whole schedule — is identical to the
 * Python implementation regardless of internal heap layout.
 *
 * Packed encodings (n = number of trace nodes):
 *   ready heaps:   prio[i]  = -height[i] * n + i        (may be negative)
 *   inflight heap: finish_cycle * n + node              (non-negative)
 *
 * Return codes: 0 ok, -1 max_cycles exceeded, -2 deadlock,
 * -3 memory op on unconfigured array, -4 allocation failure.
 */
#include <stdint.h>
#include <stdlib.h>
#include <string.h>

typedef int64_t i64;
typedef uint8_t u8;

static void heap_push(i64 *h, i64 *sz, i64 v) {
    i64 i = (*sz)++;
    while (i > 0) {
        i64 p = (i - 1) >> 1;
        if (h[p] <= v) break;
        h[i] = h[p];
        i = p;
    }
    h[i] = v;
}

static i64 heap_pop(i64 *h, i64 *sz) {
    i64 top = h[0];
    i64 m = --(*sz);
    if (m > 0) {
        i64 last = h[m];
        i64 i = 0;
        for (;;) {
            i64 l = 2 * i + 1;
            if (l >= m) break;
            i64 r = l + 1;
            i64 c = (r < m && h[r] < h[l]) ? r : l;
            if (h[c] >= last) break;
            h[i] = h[c];
            i = c;
        }
        h[i] = last;
    }
    return top;
}

/* One-pass DDG analysis over the CSR (node ids are topologically
 * ordered by construction): dependency depth (forward) and
 * latency-weighted height to sink (backward).  Same recurrences as
 * prepared.dependency_depths / prepared.schedule_heights. */
void analyze_graph(
    i64 n,
    const i64 *pred_ptr, const i64 *pred_idx,
    const i64 *succ_ptr, const i64 *succ_idx,
    const i64 *node_lat,
    i64 *depth_out, i64 *height_out)
{
    for (i64 i = 0; i < n; i++) {
        i64 d = 0;
        for (i64 e = pred_ptr[i]; e < pred_ptr[i + 1]; e++) {
            i64 pd = depth_out[pred_idx[e]] + 1;
            if (pd > d) d = pd;
        }
        depth_out[i] = d;
    }
    for (i64 i = n - 1; i >= 0; i--) {
        i64 lo = succ_ptr[i], hi = succ_ptr[i + 1];
        if (lo == hi) { height_out[i] = 0; continue; }   /* sink */
        i64 h = 0;
        for (i64 e = lo; e < hi; e++) {
            i64 sh = height_out[succ_idx[e]];
            if (sh > h) h = sh;
        }
        height_out[i] = h + node_lat[i];
    }
}

/* Python-style floor modulo for possibly-negative packed priorities. */
static inline i64 node_of(i64 item, i64 n) {
    i64 m = item % n;
    return m < 0 ? m + n : m;
}

i64 run_schedule(
    i64 n, i64 n_arrays, i64 n_classes,
    const i64 *succ_ptr, const i64 *succ_idx,
    const i64 *indegree, const i64 *height,
    const u8 *is_load, const i64 *node_lat,
    const i64 *word_idx, const i64 *klass_id,
    const i64 *fu_budgets,          /* [n_classes - n_arrays] */
    const i64 *mem_rd, const i64 *mem_wr,      /* [n_arrays] */
    const u8 *mem_banked, const i64 *mem_nbanks,
    const i64 *mem_maxfail, const u8 *mem_configured,
    i64 mem_latency, i64 ports_per_bank, i64 max_cycles,
    i64 *out)   /* [5 + n_arrays]: cycles, issued, mem_issued,
                   conflict_stalls, mem_cycles_used, per_array... */
{
    i64 rc = -4;
    i64 *npreds = NULL, *prio = NULL, *coff = NULL, *hsz = NULL;
    i64 *harena = NULL, *inflight = NULL, *deferred = NULL;
    i64 *bank_use = NULL, *touched = NULL, *per_array = NULL;
    u8 *delayed = NULL;

    i64 max_nb = 1;
    for (i64 a = 0; a < n_arrays; a++)
        if (mem_configured[a] && mem_nbanks[a] > max_nb) max_nb = mem_nbanks[a];

    npreds = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    prio = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    coff = calloc((size_t)n_classes + 1, sizeof(i64));
    hsz = calloc((size_t)n_classes, sizeof(i64));
    harena = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    inflight = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    deferred = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    bank_use = calloc((size_t)max_nb, sizeof(i64));
    touched = malloc((size_t)max_nb * sizeof(i64));
    per_array = calloc((size_t)(n_arrays > 0 ? n_arrays : 1), sizeof(i64));
    delayed = calloc((size_t)(n > 0 ? n : 1), 1);
    if (!npreds || !prio || !coff || !hsz || !harena || !inflight ||
        !deferred || !bank_use || !touched || !per_array || !delayed)
        goto cleanup;

    /* per-class heap arena offsets: heap c may hold every node of class c */
    for (i64 i = 0; i < n; i++) coff[klass_id[i] + 1]++;
    for (i64 c = 0; c < n_classes; c++) coff[c + 1] += coff[c];

    memcpy(npreds, indegree, (size_t)n * sizeof(i64));
    for (i64 i = 0; i < n; i++) prio[i] = -height[i] * n + i;

    for (i64 i = 0; i < n; i++)
        if (npreds[i] == 0) {
            i64 c = klass_id[i];
            heap_push(&harena[coff[c]], &hsz[c], prio[i]);
        }

    i64 inflight_sz = 0;
    i64 cycle = 0, issued = 0, mem_issued = 0, stalls = 0;
    i64 mem_cycles_used = 0, remaining = n;

    while (remaining > 0) {
        if (cycle > max_cycles) { rc = -1; goto cleanup; }

        /* ---- retire ---- */
        i64 retire_limit = cycle * n + n - 1;
        while (inflight_sz > 0 && inflight[0] <= retire_limit) {
            i64 node = node_of(heap_pop(inflight, &inflight_sz), n);
            remaining--;
            for (i64 e = succ_ptr[node]; e < succ_ptr[node + 1]; e++) {
                i64 s = succ_idx[e];
                if (--npreds[s] == 0) {
                    i64 c = klass_id[s];
                    heap_push(&harena[coff[c]], &hsz[c], prio[s]);
                }
            }
        }

        /* ---- issue ---- */
        i64 any_mem = 0;
        int any_active = 0;
        for (i64 c = 0; c < n_classes; c++) {
            if (hsz[c] == 0) continue;
            i64 *heap = &harena[coff[c]];
            if (c >= n_arrays) {
                i64 budget = fu_budgets[c - n_arrays];
                while (hsz[c] > 0 && budget > 0) {
                    i64 node = node_of(heap_pop(heap, &hsz[c]), n);
                    heap_push(inflight, &inflight_sz,
                              (cycle + node_lat[node]) * n + node);
                    issued++;
                    budget--;
                }
            } else {
                if (!mem_configured[c]) { rc = -3; goto cleanup; }
                i64 rd = mem_rd[c], wr = mem_wr[c];
                int bankedf = mem_banked[c];
                i64 nb = mem_nbanks[c], maxf = mem_maxfail[c];
                i64 nd = 0, failed = 0, sat = 0, ntouch = 0;
                while (hsz[c] > 0 && (rd > 0 || wr > 0)) {
                    if (bankedf && (sat >= nb || failed >= maxf)) break;
                    i64 item = heap_pop(heap, &hsz[c]);
                    i64 node = node_of(item, n);
                    int ld = is_load[node];
                    if (ld && rd <= 0) {
                        deferred[nd++] = item;
                        if (++failed >= maxf) break;
                        continue;
                    }
                    if (!ld && wr <= 0) {
                        deferred[nd++] = item;
                        if (++failed >= maxf) break;
                        continue;
                    }
                    if (bankedf) {
                        i64 bank = word_idx[node] % nb;
                        i64 used = bank_use[bank];
                        if (used >= ports_per_bank) {
                            deferred[nd++] = item;
                            if (!delayed[node]) { delayed[node] = 1; stalls++; }
                            failed++;
                            continue;
                        }
                        if (used == 0) touched[ntouch++] = bank;
                        bank_use[bank] = used + 1;
                        if (used + 1 == ports_per_bank) sat++;
                    }
                    i64 lat = ld ? mem_latency : node_lat[node];
                    heap_push(inflight, &inflight_sz, (cycle + lat) * n + node);
                    issued++;
                    mem_issued++;
                    any_mem++;
                    per_array[c]++;
                    if (ld) rd--; else wr--;
                }
                for (i64 k = 0; k < nd; k++)
                    heap_push(heap, &hsz[c], deferred[k]);
                for (i64 k = 0; k < ntouch; k++)
                    bank_use[touched[k]] = 0;
            }
            if (hsz[c] > 0) any_active = 1;
        }
        if (any_mem) mem_cycles_used++;

        cycle++;
        if (!any_active) {
            if (inflight_sz == 0) {
                if (remaining > 0) { rc = -2; goto cleanup; }
            } else {
                i64 next_finish = inflight[0] / n;
                if (next_finish > cycle) cycle = next_finish;
            }
        }
    }

    out[0] = cycle;
    out[1] = issued;
    out[2] = mem_issued;
    out[3] = stalls;
    out[4] = mem_cycles_used;
    for (i64 a = 0; a < n_arrays; a++) out[5 + a] = per_array[a];
    rc = 0;

cleanup:
    free(npreds); free(prio); free(coff); free(hsz); free(harena);
    free(inflight); free(deferred); free(bank_use); free(touched);
    free(per_array); free(delayed);
    return rc;
}
