/* Port-constrained list-scheduler cycle loop (C twin of scheduler._schedule_py).
 *
 * Compiled on demand by repro.core.sim._cycle_ext into a cached shared
 * object and called through ctypes.  The algorithm is a 1:1 port of the
 * pure-Python cycle loop; every heap holds distinct packed int64 keys,
 * so pop order — and therefore the whole schedule — is identical to the
 * Python implementation regardless of internal heap layout.
 *
 * Memory arbitration is per-kind (see repro/core/sim/arbiter.py, whose
 * PortArbiter is the reference for the NTX/remap branches below):
 *   ideal/lvt      port budgets only
 *   multipump      port budgets + shared pumped-slot budget
 *   banked         per-bank ports (seed-exact, pinned by goldens)
 *   h/b/hb ntx     leaf-bank read arbitration (direct vs parity path),
 *                  Ref re-pointing for same-half write pairs
 *   remap          live-map steering; reads hit the live bank
 *
 * Packed encodings (n = number of trace nodes):
 *   ready heaps:   prio[i]  = -height[i] * n + i        (may be negative)
 *   inflight heap: finish_cycle * n + node              (non-negative)
 *
 * Return codes: 0 ok, -1 max_cycles exceeded, -2 deadlock,
 * -3 memory op on unconfigured array, -4 allocation failure.
 */
#include <stdint.h>
#include <stdlib.h>
#include <string.h>

typedef int64_t i64;
typedef uint8_t u8;

/* kind ids + descriptor field layout: keep in sync with arbiter.py */
enum { K_IDEAL = 0, K_BANKED = 1, K_MULTIPUMP = 2, K_H_NTX = 3,
       K_B_NTX = 4, K_HB_NTX = 5, K_LVT = 6, K_REMAP = 7 };
enum { F_KIND = 0, F_RD, F_WR, F_SLOTS, F_NBANKS, F_DEPTH, F_LEVELS,
       F_HALF, F_SUB, F_MAXFAIL, F_CONFIGURED, F_NLEAVES, F_TREE_DEPTH,
       N_FIELDS };

/* issue-event path kinds: keep in sync with repro/core/sim/events.py */
enum { P_COMPUTE = 0, P_DIRECT = 1, P_PARITY = 2, P_STEERED = 3,
       P_PAIR = 4, P_BCAST = 5 };

/* Record one issue event into the caller's optional [n * 4] buffer
 * (cycle, path, resource, slot per node).  `events` may be NULL —
 * the common case — and the whole mechanism can be compiled away with
 * -DREPRO_NO_EVENTS for overhead measurement (tools/
 * measure_check_overhead.py).  Recording happens strictly after the
 * issue decision and touches no scheduler state. */
#ifndef REPRO_NO_EVENTS
#define EV_REC(nd, p, r, s) do { if (events) { \
        i64 *e_ = events + 4 * (nd); \
        e_[0] = cycle; e_[1] = (p); e_[2] = (r); e_[3] = (s); \
    } } while (0)
#else
#define EV_REC(nd, p, r, s) ((void)0)
#endif

#define MAX_LEVELS 32
#define MAX_PATHS 128          /* _schedule_c falls back to Python beyond */

static void heap_push(i64 *h, i64 *sz, i64 v) {
    i64 i = (*sz)++;
    while (i > 0) {
        i64 p = (i - 1) >> 1;
        if (h[p] <= v) break;
        h[i] = h[p];
        i = p;
    }
    h[i] = v;
}

static i64 heap_pop(i64 *h, i64 *sz) {
    i64 top = h[0];
    i64 m = --(*sz);
    if (m > 0) {
        i64 last = h[m];
        i64 i = 0;
        for (;;) {
            i64 l = 2 * i + 1;
            if (l >= m) break;
            i64 r = l + 1;
            i64 c = (r < m && h[r] < h[l]) ? r : l;
            if (h[c] >= last) break;
            h[i] = h[c];
            i = c;
        }
        h[i] = last;
    }
    return top;
}

/* One-pass DDG analysis over the CSR (node ids are topologically
 * ordered by construction): dependency depth (forward) and
 * latency-weighted height to sink (backward).  Same recurrences as
 * prepared.dependency_depths / prepared.schedule_heights. */
void analyze_graph(
    i64 n,
    const i64 *pred_ptr, const i64 *pred_idx,
    const i64 *succ_ptr, const i64 *succ_idx,
    const i64 *node_lat,
    i64 *depth_out, i64 *height_out)
{
    for (i64 i = 0; i < n; i++) {
        i64 d = 0;
        for (i64 e = pred_ptr[i]; e < pred_ptr[i + 1]; e++) {
            i64 pd = depth_out[pred_idx[e]] + 1;
            if (pd > d) d = pd;
        }
        depth_out[i] = d;
    }
    for (i64 i = n - 1; i >= 0; i--) {
        i64 lo = succ_ptr[i], hi = succ_ptr[i + 1];
        if (lo == hi) { height_out[i] = 0; continue; }   /* sink */
        i64 h = 0;
        for (i64 e = lo; e < hi; e++) {
            i64 sh = height_out[succ_idx[e]];
            if (sh > h) h = sh;
        }
        height_out[i] = h + node_lat[i];
    }
}

/* Python-style floor modulo for possibly-negative packed priorities. */
static inline i64 node_of(i64 item, i64 n) {
    i64 m = item % n;
    return m < 0 ? m + n : m;
}

/* NTX leaf paths: same construction as arbiter.ntx_tables — per level
 * the address picks its half (bit), the direct leaf is the base-3
 * number of those bits (ref digit = 2 never appears on the direct
 * path), and parity path j replaces the levels set in j by the ref
 * branch and the others by the opposite child. */
static inline void ntx_direct(i64 tree_depth, i64 k, i64 addr,
                              i64 *leaf_out, i64 *off_out, i64 *bits)
{
    i64 cur = tree_depth, off = addr, d3 = 0;
    for (i64 l = 0; l < k; l++) {
        i64 half = cur >> 1;
        i64 hi = off >= half;
        bits[l] = hi;
        d3 = d3 * 3 + hi;
        if (hi) off -= half;
        cur = half;
    }
    *leaf_out = d3;
    *off_out = off;
}

static inline void ntx_parity(i64 k, const i64 *bits, i64 *pleaf)
{
    i64 n_paths = (i64)1 << k;
    for (i64 j = 0; j < n_paths; j++) {
        i64 d3 = 0;
        for (i64 l = 0; l < k; l++) {
            i64 cbit = (j >> (k - 1 - l)) & 1;
            d3 = d3 * 3 + (cbit ? 2 : 1 - bits[l]);
        }
        pleaf[j] = d3;
    }
}

i64 run_schedule(
    i64 n, i64 n_arrays, i64 n_classes,
    const i64 *succ_ptr, const i64 *succ_idx,
    const i64 *indegree, const i64 *height,
    const u8 *is_load, const i64 *node_lat,
    const i64 *word_idx, const i64 *klass_id,
    const i64 *fu_budgets,          /* [n_classes - n_arrays] */
    const i64 *desc,                /* [n_arrays * N_FIELDS] */
    i64 mem_latency, i64 ports_per_bank, i64 max_cycles,
    i64 *out,   /* [9 + n_arrays]: cycles, issued, mem_issued,
                   bank_stalls, mem_cycles_used, parity_stalls,
                   pair_stalls, parity_reads, pair_rmws, per_array... */
    i64 *events) /* NULL, or [n * 4] (cycle, path, resource, slot) */
{
    i64 rc = -4;
    (void)events;
    i64 *npreds = NULL, *prio = NULL, *coff = NULL, *hsz = NULL;
    i64 *harena = NULL, *inflight = NULL, *deferred = NULL;
    i64 *bank_use = NULL, *touched = NULL, *per_array = NULL;
    i64 *remap_map = NULL, *map_off = NULL;
    u8 *delayed = NULL, *leaf_use = NULL, *wr_used = NULL;

    i64 max_nb = 1, max_leaf = 1, map_total = 0;
    for (i64 a = 0; a < n_arrays; a++) {
        const i64 *d = desc + a * N_FIELDS;
        if (!d[F_CONFIGURED]) continue;
        i64 kind = d[F_KIND];
        if ((kind == K_BANKED || kind == K_REMAP) && d[F_NBANKS] > max_nb)
            max_nb = d[F_NBANKS];
        if (kind == K_H_NTX || kind == K_B_NTX || kind == K_HB_NTX) {
            i64 trees = (kind == K_H_NTX) ? 1 : 3;
            i64 slots = trees * d[F_NLEAVES] * d[F_SUB];
            if (slots > max_leaf) max_leaf = slots;
        }
        if (kind == K_REMAP) map_total += d[F_DEPTH];
    }
    i64 max_touch = max_nb > max_leaf ? max_nb : max_leaf;

    npreds = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    prio = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    coff = calloc((size_t)n_classes + 1, sizeof(i64));
    hsz = calloc((size_t)n_classes, sizeof(i64));
    harena = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    inflight = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    deferred = malloc((size_t)(n > 0 ? n : 1) * sizeof(i64));
    bank_use = calloc((size_t)max_nb, sizeof(i64));
    touched = malloc((size_t)max_touch * sizeof(i64));
    per_array = calloc((size_t)(n_arrays > 0 ? n_arrays : 1), sizeof(i64));
    delayed = calloc((size_t)(n > 0 ? n : 1), 1);
    leaf_use = calloc((size_t)max_leaf, 1);
    wr_used = calloc((size_t)max_nb, 1);
    remap_map = calloc((size_t)(map_total > 0 ? map_total : 1), sizeof(i64));
    map_off = calloc((size_t)(n_arrays > 0 ? n_arrays : 1), sizeof(i64));
    if (!npreds || !prio || !coff || !hsz || !harena || !inflight ||
        !deferred || !bank_use || !touched || !per_array || !delayed ||
        !leaf_use || !wr_used || !remap_map || !map_off)
        goto cleanup;

    {   /* live-map arena offsets per remap array (maps start all-zero,
         * matching replay's init_flat) */
        i64 off = 0;
        for (i64 a = 0; a < n_arrays; a++) {
            const i64 *d = desc + a * N_FIELDS;
            if (d[F_CONFIGURED] && d[F_KIND] == K_REMAP) {
                map_off[a] = off;
                off += d[F_DEPTH];
            }
        }
    }

    /* per-class heap arena offsets: heap c may hold every node of class c */
    for (i64 i = 0; i < n; i++) coff[klass_id[i] + 1]++;
    for (i64 c = 0; c < n_classes; c++) coff[c + 1] += coff[c];

    memcpy(npreds, indegree, (size_t)n * sizeof(i64));
    for (i64 i = 0; i < n; i++) prio[i] = -height[i] * n + i;

    for (i64 i = 0; i < n; i++)
        if (npreds[i] == 0) {
            i64 c = klass_id[i];
            heap_push(&harena[coff[c]], &hsz[c], prio[i]);
        }

    i64 inflight_sz = 0;
    i64 cycle = 0, issued = 0, mem_issued = 0, bank_stalls = 0;
    i64 parity_stalls = 0, pair_stalls = 0, parity_reads = 0, pair_rmws = 0;
    i64 mem_cycles_used = 0, remaining = n;

    while (remaining > 0) {
        if (cycle > max_cycles) { rc = -1; goto cleanup; }

        /* ---- retire ---- */
        i64 retire_limit = cycle * n + n - 1;
        while (inflight_sz > 0 && inflight[0] <= retire_limit) {
            i64 node = node_of(heap_pop(inflight, &inflight_sz), n);
            remaining--;
            for (i64 e = succ_ptr[node]; e < succ_ptr[node + 1]; e++) {
                i64 s = succ_idx[e];
                if (--npreds[s] == 0) {
                    i64 c = klass_id[s];
                    heap_push(&harena[coff[c]], &hsz[c], prio[s]);
                }
            }
        }

        /* ---- issue ---- */
        i64 any_mem = 0;
        int any_active = 0;
        for (i64 c = 0; c < n_classes; c++) {
            if (hsz[c] == 0) continue;
            i64 *heap = &harena[coff[c]];
            if (c >= n_arrays) {
                i64 fub = fu_budgets[c - n_arrays];
                i64 budget = fub;
                while (hsz[c] > 0 && budget > 0) {
                    i64 node = node_of(heap_pop(heap, &hsz[c]), n);
                    heap_push(inflight, &inflight_sz,
                              (cycle + node_lat[node]) * n + node);
                    issued++;
                    EV_REC(node, P_COMPUTE, -1, fub - budget);
                    budget--;
                }
            } else {
                const i64 *dsc = desc + c * N_FIELDS;
                if (!dsc[F_CONFIGURED]) { rc = -3; goto cleanup; }
                i64 kind = dsc[F_KIND];
                i64 rd = dsc[F_RD], wr = dsc[F_WR];
                i64 maxf = dsc[F_MAXFAIL];
                i64 nd = 0, failed = 0;

                i64 mslot = 0;     /* per-class issue ordinal this cycle */
                if (kind == K_BANKED) {
                    /* seed-exact banked serialization */
                    i64 nb = dsc[F_NBANKS];
                    i64 sat = 0, ntouch = 0;
                    while (hsz[c] > 0 && (rd > 0 || wr > 0)) {
                        if (sat >= nb || failed >= maxf) break;
                        i64 item = heap_pop(heap, &hsz[c]);
                        i64 node = node_of(item, n);
                        int ld = is_load[node];
                        if (ld && rd <= 0) {
                            deferred[nd++] = item;
                            if (++failed >= maxf) break;
                            continue;
                        }
                        if (!ld && wr <= 0) {
                            deferred[nd++] = item;
                            if (++failed >= maxf) break;
                            continue;
                        }
                        i64 bank = word_idx[node] % nb;
                        i64 used = bank_use[bank];
                        if (used >= ports_per_bank) {
                            deferred[nd++] = item;
                            if (!delayed[node]) {
                                delayed[node] = 1; bank_stalls++;
                            }
                            failed++;
                            continue;
                        }
                        if (used == 0) touched[ntouch++] = bank;
                        bank_use[bank] = used + 1;
                        if (used + 1 == ports_per_bank) sat++;
                        i64 lat = ld ? mem_latency : node_lat[node];
                        heap_push(inflight, &inflight_sz,
                                  (cycle + lat) * n + node);
                        issued++; mem_issued++; any_mem++; per_array[c]++;
                        EV_REC(node, P_DIRECT, bank, mslot);
                        mslot++;
                        if (ld) rd--; else wr--;
                    }
                    for (i64 t = 0; t < ntouch; t++) bank_use[touched[t]] = 0;
                } else if (kind == K_IDEAL || kind == K_LVT ||
                           kind == K_MULTIPUMP) {
                    /* port budgets + shared pumped-slot budget */
                    i64 slots = dsc[F_SLOTS];
                    while (hsz[c] > 0 && (rd > 0 || wr > 0) && slots > 0) {
                        i64 item = heap_pop(heap, &hsz[c]);
                        i64 node = node_of(item, n);
                        int ld = is_load[node];
                        if (ld && rd <= 0) {
                            deferred[nd++] = item;
                            if (++failed >= maxf) break;
                            continue;
                        }
                        if (!ld && wr <= 0) {
                            deferred[nd++] = item;
                            if (++failed >= maxf) break;
                            continue;
                        }
                        i64 lat = ld ? mem_latency : node_lat[node];
                        heap_push(inflight, &inflight_sz,
                                  (cycle + lat) * n + node);
                        issued++; mem_issued++; any_mem++; per_array[c]++;
                        EV_REC(node,
                               (!ld && kind == K_LVT) ? P_BCAST : P_DIRECT,
                               -1, mslot);
                        mslot++;
                        slots--;
                        if (ld) rd--; else wr--;
                    }
                } else if (kind == K_REMAP) {
                    /* live-map steering (twin of PortArbiter._remap) */
                    i64 nb = dsc[F_NBANKS], dep = dsc[F_DEPTH];
                    i64 *map = remap_map + map_off[c];
                    while (hsz[c] > 0 && (rd > 0 || wr > 0)) {
                        if (failed >= maxf) break;
                        i64 item = heap_pop(heap, &hsz[c]);
                        i64 node = node_of(item, n);
                        int ld = is_load[node];
                        if (ld && rd <= 0) {
                            deferred[nd++] = item; failed++; continue;
                        }
                        if (!ld && wr <= 0) {
                            deferred[nd++] = item; failed++; continue;
                        }
                        i64 a = word_idx[node] % dep;
                        i64 pth, resv;
                        if (ld) {
                            i64 bank = map[a];
                            if (bank_use[bank] >= ports_per_bank) {
                                deferred[nd++] = item;
                                if (!delayed[node]) {
                                    delayed[node] = 1; bank_stalls++;
                                }
                                failed++;
                                continue;
                            }
                            bank_use[bank]++;
                            pth = P_DIRECT; resv = bank;
                        } else {
                            i64 chosen = -1, start = map[a];
                            for (i64 i = 0; i < nb; i++) {
                                i64 b = (start + i) % nb;
                                if (!wr_used[b] &&
                                        bank_use[b] < ports_per_bank) {
                                    chosen = b;
                                    break;
                                }
                            }
                            if (chosen < 0) {
                                deferred[nd++] = item;
                                if (!delayed[node]) {
                                    delayed[node] = 1; bank_stalls++;
                                }
                                failed++;
                                continue;
                            }
                            wr_used[chosen] = 1;
                            bank_use[chosen]++;
                            map[a] = chosen;
                            pth = P_STEERED; resv = chosen;
                        }
                        i64 lat = ld ? mem_latency : node_lat[node];
                        heap_push(inflight, &inflight_sz,
                                  (cycle + lat) * n + node);
                        issued++; mem_issued++; any_mem++; per_array[c]++;
                        EV_REC(node, pth, resv, mslot);
                        mslot++;
                        if (ld) rd--; else wr--;
                    }
                    memset(bank_use, 0, (size_t)nb * sizeof(i64));
                    memset(wr_used, 0, (size_t)nb);
                } else {
                    /* NTX kinds: leaf read arbitration + write pairing
                     * (twin of PortArbiter._ntx) */
                    i64 k = dsc[F_LEVELS], npaths = (i64)1 << k;
                    i64 nl = dsc[F_NLEAVES], sb = dsc[F_SUB];
                    i64 td = dsc[F_TREE_DEPTH], dep = dsc[F_DEPTH];
                    i64 half = dsc[F_HALF];
                    i64 bits[MAX_LEVELS], pleaf[MAX_PATHS];
                    i64 wr_half[2] = {0, 0};
                    i64 pair_used = 0, ntouch = 0;
                    while (hsz[c] > 0 && (rd > 0 || wr > 0)) {
                        if (failed >= maxf) break;
                        i64 item = heap_pop(heap, &hsz[c]);
                        i64 node = node_of(item, n);
                        int ld = is_load[node];
                        if (ld && rd <= 0) {
                            deferred[nd++] = item; failed++; continue;
                        }
                        if (!ld && wr <= 0) {
                            deferred[nd++] = item; failed++; continue;
                        }
                        i64 a = word_idx[node] % dep;
                        i64 tree = 0, ta = a;
                        if (kind != K_H_NTX) {
                            tree = a >= half;
                            ta = a - (tree ? half : 0);
                        }
                        i64 pth = P_DIRECT, resv = -1;
                        int ok = 1;
                        if (!ld) {
                            if (kind == K_H_NTX) {
                                /* single dedicated write port */
                            } else if (wr_half[tree] == 0) {
                                wr_half[tree] = 1;        /* plain write */
                            } else if (pair_used) {
                                ok = 0;                   /* one re-point */
                            } else {
                                i64 leaf, off;
                                ntx_direct(td, k, ta, &leaf, &off, bits);
                                i64 s = off % sb;
                                i64 ko = ((1 - tree) * nl + leaf) * sb + s;
                                i64 kr = (2 * nl + leaf) * sb + s;
                                if (leaf_use[ko] || leaf_use[kr]) {
                                    ok = 0;   /* Ref RMW read path busy */
                                } else {
                                    leaf_use[ko] = 1; touched[ntouch++] = ko;
                                    leaf_use[kr] = 1; touched[ntouch++] = kr;
                                    pair_used = 1;
                                    wr_half[tree]++;
                                    pair_rmws++;
                                    pth = P_PAIR;
                                }
                            }
                            if (!ok) {
                                deferred[nd++] = item;
                                if (!delayed[node]) {
                                    delayed[node] = 1; pair_stalls++;
                                }
                                failed++;
                                continue;
                            }
                        } else {
                            i64 leaf, off;
                            ntx_direct(td, k, ta, &leaf, &off, bits);
                            i64 s = off % sb;
                            i64 kd = (tree * nl + leaf) * sb + s;
                            i64 kr = (2 * nl + leaf) * sb + s;
                            int want_ref = kind != K_H_NTX;
                            if (!leaf_use[kd] && !(want_ref && leaf_use[kr])) {
                                leaf_use[kd] = 1; touched[ntouch++] = kd;
                                if (want_ref) {
                                    leaf_use[kr] = 1; touched[ntouch++] = kr;
                                }
                                resv = kd;
                            } else {
                                /* parity path: every leaf must be free */
                                ntx_parity(k, bits, pleaf);
                                ok = 1;
                                for (i64 j = 0; j < npaths && ok; j++) {
                                    i64 kp = (tree * nl + pleaf[j]) * sb + s;
                                    if (leaf_use[kp]) ok = 0;
                                    if (want_ref && ok &&
                                        leaf_use[(2 * nl + pleaf[j]) * sb + s])
                                        ok = 0;
                                }
                                if (ok) {
                                    for (i64 j = 0; j < npaths; j++) {
                                        i64 kp = (tree * nl + pleaf[j]) * sb
                                                 + s;
                                        leaf_use[kp] = 1;
                                        touched[ntouch++] = kp;
                                        if (want_ref) {
                                            i64 kq = (2 * nl + pleaf[j]) * sb
                                                     + s;
                                            leaf_use[kq] = 1;
                                            touched[ntouch++] = kq;
                                        }
                                    }
                                    parity_reads++;
                                    pth = P_PARITY;
                                } else {
                                    deferred[nd++] = item;
                                    if (!delayed[node]) {
                                        delayed[node] = 1; parity_stalls++;
                                    }
                                    failed++;
                                    continue;
                                }
                            }
                        }
                        i64 lat = ld ? mem_latency : node_lat[node];
                        heap_push(inflight, &inflight_sz,
                                  (cycle + lat) * n + node);
                        issued++; mem_issued++; any_mem++; per_array[c]++;
                        EV_REC(node, pth, resv, mslot);
                        mslot++;
                        if (ld) rd--; else wr--;
                    }
                    for (i64 t = 0; t < ntouch; t++) leaf_use[touched[t]] = 0;
                }
                for (i64 t = 0; t < nd; t++)
                    heap_push(heap, &hsz[c], deferred[t]);
            }
            if (hsz[c] > 0) any_active = 1;
        }
        if (any_mem) mem_cycles_used++;

        cycle++;
        if (!any_active) {
            if (inflight_sz == 0) {
                if (remaining > 0) { rc = -2; goto cleanup; }
            } else {
                i64 next_finish = inflight[0] / n;
                if (next_finish > cycle) cycle = next_finish;
            }
        }
    }

    out[0] = cycle;
    out[1] = issued;
    out[2] = mem_issued;
    out[3] = bank_stalls;
    out[4] = mem_cycles_used;
    out[5] = parity_stalls;
    out[6] = pair_stalls;
    out[7] = parity_reads;
    out[8] = pair_rmws;
    for (i64 a = 0; a < n_arrays; a++) out[9 + a] = per_array[a];
    rc = 0;

cleanup:
    free(npreds); free(prio); free(coff); free(hsz); free(harena);
    free(inflight); free(deferred); free(bank_use); free(touched);
    free(per_array); free(delayed); free(leaf_use); free(wr_used);
    free(remap_map); free(map_off);
    return rc;
}

/* Batched grid evaluation: every config of one design column against
 * the same resident trace in a single extension call, so a sweep stops
 * paying per-point Python dispatch and ctypes marshalling.
 *
 * With cap_mode != 0 the caller passes configs in ascending area order
 * together with each config's area and cycle time (ns); before config
 * c runs, its cycle budget is tightened to the best completed time
 * among strictly-cheaper configs.  A run abandoned at that cap has
 * time > min(cheaper completed time), so some cheaper config is at
 * least as fast and c can never appear on the time/area Pareto front —
 * it is marked capped instead of simulated to completion.
 *
 * status_all[c]:  0 completed | 1 abandoned at the front cap |
 *                 <0 run_schedule error code for that config.
 * Returns the number of configs with negative status. */
i64 run_schedule_batch(
    i64 n, i64 n_arrays, i64 n_classes, i64 n_cfg,
    const i64 *succ_ptr, const i64 *succ_idx,
    const i64 *indegree, const i64 *height,
    const u8 *is_load, const i64 *node_lat,
    const i64 *word_idx, const i64 *klass_id,
    const i64 *fu_budgets_all,   /* [n_cfg * (n_classes - n_arrays)] */
    const i64 *desc_all,         /* [n_cfg * n_arrays * N_FIELDS] */
    const i64 *mem_latency_all,  /* [n_cfg] */
    i64 ports_per_bank, i64 max_cycles, i64 cap_mode,
    const double *area_all,      /* [n_cfg], ascending (cap_mode) */
    const double *ns_all,        /* [n_cfg] cycle ns (cap_mode) */
    i64 *status_all,             /* [n_cfg] out */
    i64 *out_all)                /* [n_cfg * (9 + n_arrays)] out */
{
    i64 n_fu = n_classes - n_arrays;
    i64 out_stride = 9 + n_arrays;
    i64 n_err = 0;
    for (i64 c = 0; c < n_cfg; c++) {
        i64 budget = max_cycles;
        if (cap_mode) {
            double tmin = -1.0;
            for (i64 q = 0; q < c; q++) {
                if (status_all[q] != 0) continue;
                if (area_all[q] > area_all[c] - 1e-12) continue;
                double t = (double)out_all[q * out_stride] * ns_all[q];
                if (tmin < 0.0 || t < tmin) tmin = t;
            }
            if (tmin >= 0.0) {
                double cap = tmin / ns_all[c];
                if (cap < (double)max_cycles) {
                    i64 icap = (i64)cap + 1;   /* >= tmin/ns, so an
                                                  abandoned run is
                                                  strictly slower */
                    if (icap < budget) budget = icap;
                }
            }
        }
        i64 rc = run_schedule(
            n, n_arrays, n_classes, succ_ptr, succ_idx, indegree, height,
            is_load, node_lat, word_idx, klass_id,
            fu_budgets_all + c * n_fu,
            desc_all + (size_t)c * n_arrays * N_FIELDS,
            mem_latency_all[c], ports_per_bank, budget,
            out_all + c * out_stride, NULL);
        if (rc == -1 && budget < max_cycles) {
            status_all[c] = 1;                 /* front-capped */
        } else {
            status_all[c] = rc;
            if (rc < 0) n_err++;
        }
    }
    return n_err;
}
