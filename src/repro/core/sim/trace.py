"""Dynamic-trace representation (the Aladdin flow's LLVM trace analogue).

Aladdin compiles C to LLVM IR, executes it, and extracts a dynamic data
dependency graph (paper III-B / Fig 3).  Here each benchmark *generates*
its exact dynamic trace directly from its loop nest (same information,
no LLVM): a struct-of-arrays of ops plus CSR predecessor lists.

Op kinds: loads/stores carry (array_id, byte address); compute ops carry
a functional-unit class.  Node ids are topologically ordered by
construction (an op may only depend on earlier ops).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# op kind encoding
LOAD, STORE = 0, 1
FADD, FMUL, FDIV, IADD, IMUL, ICMP, LOGIC = 2, 3, 4, 5, 6, 7, 8

KIND_NAMES = {
    LOAD: "load", STORE: "store", FADD: "fadd", FMUL: "fmul", FDIV: "fdiv",
    IADD: "iadd", IMUL: "imul", ICMP: "icmp", LOGIC: "logic",
}
FU_CLASS = {FADD: "fadd", FMUL: "fmul", FDIV: "fdiv", IADD: "iadd",
            IMUL: "imul", ICMP: "icmp", LOGIC: "logic"}

# issue-to-result latencies in cycles (Aladdin-style 45nm FU library)
LATENCY = {LOAD: 2, STORE: 1, FADD: 3, FMUL: 4, FDIV: 16,
           IADD: 1, IMUL: 3, ICMP: 1, LOGIC: 1}


@dataclasses.dataclass
class Trace:
    kinds: np.ndarray          # [N] int8
    array_ids: np.ndarray     # [N] int16  (-1 for compute ops)
    addrs: np.ndarray          # [N] int64  byte addresses (-1 for compute)
    pred_ptr: np.ndarray       # [N+1] CSR offsets into pred_idx
    pred_idx: np.ndarray       # [E] predecessor node ids
    array_names: dict[int, str]
    word_bytes: dict[int, int]  # element size per array
    name: str = "trace"

    @property
    def n_nodes(self) -> int:
        return int(self.kinds.shape[0])

    @property
    def n_mem(self) -> int:
        return int(np.sum(self.kinds <= STORE))

    def mem_mask(self) -> np.ndarray:
        return self.kinds <= STORE

    def mem_addrs_and_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        m = self.mem_mask()
        return self.addrs[m], self.array_ids[m]

    def depths(self) -> np.ndarray:
        """Dependency depth (critical-path level) per node.

        Delegates to the memoized :class:`PreparedTrace` analysis, which
        computes depths with vectorized O(E) frontier sweeps instead of a
        per-node Python loop.
        """
        from repro.core.sim.prepared import prepare_trace
        return prepare_trace(self).depth

    def stats(self) -> dict:
        m = self.mem_mask()
        return {
            "nodes": self.n_nodes,
            "mem_ops": int(m.sum()),
            "loads": int(np.sum(self.kinds == LOAD)),
            "stores": int(np.sum(self.kinds == STORE)),
            "arrays": {self.array_names[a]: int(np.sum(self.array_ids == a))
                       for a in self.array_names},
        }


class TraceBuilder:
    """Append-only builder; node ids are return values of :meth:`add`."""

    def __init__(self, name: str = "trace") -> None:
        self.name = name
        self._kinds: list[int] = []
        self._arrays: list[int] = []
        self._addrs: list[int] = []
        self._preds: list[tuple[int, ...]] = []
        self.array_names: dict[int, str] = {}
        self.word_bytes: dict[int, int] = {}

    def declare_array(self, name: str, word_bytes: int) -> int:
        aid = len(self.array_names)
        self.array_names[aid] = name
        self.word_bytes[aid] = word_bytes
        return aid

    def add(self, kind: int, deps: tuple[int, ...] = (),
            array: int = -1, index: int = -1) -> int:
        """index is the *element* index into the array; converted to bytes."""
        nid = len(self._kinds)
        self._kinds.append(kind)
        self._arrays.append(array)
        if array >= 0 and index >= 0:
            self._addrs.append(index * self.word_bytes[array])
        else:
            self._addrs.append(-1)
        self._preds.append(tuple(int(d) for d in deps))
        return nid

    # convenience wrappers -------------------------------------------------
    def load(self, array: int, index: int, deps: tuple[int, ...] = ()) -> int:
        return self.add(LOAD, deps, array, index)

    def store(self, array: int, index: int, deps: tuple[int, ...] = ()) -> int:
        return self.add(STORE, deps, array, index)

    def op(self, kind: int, *deps: int) -> int:
        return self.add(kind, tuple(deps))

    def build(self) -> Trace:
        n = len(self._kinds)
        counts = np.fromiter((len(p) for p in self._preds), np.int64, n)
        ptr = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=ptr[1:])
        if n and int(ptr[-1]):
            idx = np.fromiter(
                (d for p in self._preds for d in p), np.int64, int(ptr[-1]))
        else:
            idx = np.empty(0, np.int64)
        return Trace(
            kinds=np.asarray(self._kinds, np.int8),
            array_ids=np.asarray(self._arrays, np.int16),
            addrs=np.asarray(self._addrs, np.int64),
            pred_ptr=ptr,
            pred_idx=idx,
            array_names=dict(self.array_names),
            word_bytes=dict(self.word_bytes),
            name=self.name,
        )
