"""On-demand compiled cycle loop (ctypes wrapper for _cycle_loop.c).

``load()`` compiles ``_cycle_loop.c`` with the system C compiler into a
shared object cached under ``$REPRO_CACHE_DIR`` (default
``~/.cache/repro``), keyed by source hash + machine, and returns the
bound ctypes function.  Any failure (no compiler, sandboxed FS, …)
returns ``None`` and the scheduler falls back to the pure-Python cycle
loop — results are identical either way (golden regression tests pin
both paths).

Set ``REPRO_PURE_PY=1`` to force the Python loop.

``build_library(defines=...)`` exposes the compile step for tooling
that needs a variant build (``tools/measure_check_overhead.py``
compiles a ``-DREPRO_NO_EVENTS`` twin to price the event-logging hook).
"""
from __future__ import annotations

import os

_SRC = os.path.join(os.path.dirname(__file__), "_cycle_loop.c")
_FN = None
_ANALYZE = None
_BATCH = None
_TRIED = False


def _cache_dir() -> str:
    root = os.environ.get("REPRO_CACHE_DIR")
    if root:
        return root
    return os.path.join(os.path.expanduser("~"), ".cache", "repro")


def build_library(defines: "tuple[str, ...]" = ()) -> str:
    """Compile ``_cycle_loop.c`` (with optional ``-D`` defines) and
    return the cached shared-object path.  Raises on any failure."""
    import hashlib
    import platform

    with open(_SRC, "rb") as f:
        src = f.read()
    tag = hashlib.sha256(src + repr(sorted(defines)).encode()
                         ).hexdigest()[:16]
    cache = _cache_dir()
    os.makedirs(cache, exist_ok=True)
    so = os.path.join(cache, f"cycle_loop-{tag}-{platform.machine()}.so")
    if not os.path.exists(so):
        import subprocess

        tmp = f"{so}.{os.getpid()}.tmp.so"
        cc = os.environ.get("CC", "cc")
        cmd = [cc, "-O2", "-shared", "-fPIC"]
        cmd += [f"-D{d}" for d in defines]
        cmd += ["-o", tmp, _SRC]
        subprocess.run(cmd, check=True, capture_output=True, timeout=120)
        os.replace(tmp, so)
    return so


def bind_run_schedule(lib):
    """Attach argtypes/restype to a CDLL's ``run_schedule`` and return it."""
    import ctypes

    i64 = ctypes.c_longlong
    i64p = ctypes.POINTER(i64)
    u8p = ctypes.POINTER(ctypes.c_ubyte)
    fn = lib.run_schedule
    fn.restype = i64
    fn.argtypes = (
        [i64, i64, i64]                # n, n_arrays, n_classes
        + [i64p] * 4                   # succ_ptr, succ_idx, indegree, height
        + [u8p, i64p, i64p, i64p]      # is_load, node_lat, word_idx, klass_id
        + [i64p, i64p]                 # fu_budgets, desc matrix
        + [i64, i64, i64, i64p]        # mem_latency, ports_per_bank,
                                       #   max_cycles, out
        + [i64p])                      # events (NULL to disable logging)
    return fn


def load():
    """Return the compiled ``run_schedule`` or ``None`` if unavailable."""
    global _FN, _ANALYZE, _BATCH, _TRIED
    if _TRIED:
        return _FN
    _TRIED = True
    if os.environ.get("REPRO_PURE_PY"):
        return None
    try:
        import ctypes

        so = build_library()
        i64 = ctypes.c_longlong
        i64p = ctypes.POINTER(i64)
        u8p = ctypes.POINTER(ctypes.c_ubyte)
        lib = ctypes.CDLL(so)
        fn = bind_run_schedule(lib)
        an = lib.analyze_graph
        an.restype = None
        an.argtypes = [i64] + [i64p] * 7
        f64p = ctypes.POINTER(ctypes.c_double)
        bt = lib.run_schedule_batch
        bt.restype = i64
        bt.argtypes = (
            [i64, i64, i64, i64]           # n, n_arrays, n_classes, n_cfg
            + [i64p] * 4                   # succ_ptr, succ_idx, indegree, height
            + [u8p, i64p, i64p, i64p]      # is_load, node_lat, word_idx, klass_id
            + [i64p, i64p, i64p]           # fu_budgets_all, desc_all, mem_lat_all
            + [i64, i64, i64]              # ports_per_bank, max_cycles, cap_mode
            + [f64p, f64p]                 # area_all, ns_all
            + [i64p, i64p])                # status_all, out_all
        _FN = fn
        _ANALYZE = an
        _BATCH = bt
    except Exception as e:
        _FN = None
        _ANALYZE = None
        _BATCH = None
        # degrade loudly, exactly once per process (the _TRIED latch):
        # results are identical on the pure-Python loop, but silently
        # losing the C backend turns a seconds sweep into minutes
        import warnings

        warnings.warn(
            f"repro C cycle-loop extension unavailable "
            f"({type(e).__name__}: {e}); falling back to the pure-Python "
            "scheduler. Results are identical but large sweeps will be "
            "slower. Set REPRO_PURE_PY=1 to silence this warning.",
            RuntimeWarning, stacklevel=2)
    return _FN


def load_analyze():
    """Return the compiled ``analyze_graph`` or ``None``."""
    load()
    return _ANALYZE


def load_batch():
    """Return the compiled ``run_schedule_batch`` or ``None``."""
    load()
    return _BATCH
