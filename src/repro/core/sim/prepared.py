"""Prepared-trace layer: one-time, vectorized per-trace analysis.

The DSE loop evaluates the *same* dynamic trace under dozens of memory
designs and unroll factors.  In the seed implementation every
``schedule()`` call rebuilt the successor CSR, the list-scheduling
heights and the per-array geometry with Python loops — identical work
repeated for all 64 design points per benchmark.  :class:`PreparedTrace`
computes everything that depends only on the trace **once** (vectorized
with numpy O(E) frontier sweeps), so each design point pays only for the
port-constrained cycle loop.

PreparedTrace contract
----------------------
A ``PreparedTrace`` is an immutable companion of one :class:`Trace`:

* graph structure: ``succ_ptr``/``succ_idx`` (CSR successor lists, same
  ordering as the seed ``_succ_lists``), ``indegree``, ``roots``;
* scheduling priorities: ``height`` (longest latency-weighted path to a
  sink, the list-scheduling priority) and ``depth`` (dependency level) —
  both bit-identical to the seed recurrences;
* per-array geometry: ``array_depths`` (power-of-two depth from the max
  word index), ``loads_per_array``/``stores_per_array``;
* locality stats: Weinberg ``locality`` over the memory stream;
* ``fingerprint``: a content hash of the trace, the cache key used by
  ``repro.core.dse.runner``;
* contiguous numpy per-node arrays (``is_load_np``, ``latency_np``,
  ``word_index_np``, ``klass_np``) consumed by the compiled C cycle
  loop, plus lazily-built plain-Python mirrors (:class:`PyMirrors`,
  via :meth:`PreparedTrace.py_mirrors`) for the pure-Python reference
  loop — built only when that fallback actually runs.

``prepare_trace(tr)`` memoizes the analysis on the trace object itself,
so repeated calls (and every consumer that passes a raw ``Trace``) share
one analysis.  ``schedule()`` accepts either a ``Trace`` or a
``PreparedTrace``; results are identical.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.sim import trace as T

_PREPARED_ATTR = "_prepared_trace"

# fixed resource-class order: class id = array_id for memory ops, or
# n_arrays + FU_ORDER.index(class) for compute ops
FU_ORDER: tuple[str, ...] = ("fadd", "fmul", "fdiv", "iadd", "imul",
                             "icmp", "logic")


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


# ----------------------------------------------------------------------
# vectorized DAG analyses (O(E) total work, swept frontier by frontier)
# ----------------------------------------------------------------------
def _flatten_ranges(starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Concatenate ``[arange(s, e) for s, e in zip(starts, ends)]``."""
    lens = ends - starts
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, np.int64)
    cum = np.cumsum(lens)
    out = np.arange(total, dtype=np.int64)
    out -= np.repeat(cum - lens, lens)
    out += np.repeat(starts, lens)
    return out


def successor_csr(pred_ptr: np.ndarray, pred_idx: np.ndarray,
                  n: int) -> tuple[np.ndarray, np.ndarray]:
    """CSR successor lists from the predecessor CSR (vectorized).

    Edge ordering matches the seed implementation: for each node ``p``
    the successors appear in increasing destination-id order.
    """
    counts = np.bincount(pred_idx, minlength=n).astype(np.int64)
    ptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    dst = np.repeat(np.arange(n, dtype=np.int64),
                    (pred_ptr[1:] - pred_ptr[:-1]))
    order = np.argsort(pred_idx, kind="stable")
    return ptr, dst[order]


def dependency_depths(pred_ptr: np.ndarray, pred_idx: np.ndarray,
                      succ_ptr: np.ndarray, succ_idx: np.ndarray) -> np.ndarray:
    """Dependency depth (critical-path level) per node, vectorized.

    Same recurrence as the seed ``Trace.depths()``:
    ``depth[i] = max(depth[preds]) + 1`` (0 for roots).
    """
    n = pred_ptr.shape[0] - 1
    indeg = (pred_ptr[1:] - pred_ptr[:-1]).astype(np.int64).copy()
    depth = np.zeros(n, np.int32)
    frontier = np.nonzero(indeg == 0)[0]
    while frontier.size:
        starts, ends = succ_ptr[frontier], succ_ptr[frontier + 1]
        edges = _flatten_ranges(starts, ends)
        if edges.size == 0:
            break
        dsts = succ_idx[edges]
        srcs = np.repeat(frontier, ends - starts)
        np.maximum.at(depth, dsts, depth[srcs] + 1)
        hit = np.bincount(dsts, minlength=n)
        indeg -= hit
        frontier = np.nonzero((indeg == 0) & (hit > 0))[0]
    return depth


def schedule_heights(kinds: np.ndarray, pred_ptr: np.ndarray,
                     pred_idx: np.ndarray, succ_ptr: np.ndarray,
                     succ_idx: np.ndarray) -> np.ndarray:
    """Longest latency-weighted path to any sink (list-sched priority).

    Same recurrence as the seed ``_heights``: sinks are 0, otherwise
    ``h[i] = max(h[succs]) + LATENCY[kind[i]]``.
    """
    n = kinds.shape[0]
    lat = np.asarray([T.LATENCY[k] for k in range(len(T.LATENCY))],
                     np.int64)[kinds]
    outdeg = (succ_ptr[1:] - succ_ptr[:-1]).astype(np.int64).copy()
    best_succ = np.zeros(n, np.int64)
    h = np.zeros(n, np.int64)
    frontier = np.nonzero(outdeg == 0)[0]          # sinks: h == 0
    while frontier.size:
        starts, ends = pred_ptr[frontier], pred_ptr[frontier + 1]
        edges = _flatten_ranges(starts, ends)
        if edges.size == 0:
            break
        preds = pred_idx[edges]
        np.maximum.at(best_succ, preds,
                      np.repeat(h[frontier], ends - starts))
        hit = np.bincount(preds, minlength=n)
        outdeg -= hit
        frontier = np.nonzero((outdeg == 0) & (hit > 0))[0]
        h[frontier] = best_succ[frontier] + lat[frontier]
    return h


# ----------------------------------------------------------------------
def trace_fingerprint(tr: T.Trace) -> str:
    """Stable content hash of a trace (the on-disk sweep-cache key)."""
    hsh = hashlib.sha256()
    hsh.update(tr.name.encode())
    for arr in (tr.kinds, tr.array_ids, tr.addrs, tr.pred_ptr, tr.pred_idx):
        hsh.update(np.ascontiguousarray(arr).tobytes())
    for aid in sorted(tr.word_bytes):
        hsh.update(f"{aid}:{tr.word_bytes[aid]}:"
                   f"{tr.array_names.get(aid, '')};".encode())
    return hsh.hexdigest()


@dataclasses.dataclass
class PyMirrors:
    """Plain-Python mirrors of the per-node arrays, used only by the
    pure-Python reference cycle loop (built lazily: when the compiled C
    loop is available these are never needed).

    ``packed_prio[i] = -height[i] * n_nodes + i``: integer comparison of
    packed entries orders exactly like the (neg_height, node) tuple
    (node < n_nodes), but heap ops avoid tuple allocation and
    lexicographic compares in the cycle loop.
    """
    succ_lists: list[list[int]]
    latency_list: list[int]
    is_load: list[bool]
    word_index: list[int]
    klass_id: list[int]        # array_id, or n_arrays + FU_ORDER index
    roots: list[int]
    packed_prio: list[int]


@dataclasses.dataclass(frozen=True)
class DeviceViews:
    """Fixed-shape, padded per-trace tensors for the batched JAX cycle
    loop (``repro.core.sim.jax_cycle``).

    Shapes are padded so that traces of similar size share one compiled
    kernel: ``n_pad`` is the node count rounded up to a power of two and
    ``n_preds_max`` the padded predecessor fan-in.  Padding is inert by
    construction — pad nodes depend on themselves (``preds_pad[i] = i``)
    so they are never ready, never issue, and never retire; real nodes
    pad their missing predecessor slots with the sentinel index
    ``n_pad``, whose finish time is pinned to ``-1`` (always retired).

    ``perm`` lists every node grouped by resource class (array ids
    first, then ``FU_ORDER`` classes, then the pad tail), each group
    sorted by the list-scheduling priority ``(-height, node)`` — i.e.
    exactly the order the reference loops pop their per-class heaps.
    ``class_bounds[c]`` is the half-open ``perm`` range of class ``c``.
    """

    n_real: int
    n_pad: int
    n_preds_max: int
    n_arrays: int
    a_pad: int                 # array-axis bucket (>= max(n_arrays, 1))
    preds_pad: np.ndarray      # [n_pad, n_preds_max] int32 (pad = n_pad)
    lat: np.ndarray            # [n_pad] int32 FU/store latency per node
    is_load: np.ndarray        # [n_pad] bool
    word_idx: np.ndarray       # [n_pad] int32 (0 for compute/pad nodes)
    perm: np.ndarray           # [n_pad] int32 class-grouped priority order
    gid_perm: np.ndarray       # [n_pad] int32 class id per perm slot:
                               #   array id, a_pad + FU index, a_pad + 7 pads
    seg_start: np.ndarray      # [a_pad + 8] int32 segment starts (+ total)
    class_bounds: tuple        # ((lo, hi), ...) per real class id

    @property
    def signature(self) -> tuple:
        """Static shape key: traces sharing it share one compiled kernel.

        Only padded dimensions enter the key — the class segment layout
        travels as device data (``gid_perm``/``seg_start``), so traces
        of similar size reuse one compiled kernel regardless of their
        class structure.
        """
        return (self.n_pad, self.n_preds_max, self.a_pad)


def _build_device_views(pt: "PreparedTrace") -> DeviceViews:
    n = pt.trace.n_nodes
    n_pad = _next_pow2(max(n, 16))
    n_classes = pt.n_arrays + len(FU_ORDER)
    a_pad = _next_pow2(max(pt.n_arrays, 1))

    indeg = pt.indegree
    p_max = _next_pow2(max(int(indeg.max()) if n else 0, 1))
    preds_pad = np.full((n_pad, p_max), n_pad, np.int32)
    if n:
        ptr = pt.trace.pred_ptr
        idx = pt.trace.pred_idx
        lens = (ptr[1:] - ptr[:-1]).astype(np.int64)
        rows = np.repeat(np.arange(n, dtype=np.int64), lens)
        cols = np.arange(idx.shape[0], dtype=np.int64) - np.repeat(
            ptr[:-1], lens)
        preds_pad[rows, cols] = idx.astype(np.int32)
    # pad nodes gate on themselves: never ready, never issued
    pad_ids = np.arange(n, n_pad, dtype=np.int32)
    preds_pad[n:] = pad_ids[:, None]

    # class-grouped, priority-sorted permutation.  np.lexsort is stable
    # and sorts by the LAST key first: (class, -height, node).
    klass = np.concatenate([pt.klass_np.astype(np.int64),
                            np.full(n_pad - n, n_classes, np.int64)])
    height = np.concatenate([pt.height.astype(np.int64),
                             np.zeros(n_pad - n, np.int64)])
    node = np.arange(n_pad, dtype=np.int64)
    perm = np.lexsort((node, -height, klass)).astype(np.int32)

    counts = np.bincount(klass[perm], minlength=n_classes + 1)
    ends = np.cumsum(counts)
    bounds = tuple((int(ends[c] - counts[c]), int(ends[c]))
                   for c in range(n_classes))

    # a_pad-relative class ids per perm slot + segment starts, as device
    # data: arrays [0, n_arrays), empty pad arrays [n_arrays, a_pad), FU
    # classes [a_pad, a_pad + 7), trace pads a_pad + 7
    gid_perm = np.full(n_pad, a_pad + len(FU_ORDER), np.int32)
    seg_start = np.zeros(a_pad + len(FU_ORDER) + 1, np.int32)
    pos = 0
    for g in range(a_pad + len(FU_ORDER)):
        c = g if g < pt.n_arrays else (
            pt.n_arrays + (g - a_pad) if g >= a_pad else -1)
        if 0 <= c < n_classes:
            lo, hi = bounds[c]
            gid_perm[lo:hi] = g
            seg_start[g] = lo
            pos = hi
        else:
            seg_start[g] = pos          # empty pad-array segment
    seg_start[-1] = pos

    lat = np.zeros(n_pad, np.int32)
    lat[:n] = pt.latency_np
    is_load = np.zeros(n_pad, bool)
    is_load[:n] = pt.is_load_np.astype(bool)
    word_idx = np.zeros(n_pad, np.int32)
    if n:
        wi = pt.word_index_np
        if wi.size and int(wi.max()) >= 2**31:
            raise ValueError("word indices exceed int32: jax backend "
                             "unsupported for this trace")
        word_idx[:n] = np.maximum(wi, 0).astype(np.int32)

    return DeviceViews(
        n_real=n, n_pad=n_pad, n_preds_max=p_max, n_arrays=pt.n_arrays,
        a_pad=a_pad, preds_pad=preds_pad, lat=lat, is_load=is_load,
        word_idx=word_idx, perm=perm, gid_perm=gid_perm,
        seg_start=seg_start, class_bounds=bounds)


@dataclasses.dataclass(frozen=True)
class MemProfile:
    """Design-independent memory-behavior statistics of one trace.

    Consumed by the analytic sweep surrogate
    (:mod:`repro.core.dse.surrogate`): everything here depends only on
    the trace, so one profile serves every design point of a sweep.

    * ``crit_height`` — latency-weighted critical-path height (the
      schedule lower bound for unlimited resources);
    * ``fu_ops`` — op count per ``FU_ORDER`` class;
    * ``load_words``/``store_words`` — per-array word-index streams in
      program order (bank/leaf conflict histograms are cheap bincounts
      over these);
    * ``load_bands``/``store_bands`` — per-array access counts per
      ``band_w``-tall height band (a proxy for how many accesses
      compete for ports in the same schedule region);
    * ``cold_loads`` — per-array loads that precede the word's first
      store (remap steering can never have re-pointed those words).
    """
    crit_height: int
    fu_ops: np.ndarray
    band_w: int
    n_bands: int
    load_words: dict[int, np.ndarray]
    store_words: dict[int, np.ndarray]
    load_bands: dict[int, np.ndarray]
    store_bands: dict[int, np.ndarray]
    cold_loads: dict[int, int]


def _build_mem_profile(pt: "PreparedTrace", band_w: int) -> MemProfile:
    tr = pt.trace
    crit = int(pt.height.max()) if pt.n_nodes else 0
    fu_ops = np.bincount(pt.klass_np, minlength=pt.n_arrays
                         + len(FU_ORDER))[pt.n_arrays:]
    n_bands = crit // band_w + 1
    mem = tr.mem_mask()
    is_load = pt.is_load_np.astype(bool)
    lw, sw, lb, sb, cold = {}, {}, {}, {}, {}
    for aid in tr.array_names:
        sel = mem & (tr.array_ids == aid)
        lm, sm = sel & is_load, sel & ~is_load
        wl, ws = pt.word_index_np[lm], pt.word_index_np[sm]
        lw[aid], sw[aid] = wl, ws
        lb[aid] = np.bincount(pt.height[lm] // band_w, minlength=n_bands)
        sb[aid] = np.bincount(pt.height[sm] // band_w, minlength=n_bands)
        # first-store program position per word, vectorized (node ids
        # are program order); loads strictly before it are cold
        if wl.size:
            span = int(max(wl.max(initial=0), ws.max(initial=0))) + 1
            first = np.full(span, np.iinfo(np.int64).max, np.int64)
            np.minimum.at(first, ws, np.nonzero(sm)[0])
            cold[aid] = int(np.sum(np.nonzero(lm)[0] < first[wl]))
        else:
            cold[aid] = 0
    return MemProfile(crit_height=crit, fu_ops=fu_ops, band_w=band_w,
                      n_bands=n_bands, load_words=lw, store_words=sw,
                      load_bands=lb, store_bands=sb, cold_loads=cold)


@dataclasses.dataclass
class PreparedTrace:
    """One-time trace analysis shared by every design-point evaluation.

    See the module docstring for the full contract.  Treat instances as
    immutable: the scheduler and sweep layers read but never mutate them.
    """
    trace: T.Trace
    fingerprint: str
    # graph structure (numpy)
    succ_ptr: np.ndarray
    succ_idx: np.ndarray
    indegree: np.ndarray
    height: np.ndarray
    depth: np.ndarray
    # per-array geometry / stats
    array_depths: dict[int, int]
    loads_per_array: dict[int, int]
    stores_per_array: dict[int, int]
    locality: float
    n_arrays: int
    # contiguous numpy per-node arrays for the compiled cycle loop
    is_load_np: np.ndarray     # [N] uint8
    latency_np: np.ndarray     # [N] int64
    word_index_np: np.ndarray  # [N] int64
    klass_np: np.ndarray       # [N] int64
    _mirrors: "PyMirrors | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    _device: "DeviceViews | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    _mem_profiles: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def name(self) -> str:
        return self.trace.name

    @property
    def n_nodes(self) -> int:
        return self.trace.n_nodes

    def py_mirrors(self) -> PyMirrors:
        """Build (once) the plain-list mirrors for the Python loop."""
        if self._mirrors is None:
            n = self.trace.n_nodes
            ptr_l = self.succ_ptr.tolist()
            idx_l = self.succ_idx.tolist()
            self._mirrors = PyMirrors(
                succ_lists=[idx_l[ptr_l[i]:ptr_l[i + 1]] for i in range(n)],
                latency_list=self.latency_np.tolist(),
                is_load=[bool(b) for b in self.is_load_np.tolist()],
                word_index=self.word_index_np.tolist(),
                klass_id=self.klass_np.tolist(),
                roots=np.nonzero(self.indegree == 0)[0].tolist(),
                packed_prio=(-self.height * max(n, 1)
                             + np.arange(n)).tolist(),
            )
        return self._mirrors

    def device_views(self) -> DeviceViews:
        """Build (once) the padded fixed-shape tensors for the batched
        JAX cycle loop — see :class:`DeviceViews`."""
        if self._device is None:
            self._device = _build_device_views(self)
        return self._device

    def mem_profile(self, band_w: int = 8) -> MemProfile:
        """Build (once per ``band_w``) the design-independent memory
        statistics consumed by the sweep surrogate — see
        :class:`MemProfile`."""
        prof = self._mem_profiles.get(band_w)
        if prof is None:
            prof = _build_mem_profile(self, band_w)
            self._mem_profiles[band_w] = prof
        return prof


def _array_depths(tr: T.Trace, word_idx: np.ndarray) -> dict[int, int]:
    """Power-of-two depth per array from the trace's max word index."""
    depths: dict[int, int] = {}
    mem = tr.mem_mask()
    for aid in tr.array_names:
        sel = mem & (tr.array_ids == aid)
        if not sel.any():
            depths[aid] = 16
            continue
        max_idx = int(word_idx[sel].max())
        depths[aid] = max(16, 1 << (max_idx + 1).bit_length())
    return depths


def _build(tr: T.Trace) -> PreparedTrace:
    from repro.core.locality import trace_locality
    from repro.core.sim import _cycle_ext

    n = tr.n_nodes
    succ_ptr, succ_idx = successor_csr(tr.pred_ptr, tr.pred_idx, n)
    lat_np = np.asarray([T.LATENCY[k] for k in range(len(T.LATENCY))],
                        np.int64)[tr.kinds]
    analyze = _cycle_ext.load_analyze()
    if analyze is not None and n:
        # single C pass over the CSR; bit-identical to the numpy sweeps
        import ctypes
        depth64 = np.zeros(n, np.int64)
        height = np.zeros(n, np.int64)
        i64p = ctypes.POINTER(ctypes.c_longlong)
        analyze(n,
                tr.pred_ptr.astype(np.int64, copy=False).ctypes.data_as(i64p),
                np.ascontiguousarray(tr.pred_idx, np.int64).ctypes.data_as(i64p),
                succ_ptr.ctypes.data_as(i64p),
                succ_idx.ctypes.data_as(i64p),
                np.ascontiguousarray(lat_np).ctypes.data_as(i64p),
                depth64.ctypes.data_as(i64p),
                height.ctypes.data_as(i64p))
        depth = depth64.astype(np.int32)
    else:
        height = schedule_heights(tr.kinds, tr.pred_ptr, tr.pred_idx,
                                  succ_ptr, succ_idx)
        depth = dependency_depths(tr.pred_ptr, tr.pred_idx,
                                  succ_ptr, succ_idx)
    indegree = (tr.pred_ptr[1:] - tr.pred_ptr[:-1]).astype(np.int64)

    # word index per node (-1 for compute ops), vectorized per array
    word_idx = np.full(n, -1, np.int64)
    mem = tr.mem_mask()
    for aid, wb in tr.word_bytes.items():
        sel = mem & (tr.array_ids == aid)
        word_idx[sel] = tr.addrs[sel] // wb

    loads = {aid: int(np.sum(mem & (tr.array_ids == aid)
                             & (tr.kinds == T.LOAD)))
             for aid in tr.array_names}
    stores = {aid: int(np.sum(mem & (tr.array_ids == aid)
                              & (tr.kinds == T.STORE)))
              for aid in tr.array_names}

    addrs_m, aids_m = tr.mem_addrs_and_arrays()
    locality = trace_locality(addrs_m, aids_m) if addrs_m.size else 0.0

    # resource class per node: array id for memory ops, else
    # n_arrays + FU_ORDER index (vectorized via a kind -> class table)
    n_arrays = (max(tr.array_names) + 1) if tr.array_names else 0
    fu_of_kind = np.zeros(len(T.LATENCY), np.int64)
    for kind, fu_name in T.FU_CLASS.items():
        fu_of_kind[kind] = n_arrays + FU_ORDER.index(fu_name)
    klass_np = np.where(mem, tr.array_ids.astype(np.int64),
                        fu_of_kind[tr.kinds])

    return PreparedTrace(
        trace=tr,
        fingerprint=trace_fingerprint(tr),
        succ_ptr=succ_ptr,
        succ_idx=succ_idx,
        indegree=indegree,
        height=height,
        depth=depth,
        array_depths=_array_depths(tr, word_idx),
        loads_per_array=loads,
        stores_per_array=stores,
        locality=float(locality),
        n_arrays=n_arrays,
        is_load_np=np.ascontiguousarray(tr.kinds == T.LOAD, np.uint8),
        latency_np=np.ascontiguousarray(lat_np),
        word_index_np=np.ascontiguousarray(word_idx, np.int64),
        klass_np=np.ascontiguousarray(klass_np),
    )


def prepare_trace(tr: "T.Trace | PreparedTrace") -> PreparedTrace:
    """Return the (memoized) :class:`PreparedTrace` for ``tr``.

    Passing an already-prepared trace is a no-op, so every API in the
    sim/dse stack accepts ``Trace | PreparedTrace`` interchangeably.
    """
    if isinstance(tr, PreparedTrace):
        return tr
    cached = getattr(tr, _PREPARED_ATTR, None)
    if cached is None:
        cached = _build(tr)
        object.__setattr__(tr, _PREPARED_ATTR, cached)
    return cached
