"""MoE top-k expert routing: gather/scatter through per-expert queues.

Mirrors ``repro.models.moe.moe_apply``'s capacity-based dispatch for a
single sequence: top-k softmax gating, row-local queue positions via
cumulative counts, capacity-dropped overflow, and the combine
scatter-add back to token order.  The memory shape is two coupled
irregular phases: routing scatters token ids into per-expert queues
(write stream ordered by the *gating*, not the address), then each
expert drains its queue with data-dependent token gathers and writes
results back through the same indirection — at cluster scale this is
the paper's lens on expert banks as a multi-ported memory
(``repro.memory.planner.expert_stream``).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
jax = lazy_import("jax")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n_tokens: int = 512
    n_experts: int = 16
    top_k: int = 2
    capacity_factor: float = 1.25
    seed: int = 31


# the tighter capacity factor makes some experts overflow at TINY size,
# so the capacity-drop path is exercised by goldens and property tests
TINY = Params(n_tokens=64, n_experts=4, capacity_factor=0.75)


def capacity(p: Params) -> int:
    """Same rule as moe.moe_apply: C = max(int(cf * T * K / E), 1)."""
    return max(int(p.capacity_factor * p.n_tokens * p.top_k
                   / p.n_experts), 1)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    return {
        "logits": rng.standard_normal(
            (p.n_tokens, p.n_experts)).astype(np.float32),
        "x": rng.standard_normal(p.n_tokens).astype(np.float32),
        "w_exp": rng.standard_normal(p.n_experts).astype(np.float32),
    }


def _route_np(logits: np.ndarray, top_k: int):
    """Top-k gating: normalized gates + expert choices, flat (t,k)
    order — the order that defines queue positions."""
    z = logits - logits.max(axis=1, keepdims=True)
    gates = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
    top_e = np.argsort(-gates, axis=1, kind="stable")[:, :top_k]
    top_g = np.take_along_axis(gates, top_e, axis=1)
    top_g = top_g / np.maximum(top_g.sum(axis=1, keepdims=True), 1e-9)
    return top_g, top_e


def run_np(logits: np.ndarray, x: np.ndarray, w_exp: np.ndarray,
           top_k: int, capacity_factor: float) -> np.ndarray:
    t_, e_ = logits.shape
    cap = max(int(capacity_factor * t_ * top_k / e_), 1)
    top_g, top_e = _route_np(logits, top_k)
    counts = np.zeros(e_, np.int64)
    y = np.zeros(t_, np.float32)
    for t in range(t_):
        for j in range(top_k):
            e = int(top_e[t, j])
            pos = counts[e]
            counts[e] += 1
            if pos < cap:                       # over capacity: dropped
                y[t] += top_g[t, j] * x[t] * w_exp[e]
    return y


def run_jax(logits: jnp.ndarray, x: jnp.ndarray, w_exp: jnp.ndarray,
            top_k: int, capacity_factor: float) -> jnp.ndarray:
    """moe_apply's sort-free dispatch-table formulation (one row)."""
    t_, e_ = logits.shape
    cap = max(int(capacity_factor * t_ * top_k / e_), 1)
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_g, top_e = jax.lax.top_k(gates, top_k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(t_ * top_k)
    onehot = jax.nn.one_hot(flat_e, e_, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    dest = jnp.where(slot < cap, flat_e * cap + slot, e_ * cap)
    token_ids = jnp.repeat(jnp.arange(t_, dtype=jnp.int32), top_k)
    table = jnp.full((e_ * cap + 1,), t_, jnp.int32
                     ).at[dest].set(token_ids, mode="drop")[:-1]
    gate_tbl = jnp.zeros((e_ * cap + 1,), jnp.float32
                         ).at[dest].set(top_g.reshape(-1),
                                        mode="drop")[:-1]
    x_pad = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
    contrib = (x_pad[table] * jnp.repeat(w_exp, cap) * gate_tbl
               ).astype(jnp.float32)
    return jnp.zeros(t_ + 1, jnp.float32).at[table].add(contrib)[:t_]


def gen_trace(p: Params = Params()) -> T.Trace:
    inp = make_inputs(p)
    _, top_e = _route_np(inp["logits"], p.top_k)
    cap = capacity(p)
    tb = T.TraceBuilder("moe_route")
    TOK = tb.declare_array("tokens", 8)
    CNT = tb.declare_array("expert_counts", 4)
    QUEUE = tb.declare_array("expert_queues", 4)
    WEXP = tb.declare_array("expert_weights", 8)
    OUT = tb.declare_array("out", 8)
    # phase 1 — routing: scatter token ids into per-expert queues; the
    # write stream is ordered by the gating decision, not the address
    counts = np.zeros(p.n_experts, np.int64)
    queues: list[list[int]] = [[] for _ in range(p.n_experts)]
    for t in range(p.n_tokens):
        lt = tb.load(TOK, t)
        sel = tb.op(T.ICMP, lt)                # top-k select of router row
        for j in range(p.top_k):
            e = int(top_e[t, j])
            lc = tb.load(CNT, e, (sel,))       # queue-tail gather
            up = tb.op(T.IADD, lc)
            tb.store(CNT, e, (up,))
            pos = int(counts[e])
            counts[e] += 1
            if pos < cap:
                queues[e].append(t)
                tb.store(QUEUE, e * cap + pos, (up, lt))
    # phase 2 — expert drain: each queue slot names a token; gather it,
    # apply the expert, scatter the result back to token order
    for e in range(p.n_experts):
        lw = tb.load(WEXP, e)
        for c, t in enumerate(queues[e]):
            lq = tb.load(QUEUE, e * cap + c)
            lx = tb.load(TOK, t, (lq,))        # data-dependent gather
            m = tb.op(T.FMUL, lx, lw)
            m2 = tb.op(T.FADD, m, lq)          # gate-weighted combine
            tb.store(OUT, t, (m2,))            # data-dependent scatter
    return tb.build()
