"""SORT-MERGE (MachSuite sort/merge): bottom-up merge sort, int32.

Two stride-one read streams + one stride-one write stream per pass;
moderate spatial locality (4-byte words).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n: int = 2048
    seed: int = 3


TINY = Params(n=64)


def make_input(p: Params) -> np.ndarray:
    rng = np.random.default_rng(p.seed)
    return rng.integers(0, 1 << 20, size=p.n, dtype=np.int32)


def run_jax(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sort(x)


def gen_trace(p: Params = Params()) -> T.Trace:
    a = make_input(p).copy()
    n = p.n
    tb = T.TraceBuilder("sort_merge")
    A = tb.declare_array("a", 4)
    TMP = tb.declare_array("temp", 4)
    width = 1
    # last_write[arr][idx] -> node id, to carry RAW deps across passes
    last_a: dict[int, int] = {}
    last_t: dict[int, int] = {}
    while width < n:
        for lo in range(0, n, 2 * width):
            mid = min(lo + width, n)
            hi = min(lo + 2 * width, n)
            i, j, k = lo, mid, lo
            while i < mid or j < hi:
                if i < mid and (j >= hi or a[i] <= a[j]):
                    src = i; i += 1
                else:
                    src = j; j += 1
                deps = (last_a[src],) if src in last_a else ()
                ld = tb.load(A, src, deps)
                cmp = tb.op(T.ICMP, ld)
                st = tb.store(TMP, k, (cmp,))
                last_t[k] = st
                k += 1
            # copy-back temp -> a
            for t in range(lo, hi):
                ld = tb.load(TMP, t, (last_t[t],))
                st = tb.store(A, t, (ld,))
                last_a[t] = st
        # mirror the merge on the value array
        out = a.copy()
        for lo in range(0, n, 2 * width):
            mid = min(lo + width, n)
            hi = min(lo + 2 * width, n)
            merged = np.concatenate([a[lo:mid], a[mid:hi]])
            out[lo:hi] = np.sort(merged, kind="stable")
        a = out
        width *= 2
    return tb.build()
