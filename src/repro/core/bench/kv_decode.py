"""Batched banked KV-cache decode at serving scale (ROADMAP north star).

One decode step of flash attention over a per-request KV cache with a
*mixed-length* batch — the continuous-batching traffic shape an LLM
inference accelerator sees (large batch, long context, every request at
a different position).  Geometry follows the checked-in model configs
(`repro.configs`: qwen3-1.7b runs 8 KV heads of head_dim 128); one
trace word stands for one head_dim vector tile.

The engine walks cache positions in lockstep across the (request,
kv-head) rows — the execution order of the batched decode kernel
(`kernels/banked_kv_decode.py`) — so consecutive K/V accesses stride by
a whole context window.  That makes the K/V streams the archetypal
low-spatial-locality multi-port burst of the paper's Fig-5 claim, while
the per-row online-softmax recurrence keeps every access data-dependent
on the request's own length.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    batch: int = 16          # concurrent decode requests
    n_kv_heads: int = 2      # KV heads kept per request (GQA groups)
    max_len: int = 128       # cache capacity S (context window)
    head_dim: int = 64       # per-head vector width (ref math only)
    seed: int = 23


TINY = Params(batch=4, n_kv_heads=2, max_len=16, head_dim=8)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    return {
        "q": rng.standard_normal(
            (p.batch, p.n_kv_heads, p.head_dim)).astype(np.float32),
        "k": rng.standard_normal(
            (p.batch, p.n_kv_heads, p.max_len, p.head_dim)
        ).astype(np.float32),
        "v": rng.standard_normal(
            (p.batch, p.n_kv_heads, p.max_len, p.head_dim)
        ).astype(np.float32),
        # mixed request lengths: each row is at its own decode position
        "lengths": rng.integers(1, p.max_len + 1, p.batch).astype(np.int32),
    }


def run_np(q: np.ndarray, k: np.ndarray, v: np.ndarray,
           lengths: np.ndarray) -> np.ndarray:
    """Online-softmax (flash) decode, position at a time — the same
    one-pass recurrence the trace generator models."""
    b_, h_, d_ = q.shape
    out = np.zeros((b_, h_, d_), np.float32)
    scale = 1.0 / np.sqrt(d_)
    for b in range(b_):
        for h in range(h_):
            m = -np.inf
            den = 0.0
            acc = np.zeros(d_, np.float64)
            for pos in range(int(lengths[b])):
                s = float(q[b, h] @ k[b, h, pos]) * scale
                m_new = max(m, s)
                c = np.exp(m - m_new) if np.isfinite(m) else 0.0
                w = np.exp(s - m_new)
                den = den * c + w
                acc = acc * c + w * v[b, h, pos].astype(np.float64)
                m = m_new
            out[b, h] = (acc / den).astype(np.float32)
    return out


def run_jax(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            lengths: jnp.ndarray) -> jnp.ndarray:
    """Masked dense decode attention (the two formulations must agree:
    online rescaling vs one-shot softmax)."""
    d_ = q.shape[-1]
    s_ = k.shape[2]
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) / jnp.sqrt(d_)
    valid = jnp.arange(s_)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jnp.where(valid, jnp.exp(scores - scores.max(-1, keepdims=True)), 0.0)
    w = w / w.sum(-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", w, v)


def gen_trace(p: Params = Params()) -> T.Trace:
    lengths = make_inputs(p)["lengths"]
    b_, h_, s_ = p.batch, p.n_kv_heads, p.max_len
    tb = T.TraceBuilder("kv_decode")
    LEN = tb.declare_array("lengths", 4)
    Q = tb.declare_array("q", 8)
    K = tb.declare_array("k_cache", 8)
    V = tb.declare_array("v_cache", 8)
    OUT = tb.declare_array("out", 8)
    llen = [tb.load(LEN, b) for b in range(b_)]
    rows = [(b, h) for b in range(b_) for h in range(h_)]
    lq = {}
    acc = {}
    for b, h in rows:
        r = b * h_ + h
        lq[r] = tb.load(Q, r, (llen[b],))
        acc[r] = -1
    # lockstep continuous batching: all live rows advance one position
    # per step, so the K/V bursts interleave across the whole batch
    for pos in range(s_):
        for b, h in rows:
            if pos >= int(lengths[b]):
                continue
            r = b * h_ + h
            lk = tb.load(K, r * s_ + pos, (lq[r],))
            s = tb.op(T.FMUL, lk, lq[r])                 # q . k tile
            mx = (tb.op(T.ICMP, s) if acc[r] < 0
                  else tb.op(T.ICMP, s, acc[r]))          # online max/rescale
            lv = tb.load(V, r * s_ + pos, (mx,))
            wv = tb.op(T.FMUL, lv, mx)
            acc[r] = wv if acc[r] < 0 else tb.op(T.FADD, wv, acc[r])
    for b, h in rows:
        r = b * h_ + h
        nrm = tb.op(T.FDIV, acc[r])                       # 1/denominator
        tb.store(OUT, r, (nrm,))
    return tb.build()
