"""MD-KNN (MachSuite md/knn): Lennard-Jones forces over a k-nearest-
neighbour list.  Position arrays are gathered through the neighbour list
-> data-dependent strides -> the paper's canonical *low* spatial
locality benchmark where true-multiport AMM shines.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n_atoms: int = 256
    max_neighbors: int = 16
    seed: int = 11


TINY = Params(n_atoms=24, max_neighbors=4)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    pos = rng.uniform(0.0, 20.0, size=(p.n_atoms, 3))
    nl = np.stack(
        [rng.choice(np.delete(np.arange(p.n_atoms), i),
                    size=p.max_neighbors, replace=False)
         for i in range(p.n_atoms)]
    ).astype(np.int32)  # an atom is never its own neighbour (r2 > 0)
    return {"position": pos, "neighbor_list": nl}


def run_jax(position: jnp.ndarray, neighbor_list: jnp.ndarray) -> jnp.ndarray:
    """LJ force accumulation (MachSuite constants lj1=1.5, lj2=2.0)."""
    lj1, lj2 = 1.5, 2.0
    pi = position[:, None, :]                       # [A,1,3]
    pj = position[neighbor_list]                    # [A,K,3]
    d = pi - pj
    r2inv = 1.0 / jnp.sum(d * d, axis=-1)           # [A,K]
    r6inv = r2inv * r2inv * r2inv
    potential = r6inv * (lj1 * r6inv - lj2)
    force = r2inv * potential
    return jnp.sum(force[..., None] * d, axis=1)    # [A,3]


def gen_trace(p: Params = Params()) -> T.Trace:
    inputs = make_inputs(p)
    nl = inputs["neighbor_list"]
    tb = T.TraceBuilder("md_knn")
    NL = tb.declare_array("NL", 4)
    PX = tb.declare_array("position_x", 8)
    PY = tb.declare_array("position_y", 8)
    PZ = tb.declare_array("position_z", 8)
    FX = tb.declare_array("force_x", 8)
    FY = tb.declare_array("force_y", 8)
    FZ = tb.declare_array("force_z", 8)
    for i in range(p.n_atoms):
        lx = tb.load(PX, i)
        ly = tb.load(PY, i)
        lz = tb.load(PZ, i)
        accx = accy = accz = -1
        for j in range(p.max_neighbors):
            ln = tb.load(NL, i * p.max_neighbors + j)
            jidx = int(nl[i, j])
            jx = tb.load(PX, jidx, (ln,))
            jy = tb.load(PY, jidx, (ln,))
            jz = tb.load(PZ, jidx, (ln,))
            dx = tb.op(T.FADD, lx, jx)
            dy = tb.op(T.FADD, ly, jy)
            dz = tb.op(T.FADD, lz, jz)
            sq = tb.op(T.FADD,
                       tb.op(T.FADD, tb.op(T.FMUL, dx, dx),
                             tb.op(T.FMUL, dy, dy)),
                       tb.op(T.FMUL, dz, dz))
            r2inv = tb.op(T.FDIV, sq)
            r6 = tb.op(T.FMUL, tb.op(T.FMUL, r2inv, r2inv), r2inv)
            pot = tb.op(T.FADD, tb.op(T.FMUL, r6, r6), r6)
            f = tb.op(T.FMUL, r2inv, pot)
            tx = tb.op(T.FMUL, f, dx)
            ty = tb.op(T.FMUL, f, dy)
            tz = tb.op(T.FMUL, f, dz)
            accx = tb.op(T.FADD, tx, accx) if accx >= 0 else tx
            accy = tb.op(T.FADD, ty, accy) if accy >= 0 else ty
            accz = tb.op(T.FADD, tz, accz) if accz >= 0 else tz
        tb.store(FX, i, (accx,))
        tb.store(FY, i, (accy,))
        tb.store(FZ, i, (accz,))
    return tb.build()
