"""GEMM-NCUBED (MachSuite gemm/ncubed): naive triple-loop fp64 matmul.

Low spatial locality per the paper IV-B: 8-byte fp64 words bound the
Weinberg contribution to <=1/8 even on the unit-element-stride stream,
and the B matrix is walked down columns (stride = 8*n bytes).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n: int = 24          # MachSuite uses 64; reduced for trace tractability


TINY = Params(n=6)


def run_jax(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, precision="highest")


def gen_trace(p: Params = Params()) -> T.Trace:
    n = p.n
    tb = T.TraceBuilder("gemm_ncubed")
    A = tb.declare_array("A", 8)
    B = tb.declare_array("B", 8)
    C = tb.declare_array("C", 8)
    for i in range(n):
        for j in range(n):
            acc = -1
            for k in range(n):
                la = tb.load(A, i * n + k)
                lb = tb.load(B, k * n + j)
                mul = tb.op(T.FMUL, la, lb)
                acc = tb.op(T.FADD, mul, acc) if acc >= 0 else tb.op(T.FADD, mul)
            tb.store(C, i * n + j, (acc,))
    return tb.build()
