"""AES-256... no — AES-128 ECB encrypt (MachSuite aes/aes is AES-256; we
use AES-128 for a compact known-answer test, same memory behaviour:
byte-oriented state walks (stride 1) + S-box gathers inside a 256-byte
table).  The paper groups AES with KMP as byte-oriented / high locality.

Validated against the FIPS-197 appendix test vector.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T

_SBOX = np.array([
    0x63,0x7c,0x77,0x7b,0xf2,0x6b,0x6f,0xc5,0x30,0x01,0x67,0x2b,0xfe,0xd7,0xab,0x76,
    0xca,0x82,0xc9,0x7d,0xfa,0x59,0x47,0xf0,0xad,0xd4,0xa2,0xaf,0x9c,0xa4,0x72,0xc0,
    0xb7,0xfd,0x93,0x26,0x36,0x3f,0xf7,0xcc,0x34,0xa5,0xe5,0xf1,0x71,0xd8,0x31,0x15,
    0x04,0xc7,0x23,0xc3,0x18,0x96,0x05,0x9a,0x07,0x12,0x80,0xe2,0xeb,0x27,0xb2,0x75,
    0x09,0x83,0x2c,0x1a,0x1b,0x6e,0x5a,0xa0,0x52,0x3b,0xd6,0xb3,0x29,0xe3,0x2f,0x84,
    0x53,0xd1,0x00,0xed,0x20,0xfc,0xb1,0x5b,0x6a,0xcb,0xbe,0x39,0x4a,0x4c,0x58,0xcf,
    0xd0,0xef,0xaa,0xfb,0x43,0x4d,0x33,0x85,0x45,0xf9,0x02,0x7f,0x50,0x3c,0x9f,0xa8,
    0x51,0xa3,0x40,0x8f,0x92,0x9d,0x38,0xf5,0xbc,0xb6,0xda,0x21,0x10,0xff,0xf3,0xd2,
    0xcd,0x0c,0x13,0xec,0x5f,0x97,0x44,0x17,0xc4,0xa7,0x7e,0x3d,0x64,0x5d,0x19,0x73,
    0x60,0x81,0x4f,0xdc,0x22,0x2a,0x90,0x88,0x46,0xee,0xb8,0x14,0xde,0x5e,0x0b,0xdb,
    0xe0,0x32,0x3a,0x0a,0x49,0x06,0x24,0x5c,0xc2,0xd3,0xac,0x62,0x91,0x95,0xe4,0x79,
    0xe7,0xc8,0x37,0x6d,0x8d,0xd5,0x4e,0xa9,0x6c,0x56,0xf4,0xea,0x65,0x7a,0xae,0x08,
    0xba,0x78,0x25,0x2e,0x1c,0xa6,0xb4,0xc6,0xe8,0xdd,0x74,0x1f,0x4b,0xbd,0x8b,0x8a,
    0x70,0x3e,0xb5,0x66,0x48,0x03,0xf6,0x0e,0x61,0x35,0x57,0xb9,0x86,0xc1,0x1d,0x9e,
    0xe1,0xf8,0x98,0x11,0x69,0xd9,0x8e,0x94,0x9b,0x1e,0x87,0xe9,0xce,0x55,0x28,0xdf,
    0x8c,0xa1,0x89,0x0d,0xbf,0xe6,0x42,0x68,0x41,0x99,0x2d,0x0f,0xb0,0x54,0xbb,0x16,
], dtype=np.uint8)

_RCON = np.array([0x01,0x02,0x04,0x08,0x10,0x20,0x40,0x80,0x1b,0x36], np.uint8)

_SHIFT = np.array([0,5,10,15,4,9,14,3,8,13,2,7,12,1,6,11])  # col-major shiftrows


@dataclasses.dataclass(frozen=True)
class Params:
    n_blocks: int = 48
    seed: int = 13


TINY = Params(n_blocks=2)


def expand_key(key: np.ndarray) -> np.ndarray:
    """AES-128 key schedule -> [11, 16] round keys (column-major words)."""
    w = key.reshape(4, 4).copy()        # 4 words of 4 bytes
    words = [w[i].copy() for i in range(4)]
    for i in range(4, 44):
        t = words[i - 1].copy()
        if i % 4 == 0:
            t = np.roll(t, -1)
            t = _SBOX[t]
            t[0] ^= _RCON[i // 4 - 1]
        words.append(words[i - 4] ^ t)
    return np.concatenate(words).reshape(11, 16)


def _xtime(b: np.ndarray) -> np.ndarray:
    return ((b << 1) ^ np.where(b & 0x80, 0x1B, 0)).astype(np.uint8)


def _mix_columns(s: np.ndarray) -> np.ndarray:
    s = s.reshape(-1, 4, 4)             # [..., col, row]
    a0, a1, a2, a3 = s[..., 0], s[..., 1], s[..., 2], s[..., 3]
    t = a0 ^ a1 ^ a2 ^ a3
    out = np.stack([
        a0 ^ t ^ _xtime(a0 ^ a1),
        a1 ^ t ^ _xtime(a1 ^ a2),
        a2 ^ t ^ _xtime(a2 ^ a3),
        a3 ^ t ^ _xtime(a3 ^ a0),
    ], axis=-1)
    return out.reshape(-1, 16)


def encrypt_np(blocks: np.ndarray, key: np.ndarray) -> np.ndarray:
    """blocks [B,16] uint8 (column-major state), key [16] -> [B,16]."""
    rk = expand_key(key)
    s = blocks ^ rk[0]
    for rnd in range(1, 10):
        s = _SBOX[s]
        s = s[:, _SHIFT]
        s = _mix_columns(s)
        s = s ^ rk[rnd]
    s = _SBOX[s]
    s = s[:, _SHIFT]
    return s ^ rk[10]


def run_jax(blocks: jnp.ndarray, key: np.ndarray) -> jnp.ndarray:
    """Same cipher in jnp (vectorized over blocks)."""
    rk = jnp.asarray(expand_key(key))
    sbox = jnp.asarray(_SBOX)
    shift = jnp.asarray(_SHIFT)

    def xtime(b):
        return ((b << 1) ^ jnp.where(b & 0x80, 0x1B, 0)).astype(jnp.uint8)

    def mix(s):
        s4 = s.reshape(-1, 4, 4)
        a = [s4[..., i] for i in range(4)]
        t = a[0] ^ a[1] ^ a[2] ^ a[3]
        cols = [a[i] ^ t ^ xtime(a[i] ^ a[(i + 1) % 4]) for i in range(4)]
        return jnp.stack(cols, axis=-1).reshape(-1, 16)

    s = blocks ^ rk[0]
    for rnd in range(1, 10):
        s = sbox[s]
        s = s[:, shift]
        s = mix(s)
        s = s ^ rk[rnd]
    s = sbox[s]
    s = s[:, shift]
    return s ^ rk[10]


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    return {
        "blocks": rng.integers(0, 256, size=(p.n_blocks, 16), dtype=np.uint8),
        "key": rng.integers(0, 256, size=16, dtype=np.uint8),
    }


def gen_trace(p: Params = Params()) -> T.Trace:
    inputs = make_inputs(p)
    blocks = inputs["blocks"]
    tb = T.TraceBuilder("aes")
    BUF = tb.declare_array("buf", 1)       # state buffer per block
    SBX = tb.declare_array("sbox", 1)
    KEY = tb.declare_array("rkey", 1)
    state_vals = encrypt_np  # only addresses matter; use real sbox indices
    rk = expand_key(inputs["key"])
    for b in range(p.n_blocks):
        s = blocks[b] ^ rk[0]
        last_store: dict[int, int] = {}
        for i in range(16):
            ld = tb.load(BUF, b * 16 + i)
            lk = tb.load(KEY, i)
            x = tb.op(T.LOGIC, ld, lk)
            last_store[i] = tb.store(BUF, b * 16 + i, (x,))
        for rnd in range(1, 11):
            # subbytes: data-dependent gathers into the sbox
            sb = np.empty(16, np.uint8)
            for i in range(16):
                ld = tb.load(BUF, b * 16 + i, (last_store[i],))
                lsb = tb.load(SBX, int(s[i]), (ld,))
                last_store[i] = tb.store(BUF, b * 16 + i, (lsb,))
                sb[i] = _SBOX[s[i]]
            s = sb[_SHIFT]
            if rnd < 10:
                s = _mix_columns(s[None])[0]
                for i in range(16):
                    l0 = tb.load(BUF, b * 16 + i, (last_store[i],))
                    l1 = tb.load(BUF, b * 16 + (i + 4) % 16, (last_store[(i + 4) % 16],))
                    x0 = tb.op(T.LOGIC, l0, l1)
                    x1 = tb.op(T.LOGIC, x0)
                    last_store[i] = tb.store(BUF, b * 16 + i, (x1,))
            for i in range(16):
                ld = tb.load(BUF, b * 16 + i, (last_store[i],))
                lk = tb.load(KEY, rnd * 16 + i)
                x = tb.op(T.LOGIC, ld, lk)
                last_store[i] = tb.store(BUF, b * 16 + i, (x,))
            s = s ^ rk[rnd]
    return tb.build()
