"""BFS-QUEUE (MachSuite bfs/queue): breadth-first traversal of a sparse
random digraph with an explicit work queue.

Every step chases pointers: node records are fetched in discovery order
(not index order), the edge list is read in per-node bursts that jump
between unrelated CSR ranges, and the byte-wide ``level`` array is
gathered/updated through edge destinations — the paper's graph-traversal
archetype of low spatial locality.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jax = lazy_import("jax")
jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n_nodes: int = 256
    avg_deg: int = 4         # MachSuite graphs average ~8; kept sparse
    seed: int = 23
    start: int = 0


TINY = Params(n_nodes=128, avg_deg=2)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    degs = rng.integers(1, 2 * p.avg_deg + 1, size=p.n_nodes)
    edge_ptr = np.zeros(p.n_nodes + 1, np.int64)
    np.cumsum(degs, out=edge_ptr[1:])
    edges = np.concatenate(
        [np.sort(rng.choice(p.n_nodes, size=int(d), replace=False))
         for d in degs]).astype(np.int64)
    return {"edge_ptr": edge_ptr, "edges": edges}


def run_np(edge_ptr: np.ndarray, edges: np.ndarray, n: int,
           start: int = 0) -> np.ndarray:
    """Queue BFS; unreached nodes keep the sentinel level ``n``."""
    level = np.full(n, n, np.int32)
    level[start] = 0
    queue = [start]
    while queue:
        v = queue.pop(0)
        for e in range(int(edge_ptr[v]), int(edge_ptr[v + 1])):
            dst = int(edges[e])
            if level[dst] == n:
                level[dst] = level[v] + 1
                queue.append(dst)
    return level


def run_jax(edge_ptr: np.ndarray, edges: jnp.ndarray, n: int,
            start: int = 0) -> jnp.ndarray:
    """Level-synchronous BFS: ``n`` rounds of scatter-min edge relaxation
    (equivalent to the queue traversal's level assignment)."""
    edge_ptr = np.asarray(edge_ptr)
    src = jnp.asarray(np.repeat(np.arange(n), np.diff(edge_ptr)))
    dst = jnp.asarray(edges)
    level0 = jnp.full(n, n, jnp.int32).at[start].set(0)

    def hop(h, level):
        cand = jnp.where(level[src] == h, h + 1, n).astype(jnp.int32)
        return level.at[dst].min(cand)

    return jax.lax.fori_loop(0, n, hop, level0)


def gen_trace(p: Params = Params()) -> T.Trace:
    inp = make_inputs(p)
    edge_ptr, edges = inp["edge_ptr"], inp["edges"]
    n = p.n_nodes
    tb = T.TraceBuilder("bfs_queue")
    NODES = tb.declare_array("nodes", 8)    # (begin, end) pair per node
    EDGES = tb.declare_array("edges", 8)
    LEVEL = tb.declare_array("level", 1)
    QUEUE = tb.declare_array("queue", 8)
    level = np.full(n, -1, np.int64)
    level[p.start] = 0
    last_level_store: dict[int, int] = {}
    queue_store: dict[int, int] = {}
    last_level_store[p.start] = tb.store(LEVEL, p.start)
    queue_store[0] = tb.store(QUEUE, 0)
    queue = [p.start]
    front, back = 0, 1
    while front < back:
        v = queue[front]
        lq = tb.load(QUEUE, front, (queue_store[front],))
        front += 1
        lb = tb.load(NODES, 2 * v, (lq,))
        le = tb.load(NODES, 2 * v + 1, (lq,))
        for e in range(int(edge_ptr[v]), int(edge_ptr[v + 1])):
            ledge = tb.load(EDGES, e, (lb, le))
            dst = int(edges[e])
            deps = (ledge,) + ((last_level_store[dst],)
                               if dst in last_level_store else ())
            llvl = tb.load(LEVEL, dst, deps)
            cmp = tb.op(T.ICMP, llvl)
            if level[dst] < 0:
                level[dst] = level[v] + 1
                last_level_store[dst] = tb.store(LEVEL, dst, (cmp,))
                queue_store[back] = tb.store(QUEUE, back, (cmp,))
                queue.append(dst)
                back += 1
    return tb.build()
