"""NW (MachSuite nw/needwun): Needleman-Wunsch global sequence alignment.

Dynamic-programming wavefront over an int32 score matrix: every cell
reads its diagonal/up/left neighbours (unit and row-pitch strides) plus
one byte of each sequence, then writes score + traceback pointer.  A
byte-oriented sequence scan keeps part of the stream stride-one, so NW
sits mid-spread on the Fig-5 locality axis.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jax = lazy_import("jax")
jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T

MATCH, MISMATCH, GAP = 1, -1, -1
ALIGN, SKIP_UP, SKIP_LEFT = 0, 1, 2    # traceback pointer codes


@dataclasses.dataclass(frozen=True)
class Params:
    alen: int = 64           # MachSuite: ALEN = BLEN = 128
    blen: int = 64
    seed: int = 29


TINY = Params(alen=12, blen=12)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    return {
        "seq_a": rng.integers(0, 4, size=p.alen).astype(np.uint8),
        "seq_b": rng.integers(0, 4, size=p.blen).astype(np.uint8),
    }


def _cell(diag: int, up: int, left: int, match: bool) -> tuple[int, int]:
    """Score + pointer for one DP cell (diag > up > left tie order)."""
    d = diag + (MATCH if match else MISMATCH)
    u = up + GAP
    l = left + GAP
    if d >= u and d >= l:
        return d, ALIGN
    if u >= l:
        return u, SKIP_UP
    return l, SKIP_LEFT


def run_np(seq_a: np.ndarray, seq_b: np.ndarray) -> tuple[np.ndarray,
                                                          np.ndarray]:
    """Full DP fill; returns (score matrix, traceback pointers), both
    ``[blen+1, alen+1]``."""
    a_n, b_n = seq_a.shape[0], seq_b.shape[0]
    m = np.zeros((b_n + 1, a_n + 1), np.int32)
    ptr = np.zeros((b_n + 1, a_n + 1), np.int8)
    m[0, :] = GAP * np.arange(a_n + 1)
    m[:, 0] = GAP * np.arange(b_n + 1)
    for b in range(1, b_n + 1):
        for a in range(1, a_n + 1):
            s, d = _cell(int(m[b - 1, a - 1]), int(m[b - 1, a]),
                         int(m[b, a - 1]), seq_a[a - 1] == seq_b[b - 1])
            m[b, a] = s
            ptr[b, a] = d
    return m, ptr


def run_jax(seq_a: jnp.ndarray, seq_b: jnp.ndarray) -> tuple[jnp.ndarray,
                                                             jnp.ndarray]:
    """Row scan (outer) x carried-left scan (inner); bit-identical to
    :func:`run_np` including the diag > up > left tie order."""
    a_n = seq_a.shape[0]
    row0 = GAP * jnp.arange(a_n + 1, dtype=jnp.int32)

    def fill_row(carry, bc):
        prev_row, b_idx = carry
        first = GAP * (b_idx + 1)

        def cell(left, xs):
            diag, up, a_char = xs
            d = diag + jnp.where(a_char == bc, MATCH, MISMATCH)
            u = up + GAP
            l = left + GAP
            s = jnp.where((d >= u) & (d >= l), d, jnp.where(u >= l, u, l))
            p = jnp.where((d >= u) & (d >= l), ALIGN,
                          jnp.where(u >= l, SKIP_UP, SKIP_LEFT))
            return s, (s, p.astype(jnp.int8))

        _, (scores, ptrs) = jax.lax.scan(
            cell, first, (prev_row[:-1], prev_row[1:],
                          seq_a.astype(jnp.int32)))
        row = jnp.concatenate([first[None], scores])
        return (row, b_idx + 1), (row, jnp.concatenate(
            [jnp.zeros(1, jnp.int8), ptrs]))

    (_, _), (rows, ptr_rows) = jax.lax.scan(
        fill_row, (row0, jnp.int32(0)), seq_b.astype(jnp.int32))
    m = jnp.concatenate([row0[None], rows])
    ptr = jnp.concatenate([jnp.zeros((1, a_n + 1), jnp.int8), ptr_rows])
    return m, ptr


def gen_trace(p: Params = Params()) -> T.Trace:
    inp = make_inputs(p)
    seq_a, seq_b = inp["seq_a"], inp["seq_b"]
    width = p.alen + 1
    tb = T.TraceBuilder("nw")
    SEQA = tb.declare_array("seqA", 1)
    SEQB = tb.declare_array("seqB", 1)
    M = tb.declare_array("M", 4)
    PTR = tb.declare_array("ptr", 1)    # char traceback codes (MachSuite)
    last_m: dict[int, int] = {}
    # boundary row/column initialisation
    for a in range(width):
        last_m[a] = tb.store(M, a)
    for b in range(1, p.blen + 1):
        last_m[b * width] = tb.store(M, b * width)
    for b in range(1, p.blen + 1):
        for a in range(1, p.alen + 1):
            la = tb.load(SEQA, a - 1)
            lb = tb.load(SEQB, b - 1)
            cmp = tb.op(T.ICMP, la, lb)
            ld = tb.load(M, (b - 1) * width + (a - 1),
                         (last_m[(b - 1) * width + a - 1],))
            lu = tb.load(M, (b - 1) * width + a,
                         (last_m[(b - 1) * width + a],))
            ll = tb.load(M, b * width + (a - 1),
                         (last_m[b * width + a - 1],))
            s0 = tb.op(T.IADD, ld, cmp)
            s1 = tb.op(T.IADD, lu)
            s2 = tb.op(T.IADD, ll)
            mx = tb.op(T.ICMP, s0, s1)
            mx = tb.op(T.ICMP, mx, s2)
            last_m[b * width + a] = tb.store(M, b * width + a, (mx,))
            tb.store(PTR, b * width + a, (mx,))
    return tb.build()
