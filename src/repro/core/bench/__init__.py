"""MachSuite-like benchmark registry (paper III-B / IV-A).

Each module provides: ``Params`` (+ ``TINY``), ``gen_trace(params)`` and
a runnable JAX implementation.  The four discussion benchmarks of the
paper (Fig 4) are fft_strided, gemm_ncubed, kmp, md_knn; sort_merge,
stencil2d and aes widen the locality spread for the Fig-5 analysis.
"""
from __future__ import annotations

from repro.core.bench import (aes, fft_strided, gemm_ncubed, kmp, md_knn,
                              sort_merge, stencil2d)

BENCHMARKS = {
    "fft_strided": fft_strided,
    "gemm_ncubed": gemm_ncubed,
    "kmp": kmp,
    "md_knn": md_knn,
    "sort_merge": sort_merge,
    "stencil2d": stencil2d,
    "aes": aes,
}

PAPER_FIG4 = ("fft_strided", "gemm_ncubed", "kmp", "md_knn")

__all__ = ["BENCHMARKS", "PAPER_FIG4"]
