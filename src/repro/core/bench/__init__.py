"""Benchmark registry (paper III-B / IV-A + the serving extension).

Each module provides: ``Params`` (+ ``TINY``), ``gen_trace(params)`` and
a runnable JAX implementation.  The four discussion benchmarks of the
paper (Fig 4) are fft_strided, gemm_ncubed, kmp, md_knn; sort_merge,
stencil2d and aes widen the locality spread for the Fig-5 analysis, and
the irregular MachSuite kernels — spmv_crs, bfs_queue, nw, viterbi,
radix_sort — populate its low/mid-locality end (sparse gathers, graph
traversal, DP wavefronts, backpointer chases, counting scatters).

The ``SERVING`` triple extends the suite past MachSuite to the
LLM-inference access patterns the ROADMAP north star cares about:
batched mixed-length KV-cache decode (kv_decode), paged-attention
block-table gather (paged_kv) and MoE top-k expert routing (moe_route)
— the low-locality, gather/scatter-heavy workload family the paper's
Fig-5 claim predicts AMMs should win on.

``get_trace`` is the preferred entry point: trace generation is pure in
the benchmark parameters, so generated traces are memoized at module
level and every consumer (DSE runner, benchmark harness, examples,
tests) shares one trace object — and therefore one memoized
:class:`~repro.core.sim.prepared.PreparedTrace` analysis.
"""
from __future__ import annotations

import dataclasses
import hashlib
import importlib
import os
from collections.abc import Mapping

_BENCH_NAMES = ("fft_strided", "gemm_ncubed", "kmp", "md_knn",
                "sort_merge", "stencil2d", "aes",
                "spmv_crs", "bfs_queue", "nw", "viterbi", "radix_sort",
                "kv_decode", "paged_kv", "moe_route")


class _LazyRegistry(Mapping):
    """name -> benchmark module, imported on first access.

    Some benchmark modules build sizeable module-level tables (e.g. the
    AES S-box); loading them lazily keeps ``--only fig4_dse``-style CLI
    runs from paying for benchmarks they never touch.
    """

    def __getitem__(self, name: str):
        if name not in _BENCH_NAMES:
            raise KeyError(name)
        return importlib.import_module(f"repro.core.bench.{name}")

    def __iter__(self):
        return iter(_BENCH_NAMES)

    def __len__(self) -> int:
        return len(_BENCH_NAMES)


BENCHMARKS = _LazyRegistry()

PAPER_FIG4 = ("fft_strided", "gemm_ncubed", "kmp", "md_knn")

# the LLM-serving workload family (ROADMAP: the millions-of-users
# scenario the MachSuite set never covered)
SERVING = ("kv_decode", "paged_kv", "moe_route")

_TRACE_MEMO: dict = {}


_TRACE_CACHE_VERSION = 1
_SRC_HASH_MEMO: dict = {}


def _module_src_hash(mod) -> str:
    """Content hash of the benchmark module's source file, so edits to a
    ``gen_trace`` automatically invalidate its on-disk trace cache."""
    path = getattr(mod, "__file__", None)
    if path not in _SRC_HASH_MEMO:
        try:
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
        except (OSError, TypeError):
            digest = "nosrc"
        _SRC_HASH_MEMO[path] = digest
    return _SRC_HASH_MEMO[path]


def _disk_cache_path(name: str, params, mod) -> "str | None":
    """Trace generation is pure in (benchmark, params); cache the built
    arrays on disk next to the compiled cycle loop so repeat CLI runs
    skip the Python trace-builder loops entirely.  The key includes the
    generator module's source hash: stale traces are never reused."""
    if os.environ.get("REPRO_NO_TRACE_CACHE"):
        return None
    root = os.environ.get("REPRO_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro")
    key = hashlib.sha256(
        repr((_TRACE_CACHE_VERSION, _module_src_hash(mod), name,
              dataclasses.astuple(params))).encode()).hexdigest()[:24]
    return os.path.join(root, "traces", f"{name}-{key}.npz")


def _trace_from_disk(path: str):
    import json

    import numpy as np

    from repro.core.sim.trace import Trace

    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            return Trace(
                kinds=z["kinds"], array_ids=z["array_ids"], addrs=z["addrs"],
                pred_ptr=z["pred_ptr"], pred_idx=z["pred_idx"],
                array_names={int(k): v for k, v in meta["array_names"].items()},
                word_bytes={int(k): int(v)
                            for k, v in meta["word_bytes"].items()},
                name=meta["name"])
    except Exception:
        return None


def _trace_to_disk(path: str, tr) -> None:
    import json

    import numpy as np

    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        meta = json.dumps({"name": tr.name, "array_names": tr.array_names,
                           "word_bytes": tr.word_bytes})
        with open(tmp, "wb") as f:
            np.savez(f, kinds=tr.kinds, array_ids=tr.array_ids,
                     addrs=tr.addrs, pred_ptr=tr.pred_ptr,
                     pred_idx=tr.pred_idx, meta=np.asarray(meta))
        os.replace(tmp, path)
    except Exception:
        pass


def trace_cache_key(name: str, params=None, *, full: bool = False) -> str:
    """Stable identity of ``get_trace(name, params, full=full)`` WITHOUT
    generating the trace.

    Trace generation is pure in (module source, params), so this key
    changes exactly when the generated trace would.  The DSE sweep cache
    maps it to the trace *fingerprint* (``manifest.json``), letting a
    fully-cached sweep skip trace generation and preparation entirely.
    """
    mod = BENCHMARKS[name]
    if params is None:
        params = mod.Params() if full else mod.TINY
    return hashlib.sha256(
        repr((_TRACE_CACHE_VERSION, _module_src_hash(mod), name,
              dataclasses.astuple(params))).encode()).hexdigest()[:24]


def get_trace(name: str, params=None, *, full: bool = False):
    """Memoized ``BENCHMARKS[name].gen_trace(params)``.

    ``params`` defaults to the module's full-size ``Params()`` when
    ``full`` else ``TINY``.  Traces are cached per (benchmark, params) —
    in memory for the process lifetime and on disk under
    ``$REPRO_CACHE_DIR`` (``~/.cache/repro``) across runs — so every
    consumer shares one trace object and its prepared-trace analysis.
    """
    mod = BENCHMARKS[name]
    if params is None:
        params = mod.Params() if full else mod.TINY
    key = (name, dataclasses.astuple(params))
    tr = _TRACE_MEMO.get(key)
    if tr is None:
        path = _disk_cache_path(name, params, mod)
        if path is not None and os.path.exists(path):
            tr = _trace_from_disk(path)
        if tr is None:
            tr = mod.gen_trace(params)
            if path is not None:
                _trace_to_disk(path, tr)
        _TRACE_MEMO[key] = tr
    return _TRACE_MEMO[key]


__all__ = ["BENCHMARKS", "PAPER_FIG4", "SERVING", "get_trace",
           "trace_cache_key"]
