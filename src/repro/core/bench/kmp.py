"""KMP (MachSuite kmp/kmp): Knuth-Morris-Pratt string search.

Byte-oriented, stride-one text scan -> the paper's canonical
high-spatial-locality benchmark (L ~ 1), where array-partitioned
banking wins and true multiport is wasted area (Fig 4c/5).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jax = lazy_import("jax")
jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T

PATTERN = b"bull"


@dataclasses.dataclass(frozen=True)
class Params:
    n: int = 8192        # text length (MachSuite: 32411)
    seed: int = 7


TINY = Params(n=256)


def make_text(p: Params) -> np.ndarray:
    rng = np.random.default_rng(p.seed)
    text = rng.integers(ord("a"), ord("e"), size=p.n, dtype=np.uint8)
    # plant a few patterns
    for pos in rng.integers(0, p.n - len(PATTERN), size=max(4, p.n // 512)):
        text[pos:pos + len(PATTERN)] = np.frombuffer(PATTERN, np.uint8)
    return text


def failure_table(pattern: bytes) -> np.ndarray:
    m = len(pattern)
    nxt = np.zeros(m, np.int64)
    k = 0
    for q in range(1, m):
        while k > 0 and pattern[k] != pattern[q]:
            k = int(nxt[k - 1])
        if pattern[k] == pattern[q]:
            k += 1
        nxt[q] = k
    return nxt


def run_np(text: np.ndarray, pattern: bytes = PATTERN) -> int:
    nxt = failure_table(pattern)
    q = matches = 0
    pat = np.frombuffer(pattern, np.uint8)
    m = len(pat)
    for c in text:
        while q > 0 and pat[q] != c:
            q = int(nxt[q - 1])
        if pat[q] == c:
            q += 1
        if q == m:
            matches += 1
            q = int(nxt[q - 1])
    return matches


def run_jax(text: jnp.ndarray, pattern: bytes = PATTERN) -> jnp.ndarray:
    """KMP as a lax.scan with carry q (the DFA state)."""
    nxt = jnp.asarray(failure_table(pattern), jnp.int32)
    pat = jnp.asarray(np.frombuffer(pattern, np.uint8))
    m = len(pattern)

    def dfa_step(q, c):
        # while q>0 and pat[q]!=c: q = nxt[q-1]  — bounded by m iterations
        def body(_, q):
            cond = jnp.logical_and(q > 0, pat[q] != c)
            return jnp.where(cond, nxt[jnp.maximum(q - 1, 0)], q)
        q = jax.lax.fori_loop(0, m, body, q)
        q = jnp.where(pat[q] == c, q + 1, q)
        hit = q == m
        q = jnp.where(hit, nxt[q - 1], q)
        return q, hit

    _, hits = jax.lax.scan(dfa_step, jnp.int32(0), text)
    return jnp.sum(hits)


def gen_trace(p: Params = Params()) -> T.Trace:
    text = make_text(p)
    pat = np.frombuffer(PATTERN, np.uint8)
    nxt = failure_table(PATTERN)
    m = len(pat)
    tb = T.TraceBuilder("kmp")
    TXT = tb.declare_array("text", 1)
    PAT = tb.declare_array("pattern", 1)
    NXT = tb.declare_array("kmp_next", 4)
    MAT = tb.declare_array("n_matches", 4)
    q = 0
    carry = -1  # control/data dependence through q
    n_matches = 0
    for i, c in enumerate(text):
        deps = (carry,) if carry >= 0 else ()
        lt = tb.load(TXT, i, deps)
        while q > 0 and pat[q] != c:
            lp = tb.load(PAT, q, (lt,))
            cmp = tb.op(T.ICMP, lt, lp)
            ln = tb.load(NXT, q - 1, (cmp,))
            carry = ln
            q = int(nxt[q - 1])
        lp = tb.load(PAT, q, (lt,))
        cmp = tb.op(T.ICMP, lt, lp)
        carry = cmp
        if pat[q] == c:
            q += 1
        if q == m:
            n_matches += 1
            add = tb.op(T.IADD, cmp)
            carry = tb.store(MAT, 0, (add,))
            q = int(nxt[q - 1])
    return tb.build()
