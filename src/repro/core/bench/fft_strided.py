"""FFT-Strided (MachSuite fft/strided): iterative radix-2 DIF FFT, fp64.

Per-stage strides are N/2, N/4, ..., 1 *elements* (x8 bytes) — the
paper's example of a double-precision program with >=8-byte minimum
stride and hence low spatial locality.

``run_jax`` performs the same DIF butterfly passes; its output is in
bit-reversed order (validated against ``jnp.fft.fft`` + bit reversal).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n: int = 1024


TINY = Params(n=32)


def _bit_reverse_perm(n: int) -> np.ndarray:
    bits = int(np.log2(n))
    idx = np.arange(n)
    out = np.zeros(n, np.int64)
    for b in range(bits):
        out |= ((idx >> b) & 1) << (bits - 1 - b)
    return out


def run_jax(x: jnp.ndarray) -> jnp.ndarray:
    """DIF butterflies; returns the spectrum in *bit-reversed* order."""
    n = x.shape[0]
    span = n // 2
    while span >= 1:
        xr = x.reshape(-1, 2 * span)
        a, b = xr[:, :span], xr[:, span:]
        j = jnp.arange(span)
        w = jnp.exp(-2j * jnp.pi * j * (n // (2 * span)) / n)
        xr = jnp.concatenate([a + b, (a - b) * w[None, :]], axis=1)
        x = xr.reshape(n)
        span //= 2
    return x


def spectrum(x: jnp.ndarray) -> jnp.ndarray:
    """Natural-order FFT via the strided kernel + bit-reversal."""
    y = run_jax(x)
    return y[_bit_reverse_perm(x.shape[0])]


def gen_trace(p: Params = Params()) -> T.Trace:
    n = p.n
    tb = T.TraceBuilder("fft_strided")
    RE = tb.declare_array("real", 8)
    IM = tb.declare_array("img", 8)
    TR = tb.declare_array("real_twid", 8)
    TI = tb.declare_array("img_twid", 8)
    span = n // 2
    while span >= 1:
        for start in range(0, n, 2 * span):
            for j in range(span):
                i0, i1 = start + j, start + j + span
                ar, ai = tb.load(RE, i0), tb.load(IM, i0)
                br, bi = tb.load(RE, i1), tb.load(IM, i1)
                # even = a + b
                er = tb.op(T.FADD, ar, br)
                ei = tb.op(T.FADD, ai, bi)
                # odd = (a - b) * w
                dr = tb.op(T.FADD, ar, br)
                di = tb.op(T.FADD, ai, bi)
                tw = j * (n // (2 * span))
                wr, wi = tb.load(TR, tw), tb.load(TI, tw)
                m0 = tb.op(T.FMUL, dr, wr)
                m1 = tb.op(T.FMUL, di, wi)
                m2 = tb.op(T.FMUL, dr, wi)
                m3 = tb.op(T.FMUL, di, wr)
                orr = tb.op(T.FADD, m0, m1)
                oii = tb.op(T.FADD, m2, m3)
                tb.store(RE, i0, (er,))
                tb.store(IM, i0, (ei,))
                tb.store(RE, i1, (orr,))
                tb.store(IM, i1, (oii,))
        span //= 2
    return tb.build()
