"""RADIX-SORT (MachSuite sort/radix): LSD radix sort, 2-bit digits,
ping-ponged int32 buffers.

Each pass histograms the keys, exclusive-scans the 4-entry bucket
array, then scatters every key to its counted position — the scatter
stores land at data-dependent addresses that interleave the four digit
regions, while the key reads stay stride-one.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jax = lazy_import("jax")
jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T

RADIX_BITS = 2
N_BUCKETS = 1 << RADIX_BITS


@dataclasses.dataclass(frozen=True)
class Params:
    n: int = 256             # MachSuite: 2048 keys
    value_bits: int = 16     # MachSuite: full 32-bit keys (16 passes)
    seed: int = 37


TINY = Params(n=48, value_bits=6)


def make_input(p: Params) -> np.ndarray:
    rng = np.random.default_rng(p.seed)
    return rng.integers(0, 1 << p.value_bits, size=p.n, dtype=np.int32)


def n_passes(p: Params) -> int:
    return (p.value_bits + RADIX_BITS - 1) // RADIX_BITS


def run_np(a: np.ndarray, value_bits: int) -> np.ndarray:
    a = a.copy()
    for shift in range(0, value_bits, RADIX_BITS):
        digit = (a >> shift) & (N_BUCKETS - 1)
        bucket = np.bincount(digit, minlength=N_BUCKETS)
        offset = np.zeros(N_BUCKETS, np.int64)
        np.cumsum(bucket[:-1], out=offset[1:])
        out = np.empty_like(a)
        for x, d in zip(a, digit):
            out[offset[d]] = x
            offset[d] += 1
        a = out
    return a


def run_jax(a: jnp.ndarray, value_bits: int) -> jnp.ndarray:
    """Counting sort per 2-bit digit: one-hot histogram + exclusive scan
    + rank-within-digit scatter (a stable LSD radix sort)."""
    n = a.shape[0]

    def one_pass(a, shift):
        digit = (a >> shift) & (N_BUCKETS - 1)
        onehot = (digit[:, None] == jnp.arange(N_BUCKETS)[None, :])
        counts = jnp.sum(onehot, axis=0)
        offset = jnp.cumsum(counts) - counts           # exclusive scan
        rank = jnp.cumsum(onehot, axis=0) - onehot     # stable within digit
        pos = offset[digit] + rank[jnp.arange(n), digit]
        return jnp.zeros_like(a).at[pos].set(a), None

    shifts = jnp.arange(0, value_bits, RADIX_BITS)
    a, _ = jax.lax.scan(one_pass, a, shifts)
    return a


def gen_trace(p: Params = Params()) -> T.Trace:
    a = make_input(p).astype(np.int64)
    tb = T.TraceBuilder("radix_sort")
    # ping-pong key buffers + histogram/scan scratch (MachSuite a/b/bucket)
    BUF = [tb.declare_array("a", 4), tb.declare_array("b", 4)]
    BUCKET = tb.declare_array("bucket", 4)
    SUM = tb.declare_array("sum", 4)
    last_buf: list[dict[int, int]] = [{}, {}]
    src = 0
    for shift in range(0, p.value_bits, RADIX_BITS):
        digit = (a >> shift) & (N_BUCKETS - 1)
        # histogram
        last_bucket: dict[int, int] = {}
        for i in range(p.n):
            deps = (last_buf[src][i],) if i in last_buf[src] else ()
            lk = tb.load(BUF[src], i, deps)
            dig = tb.op(T.LOGIC, lk)
            d = int(digit[i])
            bdeps = (dig,) + ((last_bucket[d],) if d in last_bucket else ())
            lb = tb.load(BUCKET, d, bdeps)
            inc = tb.op(T.IADD, lb)
            last_bucket[d] = tb.store(BUCKET, d, (inc,))
        # exclusive scan of the 4 buckets into sum
        last_sum: dict[int, int] = {}
        carry = -1
        for k in range(N_BUCKETS):
            lb = tb.load(BUCKET, k, (last_bucket[k],)
                         if k in last_bucket else ())
            acc = tb.op(T.IADD, lb, carry) if carry >= 0 else tb.op(T.IADD, lb)
            last_sum[k] = tb.store(SUM, k, (acc,))
            carry = acc
        # scatter
        offset = np.zeros(N_BUCKETS, np.int64)
        np.cumsum(np.bincount(digit, minlength=N_BUCKETS)[:-1],
                  out=offset[1:])
        out = np.empty_like(a)
        for i in range(p.n):
            deps = (last_buf[src][i],) if i in last_buf[src] else ()
            lk = tb.load(BUF[src], i, deps)
            dig = tb.op(T.LOGIC, lk)
            d = int(digit[i])
            ls = tb.load(SUM, d, (dig, last_sum[d]))
            pos = int(offset[d])
            offset[d] += 1
            out[pos] = a[i]
            last_buf[1 - src][pos] = tb.store(BUF[1 - src], pos, (ls,))
            inc = tb.op(T.IADD, ls)
            last_sum[d] = tb.store(SUM, d, (inc,))
        a = out
        src = 1 - src
    return tb.build()
