"""Paged-attention KV gather: block-table indirection at serving scale.

vLLM-style paged KV storage: each request's context lives in fixed-size
pages scattered through a shared physical pool, found through a per
-request block table.  The pool fragments the way a real serving pool
does — requests grow one page at a time while other requests are
interleaved between them — so a request's pages stride by the number of
concurrently-growing requests, and which physical page a load touches
is only known after the previous block-table load resolves: the
archetypal data-dependent index chase (same family as ``spmv_crs``'s
column gather, but with the indirection *in the address path*).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n_seqs: int = 32         # concurrent requests sharing the pool
    page_size: int = 8       # tokens per physical page
    max_pages: int = 16      # block-table width (max context / page_size)
    seed: int = 29


TINY = Params(n_seqs=4, page_size=4, max_pages=4)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    max_len = p.page_size * p.max_pages
    lengths = rng.integers(1, max_len + 1, p.n_seqs).astype(np.int32)
    n_pages = -(-lengths // p.page_size)                 # ceil division
    # fragmented allocation: pages are handed out in growth order, all
    # live requests interleaved (request b's pages stride by however
    # many requests were still growing when it claimed each one)
    table = np.full((p.n_seqs, p.max_pages), -1, np.int32)
    counter = 0
    for step in range(p.max_pages):
        for b in range(p.n_seqs):
            if step < n_pages[b]:
                table[b, step] = counter
                counter += 1
    return {
        "block_table": table,
        "lengths": lengths,
        "kv_pool": rng.standard_normal(
            counter * p.page_size).astype(np.float32),
        "weights": rng.standard_normal(max_len).astype(np.float32),
    }


def run_np(block_table: np.ndarray, lengths: np.ndarray,
           kv_pool: np.ndarray, weights: np.ndarray,
           page_size: int) -> np.ndarray:
    """Token gather through the block table + weighted reduction (the
    attention-value accumulation with scores precomputed)."""
    out = np.zeros(lengths.shape[0], np.float32)
    for b in range(lengths.shape[0]):
        acc = 0.0
        for t in range(int(lengths[b])):
            pp = int(block_table[b, t // page_size])
            acc += kv_pool[pp * page_size + t % page_size] * weights[t]
        out[b] = acc
    return out


def run_jax(block_table: jnp.ndarray, lengths: jnp.ndarray,
            kv_pool: jnp.ndarray, weights: jnp.ndarray,
            page_size: int) -> jnp.ndarray:
    max_len = block_table.shape[1] * page_size
    t = jnp.arange(max_len)
    pp = jnp.take_along_axis(block_table, t[None, :] // page_size, axis=1)
    mask = t[None, :] < lengths[:, None]
    idx = jnp.where(mask, pp * page_size + t[None, :] % page_size, 0)
    vals = jnp.take(kv_pool, idx) * weights[None, :]
    return jnp.where(mask, vals, 0.0).sum(axis=1)


def gen_trace(p: Params = Params()) -> T.Trace:
    inp = make_inputs(p)
    table, lengths = inp["block_table"], inp["lengths"]
    tb = T.TraceBuilder("paged_kv")
    LEN = tb.declare_array("lengths", 4)
    BT = tb.declare_array("block_table", 4)
    KV = tb.declare_array("kv_pool", 8)
    W = tb.declare_array("weights", 8)
    OUT = tb.declare_array("out", 8)
    for b in range(p.n_seqs):
        ll = tb.load(LEN, b)
        acc = -1
        for lp in range(-(-int(lengths[b]) // p.page_size)):
            lbt = tb.load(BT, b * p.max_pages + lp, (ll,))
            pp = int(table[b, lp])
            n_tok = min(p.page_size, int(lengths[b]) - lp * p.page_size)
            for slot in range(n_tok):
                # page chase: the address is the block-table load's value
                lkv = tb.load(KV, pp * p.page_size + slot, (lbt,))
                lw = tb.load(W, lp * p.page_size + slot)
                m = tb.op(T.FMUL, lkv, lw)
                acc = tb.op(T.FADD, m, acc) if acc >= 0 else m
        tb.store(OUT, b, (acc,) if acc >= 0 else ())
    return tb.build()
