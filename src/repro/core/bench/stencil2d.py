"""STENCIL2D (MachSuite stencil/stencil2d): 3x3 convolution over a 2-D
grid, fp32.  Compute-intensive with mixed strides (unit within a row,
row-pitch across rows).
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    rows: int = 64
    cols: int = 64
    seed: int = 5


TINY = Params(rows=10, cols=10)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    return {
        "orig": rng.standard_normal((p.rows, p.cols)).astype(np.float32),
        "filter": rng.standard_normal((3, 3)).astype(np.float32),
    }


def run_jax(orig: jnp.ndarray, filt: jnp.ndarray) -> jnp.ndarray:
    r, c = orig.shape
    out = jnp.zeros((r - 2, c - 2), orig.dtype)
    for k1 in range(3):
        for k2 in range(3):
            out = out + filt[k1, k2] * orig[k1:k1 + r - 2, k2:k2 + c - 2]
    return out


def gen_trace(p: Params = Params()) -> T.Trace:
    tb = T.TraceBuilder("stencil2d")
    ORIG = tb.declare_array("orig", 4)
    FILT = tb.declare_array("filter", 4)
    SOL = tb.declare_array("sol", 4)
    filter_loads = [tb.load(FILT, i) for i in range(9)]
    for r in range(p.rows - 2):
        for c in range(p.cols - 2):
            acc = -1
            for k1 in range(3):
                for k2 in range(3):
                    ld = tb.load(ORIG, (r + k1) * p.cols + (c + k2))
                    mul = tb.op(T.FMUL, ld, filter_loads[k1 * 3 + k2])
                    acc = tb.op(T.FADD, mul, acc) if acc >= 0 else mul
            tb.store(SOL, r * p.cols + c, (acc,))
    return tb.build()
