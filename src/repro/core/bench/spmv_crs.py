"""SPMV-CRS (MachSuite spmv/crs): sparse matrix-vector multiply over a
compressed-row-storage matrix, fp64 values + int32 column indices.

The dense vector is gathered through ``cols`` — a data-dependent access
stream whose strides follow the (random) sparsity pattern, the paper's
archetype of an index-chasing, low-spatial-locality kernel.  The ``val``
and ``cols`` streams themselves are stride-one.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n: int = 494             # MachSuite: N=494 rows
    nnz_per_row: int = 10    # MachSuite: L=10 nonzeros/row (mean here)
    seed: int = 19


TINY = Params(n=32, nnz_per_row=4)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    counts = rng.integers(1, 2 * p.nnz_per_row, size=p.n)
    row_ptr = np.zeros(p.n + 1, np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    cols = np.concatenate(
        [np.sort(rng.choice(p.n, size=int(c), replace=False))
         for c in counts]).astype(np.int32)
    return {
        "vals": rng.standard_normal(int(row_ptr[-1])),
        "cols": cols,
        "row_ptr": row_ptr,
        "vec": rng.standard_normal(p.n),
    }


def run_np(vals: np.ndarray, cols: np.ndarray, row_ptr: np.ndarray,
           vec: np.ndarray) -> np.ndarray:
    out = np.zeros(row_ptr.shape[0] - 1, vals.dtype)
    for i in range(out.shape[0]):
        acc = 0.0
        for j in range(int(row_ptr[i]), int(row_ptr[i + 1])):
            acc += vals[j] * vec[cols[j]]
        out[i] = acc
    return out


def run_jax(vals: jnp.ndarray, cols: jnp.ndarray, row_ptr: np.ndarray,
            vec: jnp.ndarray) -> jnp.ndarray:
    """CRS y = A @ x as a gather + segment scatter-add.

    ``row_ptr`` is static (numpy): the row segmentation is part of the
    matrix structure, like the trace generator's loop bounds.
    """
    row_ptr = np.asarray(row_ptr)
    n = row_ptr.shape[0] - 1
    rows = jnp.asarray(np.repeat(np.arange(n), np.diff(row_ptr)))
    contrib = vals * vec[cols]
    return jnp.zeros(n, vals.dtype).at[rows].add(contrib)


def gen_trace(p: Params = Params()) -> T.Trace:
    inp = make_inputs(p)
    cols, row_ptr = inp["cols"], inp["row_ptr"]
    tb = T.TraceBuilder("spmv_crs")
    VAL = tb.declare_array("val", 8)
    COL = tb.declare_array("cols", 4)
    ROWD = tb.declare_array("rowDelimiters", 4)
    VEC = tb.declare_array("vec", 8)
    OUT = tb.declare_array("out", 8)
    for i in range(p.n):
        lb = tb.load(ROWD, i)
        le = tb.load(ROWD, i + 1)
        acc = -1
        for j in range(int(row_ptr[i]), int(row_ptr[i + 1])):
            lv = tb.load(VAL, j, (lb, le))
            lc = tb.load(COL, j, (lb, le))
            lx = tb.load(VEC, int(cols[j]), (lc,))   # data-dependent gather
            mul = tb.op(T.FMUL, lv, lx)
            acc = tb.op(T.FADD, mul, acc) if acc >= 0 else mul
        tb.store(OUT, i, (acc,) if acc >= 0 else ())
    return tb.build()
