"""VITERBI (MachSuite viterbi/viterbi): HMM decoding, min-sum over
-log-probabilities.

The transition matrix is walked down columns (stride = 8*n_states
bytes), the emission matrix is gathered through the observation tokens,
and the final traceback chases backpointers state-by-state — a
low-spatial-locality mix of strided and data-dependent accesses.
"""
from __future__ import annotations

import dataclasses

from repro.core._lazy import lazy_import

jax = lazy_import("jax")
jnp = lazy_import("jax.numpy")
import numpy as np

from repro.core.sim import trace as T


@dataclasses.dataclass(frozen=True)
class Params:
    n_states: int = 16       # MachSuite: N_STATES=64
    n_steps: int = 24        # MachSuite: N_OBS=140
    n_tokens: int = 32       # MachSuite: N_TOKENS=64
    seed: int = 31


TINY = Params(n_states=5, n_steps=8, n_tokens=8)


def make_inputs(p: Params) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(p.seed)
    return {
        "obs": rng.integers(0, p.n_tokens, size=p.n_steps).astype(np.uint8),
        "init": rng.uniform(0.1, 5.0, size=p.n_states),
        "transition": rng.uniform(0.1, 5.0, size=(p.n_states, p.n_states)),
        "emission": rng.uniform(0.1, 5.0, size=(p.n_states, p.n_tokens)),
    }


def run_np(obs: np.ndarray, init: np.ndarray, transition: np.ndarray,
           emission: np.ndarray) -> np.ndarray:
    """Most-likely state path (min-sum Viterbi with backtrack)."""
    t_n, s_n = obs.shape[0], init.shape[0]
    llike = np.zeros((t_n, s_n))
    bptr = np.zeros((t_n, s_n), np.int64)
    llike[0] = init + emission[:, obs[0]]
    for t in range(1, t_n):
        for curr in range(s_n):
            trans = llike[t - 1] + transition[:, curr]
            best = int(np.argmin(trans))
            bptr[t, curr] = best
            llike[t, curr] = trans[best] + emission[curr, obs[t]]
    path = np.zeros(t_n, np.int64)
    path[-1] = int(np.argmin(llike[-1]))
    for t in range(t_n - 2, -1, -1):
        path[t] = bptr[t + 1, path[t + 1]]
    return path


def run_jax(obs: jnp.ndarray, init: jnp.ndarray, transition: jnp.ndarray,
            emission: jnp.ndarray) -> jnp.ndarray:
    """lax.scan forward pass + backpointer scan (matches run_np exactly:
    both argmins take the first minimum)."""
    ll0 = init + emission[:, obs[0]]

    def fwd(ll_prev, ob):
        trans = ll_prev[:, None] + transition          # [prev, curr]
        best = jnp.argmin(trans, axis=0)               # per curr
        ll = jnp.min(trans, axis=0) + emission[:, ob]
        return ll, best

    ll_last, bptrs = jax.lax.scan(fwd, ll0, obs[1:])

    def back(state, bp):
        prev = bp[state]
        return prev, prev

    last = jnp.argmin(ll_last)
    _, rest = jax.lax.scan(back, last, bptrs, reverse=True)
    return jnp.concatenate([rest, last[None]])


def gen_trace(p: Params = Params()) -> T.Trace:
    inp = make_inputs(p)
    obs = inp["obs"]
    s_n, t_n = p.n_states, p.n_steps
    # mirror the DP to know the traceback addresses
    llike = np.zeros((t_n, s_n))
    bptr_np = np.zeros((t_n, s_n), np.int64)
    llike[0] = inp["init"] + inp["emission"][:, obs[0]]
    tb = T.TraceBuilder("viterbi")
    OBS = tb.declare_array("obs", 1)
    INIT = tb.declare_array("init", 8)
    TRANS = tb.declare_array("transition", 8)
    EMIS = tb.declare_array("emission", 8)
    LL = tb.declare_array("llike", 8)
    BP = tb.declare_array("bptr", 1)
    PATH = tb.declare_array("path", 1)
    last_ll: dict[int, int] = {}
    last_bp: dict[int, int] = {}
    lobs = tb.load(OBS, 0)
    for s in range(s_n):
        li = tb.load(INIT, s)
        le = tb.load(EMIS, s * p.n_tokens + int(obs[0]), (lobs,))
        add = tb.op(T.FADD, li, le)
        last_ll[s] = tb.store(LL, s, (add,))
    for t in range(1, t_n):
        lobs = tb.load(OBS, t)
        for curr in range(s_n):
            trans = llike[t - 1] + inp["transition"][:, curr]
            best = int(np.argmin(trans))
            bptr_np[t, curr] = best
            llike[t, curr] = trans[best] + inp["emission"][curr, int(obs[t])]
            acc = -1
            for prev in range(s_n):
                ll = tb.load(LL, (t - 1) * s_n + prev,
                             (last_ll[(t - 1) * s_n + prev],))
                lt = tb.load(TRANS, prev * s_n + curr)
                add = tb.op(T.FADD, ll, lt)
                acc = tb.op(T.ICMP, add, acc) if acc >= 0 else add
            le = tb.load(EMIS, curr * p.n_tokens + int(obs[t]), (lobs,))
            add = tb.op(T.FADD, acc, le)
            last_ll[t * s_n + curr] = tb.store(LL, t * s_n + curr, (add,))
            last_bp[t * s_n + curr] = tb.store(BP, t * s_n + curr, (acc,))
    # final argmin over llike[T-1] + backpointer chase
    acc = -1
    for s in range(s_n):
        ll = tb.load(LL, (t_n - 1) * s_n + s, (last_ll[(t_n - 1) * s_n + s],))
        acc = tb.op(T.ICMP, ll, acc) if acc >= 0 else ll
    state = int(np.argmin(llike[-1]))
    carry = tb.store(PATH, t_n - 1, (acc,))
    for t in range(t_n - 2, -1, -1):
        lb = tb.load(BP, (t + 1) * s_n + state,
                     (carry, last_bp[(t + 1) * s_n + state]))
        state = int(bptr_np[t + 1, state])
        carry = tb.store(PATH, t, (lb,))
    return tb.build()
