"""Fault-injection & resilience layer (PR 7).

The paper's algorithmic multi-port memories buy extra ports with
*redundant storage* — NTX parity planes, LVT bank replicas.  This
package asks the follow-on question: how much fault tolerance does that
same redundancy buy for free?  It injects seeded transient bit-flips,
stuck-at bits and whole-bank failures into the flat replay state of
every design kind, then classifies each post-injection read as
benign / corrected / detected / SDC using only the design's own
read-path redundancy (:mod:`repro.core.fault.campaign`).

The resulting :class:`Resilience` record rides along the DSE sweep
(``DSEPoint.res_*`` fields, runner CSV, ``--faults`` CLI axis) and the
``fault_campaign`` benchmark table.
"""
from repro.core.fault.campaign import (CampaignResult, FaultConfig,
                                       attach_resilience, design_resilience,
                                       run_campaign)
from repro.core.fault.metrics import (COVER, RES_FIELDS, Resilience,
                                      resilience_fields)
from repro.core.fault.model import (FAULT_KINDS, FaultSpec, build_masks,
                                    sample_faults, state_geometry,
                                    tile_states)

__all__ = [
    "FAULT_KINDS", "FaultSpec", "state_geometry", "sample_faults",
    "build_masks", "tile_states",
    "COVER", "RES_FIELDS", "Resilience", "resilience_fields",
    "FaultConfig", "CampaignResult", "run_campaign", "design_resilience",
    "attach_resilience",
]
