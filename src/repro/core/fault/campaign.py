"""Seeded fault-injection campaigns and the per-design resilience checker.

A campaign replays one seeded op trace through a design twice: once
clean (golden) and once per injected fault (batched over the fault
population with ``replay_faulty_batched``).  Every post-injection read
is then classified against the golden values using *only* the
redundancy the design actually has:

* ``h_ntx_rd`` / ``hb_ntx`` (cover ``parity``) — the replay exposes
  both the direct-path and the XOR-reconstruction-path value per read.
  A single physical fault lives in exactly one leaf, and an address's
  parity path never contains its direct leaf, so at most one of the two
  paths is corrupt: the other reconstructs the golden word (corrected).
  Both-paths-corrupt can only arise from accumulated write-invariant
  damage; disagreeing paths are a detected error, agreeing-but-wrong
  paths are SDC.
* ``lvt`` (cover ``replica``) — the hardware keeps ``n_read`` physical
  replicas of every write bank.  A single fault lands in one replica;
  the other ``n_read - 1`` replicas return the golden value, so only
  two replays are needed.  With >= 3 replicas a majority vote corrects;
  with exactly 2 a mismatch is detected but not attributable; with 1
  a corrupt read is silent.
* everything else (cover ``none``) — banked/ideal/multipump have a
  single copy, ``remap``'s spare bank holds stale (not redundant) data,
  and ``b_ntx_wr``'s Ref plane is *write-bandwidth* redundancy: ``lo =
  s0 ^ ref`` only helps if you know which plane is corrupt, and the
  read path has no disagreement signal.  Any wrong read is SDC.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Sequence

import numpy as np

from repro.core.amm import replay as rp
from repro.core.amm.spec import AMMSpec
from repro.core.fault.metrics import COVER, Resilience, resilience_fields
from repro.core.fault.model import (FAULT_KINDS, FaultSpec, build_masks,
                                    sample_faults, tile_states)

__all__ = ["FaultConfig", "CampaignResult", "run_campaign",
           "design_resilience", "attach_resilience"]


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """One campaign's shape: population size, trace length, seed.

    Hashable so :func:`design_resilience` can memoise per
    ``(design, depth, width, config)``.
    """

    n_faults: int = 32
    n_cycles: int = 128
    seed: int = 0
    kinds: tuple[str, ...] = FAULT_KINDS
    write_prob: float = 0.35


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """A classified campaign: the injected population, each fault's
    worst observed outcome, and the aggregate record."""

    spec_label: str
    faults: tuple[FaultSpec, ...]
    outcomes: tuple[str, ...]      # worst per fault: benign<corrected<detected<sdc
    resilience: Resilience


_SEVERITY = ("benign", "corrected", "detected", "sdc")


def _classify(cover: str, n_read: int, golden: np.ndarray, f_vals: np.ndarray,
              f_par: np.ndarray) -> tuple[np.ndarray, ...]:
    """Per-read boolean masks [F, T, R]: (benign, corrected, detected, sdc).

    ``golden`` [T, R]; ``f_vals``/``f_par`` [F, T, R].
    """
    d_bad = f_vals != golden[None]
    if cover == "parity":
        p_bad = f_par != golden[None]
        benign = ~d_bad & ~p_bad
        corrected = d_bad ^ p_bad           # exactly one path corrupt
        both = d_bad & p_bad
        detected = both & (f_vals != f_par)
        sdc = both & (f_vals == f_par)
    elif cover == "replica":
        # one replica faulty, n_read - 1 healthy replicas read golden
        benign = ~d_bad
        if n_read >= 3:
            corrected, detected, sdc = d_bad, ~d_bad & False, d_bad & False
        elif n_read == 2:
            corrected, detected, sdc = d_bad & False, d_bad, d_bad & False
        else:
            corrected, detected, sdc = d_bad & False, d_bad & False, d_bad
    else:
        benign = ~d_bad
        corrected = detected = d_bad & False
        sdc = d_bad
    return benign, corrected, detected, sdc


def run_campaign(spec: AMMSpec, cfg: FaultConfig = FaultConfig()
                 ) -> CampaignResult:
    """Inject ``cfg.n_faults`` seeded faults into ``spec`` and classify
    every post-injection read.  Fully deterministic per ``(spec, cfg)``."""
    cover = COVER[spec.kind]
    rng = np.random.default_rng(
        [cfg.seed, rp.spec_seed(spec, salt="campaign")])
    ra, wa, wv, wm = rp.make_trace(spec, cfg.n_cycles, rng=rng,
                                   write_prob=cfg.write_prob)
    values = rng.integers(0, 1 << 32, spec.depth, dtype=np.uint32)

    _, g = rp.replay(spec, rp.init_flat(spec, values), ra, wa, wv, wm)
    golden = np.asarray(g.read_vals)

    faults = sample_faults(spec, cfg.n_faults, cfg.seed, cfg.n_cycles,
                           cfg.kinds)
    masks = build_masks(spec, faults)
    states = tile_states(spec, values, len(faults))
    _, res = rp.replay_faulty_batched(spec, states, masks, ra, wa, wv, wm,
                                      share_trace=True)
    f_vals = np.asarray(res.read_vals)
    f_par = np.asarray(res.parity_vals)

    benign, corrected, detected, sdc = _classify(
        cover, spec.n_read, golden, f_vals, f_par)

    # only reads at/after each fault's injection cycle count as observations
    cycles = np.arange(cfg.n_cycles)[None, :, None]                 # [1,T,1]
    live = cycles >= np.asarray([f.cycle for f in faults])[:, None, None]
    n_ports = golden.shape[1]
    n_reads = int(round(live.sum() * n_ports / max(len(faults), 1)))

    counts = {}
    for name, m in (("benign", benign), ("corrected", corrected),
                    ("detected", detected), ("sdc", sdc)):
        counts[name] = int((m & live).sum())

    # detection latency: first observable (corrected|detected) read per fault
    observable = (corrected | detected) & live
    lat = []
    outcomes = []
    for i, f in enumerate(faults):
        tr_hit = observable[i].any(axis=1)
        if tr_hit.any():
            lat.append(int(np.argmax(tr_hit)) - f.cycle)
        worst = 0
        for j, m in enumerate((benign, corrected, detected, sdc)):
            if (m[i] & live[i]).any():
                worst = j
        outcomes.append(_SEVERITY[worst])
    det_latency = float(np.mean(lat)) if lat else -1.0

    resilience = Resilience(
        cover=cover, n_faults=len(faults), n_reads=n_reads,
        benign=counts["benign"], corrected=counts["corrected"],
        detected=counts["detected"], sdc=counts["sdc"],
        det_latency=det_latency)
    return CampaignResult(spec.describe(), tuple(faults), tuple(outcomes),
                          resilience)


@lru_cache(maxsize=None)
def design_resilience(dp, depth: int, width_bits: int,
                      cfg: FaultConfig = FaultConfig()) -> Resilience:
    """Campaign record for one DSE design template at a given geometry.

    ``dp`` is a :class:`repro.core.dse.sweep.DesignPoint` (imported
    lazily to keep ``fault`` importable without the DSE layer).
    Memoised: a sweep shares one campaign across benches/unrolls since
    resilience is a property of the design, not the workload trace.
    """
    from repro.core.dse.sweep import _spec_for
    return run_campaign(_spec_for(dp, depth, width_bits), cfg).resilience


def attach_resilience(points: Sequence, designs: Sequence,
                      depth: int = 256, width_bits: int = 32,
                      cfg: FaultConfig = FaultConfig()) -> list:
    """Return ``points`` with ``res_*`` fields filled from per-design
    campaigns (``DSEPoint`` is matched to its design by label).

    Runs *after* sweep caching: cached timing points stay fault-agnostic
    and the campaign is evaluated once per distinct design label.
    """
    by_label = {d.label: d for d in designs}
    out = []
    for p in points:
        d = by_label.get(p.design)
        if d is None:
            out.append(p)
            continue
        rec = design_resilience(d, depth, width_bits, cfg)
        out.append(dataclasses.replace(p, **resilience_fields(rec)))
    return out
