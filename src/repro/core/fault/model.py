"""Architectural fault model: what can break inside an AMM's storage.

The taxonomy covers the three standard SRAM failure classes, lowered
onto the *flat replay state* of every design kind
(:mod:`repro.core.amm.replay`):

``bit_flip``   transient single-event upset — one bit of one word of one
               physical bank XORs at an injection cycle; heals when the
               word is overwritten.
``stuck_at``   hard single-bit fault — one bit is forced to 0/1 from the
               injection cycle onward; writes to it never take.
``bank_loss``  whole-structure failure — an entire physical leaf bank
               (one row of a 2-D state matrix, one word-interleaved
               bank of a banked array, or a whole 1-D structure) reads
               as zeros from the injection cycle onward.  This is the
               erasure case the paper's parity structures can cover.

A :class:`FaultSpec` is a *logical* description (design-independent
except for the target key); :func:`build_masks` lowers a batch of them
to the stacked :class:`repro.core.amm.replay.FaultMask` arrays the
vmapped fault replay consumes.  :func:`sample_faults` draws a seeded,
reproducible campaign population over the design's physical storage.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.amm import replay as rp
from repro.core.amm.spec import AMM_KINDS, AMMSpec

FAULT_KINDS: tuple[str, ...] = ("bit_flip", "stuck_at", "bank_loss")

__all__ = ["FAULT_KINDS", "FaultSpec", "state_geometry", "sample_faults",
           "build_masks"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injected physical fault.

    Attributes:
      kind: one of :data:`FAULT_KINDS`.
      key: flat-state array the fault lands in (``banks`` / ``s0`` /
        ``s1`` / ``ref`` / ``mem`` — data storage only; the LVT/remap
        steering tables are out of scope for this campaign model).
      bank: row index for 2-D state matrices (leaf bank / write bank);
        for 1-D arrays under ``bank_loss`` it selects the
        word-interleaved bank when the design is ``banked`` (the
        ``mem`` words with ``index % n_banks == bank``), else 0.
      offset: word offset inside the bank (ignored by ``bank_loss``).
      bit: bit position 0..width-1 (``bit_flip`` / ``stuck_at``).
      value: the forced bit value for ``stuck_at`` (0 or 1).
      cycle: injection cycle (reads from this cycle on see the fault).
    """

    kind: str
    key: str
    bank: int
    offset: int
    bit: int
    value: int
    cycle: int


def state_geometry(spec: AMMSpec) -> dict[str, tuple[int, ...]]:
    """Shapes of the *data* arrays of ``spec``'s flat replay state
    (steering tables excluded — they are logic, not SRAM content)."""
    k = spec.read_tree_levels
    if spec.kind == "h_ntx_rd":
        return {"banks": (3 ** k, spec.depth >> k)}
    if spec.kind == "b_ntx_wr":
        half = spec.depth // 2
        return {"s0": (half,), "s1": (half,), "ref": (half,)}
    if spec.kind == "hb_ntx":
        half = spec.depth // 2
        shape = (3 ** k, half >> k)
        return {"s0": shape, "s1": shape, "ref": shape}
    if spec.kind == "lvt":
        return {"banks": (spec.n_write, spec.depth)}
    if spec.kind == "remap":
        return {"banks": (spec.n_write + 1, spec.depth)}
    return {"mem": (spec.depth,)}


def sample_faults(spec: AMMSpec, n_faults: int, seed: int,
                  n_cycles: int,
                  kinds: tuple[str, ...] = FAULT_KINDS) -> list[FaultSpec]:
    """Draw a deterministic fault population over ``spec``'s storage.

    Faults are injected in the first half of the trace so every fault
    has post-injection reads to classify.  The same ``(spec, seed,
    n_faults, n_cycles, kinds)`` always yields the same population —
    campaigns are goldenable.
    """
    for k in kinds:
        if k not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {k!r}")
    geo = state_geometry(spec)
    keys = sorted(geo)
    rng = np.random.default_rng([seed, rp.spec_seed(spec, salt="fault")])
    faults = []
    for _ in range(n_faults):
        kind = kinds[rng.integers(len(kinds))]
        key = keys[rng.integers(len(keys))]
        shape = geo[key]
        if len(shape) == 2:
            bank = int(rng.integers(shape[0]))
            offset = int(rng.integers(shape[1]))
        else:
            nb = spec.n_banks if spec.kind == "banked" else 1
            bank = int(rng.integers(nb)) if kind == "bank_loss" else 0
            offset = int(rng.integers(shape[0]))
        faults.append(FaultSpec(
            kind=kind, key=key, bank=bank, offset=offset,
            bit=int(rng.integers(spec.width if spec.width <= 32 else 32)),
            value=int(rng.integers(2)),
            cycle=int(rng.integers(max(1, n_cycles // 2)))))
    return faults


def _lower_one(spec: AMMSpec, geo: dict, f: FaultSpec,
               xor_once: dict, stuck_mask: dict, stuck_val: dict) -> None:
    """Fill one fault's numpy masks in place."""
    if f.key not in geo:
        raise KeyError(f"{f.key!r} is not a data array of {spec.describe()}")
    shape = geo[f.key]
    bit = np.uint32(1) << np.uint32(f.bit % 32)
    if f.kind == "bit_flip":
        idx = (f.bank, f.offset) if len(shape) == 2 else (f.offset,)
        xor_once[f.key][idx] ^= bit
    elif f.kind == "stuck_at":
        idx = (f.bank, f.offset) if len(shape) == 2 else (f.offset,)
        stuck_mask[f.key][idx] |= bit
        if f.value:
            stuck_val[f.key][idx] |= bit
        else:
            stuck_val[f.key][idx] &= ~bit
    elif f.kind == "bank_loss":
        full = np.uint32(0xFFFFFFFF)
        if len(shape) == 2:
            stuck_mask[f.key][f.bank, :] = full
            stuck_val[f.key][f.bank, :] = 0
        elif spec.kind == "banked" and spec.n_banks > 1:
            # banked arrays interleave words across banks: losing bank b
            # kills every word with index % n_banks == b
            stuck_mask[f.key][f.bank::spec.n_banks] = full
            stuck_val[f.key][f.bank::spec.n_banks] = 0
        else:
            stuck_mask[f.key][:] = full
            stuck_val[f.key][:] = 0
    else:
        raise ValueError(f"unknown fault kind {f.kind!r}")


def build_masks(spec: AMMSpec, faults: list[FaultSpec]) -> rp.FaultMask:
    """Lower ``faults`` to a stacked :class:`FaultMask` (axis 0 = fault
    instance) ready for :func:`repro.core.amm.replay.replay_faulty_batched`.

    Non-data state keys (LVT/remap steering tables) get all-zero masks
    so the pytree matches the full flat state.
    """
    tmpl = rp.init_flat(spec)
    geo = state_geometry(spec)
    F = len(faults)
    per_key = {
        k: (np.zeros((F,) + tuple(v.shape), np.uint32),
            np.zeros((F,) + tuple(v.shape), np.uint32),
            np.zeros((F,) + tuple(v.shape), np.uint32))
        for k, v in tmpl.items()
    }
    for i, f in enumerate(faults):
        xor_once = {k: a[0][i] for k, a in per_key.items()}
        stuck_mask = {k: a[1][i] for k, a in per_key.items()}
        stuck_val = {k: a[2][i] for k, a in per_key.items()}
        _lower_one(spec, geo, f, xor_once, stuck_mask, stuck_val)
    as_state = lambda j: {k: jnp.asarray(a[j]) for k, a in per_key.items()}  # noqa: E731
    return rp.FaultMask(
        jnp.asarray([f.cycle for f in faults], jnp.int32),
        as_state(0), as_state(1), as_state(2))


def tile_states(spec: AMMSpec, values, n: int) -> rp.FlatState:
    """``n`` identical initial flat states (the batch axis for a
    campaign: every fault instance starts from the same contents)."""
    base = rp.init_flat(spec, values)
    return jax.tree.map(lambda v: jnp.broadcast_to(v, (n,) + v.shape), base)
