"""Resilience metrics: fold a classified fault campaign into one record.

Per-read classification (standard fault-injection taxonomy):

``benign``     the read returned the golden value and no redundant path
               disagreed — the fault was masked for this read.
``corrected``  the raw value a path produced was corrupt, but the
               design's own redundancy recovered the golden value
               (NTX parity-path XOR reconstruction, LVT replica
               majority vote).
``detected``   the redundancy *flagged* the corruption (paths/replicas
               disagree) but could not prove which value is right —
               a detected-unrecoverable error (DUE).
``sdc``        the read returned a wrong value with no disagreement
               anywhere — silent data corruption, the worst outcome.

The aggregate :class:`Resilience` record is what flows into
:class:`repro.core.dse.sweep.DSEPoint` (flattened to the ``res_*``
fields), the runner CSV and the ``fault_campaign`` benchmark rows.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Resilience", "RES_FIELDS", "resilience_fields"]

# cover mechanism per design kind: which redundancy (if any) the
# classifier may use.  b_ntx_wr's Ref unit is *bandwidth* redundancy
# (3 stored planes for 2 logical words, but s0 is unrecoverable without
# s0) and the remap/banked/ideal tables hold no second copy of live
# data, so none of them can detect or correct — measured honestly as
# cover="none".
COVER = {
    "h_ntx_rd": "parity",
    "hb_ntx": "parity",
    "lvt": "replica",
    "b_ntx_wr": "none",
    "remap": "none",
    "banked": "none",
    "ideal": "none",
    "multipump": "none",
}


@dataclasses.dataclass(frozen=True)
class Resilience:
    """Aggregate outcome of one seeded fault campaign on one design.

    ``benign``/``corrected``/``detected``/``sdc`` are read-event totals
    over all ``n_faults`` x ``n_reads`` observations;
    ``det_latency`` is the mean number of cycles from injection to the
    first read that detected (or corrected) the fault, over faults that
    were ever detected (-1.0 when none were).
    """

    cover: str
    n_faults: int
    n_reads: int           # read observations per fault (T x read ports)
    benign: int
    corrected: int
    detected: int
    sdc: int
    det_latency: float

    @property
    def affected(self) -> int:
        return self.corrected + self.detected + self.sdc

    @property
    def sdc_rate(self) -> float:
        return self.sdc / max(self.n_faults * self.n_reads, 1)

    @property
    def corrected_frac(self) -> float:
        return self.corrected / self.affected if self.affected else 0.0

    @property
    def detected_frac(self) -> float:
        return self.detected / self.affected if self.affected else 0.0

    def summary(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(affected=self.affected, sdc_rate=self.sdc_rate,
                 corrected_frac=self.corrected_frac,
                 detected_frac=self.detected_frac)
        return d


# DSEPoint carries the record flattened into these fields (sentinel
# -1.0 / "-" = no campaign attached to the point).
RES_FIELDS = ("res_cover", "res_sdc_rate", "res_corrected", "res_detected",
              "res_latency")


def resilience_fields(r: Resilience) -> dict:
    """The ``DSEPoint`` field values for one record."""
    return {
        "res_cover": r.cover,
        "res_sdc_rate": r.sdc_rate,
        "res_corrected": r.corrected_frac,
        "res_detected": r.detected_frac,
        "res_latency": r.det_latency,
    }
