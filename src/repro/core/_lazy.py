"""Deferred module imports for heavy optional dependencies.

The Pre-RTL DSE path (trace generation → scheduler → cost models) is
pure numpy; only the functional JAX implementations (``run_jax``, AMM
state machines, Pallas kernels) need jax.  Importing jax eagerly adds
~1s to every CLI invocation, so modules that need it only on some paths
bind ``jnp = lazy_import("jax.numpy")`` instead: the real import happens
on first attribute access.
"""
from __future__ import annotations

import importlib


class _LazyModule:
    __slots__ = ("_name", "_mod")

    def __init__(self, name: str) -> None:
        self._name = name
        self._mod = None

    def __getattr__(self, attr: str):
        mod = self._mod
        if mod is None:
            mod = self._mod = importlib.import_module(self._name)
        return getattr(mod, attr)

    def __repr__(self) -> str:
        state = "loaded" if self._mod is not None else "deferred"
        return f"<lazy module {self._name!r} ({state})>"


def lazy_import(name: str) -> _LazyModule:
    """Return a proxy that imports ``name`` on first attribute access."""
    return _LazyModule(name)
