"""Functional AMM models: the pure-JAX state machines behind ``make_amm``.

Split out of ``repro.core.amm.__init__`` so that scheduler/cost-model
consumers of :class:`AMMSpec` (pure numpy) do not import jax; this
module is loaded lazily on first ``make_amm``/``AMMSim`` access.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.amm import banked as _banked
from repro.core.amm import lvt as _lvt
from repro.core.amm import ntx as _ntx
from repro.core.amm import replay as _replay
from repro.core.amm.spec import AMMSpec

__all__ = ["AMMSim", "make_amm"]


@dataclasses.dataclass
class AMMSim:
    """Uniform wrapper over one design's pure-JAX state machine.

    Two simulation paths share the same state:

    * per-step — ``state, vals = sim.step(state, ra, wa, wv, wm)`` advances
      one cycle (interactive use, incremental drivers);
    * whole-trace — ``state, result = sim.replay(state, ra[T], wa[T], wv[T],
      wm[T])`` replays T cycles in one compiled ``lax.scan``
      (:mod:`repro.core.amm.replay`), returning direct- and parity-path
      reads for every cycle.  Both paths are pinned bit-exact.
    """

    spec: AMMSpec
    state: Any
    read: Callable
    read_parity: Callable
    step: Callable
    peek: Callable
    replay: Callable
    replay_faulty: Callable


def _make_replay(spec: AMMSpec) -> Callable:
    """Whole-trace replay operating on the step-path (pytree) state."""
    def run(state, read_addrs, write_addrs, write_vals, write_mask):
        flat = _replay.flatten_state(spec, state)
        flat, result = _replay.replay(spec, flat, read_addrs, write_addrs,
                                      write_vals, write_mask)
        return _replay.unflatten_state(spec, flat), result
    return run


def _make_replay_faulty(spec: AMMSpec) -> Callable:
    """Whole-trace fault-injected replay on the step-path (pytree) state.

    ``fault`` is a :class:`repro.core.amm.replay.FaultMask` (lowered
    from a :class:`repro.core.fault.FaultSpec`); zero masks reproduce
    the clean replay bit-exactly.
    """
    def run(state, fault, read_addrs, write_addrs, write_vals, write_mask):
        flat = _replay.flatten_state(spec, state)
        flat, result = _replay.replay_faulty(
            spec, flat, fault, read_addrs, write_addrs, write_vals,
            write_mask)
        return _replay.unflatten_state(spec, flat), result
    return run


def make_amm(spec: AMMSpec, values: jax.Array | None = None) -> AMMSim:
    if values is None:
        values = jnp.zeros((spec.depth,), jnp.uint32)
    values = jnp.asarray(values, jnp.uint32)
    if values.shape != (spec.depth,):
        raise ValueError(f"init values must be [{spec.depth}]")

    run = _make_replay(spec)
    run_faulty = _make_replay_faulty(spec)
    if spec.kind in ("h_ntx_rd", "b_ntx_wr", "hb_ntx"):
        state, fns = _ntx.make_ntx(spec, values)
        return AMMSim(spec, state, fns["read"], fns["read_parity"],
                      fns["step"], fns["peek"], run, run_faulty)
    if spec.kind == "lvt":
        state = _lvt.lvt_init(spec, values)
        return AMMSim(spec, state, _lvt.lvt_read, _lvt.lvt_read,
                      _lvt.lvt_step, _lvt.lvt_peek, run, run_faulty)
    if spec.kind == "remap":
        state = _lvt.remap_init(spec, values)
        return AMMSim(spec, state, _lvt.remap_read, _lvt.remap_read,
                      _lvt.remap_step, _lvt.remap_peek, run, run_faulty)
    if spec.kind in ("ideal", "banked", "multipump"):
        state = _banked.ideal_init(spec, values)
        return AMMSim(spec, state, _banked.ideal_read, _banked.ideal_read,
                      _banked.ideal_step, _banked.ideal_peek, run, run_faulty)
    raise ValueError(f"unknown design kind: {spec.kind}")
