"""Algorithmic Multi-Port Memories — functional models + design specs.

``make_amm(spec, values)`` returns an :class:`AMMSim` wrapping the design's
pure-JAX state machine with a uniform interface:

    sim = make_amm(spec, init_values)
    sim.state, vals = sim.step(sim.state, read_addrs, w_addrs, w_vals, w_mask)
    logical = sim.peek(sim.state)          # full decoded logical array
    v  = sim.read(sim.state, addr)         # direct path
    vp = sim.read_parity(sim.state, addr)  # XOR-reconstruction path

Whole traces replay in one compiled ``lax.scan`` (10-90x faster than the
per-step loop, bit-exact with it) and ``vmap``-batch across instances:

    state, result = sim.replay(sim.state, ra[T], wa[T], wv[T], wm[T])

See :mod:`repro.core.amm.replay` for the flat-state engine
(``init_flat`` / ``replay`` / ``replay_batched``).  All payloads are
uint32 words.

:class:`AMMSpec` and its structural formulas are pure numpy/stdlib; the
JAX-backed simulators live in ``repro.core.amm.sim`` and are imported
lazily on first ``make_amm``/``AMMSim`` access, so the scheduler / cost
/ DSE stack does not pay the jax import.
"""
from __future__ import annotations

from repro.core.amm.spec import AMM_KINDS, AMMSpec

__all__ = ["AMMSpec", "AMM_KINDS", "AMMSim", "make_amm"]

_LAZY = ("AMMSim", "make_amm")


def __getattr__(name: str):
    if name in _LAZY:
        from repro.core.amm import sim
        return getattr(sim, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list:
    return sorted(set(globals()) | set(_LAZY))
