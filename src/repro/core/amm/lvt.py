"""Table-based AMM designs (paper section II-B): LVT and remap table.

* LVT (live value table): one full-depth bank per write port (each
  conceptually replicated ``n_read`` times in hardware for read scaling —
  functionally the replicas are identical so we store one copy).  The
  LVT records, per address, which write-port bank holds the newest value.

* Remap table: ``n_write + 1`` full-depth banks.  Each incoming write is
  steered to a bank not used by another write this cycle (always possible
  with one spare bank); the remap table tracks the live bank per address.

These are the per-step models (one jit'd dispatch per cycle, ``lax.cond``
port chains).  ``repro.core.amm.replay`` carries mask-based flat twins of
both step functions that replay whole traces in one ``lax.scan`` — keep
any semantic change in sync (``tests/test_replay.py`` pins the two paths
bit-exact, and the remap bank-steering invariant is tested there too).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.amm.spec import AMMSpec

U32 = jnp.uint32
Tree = dict[str, jax.Array]


# ----------------------------------------------------------------------
# LVT
# ----------------------------------------------------------------------
def lvt_init(spec: AMMSpec, values: jax.Array) -> Tree:
    banks = jnp.tile(values.astype(U32)[None, :], (spec.n_write, 1))
    table = jnp.zeros((spec.depth,), jnp.int32)
    return {"banks": banks, "lvt": table}


def lvt_read(state: Tree, addr: jax.Array) -> jax.Array:
    return state["banks"][state["lvt"][addr], addr]


def lvt_write_port(state: Tree, port: int, addr: jax.Array,
                   value: jax.Array, mask: jax.Array) -> Tree:
    banks = jax.lax.cond(
        mask,
        lambda s: s["banks"].at[port, addr].set(value.astype(U32)),
        lambda s: s["banks"],
        state,
    )
    lvt = jax.lax.cond(
        mask,
        lambda s: s["lvt"].at[addr].set(jnp.int32(port)),
        lambda s: s["lvt"],
        state,
    )
    return {"banks": banks, "lvt": lvt}


@jax.jit
def lvt_step(state, read_addrs, write_addrs, write_vals, write_mask):
    vals = jax.vmap(lambda a: lvt_read(state, a))(read_addrs)
    n_write = state["banks"].shape[0]
    for p in range(n_write):  # ports resolve in order; later port wins
        state = lvt_write_port(state, p, write_addrs[p], write_vals[p],
                               write_mask[p])
    return state, vals


def lvt_peek(state: Tree) -> jax.Array:
    depth = state["lvt"].shape[0]
    idx = jnp.arange(depth)
    return state["banks"][state["lvt"][idx], idx]


# ----------------------------------------------------------------------
# Remap table
# ----------------------------------------------------------------------
def remap_init(spec: AMMSpec, values: jax.Array) -> Tree:
    n_banks = spec.n_write + 1
    banks = jnp.tile(values.astype(U32)[None, :], (n_banks, 1))
    table = jnp.zeros((spec.depth,), jnp.int32)
    return {"banks": banks, "map": table}


def remap_read(state: Tree, addr: jax.Array) -> jax.Array:
    return state["banks"][state["map"][addr], addr]


@jax.jit
def remap_step(state, read_addrs, write_addrs, write_vals, write_mask):
    vals = jax.vmap(lambda a: remap_read(state, a))(read_addrs)
    n_banks = state["banks"].shape[0]
    used = jnp.zeros((n_banks,), bool)
    banks, table = state["banks"], state["map"]
    for p in range(write_addrs.shape[0]):
        a, v, m = write_addrs[p], write_vals[p], write_mask[p]
        pref = table[a]
        # first bank, scanning from the preferred one, not used this cycle
        order = (pref + jnp.arange(n_banks)) % n_banks
        free = jnp.logical_not(used[order])
        d = jnp.argmax(free)  # first free slot in rotated order
        bank = order[d]
        banks = jax.lax.cond(
            m, lambda b: b.at[bank, a].set(v.astype(U32)), lambda b: b, banks
        )
        table = jax.lax.cond(
            m, lambda t: t.at[a].set(bank), lambda t: t, table
        )
        used = jax.lax.cond(
            m, lambda u: u.at[bank].set(True), lambda u: u, used
        )
    return {"banks": banks, "map": table}, vals


def remap_peek(state: Tree) -> jax.Array:
    depth = state["map"].shape[0]
    idx = jnp.arange(depth)
    return state["banks"][state["map"][idx], idx]
