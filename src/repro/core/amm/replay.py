"""Vectorized whole-trace AMM replay engine (scan + vmap).

The per-step functional models in ``ntx.py`` / ``lvt.py`` / ``banked.py``
are faithful to the paper's RMW flows but are driven one cycle at a time
from Python, with recursive ``3**k``-leaf pytree state (H-NTX) and
per-port ``lax.cond`` chains that XLA cannot fuse.  This module replays
an *entire* op trace in one compiled call:

* Every design's state is flattened to fixed-shape arrays.  The H-NTX
  ternary tree becomes a ``(3**k, leaf_depth)`` bank matrix plus three
  precomputed path-index tables (direct leaf, the ``2**k`` write-path
  leaves, the ``2**k`` parity-reconstruction leaves) — see
  :class:`HTables`.  LVT / remap / banked / ideal already have flat
  state; their ``lax.cond`` port chains become mask-based ``where``
  updates (an XOR write of a masked-to-zero delta is the conditional).

* :func:`replay` runs the whole trace — ``read_addrs [T, R]``,
  ``write_addrs/vals/mask [T, W]`` — through a single ``jax.lax.scan``
  and returns the final flat state plus per-cycle direct-path *and*
  parity-path read values (:class:`ReplayResult`).

* :func:`replay_batched` ``vmap``s the replay across design instances
  (axis 0 of the state) and, optionally, across independent traces —
  batched oracle verification of many seeds in one compiled call.

Flat state is interchangeable with the step-path pytree state via
:func:`flatten_state` / :func:`unflatten_state`; the leaf contents are
bit-identical on both paths (pinned by ``tests/test_replay.py``), so a
trace can be replayed, then continued step-by-step, or vice versa.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import lru_cache, partial, reduce
from typing import Any, Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.amm.spec import AMMSpec

U32 = jnp.uint32
FlatState = dict[str, jax.Array]

__all__ = [
    "ReplayResult", "HTables", "h_tables",
    "init_flat", "flatten_state", "unflatten_state", "peek_flat",
    "replay", "replay_batched", "make_trace",
    "FaultMask", "zero_fault", "replay_faulty", "replay_faulty_batched",
]


class ReplayResult(NamedTuple):
    """Per-cycle outputs of a whole-trace replay.

    ``read_vals``   [T, R] uint32 — direct-path reads (== ``step``'s vals).
    ``parity_vals`` [T, R] uint32 — XOR-reconstruction-path reads (what the
                    hardware returns under a bank conflict; equals
                    ``read_vals`` whenever the design is correct).
    ``write_banks`` [T, W] int32 or None — for ``remap`` only: the physical
                    bank each masked write was steered to this cycle
                    (-1 where the port was idle).  Feeds the
                    no-two-writes-share-a-bank invariant test.
    """

    read_vals: jax.Array
    parity_vals: jax.Array
    write_banks: jax.Array | None


# ======================================================================
# H-NTX path-index tables
# ======================================================================
@dataclasses.dataclass(frozen=True)
class HTables:
    """Precomputed leaf-path tables for one H-NTX-Rd tree geometry.

    A tree over ``depth`` words with ``levels=k`` has ``3**k`` leaves of
    ``leaf_depth = depth >> k`` words, indexed by base-3 digits
    (0 = b0, 1 = b1, 2 = ref), most-significant level first.  For every
    logical address ``a``:

    ``direct[a]``        the single leaf the direct read path lands in
                         (digits = the address's per-level hi/lo bits).
    ``write_paths[a]``   the ``2**k`` leaves an invariant-maintaining
                         write touches (each level: own child OR ref).
    ``parity_paths[a]``  the ``2**k`` leaves whose XOR reconstructs the
                         word (each level: *other* child OR ref).
    ``offset[a]``        the word offset inside every one of those leaves.
    """

    depth: int
    levels: int
    leaf_depth: int
    direct: np.ndarray        # [depth]        int32
    write_paths: np.ndarray   # [depth, 2**k]  int32
    parity_paths: np.ndarray  # [depth, 2**k]  int32
    offset: np.ndarray        # [depth]        int32


@lru_cache(maxsize=None)
def h_tables(depth: int, levels: int) -> HTables:
    k = levels
    addrs = np.arange(depth, dtype=np.int64)
    off = addrs.copy()
    bits = np.zeros((depth, k), np.int64)
    cur = depth
    for lvl in range(k):
        half = cur // 2
        hi = (off >= half).astype(np.int64)
        bits[:, lvl] = hi
        off -= hi * half
        cur = half
    w3 = 3 ** np.arange(k - 1, -1, -1, dtype=np.int64)  # MSB level first
    direct = bits @ w3
    n_paths = 1 << k
    write_paths = np.zeros((depth, n_paths), np.int64)
    parity_paths = np.zeros((depth, n_paths), np.int64)
    for j, choice in enumerate(itertools.product((0, 1), repeat=k)):
        c = np.asarray(choice, np.int64)  # 1 = take the ref branch
        write_paths[:, j] = np.where(c, 2, bits) @ w3
        parity_paths[:, j] = np.where(c, 2, 1 - bits) @ w3
    return HTables(depth, k, depth >> k, direct.astype(np.int32),
                   write_paths.astype(np.int32),
                   parity_paths.astype(np.int32), off.astype(np.int32))


def _h_direct(tb: HTables, banks: jax.Array, addr: jax.Array) -> jax.Array:
    """Direct-path read; ``addr`` may be scalar or [R]."""
    d = jnp.asarray(tb.direct)[addr]
    o = jnp.asarray(tb.offset)[addr]
    return banks[d, o]


def _h_parity(tb: HTables, banks: jax.Array, addr: jax.Array) -> jax.Array:
    """Reconstruction-path read: XOR of the 2**k parity-path leaves."""
    rows = jnp.asarray(tb.parity_paths)[addr]          # [..., 2**k]
    o = jnp.asarray(tb.offset)[addr]
    leaves = banks[rows, o[..., None]]                 # [..., 2**k]
    return reduce(jnp.bitwise_xor,
                  [leaves[..., j] for j in range(rows.shape[-1])])


def _h_xor_write(tb: HTables, banks: jax.Array, addr: jax.Array,
                 delta: jax.Array) -> jax.Array:
    """XOR ``delta`` into every write-path leaf of ``addr``.

    Because ``ref = b0 ^ b1`` holds at every level, a logical write of
    value ``v`` is exactly ``delta = v ^ old`` XORed into the write-path
    leaves — and a masked-off write is ``delta = 0`` (XOR identity), so
    no ``lax.cond`` is needed.  The rows of one path set are distinct,
    so the scatter is deterministic.
    """
    rows = jnp.asarray(tb.write_paths)[addr]           # [2**k]
    o = jnp.asarray(tb.offset)[addr]
    return banks.at[rows, o].set(banks[rows, o] ^ delta)


def _h_set_write(tb: HTables, banks: jax.Array, addr: jax.Array,
                 value: jax.Array, mask: jax.Array) -> jax.Array:
    delta = jnp.where(mask, value ^ _h_direct(tb, banks, addr), U32(0))
    return _h_xor_write(tb, banks, addr, delta)


# ======================================================================
# Flat per-cycle step functions (scan bodies)
# ======================================================================
def _split(addr: jax.Array, half: int) -> tuple[jax.Array, jax.Array]:
    hi = addr >= half
    return hi, addr - jnp.where(hi, half, 0)


def _h_step(tb: HTables, state: FlatState, ra, wa, wv, wm):
    banks = state["banks"]
    vals = _h_direct(tb, banks, ra)
    parity = _h_parity(tb, banks, ra)
    banks = _h_set_write(tb, banks, wa[0], wv[0].astype(U32), wm[0])
    return {"banks": banks}, (vals, parity, None)


def _b_step(half: int, state: FlatState, ra, wa, wv, wm):
    s0, s1, ref = state["s0"], state["s1"], state["ref"]
    hi, off = _split(ra, half)
    enc = jnp.where(hi, s1[off], s0[off])
    vals = enc ^ ref[off]
    # write port 0: plain encoded write into its half
    hi0, off0 = _split(wa[0], half)
    enc0 = wv[0].astype(U32) ^ ref[off0]
    s0 = s0.at[off0].set(jnp.where(wm[0] & ~hi0, enc0, s0[off0]))
    s1 = s1.at[off0].set(jnp.where(wm[0] & hi0, enc0, s1[off0]))
    # write port 1: plain if it lands in the other bank, else the paper's
    # Ref re-pointing RMW sequence
    hi1, off1 = _split(wa[1], half)
    conflict = wm[1] & wm[0] & (hi0 == hi1)
    plain = wm[1] & ~(wm[0] & (hi0 == hi1))
    enc1 = wv[1].astype(U32) ^ ref[off1]
    t = jnp.where(hi1, s0[off1], s1[off1]) ^ ref[off1]
    new_ref = wv[1].astype(U32) ^ jnp.where(hi1, s1[off1], s0[off1])
    m_s0 = (plain & ~hi1) | (conflict & hi1)
    v_s0 = jnp.where(conflict & hi1, new_ref ^ t, enc1)
    m_s1 = (plain & hi1) | (conflict & ~hi1)
    v_s1 = jnp.where(conflict & ~hi1, new_ref ^ t, enc1)
    s0 = s0.at[off1].set(jnp.where(m_s0, v_s0, s0[off1]))
    s1 = s1.at[off1].set(jnp.where(m_s1, v_s1, s1[off1]))
    ref = ref.at[off1].set(jnp.where(conflict, new_ref, ref[off1]))
    return {"s0": s0, "s1": s1, "ref": ref}, (vals, vals, None)


def _hb_step(tb: HTables, half: int, state: FlatState, ra, wa, wv, wm):
    s0, s1, ref = state["s0"], state["s1"], state["ref"]
    hi, off = _split(ra, half)
    vals = jnp.where(hi, _h_direct(tb, s1, off), _h_direct(tb, s0, off)) \
        ^ _h_direct(tb, ref, off)
    parity = jnp.where(hi, _h_parity(tb, s1, off), _h_parity(tb, s0, off)) \
        ^ _h_parity(tb, ref, off)
    # write port 0
    hi0, off0 = _split(wa[0], half)
    enc0 = wv[0].astype(U32) ^ _h_direct(tb, ref, off0)
    s0 = _h_set_write(tb, s0, off0, enc0, wm[0] & ~hi0)
    s1 = _h_set_write(tb, s1, off0, enc0, wm[0] & hi0)
    # write port 1
    hi1, off1 = _split(wa[1], half)
    conflict = wm[1] & wm[0] & (hi0 == hi1)
    plain = wm[1] & ~(wm[0] & (hi0 == hi1))
    enc1 = wv[1].astype(U32) ^ _h_direct(tb, ref, off1)
    t = jnp.where(hi1, _h_direct(tb, s0, off1), _h_direct(tb, s1, off1)) \
        ^ _h_direct(tb, ref, off1)
    new_ref = wv[1].astype(U32) ^ jnp.where(
        hi1, _h_direct(tb, s1, off1), _h_direct(tb, s0, off1))
    m_s0 = (plain & ~hi1) | (conflict & hi1)
    v_s0 = jnp.where(conflict & hi1, new_ref ^ t, enc1)
    m_s1 = (plain & hi1) | (conflict & ~hi1)
    v_s1 = jnp.where(conflict & ~hi1, new_ref ^ t, enc1)
    s0 = _h_set_write(tb, s0, off1, v_s0, m_s0)
    s1 = _h_set_write(tb, s1, off1, v_s1, m_s1)
    ref = _h_set_write(tb, ref, off1, new_ref, conflict)
    return {"s0": s0, "s1": s1, "ref": ref}, (vals, parity, None)


def _lvt_step(n_write: int, state: FlatState, ra, wa, wv, wm):
    banks, lvt = state["banks"], state["lvt"]
    vals = banks[lvt[ra], ra]
    for p in range(n_write):  # ports resolve in order; later port wins
        a = wa[p]
        banks = banks.at[p, a].set(
            jnp.where(wm[p], wv[p].astype(U32), banks[p, a]))
        lvt = lvt.at[a].set(jnp.where(wm[p], jnp.int32(p), lvt[a]))
    return {"banks": banks, "lvt": lvt}, (vals, vals, None)


def _remap_step(n_banks: int, state: FlatState, ra, wa, wv, wm):
    banks, table = state["banks"], state["map"]
    vals = banks[table[ra], ra]
    used = jnp.zeros((n_banks,), bool)
    chosen = []
    for p in range(wa.shape[0]):
        a, v, m = wa[p], wv[p], wm[p]
        # first bank, scanning from the preferred one, not used this cycle
        order = (table[a] + jnp.arange(n_banks)) % n_banks
        bank = order[jnp.argmax(jnp.logical_not(used[order]))]
        banks = banks.at[bank, a].set(jnp.where(m, v.astype(U32),
                                                banks[bank, a]))
        table = table.at[a].set(jnp.where(m, bank, table[a]))
        used = used.at[bank].set(used[bank] | m)
        chosen.append(jnp.where(m, bank, jnp.int32(-1)))
    return ({"banks": banks, "map": table},
            (vals, vals, jnp.stack(chosen)))


def _ideal_step(state: FlatState, ra, wa, wv, wm):
    mem = state["mem"]
    vals = mem[ra]
    for p in range(wa.shape[0]):  # later ports win, like LVT order
        mem = mem.at[wa[p]].set(
            jnp.where(wm[p], wv[p].astype(U32), mem[wa[p]]))
    return {"mem": mem}, (vals, vals, None)


def _step_fn(spec: AMMSpec) -> Callable:
    if spec.kind == "h_ntx_rd":
        return partial(_h_step, h_tables(spec.depth, spec.read_tree_levels))
    if spec.kind == "b_ntx_wr":
        return partial(_b_step, spec.depth // 2)
    if spec.kind == "hb_ntx":
        return partial(_hb_step,
                       h_tables(spec.depth // 2, spec.read_tree_levels),
                       spec.depth // 2)
    if spec.kind == "lvt":
        return partial(_lvt_step, spec.n_write)
    if spec.kind == "remap":
        return partial(_remap_step, spec.n_write + 1)
    if spec.kind in ("ideal", "banked", "multipump"):
        return _ideal_step
    raise ValueError(f"unknown design kind: {spec.kind}")


# ======================================================================
# Flat state construction / conversion
# ======================================================================
def _h_encode(values: np.ndarray | jax.Array, levels: int) -> jax.Array:
    """Canonical leaf matrix for logical content ``values``: recursively
    stack [encode(lo), encode(hi), encode(lo ^ hi)] (b0/b1/ref order)."""
    values = jnp.asarray(values, U32)
    if levels == 0:
        return values[None, :]
    half = values.shape[0] // 2
    lo, hi = values[:half], values[half:]
    return jnp.concatenate([_h_encode(lo, levels - 1),
                            _h_encode(hi, levels - 1),
                            _h_encode(lo ^ hi, levels - 1)])


def init_flat(spec: AMMSpec, values: jax.Array | None = None) -> FlatState:
    """Flat initial state holding logical content ``values`` (zeros if None)."""
    if values is None:
        values = jnp.zeros((spec.depth,), U32)
    values = jnp.asarray(values, U32)
    if values.shape != (spec.depth,):
        raise ValueError(f"init values must be [{spec.depth}]")
    k = spec.read_tree_levels
    if spec.kind == "h_ntx_rd":
        return {"banks": _h_encode(values, k)}
    if spec.kind == "b_ntx_wr":
        half = spec.depth // 2
        return {"s0": values[:half], "s1": values[half:],
                "ref": jnp.zeros((half,), U32)}
    if spec.kind == "hb_ntx":
        half = spec.depth // 2
        return {"s0": _h_encode(values[:half], k),
                "s1": _h_encode(values[half:], k),
                "ref": _h_encode(jnp.zeros((half,), U32), k)}
    if spec.kind == "lvt":
        return {"banks": jnp.tile(values[None, :], (spec.n_write, 1)),
                "lvt": jnp.zeros((spec.depth,), jnp.int32)}
    if spec.kind == "remap":
        return {"banks": jnp.tile(values[None, :], (spec.n_write + 1, 1)),
                "map": jnp.zeros((spec.depth,), jnp.int32)}
    if spec.kind in ("ideal", "banked", "multipump"):
        return {"mem": values}
    raise ValueError(f"unknown design kind: {spec.kind}")


def _h_flatten(node: dict) -> jax.Array:
    if "leaf" in node:
        return node["leaf"][None, :]
    return jnp.concatenate([_h_flatten(node["b0"]), _h_flatten(node["b1"]),
                            _h_flatten(node["ref"])])


def _h_unflatten(banks: jax.Array) -> dict:
    if banks.shape[0] == 1:
        return {"leaf": banks[0]}
    third = banks.shape[0] // 3
    return {"b0": _h_unflatten(banks[:third]),
            "b1": _h_unflatten(banks[third:2 * third]),
            "ref": _h_unflatten(banks[2 * third:])}


def flatten_state(spec: AMMSpec, state: Any) -> FlatState:
    """Step-path pytree state -> flat replay state (bit-identical leaves)."""
    if spec.kind == "h_ntx_rd":
        return {"banks": _h_flatten(state)}
    if spec.kind == "hb_ntx":
        return {"s0": _h_flatten(state["s0"]), "s1": _h_flatten(state["s1"]),
                "ref": _h_flatten(state["ref"])}
    return dict(state)  # b_ntx_wr / lvt / remap / ideal are already flat


def unflatten_state(spec: AMMSpec, flat: FlatState) -> Any:
    """Flat replay state -> step-path pytree state."""
    if spec.kind == "h_ntx_rd":
        return _h_unflatten(flat["banks"])
    if spec.kind == "hb_ntx":
        return {"s0": _h_unflatten(flat["s0"]),
                "s1": _h_unflatten(flat["s1"]),
                "ref": _h_unflatten(flat["ref"])}
    return dict(flat)


def peek_flat(spec: AMMSpec, flat: FlatState) -> jax.Array:
    """Decode the full logical array from a flat state."""
    if spec.kind == "h_ntx_rd":
        tb = h_tables(spec.depth, spec.read_tree_levels)
        idx = jnp.arange(spec.depth)
        return _h_direct(tb, flat["banks"], idx)
    if spec.kind == "b_ntx_wr":
        return jnp.concatenate([flat["s0"] ^ flat["ref"],
                                flat["s1"] ^ flat["ref"]])
    if spec.kind == "hb_ntx":
        tb = h_tables(spec.depth // 2, spec.read_tree_levels)
        idx = jnp.arange(spec.depth // 2)
        ref = _h_direct(tb, flat["ref"], idx)
        return jnp.concatenate([_h_direct(tb, flat["s0"], idx) ^ ref,
                                _h_direct(tb, flat["s1"], idx) ^ ref])
    if spec.kind == "lvt":
        idx = jnp.arange(flat["lvt"].shape[0])
        return flat["banks"][flat["lvt"][idx], idx]
    if spec.kind == "remap":
        idx = jnp.arange(flat["map"].shape[0])
        return flat["banks"][flat["map"][idx], idx]
    return flat["mem"]


# ======================================================================
# Whole-trace replay
# ======================================================================
def _replay_impl(spec: AMMSpec, state: FlatState, read_addrs, write_addrs,
                 write_vals, write_mask):
    step = _step_fn(spec)

    def body(st, xs):
        ra, wa, wv, wm = xs
        return step(st, ra, wa, wv, wm)

    state, (vals, parity, aux) = jax.lax.scan(
        body, state, (read_addrs, write_addrs, write_vals, write_mask))
    return state, ReplayResult(vals, parity, aux)


@lru_cache(maxsize=None)
def _replay_jit(spec: AMMSpec) -> Callable:
    return jax.jit(partial(_replay_impl, spec))


@lru_cache(maxsize=None)
def _replay_vmap(spec: AMMSpec, share_trace: bool) -> Callable:
    trace_ax = None if share_trace else 0
    return jax.jit(jax.vmap(partial(_replay_impl, spec),
                            in_axes=(0,) + (trace_ax,) * 4))


def _as_ops(read_addrs, write_addrs, write_vals, write_mask):
    return (jnp.asarray(read_addrs, jnp.int32),
            jnp.asarray(write_addrs, jnp.int32),
            jnp.asarray(write_vals, U32),
            jnp.asarray(write_mask, bool))


def replay(spec: AMMSpec, state: FlatState, read_addrs, write_addrs,
           write_vals, write_mask) -> tuple[FlatState, ReplayResult]:
    """Replay a whole op trace through one compiled ``lax.scan``.

    Args:
      state: flat state from :func:`init_flat` / :func:`flatten_state`.
      read_addrs:  [T, n_read]  int32.
      write_addrs: [T, n_write] int32.
      write_vals:  [T, n_write] uint32.
      write_mask:  [T, n_write] bool.

    Returns ``(final_state, ReplayResult)``; reads are served before
    writes within each cycle, exactly like the per-step path.
    """
    return _replay_jit(spec)(
        state, *_as_ops(read_addrs, write_addrs, write_vals, write_mask))


def replay_batched(spec: AMMSpec, states: FlatState, read_addrs, write_addrs,
                   write_vals, write_mask, share_trace: bool = False
                   ) -> tuple[FlatState, ReplayResult]:
    """``vmap``-batched :func:`replay` across design instances.

    ``states`` carries a leading batch axis on every array (stack
    :func:`init_flat` results with ``jax.tree.map``).  With
    ``share_trace=False`` the four trace arrays are [B, T, ...] — one
    independent trace per instance (e.g. per random seed); with
    ``share_trace=True`` a single [T, ...] trace is broadcast to all
    instances (e.g. one request stream against many design points).
    """
    return _replay_vmap(spec, share_trace)(
        states, *_as_ops(read_addrs, write_addrs, write_vals, write_mask))


# ======================================================================
# Fault injection (repro.core.fault drives this; see that package for
# the sampling / classification layer)
# ======================================================================
class FaultMask(NamedTuple):
    """One physical fault, lowered to per-state-array masks.

    Applied inside the replay ``lax.scan`` body at the start of every
    cycle, *before* the cycle's reads — so reads from cycle ``cycle``
    onward observe the corrupted storage, and in-cycle writes behave
    like real hardware (a later write overwrites a transient flip; a
    stuck bit re-asserts itself every cycle, so writes never take).

    ``cycle``      int32 scalar — the injection cycle.
    ``xor_once``   per-key array XORed into the state at ``cycle`` only
                   (transient single-event upset; heals on overwrite).
    ``stuck_mask`` per-key bit mask forced from ``cycle`` onward.
    ``stuck_val``  the value those bits are forced to (stuck-at-0/1 and
                   whole-bank loss = a full-word mask stuck to zero).

    Every key of the design's flat state must be present (zeros =
    untouched); :func:`zero_fault` builds the no-op template.  All
    leading axes may carry a batch dimension for
    :func:`replay_faulty_batched`.
    """

    cycle: jax.Array
    xor_once: FlatState
    stuck_mask: FlatState
    stuck_val: FlatState


def zero_fault(spec: AMMSpec) -> FaultMask:
    """The identity fault (all masks zero) for ``spec``'s flat state."""
    tmpl = init_flat(spec)

    def zeros() -> FlatState:
        return {k: jnp.zeros_like(v) for k, v in tmpl.items()}

    return FaultMask(jnp.int32(0), zeros(), zeros(), zeros())


def _apply_fault(state: FlatState, fm: FaultMask, cycle) -> FlatState:
    armed = cycle >= fm.cycle
    once = cycle == fm.cycle
    out = {}
    for k, v in state.items():
        xo = fm.xor_once[k].astype(v.dtype)
        sm = fm.stuck_mask[k].astype(v.dtype)
        sv = fm.stuck_val[k].astype(v.dtype)
        v = jnp.where(once, v ^ xo, v)
        out[k] = jnp.where(armed, (v & ~sm) | (sv & sm), v)
    return out


def _replay_fault_impl(spec: AMMSpec, state: FlatState, fm: FaultMask,
                       read_addrs, write_addrs, write_vals, write_mask):
    step = _step_fn(spec)

    def body(carry, xs):
        st, cyc = carry
        st = _apply_fault(st, fm, cyc)
        ra, wa, wv, wm = xs
        st, out = step(st, ra, wa, wv, wm)
        return (st, cyc + 1), out

    (state, _), (vals, parity, aux) = jax.lax.scan(
        body, (state, jnp.int32(0)),
        (read_addrs, write_addrs, write_vals, write_mask))
    return state, ReplayResult(vals, parity, aux)


@lru_cache(maxsize=None)
def _replay_fault_jit(spec: AMMSpec) -> Callable:
    return jax.jit(partial(_replay_fault_impl, spec))


@lru_cache(maxsize=None)
def _replay_fault_vmap(spec: AMMSpec, share_trace: bool) -> Callable:
    trace_ax = None if share_trace else 0
    return jax.jit(jax.vmap(partial(_replay_fault_impl, spec),
                            in_axes=(0, 0) + (trace_ax,) * 4))


def replay_faulty(spec: AMMSpec, state: FlatState, fault: FaultMask,
                  read_addrs, write_addrs, write_vals, write_mask
                  ) -> tuple[FlatState, ReplayResult]:
    """:func:`replay` with ``fault`` injected inside the scan body.

    With :func:`zero_fault` masks the result is bit-identical to the
    clean replay (pinned by ``tests/test_fault.py``); the fault
    subsystem in :mod:`repro.core.fault` compares the two to classify
    each read as benign / corrected / detected / silent corruption.
    """
    return _replay_fault_jit(spec)(
        state, fault,
        *_as_ops(read_addrs, write_addrs, write_vals, write_mask))


def replay_faulty_batched(spec: AMMSpec, states: FlatState,
                          faults: FaultMask, read_addrs, write_addrs,
                          write_vals, write_mask, share_trace: bool = True
                          ) -> tuple[FlatState, ReplayResult]:
    """``vmap``-batched :func:`replay_faulty`: axis 0 of ``states`` and
    every ``faults`` array is the fault-instance axis, so a whole
    campaign (F independent faults against one design + op stream)
    runs in a single compiled call.  ``share_trace=True`` (the
    campaign default) broadcasts one [T, ...] trace to all instances.
    """
    return _replay_fault_vmap(spec, share_trace)(
        states, faults,
        *_as_ops(read_addrs, write_addrs, write_vals, write_mask))


def make_trace(spec: AMMSpec, n_cycles: int, seed: int = 0,
               write_prob: float = 0.5,
               rng: np.random.Generator | None = None):
    """Random op trace in replay layout (numpy; handy for tests/benchmarks).

    Pass ``rng`` to draw from an existing generator instead of ``seed``.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    ra = rng.integers(0, spec.depth, (n_cycles, spec.n_read)).astype(np.int32)
    wa = rng.integers(0, spec.depth, (n_cycles, spec.n_write)).astype(np.int32)
    wv = rng.integers(0, 2**32, (n_cycles, spec.n_write), dtype=np.uint32)
    wm = rng.random((n_cycles, spec.n_write)) < write_prob
    return ra, wa, wv, wm


def spec_seed(spec: AMMSpec, salt: str = "") -> int:
    """Stable per-spec RNG seed (unlike ``hash()``, identical across runs)."""
    import zlib
    return zlib.crc32((salt + spec.describe()).encode())
