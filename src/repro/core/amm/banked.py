"""Conventional baselines: ideal multiport RAM, array-partitioned banking,
and multi-pumping (paper section I).

Banking has *identical functional semantics* to an ideal RAM — what
differs is timing: concurrent accesses that map to the same bank
serialize.  ``conflict_cycles`` is the timing model the scheduler uses.
Multi-pumping doubles the per-cycle port count but halves the maximum
external frequency (``AMMSpec.frequency_factor``).

``ideal_step`` has a flat whole-trace twin in ``repro.core.amm.replay``
(one ``lax.scan`` over the op trace, pinned bit-exact by
``tests/test_replay.py``); keep any semantic change in sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.amm.spec import AMMSpec

U32 = jnp.uint32
Tree = dict[str, jax.Array]


def ideal_init(spec: AMMSpec, values: jax.Array) -> Tree:
    return {"mem": values.astype(U32)}


def ideal_read(state: Tree, addr: jax.Array) -> jax.Array:
    return state["mem"][addr]


@jax.jit
def ideal_step(state, read_addrs, write_addrs, write_vals, write_mask):
    vals = state["mem"][read_addrs]
    mem = state["mem"]
    for p in range(write_addrs.shape[0]):  # later ports win, like LVT order
        mem = jnp.where(
            write_mask[p],
            mem.at[write_addrs[p]].set(write_vals[p].astype(U32)),
            mem,
        )
    return {"mem": mem}, vals


def ideal_peek(state: Tree) -> jax.Array:
    return state["mem"]


# ----------------------------------------------------------------------
# Banking timing model
# ----------------------------------------------------------------------
def bank_of(addrs: jax.Array, n_banks: int) -> jax.Array:
    """Cyclic interleave: word address modulo bank count (paper IV-A:
    'arrays which have single-stride access can be partitioned cyclically')."""
    return jnp.mod(addrs, n_banks)


def conflict_cycles(
    addrs: jax.Array,
    mask: jax.Array,
    n_banks: int,
    ports_per_bank: int = 1,
) -> jax.Array:
    """Cycles needed to issue one *group* of parallel accesses.

    addrs: [W] word addresses wanting to issue in the same cycle.
    mask:  [W] validity.
    Returns max over banks of ceil(hits / ports_per_bank); 0 if empty.
    """
    banks = bank_of(addrs, n_banks)
    hits = jnp.sum(
        jnp.where(mask[:, None], jax.nn.one_hot(banks, n_banks, dtype=jnp.int32), 0),
        axis=0,
    )
    worst = jnp.max(hits)
    return jnp.where(worst > 0, -(-worst // ports_per_bank), 0)


def conflict_cycles_grouped(
    addr_groups: jax.Array,
    mask_groups: jax.Array,
    n_banks: int,
    ports_per_bank: int = 1,
) -> jax.Array:
    """Vectorized over [G, W] groups -> [G] cycles per group."""
    return jax.vmap(
        lambda a, m: conflict_cycles(a, m, n_banks, ports_per_bank)
    )(addr_groups, mask_groups)
