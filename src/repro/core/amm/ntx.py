"""Non-table XOR-based AMM designs (paper section II-A).

Three functional models, each a pure-JAX state machine over ``uint32``
word payloads (wider/narrower logical words are packed by the caller):

* ``h_ntx_rd``  — H-NTX-Rd: hierarchical read scaling.  Bank0 stores the
  low half, Bank1 the high half, Ref stores ``Bank0 ^ Bank1``.  A second
  read hitting the same bank is served as ``other_bank[o] ^ ref[o]``.
  Scaling to ``2**k`` read ports recurses: every bank (including Ref) is
  itself an H-NTX-Rd structure -> a ternary tree with ``3**k`` leaves.

* ``b_ntx_wr``  — B-NTX-Wr: banks store *encoded* data ``D ^ Ref``.
  Two conflicting writes are absorbed by re-pointing ``Ref`` (the paper's
  RMW sequence: ``T = S1[j]^Ref[j]; Ref[j] = W1 ^ S0[j]; S1[j] = Ref[j]^T``).

* ``hb_ntx``    — HB-NTX-RdWr (paper Fig 2): B-NTX-Wr at the top level
  where S0 / S1 / Ref are each H-NTX-Rd trees, yielding nR x 2W.

The models expose ``init / read / read_parity / write* / step / peek``.
``read`` decodes through the direct path; ``read_parity`` decodes through
the XOR-reconstruction path that hardware uses under a bank conflict.
The central correctness property (tested with hypothesis) is that after
*any* op sequence both paths agree with a plain-RAM oracle.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.amm.spec import AMMSpec

Tree = dict[str, Any]
U32 = jnp.uint32


# ======================================================================
# H-NTX-Rd : ternary XOR parity tree
# ======================================================================
def h_init(values: jax.Array, levels: int) -> Tree:
    values = values.astype(U32)
    if levels == 0:
        return {"leaf": values}
    half = values.shape[0] // 2
    lo, hi = values[:half], values[half:]
    return {
        "b0": h_init(lo, levels - 1),
        "b1": h_init(hi, levels - 1),
        "ref": h_init(lo ^ hi, levels - 1),
    }


def _h_depth(node: Tree) -> int:
    if "leaf" in node:
        return node["leaf"].shape[0]
    return 2 * _h_depth(node["b0"])


def h_read(node: Tree, addr: jax.Array) -> jax.Array:
    """Direct-path read of logical address ``addr``."""
    if "leaf" in node:
        return node["leaf"][addr]
    half = _h_depth(node["b0"])
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    return jnp.where(hi, h_read(node["b1"], off), h_read(node["b0"], off))


def h_read_parity(node: Tree, addr: jax.Array) -> jax.Array:
    """Conflict-path read: reconstruct from the *other* bank and Ref,
    recursing through the parity path at every level of the tree."""
    if "leaf" in node:
        return node["leaf"][addr]
    half = _h_depth(node["b0"])
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    rec0 = h_read_parity(node["b1"], off) ^ h_read_parity(node["ref"], off)
    rec1 = h_read_parity(node["b0"], off) ^ h_read_parity(node["ref"], off)
    return jnp.where(hi, rec1, rec0)


def h_write(node: Tree, addr: jax.Array, value: jax.Array) -> Tree:
    """Single-port write maintaining the parity invariant at every level."""
    if "leaf" in node:
        return {"leaf": node["leaf"].at[addr].set(value.astype(U32))}
    half = _h_depth(node["b0"])
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)

    def wr_hi(nd: Tree) -> Tree:
        other = h_read(nd["b0"], off)
        return {
            "b0": nd["b0"],
            "b1": h_write(nd["b1"], off, value),
            "ref": h_write(nd["ref"], off, value ^ other),
        }

    def wr_lo(nd: Tree) -> Tree:
        other = h_read(nd["b1"], off)
        return {
            "b0": h_write(nd["b0"], off, value),
            "b1": nd["b1"],
            "ref": h_write(nd["ref"], off, value ^ other),
        }

    return jax.lax.cond(hi, wr_hi, wr_lo, node)


def h_peek(node: Tree) -> jax.Array:
    if "leaf" in node:
        return node["leaf"]
    return jnp.concatenate([h_peek(node["b0"]), h_peek(node["b1"])])


# ======================================================================
# B-NTX-Wr : encoded banks + reference, 2 conflict-free writes
# ======================================================================
def b_init(values: jax.Array) -> Tree:
    values = values.astype(U32)
    half = values.shape[0] // 2
    ref = jnp.zeros((half,), U32)
    # Banks store encoded data D ^ Ref; with Ref == 0 that's D itself.
    return {"s0": values[:half], "s1": values[half:], "ref": ref}


def _b_half(state: Tree) -> int:
    return state["ref"].shape[0]


def b_read(state: Tree, addr: jax.Array) -> jax.Array:
    half = _b_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    enc = jnp.where(hi, state["s1"][off], state["s0"][off])
    return enc ^ state["ref"][off]


def b_write1(state: Tree, addr: jax.Array, value: jax.Array) -> Tree:
    """Non-conflict single write: S_h[o] = W ^ Ref[o]."""
    half = _b_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    enc = value.astype(U32) ^ state["ref"][off]

    def hi_fn(st: Tree) -> Tree:
        return {**st, "s1": st["s1"].at[off].set(enc)}

    def lo_fn(st: Tree) -> Tree:
        return {**st, "s0": st["s0"].at[off].set(enc)}

    return jax.lax.cond(hi, hi_fn, lo_fn, state)


def b_write_conflict(state: Tree, addr: jax.Array, value: jax.Array) -> Tree:
    """Second conflicting write into the same bank as the first one.

    Paper sequence (both writes landed in bank h):
        T      = S_other[j] ^ Ref[j]        # save the other half's value
        Ref[j] = W1 ^ S_h[j]                # re-point Ref so S_h decodes to W1
        S_other[j] = Ref[j] ^ T             # re-encode the other half
    """
    half = _b_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    value = value.astype(U32)

    def hi_fn(st: Tree) -> Tree:  # conflict in bank 1 -> other is s0
        t = st["s0"][off] ^ st["ref"][off]
        new_ref = value ^ st["s1"][off]
        return {
            "s0": st["s0"].at[off].set(new_ref ^ t),
            "s1": st["s1"],
            "ref": st["ref"].at[off].set(new_ref),
        }

    def lo_fn(st: Tree) -> Tree:  # conflict in bank 0 -> other is s1
        t = st["s1"][off] ^ st["ref"][off]
        new_ref = value ^ st["s0"][off]
        return {
            "s0": st["s0"],
            "s1": st["s1"].at[off].set(new_ref ^ t),
            "ref": st["ref"].at[off].set(new_ref),
        }

    return jax.lax.cond(hi, hi_fn, lo_fn, state)


def b_write2(
    state: Tree,
    a0: jax.Array, v0: jax.Array, m0: jax.Array,
    a1: jax.Array, v1: jax.Array, m1: jax.Array,
) -> Tree:
    """Dual-port write with the paper's conflict handling."""
    half = _b_half(state)
    state = jax.lax.cond(m0, lambda s: b_write1(s, a0, v0), lambda s: s, state)
    same_bank = jnp.logical_and(m0, (a0 >= half) == (a1 >= half))

    def do_w1(st: Tree) -> Tree:
        return jax.lax.cond(
            same_bank,
            lambda s: b_write_conflict(s, a1, v1),
            lambda s: b_write1(s, a1, v1),
            st,
        )

    return jax.lax.cond(m1, do_w1, lambda s: s, state)


def b_peek(state: Tree) -> jax.Array:
    return jnp.concatenate(
        [state["s0"] ^ state["ref"], state["s1"] ^ state["ref"]]
    )


# ======================================================================
# HB-NTX-RdWr : B at the top, every bank an H read tree (paper Fig 2)
# ======================================================================
def hb_init(values: jax.Array, read_levels: int) -> Tree:
    values = values.astype(U32)
    half = values.shape[0] // 2
    zeros = jnp.zeros((half,), U32)
    return {
        "s0": h_init(values[:half], read_levels),
        "s1": h_init(values[half:], read_levels),
        "ref": h_init(zeros, read_levels),
    }


def _hb_half(state: Tree) -> int:
    return _h_depth(state["ref"])


def hb_read(state: Tree, addr: jax.Array) -> jax.Array:
    half = _hb_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    enc = jnp.where(hi, h_read(state["s1"], off), h_read(state["s0"], off))
    return enc ^ h_read(state["ref"], off)


def hb_read_parity(state: Tree, addr: jax.Array) -> jax.Array:
    half = _hb_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    enc = jnp.where(
        hi, h_read_parity(state["s1"], off), h_read_parity(state["s0"], off)
    )
    return enc ^ h_read_parity(state["ref"], off)


def hb_write1(state: Tree, addr: jax.Array, value: jax.Array) -> Tree:
    half = _hb_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    enc = value.astype(U32) ^ h_read(state["ref"], off)

    def hi_fn(st: Tree) -> Tree:
        return {**st, "s1": h_write(st["s1"], off, enc)}

    def lo_fn(st: Tree) -> Tree:
        return {**st, "s0": h_write(st["s0"], off, enc)}

    return jax.lax.cond(hi, hi_fn, lo_fn, state)


def hb_write_conflict(state: Tree, addr: jax.Array, value: jax.Array) -> Tree:
    half = _hb_half(state)
    hi = addr >= half
    off = addr - jnp.where(hi, half, 0)
    value = value.astype(U32)

    def hi_fn(st: Tree) -> Tree:
        t = h_read(st["s0"], off) ^ h_read(st["ref"], off)
        new_ref = value ^ h_read(st["s1"], off)
        return {
            "s0": h_write(st["s0"], off, new_ref ^ t),
            "s1": st["s1"],
            "ref": h_write(st["ref"], off, new_ref),
        }

    def lo_fn(st: Tree) -> Tree:
        t = h_read(st["s1"], off) ^ h_read(st["ref"], off)
        new_ref = value ^ h_read(st["s0"], off)
        return {
            "s0": st["s0"],
            "s1": h_write(st["s1"], off, new_ref ^ t),
            "ref": h_write(st["ref"], off, new_ref),
        }

    return jax.lax.cond(hi, hi_fn, lo_fn, state)


def hb_write2(
    state: Tree,
    a0: jax.Array, v0: jax.Array, m0: jax.Array,
    a1: jax.Array, v1: jax.Array, m1: jax.Array,
) -> Tree:
    half = _hb_half(state)
    state = jax.lax.cond(m0, lambda s: hb_write1(s, a0, v0), lambda s: s, state)
    same_bank = jnp.logical_and(m0, (a0 >= half) == (a1 >= half))

    def do_w1(st: Tree) -> Tree:
        return jax.lax.cond(
            same_bank,
            lambda s: hb_write_conflict(s, a1, v1),
            lambda s: hb_write1(s, a1, v1),
            st,
        )

    return jax.lax.cond(m1, do_w1, lambda s: s, state)


def hb_peek(state: Tree) -> jax.Array:
    ref = h_peek(state["ref"])
    return jnp.concatenate(
        [h_peek(state["s0"]) ^ ref, h_peek(state["s1"]) ^ ref]
    )


# ======================================================================
# Uniform step() wrappers (read-before-write semantics)
# ======================================================================
def _gather_reads(read_fn, state, read_addrs):
    return jax.vmap(lambda a: read_fn(state, a))(read_addrs)


@jax.jit
def h_step(state, read_addrs, write_addrs, write_vals, write_mask):
    if write_addrs.shape[0] != 1:
        raise ValueError(
            f"h_ntx_rd has a single write port, got {write_addrs.shape[0]}"
        )
    vals = _gather_reads(lambda s, a: h_read(s, a), state, read_addrs)
    state = jax.lax.cond(
        write_mask[0],
        lambda s: h_write(s, write_addrs[0], write_vals[0]),
        lambda s: s,
        state,
    )
    return state, vals


@jax.jit
def b_step(state, read_addrs, write_addrs, write_vals, write_mask):
    vals = _gather_reads(b_read, state, read_addrs)
    state = b_write2(
        state,
        write_addrs[0], write_vals[0], write_mask[0],
        write_addrs[1], write_vals[1], write_mask[1],
    )
    return state, vals


@jax.jit
def hb_step(state, read_addrs, write_addrs, write_vals, write_mask):
    vals = _gather_reads(hb_read, state, read_addrs)
    state = hb_write2(
        state,
        write_addrs[0], write_vals[0], write_mask[0],
        write_addrs[1], write_vals[1], write_mask[1],
    )
    return state, vals


def make_ntx(spec: AMMSpec, values: jax.Array):
    """Factory: returns (state, fns dict) for the requested NTX design."""
    if spec.kind == "h_ntx_rd":
        if spec.n_write != 1:
            raise ValueError("h_ntx_rd supports a single write port")
        state = h_init(values, spec.read_tree_levels)
        return state, {
            "read": h_read,
            "read_parity": h_read_parity,
            "step": h_step,
            "peek": h_peek,
        }
    if spec.kind == "b_ntx_wr":
        state = b_init(values)
        return state, {
            "read": b_read,
            "read_parity": b_read,  # B has no read-scaling parity path
            "step": b_step,
            "peek": b_peek,
        }
    if spec.kind == "hb_ntx":
        state = hb_init(values, spec.read_tree_levels)
        return state, {
            "read": hb_read,
            "read_parity": hb_read_parity,
            "step": hb_step,
            "peek": hb_peek,
        }
    raise ValueError(f"not an NTX design: {spec.kind}")
