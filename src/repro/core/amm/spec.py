"""AMM design specifications and structural formulas.

An :class:`AMMSpec` names one point in the paper's memory design space:
a design kind (ideal / banked / multipump / NTX-family / LVT / remap),
a read/write port configuration, a logical depth and word width, and a
banking factor.  The structural formulas here (leaf-bank counts, storage
overhead, table bits) are consumed by the cost models in
``repro.core.cost`` and by the port-constrained scheduler in
``repro.core.sim``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

DesignKind = Literal[
    "ideal",      # true multiport RAM (oracle; circuit-level baseline)
    "banked",     # array-partitioned banking (conflicts serialize)
    "multipump",  # internally double-clocked 2-port macro
    "h_ntx_rd",   # non-table XOR, hierarchical read scaling  (paper II-A)
    "b_ntx_wr",   # non-table XOR, write pairing              (paper II-A)
    "hb_ntx",     # HB-NTX-RdWr combined flow                 (paper II-A, Fig 2)
    "lvt",        # live-value-table                          (paper II-B)
    "remap",      # table-based remap                         (paper II-B)
]

AMM_KINDS: tuple[str, ...] = ("h_ntx_rd", "b_ntx_wr", "hb_ntx", "lvt", "remap")


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


@dataclasses.dataclass(frozen=True)
class AMMSpec:
    """One memory design point.

    Attributes:
      kind: design family.
      n_read: read ports exposed to the datapath.
      n_write: write ports exposed to the datapath.
      depth: logical number of words.
      width: word width in bits.
      n_banks: banking-structure factor.  For kind=="banked" it is the
        array-partitioning factor.  For AMM kinds the *leaf* structure is
        implied by the port config and ``n_banks`` is the additional leaf
        sub-banking factor (paper Sec. III: depth x port config x
        banking): every leaf macro is split into ``n_banks``
        word-interleaved sub-banks — smaller/faster macros in the cost
        model, finer conflict granularity in the NTX arbitration.
    """

    kind: DesignKind
    n_read: int = 1
    n_write: int = 1
    depth: int = 1024
    width: int = 32
    n_banks: int = 1

    def __post_init__(self) -> None:
        if self.depth <= 0 or self.width <= 0:
            raise ValueError(f"bad geometry {self.depth}x{self.width}")
        if self.n_read < 1 or self.n_write < 1:
            raise ValueError("need at least 1R1W")
        if self.kind == "h_ntx_rd":
            if not _is_pow2(self.n_read):
                raise ValueError("h_ntx_rd read ports must be a power of two")
            if self.n_write != 1:
                raise ValueError("h_ntx_rd supports a single write port")
            if self.depth % self.n_read != 0:
                raise ValueError("depth must divide by read ports")
        if self.kind == "b_ntx_wr":
            if self.n_write != 2:
                raise ValueError("b_ntx_wr provides exactly 2 write ports")
            if self.depth % 2 != 0:
                raise ValueError("depth must be even")
        if self.kind == "hb_ntx":
            if not _is_pow2(self.n_read):
                raise ValueError("hb_ntx read ports must be a power of two")
            if self.n_write != 2:
                raise ValueError("hb_ntx provides exactly 2 write ports (paper flow)")
            if self.depth % (2 * max(self.n_read, 1)) != 0:
                raise ValueError("depth must divide by 2*n_read")
        if self.kind == "banked" and self.n_banks < 1:
            raise ValueError("banked needs >=1 bank")
        if self.kind in AMM_KINDS:
            if not _is_pow2(self.n_banks):
                raise ValueError(
                    "AMM leaf sub-banking must be a power of two")
            if self.n_banks > self.leaf_banks()[1]:
                raise ValueError("leaf sub-banking exceeds leaf depth")

    # ------------------------------------------------------------------
    # Structural formulas (feed the cost model).
    # ------------------------------------------------------------------
    @property
    def read_tree_levels(self) -> int:
        """k such that n_read == 2**k for the hierarchical XOR read tree."""
        return int(math.log2(self.n_read)) if self.n_read > 1 else 0

    def leaf_banks(self) -> tuple[int, int]:
        """(number of physical leaf SRAM banks, depth of each leaf bank).

        h_ntx_rd with 2**k read ports is a ternary tree of XOR parity:
        3**k leaves of depth N/2**k  -> storage overhead (3/2)**k.
        b_ntx_wr triples the top level: 3 structures of depth N/2.
        hb_ntx composes both: 3 * 3**k leaves of depth N/(2*2**k).
        lvt replicates: n_write banks x n_read replicas, full depth.
        remap: n_write+1 full-depth banks.
        banked: n_banks of depth N/n_banks.
        """
        n, k = self.depth, self.read_tree_levels
        if self.kind == "h_ntx_rd":
            return 3**k, n // (2**k)
        if self.kind == "b_ntx_wr":
            return 3, n // 2
        if self.kind == "hb_ntx":
            return 3 * 3**k, n // (2 * 2**k)
        if self.kind == "lvt":
            return self.n_write * max(self.n_read, 1), n
        if self.kind == "remap":
            return self.n_write + 1, n
        if self.kind == "banked":
            return self.n_banks, -(-n // self.n_banks)
        if self.kind == "multipump":
            return 1, n
        return 1, n  # ideal

    def storage_bits(self) -> int:
        banks, bank_depth = self.leaf_banks()
        return banks * bank_depth * self.width

    def table_bits(self) -> int:
        """Lookup-table state (registers/LUT) for table-based designs."""
        if self.kind == "lvt":
            return self.depth * max(1, math.ceil(math.log2(max(self.n_write, 2))))
        if self.kind == "remap":
            return self.depth * max(1, math.ceil(math.log2(self.n_write + 1)))
        return 0

    @property
    def conflict_free(self) -> bool:
        """Architecturally conflict-free port guarantee (any nR+nW issue
        in one cycle when the design's structural rules are met).  The
        cycle-level arbitration layer (``repro.core.sim.arbiter``) still
        models the internal mechanics — parity-path fan-out, write
        pairing, live-bank steering — that deliver the guarantee."""
        return self.kind in ("ideal", "h_ntx_rd", "b_ntx_wr", "hb_ntx", "lvt", "remap")

    @property
    def frequency_factor(self) -> float:
        """External clock degradation (1.0 = full speed). Paper I: multi-pumping
        degrades max external operating frequency."""
        return 0.5 if self.kind == "multipump" else 1.0

    def describe(self) -> str:
        return (
            f"{self.kind}[{self.n_read}R{self.n_write}W {self.depth}x{self.width}b"
            + (f" banks={self.n_banks}" if self.kind == "banked" else "")
            + (f" sub={self.n_banks}"
               if self.kind in AMM_KINDS and self.n_banks > 1 else "")
            + "]"
        )
