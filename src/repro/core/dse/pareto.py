"""Pareto-front utilities for the (execution-time, area) and
(execution-time, power) trade-off plots (paper Fig 4)."""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.core.dse.sweep import DSEPoint


def pareto_front(
    points: Sequence[DSEPoint],
    cost: Callable[[DSEPoint], float] = lambda p: p.area_mm2,
) -> list[DSEPoint]:
    """Non-dominated set in (time_us, cost), sorted by time."""
    pts = sorted(points, key=lambda p: (p.time_us, cost(p)))
    front: list[DSEPoint] = []
    best = float("inf")
    for p in pts:
        c = cost(p)
        if c < best - 1e-12:
            front.append(p)
            best = c
    return front


def cost_at_time(
    front: Sequence[DSEPoint],
    t_us: float,
    cost: Callable[[DSEPoint], float] = lambda p: p.area_mm2,
) -> float:
    """Min cost achievable within time budget t (step interpolation on the
    front); inf if the family cannot reach t at all."""
    feas = [cost(p) for p in front if p.time_us <= t_us * (1 + 1e-9)]
    return min(feas) if feas else float("inf")


def design_space_expansion(
    banking: Sequence[DSEPoint], amm: Sequence[DSEPoint]
) -> float:
    """How much faster the fastest AMM design is vs the fastest banking
    design (>1 means AMM expands the high-performance design space —
    the blue-shaded region of Fig 4).  ``nan`` when either family is
    empty (a sweep restricted to one family has no expansion to report).
    """
    if not banking or not amm:
        return float("nan")
    tb = min(p.time_us for p in banking)
    ta = min(p.time_us for p in amm)
    return tb / ta
