from repro.core.dse.pareto import (cost_at_time, design_space_expansion,
                                   pareto_front)
from repro.core.dse.ratio import performance_ratio, spearman_rho
from repro.core.dse.runner import (BACKENDS, SweepCache, kill_pool,
                                   point_key, run_sweep, run_sweep_bench,
                                   shutdown_pool)
from repro.core.dse.sweep import (DEFAULT_DESIGNS, DEFAULT_UNROLLS,
                                  DesignPoint, DSEPoint, evaluate_point,
                                  sweep)

__all__ = [
    "DesignPoint", "DSEPoint", "sweep", "evaluate_point",
    "run_sweep", "run_sweep_bench", "SweepCache", "point_key", "BACKENDS",
    "kill_pool", "shutdown_pool",
    "DEFAULT_DESIGNS", "DEFAULT_UNROLLS",
    "pareto_front", "cost_at_time", "design_space_expansion",
    "performance_ratio", "spearman_rho",
]
