"""Design-space sweep (paper IV-A): 'different compositions are possible
by loop-unrolling, array-partitioning, changing word-size and number of
read and write ports. We use a sweep of such compositions, in the
implemented Mem-Aladdin Framework.'

One :class:`DSEPoint` = one accelerator composition: a memory design
applied per array (banked partitioning or an AMM port config) x a loop
unroll factor (scaling functional units).  Cycles come from the
port-constrained scheduler; time/area/power from the cost models.

``evaluate_point``/``sweep`` accept a raw :class:`Trace` or a
:class:`PreparedTrace`; per-trace analysis (successor CSR, heights,
array depths, access counts) is computed once and shared across every
design point.  ``sweep`` delegates to ``repro.core.dse.runner`` for
parallel evaluation and on-disk result caching.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.amm.spec import AMMSpec
from repro.core.cost import (FU_AREA_MM2, FU_LEAK_MW, FU_POWER_MW,
                             memory_cost)
from repro.core.sim import trace as T
from repro.core.sim.arbiter import STALL_KEYS
from repro.core.sim.prepared import PreparedTrace, prepare_trace
from repro.core.sim.scheduler import ScheduleConfig, schedule

# ScheduleResult / DSEPoint stall-field names, in STALL_KEYS order (the
# scheduler's stall taxonomy is the single source of truth; the assert
# under DSEPoint keeps this file from drifting when a key is added)
_STALL_FIELDS = tuple(f"{k}_stalls" for k in STALL_KEYS)

# base FU mix at unroll=1 (Aladdin constructs multi-issue ALUs by unrolling)
_BASE_FU = {"fadd": 1, "fmul": 1, "fdiv": 1, "iadd": 2, "imul": 1,
            "icmp": 2, "logic": 4}
_MIN_CYCLE_NS = 0.9  # FU critical path floor at 45nm


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """A memory design template, instantiated per array.

    ``n_banks`` is the banking-structure axis (paper Sec. III: depth x
    port config x banking): the partitioning factor for ``banked`` and
    the *leaf sub-banking* factor for AMM kinds (each internal leaf
    macro split into ``n_banks`` word-interleaved sub-banks).
    """
    kind: str
    n_read: int = 1
    n_write: int = 1
    n_banks: int = 1

    @property
    def label(self) -> str:
        if self.kind == "banked":
            return f"banked{self.n_banks}"
        base = f"{self.kind}-{self.n_read}R{self.n_write}W"
        if self.is_amm and self.n_banks > 1:
            return f"{base}-b{self.n_banks}"
        return base

    @property
    def is_amm(self) -> bool:
        return self.kind in ("h_ntx_rd", "b_ntx_wr", "hb_ntx", "lvt", "remap")


DEFAULT_DESIGNS: tuple[DesignPoint, ...] = (
    DesignPoint("banked", n_banks=1),
    DesignPoint("banked", n_banks=2),
    DesignPoint("banked", n_banks=4),
    DesignPoint("banked", n_banks=8),
    DesignPoint("banked", n_banks=16),
    DesignPoint("banked", n_banks=32),
    DesignPoint("multipump", 2, 2),
    DesignPoint("h_ntx_rd", 2, 1),
    DesignPoint("h_ntx_rd", 4, 1),
    DesignPoint("b_ntx_wr", 1, 2),
    DesignPoint("hb_ntx", 2, 2),
    DesignPoint("hb_ntx", 4, 2),
    DesignPoint("lvt", 2, 2),
    DesignPoint("lvt", 4, 2),
    DesignPoint("remap", 2, 2),
    DesignPoint("remap", 4, 2),
    # banking-structure axis: AMM internal leaf sub-banking
    DesignPoint("h_ntx_rd", 4, 1, n_banks=4),
    DesignPoint("hb_ntx", 4, 2, n_banks=4),
    DesignPoint("lvt", 4, 2, n_banks=4),
    DesignPoint("remap", 4, 2, n_banks=4),
)

DEFAULT_UNROLLS: tuple[int, ...] = (1, 2, 4, 8)


@dataclasses.dataclass
class DSEPoint:
    bench: str
    design: str
    is_amm: bool
    unroll: int
    cycles: int
    cycle_ns: float
    time_us: float
    area_mm2: float
    power_mw: float
    bank_conflict_stalls: int
    parity_fanout_stalls: int
    write_pair_stalls: int
    avg_mem_parallelism: float
    # resilience record from a seeded fault campaign on this point's
    # design (repro.core.fault; attached by run_sweep(faults=...)).
    # Sentinels ("-" / -1.0, not NaN: NaN breaks dataclass equality)
    # mean no campaign was attached.
    res_cover: str = "-"
    res_sdc_rate: float = -1.0
    res_corrected: float = -1.0
    res_detected: float = -1.0
    res_latency: float = -1.0

    @property
    def total_stalls(self) -> int:
        return sum(getattr(self, f) for f in _STALL_FIELDS)

    def row(self) -> dict:
        return dataclasses.asdict(self)


# the CSV schema (runner writes dataclasses.fields(DSEPoint)) must carry
# exactly the scheduler's stall taxonomy — fail at import time on drift
assert {f.name for f in dataclasses.fields(DSEPoint)} >= set(_STALL_FIELDS), \
    f"DSEPoint is missing stall fields for STALL_KEYS={STALL_KEYS}"


def _array_depths(tr: "T.Trace | PreparedTrace") -> dict[int, int]:
    """Power-of-two depth per array from the trace's max word index."""
    return prepare_trace(tr).array_depths


def _spec_for(dp: DesignPoint, depth: int, width_bits: int) -> AMMSpec:
    if dp.kind == "banked":
        nb = min(dp.n_banks, max(depth // 4, 1))
        return AMMSpec("banked", n_read=2 * nb, n_write=2 * nb,
                       depth=depth, width=width_bits, n_banks=nb)
    depth = max(depth, 4 * max(dp.n_read, dp.n_write, 1))
    sub = 1
    if dp.is_amm and dp.n_banks > 1:
        # clamp leaf sub-banking to the leaf depth (pow2, like banked's
        # depth//4 clamp) so tiny arrays never over-partition
        leaf_depth = AMMSpec(dp.kind, dp.n_read, dp.n_write, depth,
                             width_bits).leaf_banks()[1]
        sub = min(dp.n_banks, 1 << max(leaf_depth.bit_length() - 1, 0))
    return AMMSpec(dp.kind, dp.n_read, dp.n_write, depth, width_bits,
                   n_banks=sub)


def schedule_config_for(
    tr: "T.Trace | PreparedTrace",
    dp: DesignPoint,
    unroll: int,
    mem_latency: int = 2,
) -> ScheduleConfig:
    """The scheduler configuration one ``(design, unroll)`` point implies.

    Shared by every execution backend: the serial/pooled paths build it
    inside :func:`evaluate_point`, the batched JAX path builds one per
    grid point and hands the whole list to ``schedule_batched``.
    """
    pt = prepare_trace(tr)
    trace = pt.trace
    depths = pt.array_depths
    specs = {
        aid: _spec_for(dp, depths[aid], trace.word_bytes[aid] * 8)
        for aid in trace.array_names
    }
    return ScheduleConfig(
        mem=specs,
        fu_counts={k: v * unroll for k, v in _BASE_FU.items()},
        mem_latency=mem_latency,
    )


def evaluate_point(
    tr: "T.Trace | PreparedTrace",
    dp: DesignPoint,
    unroll: int,
    mem_latency: int = 2,
    backend: str = "auto",
) -> DSEPoint:
    pt = prepare_trace(tr)
    cfg = schedule_config_for(pt, dp, unroll, mem_latency)
    res = schedule(pt, cfg, backend=backend)
    return point_from_schedule(pt, dp, unroll, cfg, res)


def _point_static_cost(cfg: ScheduleConfig, unroll: int) -> tuple[float, float]:
    """(area_mm2, cycle_ns) of a point before any simulation.

    Must mirror :func:`point_from_schedule` exactly — the batched front
    cap compares cheap-config times against these areas, so a mismatch
    would silently break cap soundness."""
    costs = [memory_cost(s) for s in cfg.mem.values()]
    cycle_ns = max([_MIN_CYCLE_NS] + [c.cycle_ns for c in costs])
    area = sum(c.area_mm2 for c in costs)
    area += sum(FU_AREA_MM2[k] * v * unroll for k, v in _BASE_FU.items())
    return area, cycle_ns


def evaluate_points(
    tr: "T.Trace | PreparedTrace",
    points: "Sequence[tuple[DesignPoint, int]]",
    mem_latency: int = 2,
    *,
    front_cap: bool = False,
) -> "list[DSEPoint | None]":
    """Evaluate many ``(design, unroll)`` points in one batched C call.

    The whole column of configs runs against a single resident
    :class:`PreparedTrace` inside one extension call — no per-point
    marshalling of the trace arrays.  Results are bitwise identical to
    per-point :func:`evaluate_point` calls and come back in input order.

    With ``front_cap=True`` the batch runs internally in ascending-area
    order and the C loop abandons any config once its elapsed time
    provably exceeds a strictly cheaper completed config's time (such a
    point cannot be on the time/area Pareto front).  Abandoned points
    return ``None``; the surviving points still contain every member of
    the exact Pareto front.
    """
    from repro.core.sim.scheduler import schedule_batch

    pt = prepare_trace(tr)
    cfgs = [schedule_config_for(pt, dp, u, mem_latency) for dp, u in points]
    if not front_cap:
        results = schedule_batch(pt, cfgs)
        return [point_from_schedule(pt, dp, u, cfg, r)
                for (dp, u), cfg, r in zip(points, cfgs, results)]

    statics = [_point_static_cost(cfg, u)
               for cfg, (_, u) in zip(cfgs, points)]
    order = sorted(range(len(points)), key=lambda i: statics[i][0])
    results = schedule_batch(
        pt, [cfgs[i] for i in order],
        areas=[statics[i][0] for i in order],
        cycle_ns=[statics[i][1] for i in order],
        front_cap=True)
    out: "list[DSEPoint | None]" = [None] * len(points)
    for rank, i in enumerate(order):
        res = results[rank]
        if res is not None:
            dp, u = points[i]
            out[i] = point_from_schedule(pt, dp, u, cfgs[i], res)
    return out


def point_from_schedule(
    tr: "T.Trace | PreparedTrace",
    dp: DesignPoint,
    unroll: int,
    cfg: ScheduleConfig,
    res,
) -> DSEPoint:
    """Fold one ``ScheduleResult`` into a costed :class:`DSEPoint`.

    Deterministic given its inputs, so a point is bitwise identical
    whichever backend produced the schedule."""
    pt = prepare_trace(tr)
    trace = pt.trace
    specs = cfg.mem

    costs = {aid: memory_cost(s) for aid, s in specs.items()}
    cycle_ns = max([_MIN_CYCLE_NS] + [c.cycle_ns for c in costs.values()])
    time_us = res.cycles * cycle_ns * 1e-3

    area = sum(c.area_mm2 for c in costs.values())
    area += sum(FU_AREA_MM2[k] * v * unroll for k, v in _BASE_FU.items())

    # dynamic memory energy (per-array access counts precomputed on the
    # prepared trace)
    e_pj = 0.0
    for aid in trace.array_names:
        e_pj += (pt.loads_per_array[aid] * costs[aid].read_energy_pj
                 + pt.stores_per_array[aid] * costs[aid].write_energy_pj)
    p_mem_dyn = e_pj / max(time_us, 1e-9) * 1e-3          # pJ/us -> mW
    p_leak = sum(c.leakage_mw for c in costs.values())
    # FU power at achieved utilization
    fu_total = sum(v * unroll for v in _BASE_FU.values())
    util = min(1.0, res.issued / max(res.cycles * fu_total, 1))
    p_fu = sum(FU_POWER_MW[k] * v * unroll * util + FU_LEAK_MW[k] * v * unroll
               for k, v in _BASE_FU.items())

    return DSEPoint(
        bench=trace.name,
        design=dp.label,
        is_amm=dp.is_amm,
        unroll=unroll,
        cycles=res.cycles,
        cycle_ns=cycle_ns,
        time_us=time_us,
        area_mm2=area,
        power_mw=p_mem_dyn + p_leak + p_fu,
        avg_mem_parallelism=res.avg_mem_parallelism,
        **{f: getattr(res, f) for f in _STALL_FIELDS},
    )


def sweep(
    tr: "T.Trace | PreparedTrace",
    designs: Sequence[DesignPoint] = DEFAULT_DESIGNS,
    unrolls: Iterable[int] = DEFAULT_UNROLLS,
    *,
    mem_latency: int = 2,
    jobs: int | None = None,
    cache_dir: "str | None" = None,
    backend: str = "auto",
    prune: "str | None" = None,
    margin: "float | None" = None,
    verbose: bool = False,
) -> list[DSEPoint]:
    """Evaluate ``designs x unrolls`` on one trace.

    Thin wrapper over :func:`repro.core.dse.runner.run_sweep`: pass
    ``jobs`` for multi-process evaluation, ``cache_dir`` for the
    on-disk result cache, ``backend`` to pick the cycle-loop
    implementation (``auto``/``c``/``py``/``jax``) and
    ``prune="surrogate"`` for the analytically pruned sweep (returns a
    subset of the grid that still contains the exact Pareto front).
    Point order is always ``designs``-major, ``unrolls``-minor,
    independent of parallelism, backend or cache hits.
    """
    from repro.core.dse.runner import run_sweep
    return run_sweep(tr, designs, unrolls, mem_latency=mem_latency,
                     jobs=jobs, cache_dir=cache_dir, backend=backend,
                     prune=prune, margin=margin, verbose=verbose)
