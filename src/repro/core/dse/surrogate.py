"""Analytic sweep surrogate: predict cycles + stall breakdown per point.

The exact DSE loop pays one port-constrained cycle-loop simulation per
``(design, unroll)`` grid point — seconds per full-size bench.  This
module predicts the outcome of that simulation in microseconds from
statistics the prepared trace already has (critical-path height,
per-array access/conflict histograms, read/write mix, first-store cold
ranges) combined with the compiled :class:`~repro.core.sim.arbiter.
ArbDescriptor` of each design (port budgets, banking modulus, parity
fan-out ``2^k``, remap steering banks, multipump slot ratio).

Model shape (per point)::

    compute  = b0 * max(dep, fu) + b1 * min(dep, fu)
    port     = p0 * max(port_pressure, conflict) + p1 * band
               + p2 * couple + p3 * min(compute_max, mem_max) + p4
    interf   = compute + ic * max(0, conflict - compute_max / 2)
    cycles   = max(compute, port, interf)

``compute`` is kind-independent (critical path vs FU throughput — its
``max``-form keeps compute-bound designs exactly tied, which is what
makes rank correlation work); ``port``/``interf`` carry per-kind
coefficients fitted by least squares + deterministic coordinate descent
against the 312 pinned golden rows (``tools/fit_surrogate.py`` -> the
checked-in ``_surrogate_coef`` constants; no ML dependency).  Stall
fields are per-kind linear models on summed conflict features.

Pruned sweeps (:func:`select_band`) keep a grid point only if no
cheaper-area point is predicted faster by more than the safety margin;
see ``repro.core.dse.runner`` for the exact-refinement step that makes
the pruned Pareto front provably equal the exhaustive one.

The model is calibrated for the default ``mem_latency=2`` /
``ports_per_bank=2`` operating point; callers gate on that (the runner
falls back to exhaustive sweeps elsewhere).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.cost import FU_AREA_MM2, memory_cost
from repro.core.dse import _surrogate_coef as C
from repro.core.dse.sweep import (DesignPoint, _BASE_FU, _MIN_CYCLE_NS,
                                  _spec_for)
from repro.core.sim.arbiter import (KIND_BANKED, KIND_H_NTX,
                                    KIND_MULTIPUMP, KIND_REMAP,
                                    _NTX_KINDS, STALL_KEYS, compile_spec)
from repro.core.sim.prepared import FU_ORDER, PreparedTrace, prepare_trace

# conflict-feature column feeding each stall model, in STALL_KEYS order
_STALL_FEATURES = ("sum_conf", "sum_top2", "sum_wr")
assert len(_STALL_FEATURES) == len(STALL_KEYS), \
    "a new STALL_KEYS entry needs a surrogate feature column here"

# height-band width (cycles of schedule height per access-histogram bin)
BAND_W = 8
# Default pruning band: keep points predicted within 10% of the best
# cheaper-area prediction.  Sized against the worst observed ranking
# error of a true-front point across every TINY bench on the default
# 20x4 grid (0.011, bfs_queue banked2@u1) and the full-size 13-design
# matrix at unrolls 1/2/4/8 and the 13x2 calibration grid (both 0.0),
# with ~9x headroom — tests/test_surrogate.py asserts pruned ==
# exhaustive fronts at this margin on all twelve TINY benches.
DEFAULT_MARGIN = 0.10
# the model is fitted at the default operating point only
CALIBRATED_MEM_LATENCY = 2
_AREA_EPS = 1e-12

# the 12-bench x 13-design calibration/regression matrix (one point per
# arbitration kind + the -b4 leaf-sub-banked variants; mirrors the
# pinned golden matrix in tests/test_golden_schedule.py, which asserts
# the two stay in sync)
CALIBRATION_DESIGNS: dict[str, DesignPoint] = {
    "banked4": DesignPoint("banked", 1, 1, 4),
    "banked32": DesignPoint("banked", 1, 1, 32),
    "multipump-2R2W": DesignPoint("multipump", 2, 2, 1),
    "hb_ntx-2R2W": DesignPoint("hb_ntx", 2, 2, 1),
    "lvt-4R2W": DesignPoint("lvt", 4, 2, 1),
    "ideal-2R2W": DesignPoint("ideal", 2, 2, 1),
    "h_ntx_rd-4R1W": DesignPoint("h_ntx_rd", 4, 1, 1),
    "b_ntx_wr-1R2W": DesignPoint("b_ntx_wr", 1, 2, 1),
    "remap-2R2W": DesignPoint("remap", 2, 2, 1),
    "h_ntx_rd-4R1W-b4": DesignPoint("h_ntx_rd", 4, 1, n_banks=4),
    "hb_ntx-4R2W-b4": DesignPoint("hb_ntx", 4, 2, n_banks=4),
    "lvt-4R2W-b4": DesignPoint("lvt", 4, 2, n_banks=4),
    "remap-4R2W-b4": DesignPoint("remap", 4, 2, n_banks=4),
}
CALIBRATION_UNROLLS: tuple[int, ...] = (1, 4)

# trace families the coefficients are fitted on (the MachSuite golden
# matrix).  Benches outside this set — today the LLM-serving family
# (kv_decode / paged_kv / moe_route) — carry golden rows for backend
# conformance and legality audits but are NOT calibrated: a from-scratch
# refit over the mixed matrix degrades the MachSuite ranking fidelity
# (bfs_queue/nw drop below rho 0.6), so ``run_sweep(prune="surrogate")``
# auto-falls back to the exhaustive grid for them instead — exactness
# is pinned either way (tests/test_surrogate.py).
CALIBRATED_BENCHES = frozenset({
    "fft_strided", "gemm_ncubed", "kmp", "md_knn", "sort_merge",
    "stencil2d", "aes", "spmv_crs", "bfs_queue", "nw", "viterbi",
    "radix_sort"})


@dataclasses.dataclass(frozen=True)
class SurrogatePrediction:
    """Predicted schedule outcome of one ``(design, unroll)`` point."""
    cycles: float
    bank_conflict_stalls: float
    parity_fanout_stalls: float
    write_pair_stalls: float
    # model-term diagnostics (cycles == max of the three)
    compute_term: float
    port_term: float
    interference_term: float


assert all(f"{k}_stalls" in SurrogatePrediction.__dataclass_fields__
           for k in STALL_KEYS), \
    f"SurrogatePrediction is missing stall fields for STALL_KEYS={STALL_KEYS}"


class TraceFeatures:
    """Per-trace feature extractor shared across a whole sweep grid.

    Wraps the trace's :class:`~repro.core.sim.prepared.MemProfile` and
    memoizes the design-dependent conflict reductions (bank-modulus
    histograms, NTX leaf top-2 pressure) that repeat across grid points
    sharing a banking geometry.
    """

    def __init__(self, tr: "PreparedTrace", ports_per_bank: int = 2):
        self.pt = prepare_trace(tr)
        self.prof = self.pt.mem_profile(BAND_W)
        self.ppb = ports_per_bank
        self._memo: dict = {}

    def _words(self, aid: int, what: str) -> np.ndarray:
        prof = self.prof
        if what == "l":
            return prof.load_words[aid]
        key = ("w", aid)
        if key not in self._memo:
            self._memo[key] = np.concatenate(
                [prof.load_words[aid], prof.store_words[aid]])
        return self._memo[key]

    def max_mod(self, aid: int, n_banks: int, what: str = "all") -> int:
        """Worst-bank access count under ``word % n_banks`` banking."""
        key = ("mod", aid, n_banks, what)
        if key not in self._memo:
            w = self._words(aid, what)
            self._memo[key] = (int(np.bincount(w % n_banks,
                                               minlength=n_banks).max())
                               if w.size else 0)
        return self._memo[key]

    def top2_leaf(self, aid: int, depth: int, levels: int, sub: int,
                  split: bool) -> float:
        """Mean of the two worst NTX leaf-bank load counts.

        Mirrors the descriptor's address -> (tree, leaf, sub-bank)
        projection: parity fan-out serializes when one leaf (or its Ref
        twin) concentrates the load stream, and two hot leaves bound
        the sustainable rate at 2 accesses/cycle.
        """
        key = ("leaf", aid, depth, levels, sub, split)
        if key not in self._memo:
            w = self.prof.load_words[aid]
            if not w.size:
                self._memo[key] = 0.0
            else:
                a = w % depth
                if split:
                    half = depth // 2
                    tree = (a >= half).astype(np.int64)
                    ta = a - tree * half
                    td = half
                else:
                    tree = np.zeros_like(a)
                    ta = a
                    td = depth
                if levels:
                    leaf = ta >> max((td.bit_length() - 1) - levels, 0)
                else:
                    leaf = np.zeros_like(ta)
                b = (tree * (1 << levels) + leaf) * sub + ta % sub
                cnt = np.sort(np.bincount(b))[::-1]
                top2 = cnt[0] + (cnt[1] if cnt.size > 1 else 0)
                self._memo[key] = float(top2) / 2.0
        return self._memo[key]

    def features(self, dp: DesignPoint, unroll: int) -> dict:
        """The scalar feature vector of one grid point."""
        pt, prof, ppb = self.pt, self.prof, self.ppb
        dep = float(prof.crit_height)
        fu = 0.0
        for i, name in enumerate(FU_ORDER):
            budget = _BASE_FU[name] * unroll
            if budget:
                fu = max(fu, prof.fu_ops[i] / budget)
        port = conf = couple = 0.0
        sum_conf = sum_top2 = sum_wr = 0.0
        band = np.zeros(prof.n_bands)
        for aid in pt.trace.array_names:
            spec = _spec_for(dp, pt.array_depths[aid],
                             pt.trace.word_bytes[aid] * 8)
            d = compile_spec(spec, ppb)
            loads = pt.loads_per_array[aid]
            stores = pt.stores_per_array[aid]
            pressure = max(loads / d.rd, stores / d.wr)
            cf = 0.0
            if d.kind == KIND_BANKED:
                pressure = max(pressure,
                               (loads + stores) / (d.n_banks * ppb))
                # a single bank has no conflict dimension: every access
                # lands in it and the port-pressure term above already
                # models the serialization exactly (mod-1 "collisions"
                # would double-count it through the interference term)
                if d.n_banks > 1:
                    cf = self.max_mod(aid, d.n_banks) / ppb
            elif d.kind == KIND_MULTIPUMP:
                pressure = max(pressure, (loads + stores) / d.slots)
            elif d.kind == KIND_REMAP:
                # cold loads hit the un-steered bank map; warm loads
                # spread over the write-steered banks
                spread = (max(1, min(d.n_banks - 1, d.wr)) * ppb
                          * max(1.0, d.sub) ** 0.5)
                cold = prof.cold_loads[aid]
                cf = cold / ppb + (loads - cold) / spread
            elif d.kind in _NTX_KINDS:
                cf = self.top2_leaf(aid, d.depth, d.levels, d.sub,
                                    d.kind != KIND_H_NTX)
                sum_top2 += cf
                if d.kind != KIND_H_NTX:
                    sum_wr += stores / d.wr
            band = np.maximum(band,
                              np.maximum(prof.load_bands[aid] / d.rd,
                                         prof.store_bands[aid] / d.wr))
            port = max(port, pressure)
            conf = max(conf, cf)
            couple = max(couple, min(loads / d.rd, stores / d.wr))
            sum_conf += cf
        return {
            "dep": dep, "fu": fu, "port": port, "conf": conf,
            "band": float(band.sum()), "couple": couple,
            "sum_conf": sum_conf, "sum_top2": sum_top2, "sum_wr": sum_wr,
        }


def _predict_from_features(feats: dict, kind: str) -> SurrogatePrediction:
    basemax = max(feats["dep"], feats["fu"])
    memraw = max(feats["port"], feats["conf"])
    b = C.BASE
    compute = b[0] * basemax + b[1] * min(feats["dep"], feats["fu"])
    p = C.PORT[kind]
    port = (p[0] * memraw + p[1] * feats["band"] + p[2] * feats["couple"]
            + p[3] * min(basemax, memraw) + p[4])
    interf = compute + C.INTF[kind] * max(0.0, feats["conf"]
                                          - 0.5 * basemax)
    stalls = {f"{k}_stalls": C.STALL[f"{k}_stalls"].get(kind, 0.0) * feats[x]
              for k, x in zip(STALL_KEYS, _STALL_FEATURES)}
    return SurrogatePrediction(
        cycles=max(compute, port, interf),
        compute_term=compute, port_term=port, interference_term=interf,
        **{f: max(0.0, v) for f, v in stalls.items()})


def _coef_kind(dp: DesignPoint) -> str:
    """Coefficient family for a design point.

    A single-bank banked memory has no conflict dimension — it behaves
    like a plain port-limited macro, so the conflict-heavy banked port
    model (fitted exclusively on multi-bank rows) badly overpredicts it.
    Route it through the ideal/multipump port model instead.
    """
    if dp.kind == "banked" and dp.n_banks == 1:
        return "ideal"
    return dp.kind


def predict(tr: "PreparedTrace", dp: DesignPoint, unroll: int,
            feats: "TraceFeatures | None" = None) -> SurrogatePrediction:
    """Predict the schedule outcome of one grid point.

    Pass a shared :class:`TraceFeatures` when predicting many points of
    one trace (the conflict-histogram memos carry across points).
    """
    tf = feats if feats is not None else TraceFeatures(tr)
    return _predict_from_features(tf.features(dp, unroll), _coef_kind(dp))


@dataclasses.dataclass(frozen=True)
class GridPrediction:
    """One grid point's surrogate ranking entry (pre-simulation)."""
    design: DesignPoint
    unroll: int
    prediction: SurrogatePrediction
    cycle_ns: float
    area_mm2: float

    @property
    def pred_time_us(self) -> float:
        return self.prediction.cycles * self.cycle_ns * 1e-3


def grid_predictions(
    tr: "PreparedTrace",
    designs: Sequence[DesignPoint],
    unrolls: Iterable[int],
    feats: "TraceFeatures | None" = None,
) -> list[GridPrediction]:
    """Surrogate predictions + exact pre-sim costs for a whole grid.

    ``cycle_ns`` and ``area_mm2`` come from the real cost model (they
    do not depend on the schedule), so only predicted *cycles* are
    approximate.  Order is designs-major, unrolls-minor — the same
    order every sweep entry point uses.
    """
    pt = prepare_trace(tr)
    tf = feats if feats is not None else TraceFeatures(pt)
    unrolls = list(unrolls)
    out = []
    for dp in designs:
        specs = [_spec_for(dp, pt.array_depths[aid],
                           pt.trace.word_bytes[aid] * 8)
                 for aid in pt.trace.array_names]
        costs = [memory_cost(s) for s in specs]
        cycle_ns = max([_MIN_CYCLE_NS] + [c.cycle_ns for c in costs])
        mem_area = sum(c.area_mm2 for c in costs)
        for u in unrolls:
            area = mem_area + sum(FU_AREA_MM2[k] * v * u
                                  for k, v in _BASE_FU.items())
            out.append(GridPrediction(
                design=dp, unroll=u,
                prediction=_predict_from_features(
                    tf.features(dp, u), _coef_kind(dp)),
                cycle_ns=cycle_ns, area_mm2=area))
    return out


def select_band(
    preds: Sequence[GridPrediction],
    margin: float = DEFAULT_MARGIN,
) -> list[bool]:
    """Keep the predicted Pareto band: mask of grid points to simulate.

    A point is dropped only when some strictly-cheaper-area point is
    predicted faster by more than the safety margin — i.e. kept iff::

        pred_time <= (1 + margin) * min(pred_time of cheaper points)

    Ties and near-ties always survive (their true ordering is beyond
    the model's resolution), so the kept set provably contains the true
    Pareto front whenever the relative prediction error stays within
    ``margin``; the runner additionally re-checks front equality where
    exhaustive results exist (TINY benches, in CI).
    """
    t = [p.pred_time_us for p in preds]
    a = [p.area_mm2 for p in preds]
    n = len(preds)
    keep = []
    for i in range(n):
        lo = min((t[j] for j in range(n) if a[j] <= a[i] - _AREA_EPS),
                 default=float("inf"))
        keep.append(t[i] <= (1.0 + margin) * lo)
    return keep
