"""Fitted surrogate coefficients — GENERATED, do not edit by hand.

Regenerate with::

    PYTHONPATH=src python tools/fit_surrogate.py

The fit is deterministic (weighted least-squares init + fixed-step
coordinate descent on the 312 pinned golden rows), so regeneration is
reproducible; tests/test_surrogate.py pins the resulting accuracy.
"""

BASE = (1.014725, 0.269732)

PORT = {
    "b_ntx_wr": (0.859259, -0.035347, 0.525657, 0.055742, 40.786443),
    "banked": (0.870000, 0.280000, -0.030000, -0.080000, 20.200000),
    "h_ntx_rd": (0.908945, 0.078345, -0.345638, 0.197631, 18.875282),
    "hb_ntx": (0.547926, 0.248189, 0.273793, 0.145060, 15.934971),
    "ideal": (0.180451, 0.683781, 0.144698, -0.009555, 7.505630),
    "lvt": (0.337965, 0.735357, -0.218818, -0.060000, 2.807079),
    "multipump": (0.180451, 0.683781, 0.144698, -0.009555, 7.505630),
    "remap": (1.001034, 0.003351, -0.083796, 0.264817, 14.080767),
}

INTF = {
    "b_ntx_wr": 0.100000,
    "banked": 0.170000,
    "h_ntx_rd": 0.000000,
    "hb_ntx": 0.100000,
    "ideal": 0.100000,
    "lvt": 0.100000,
    "multipump": 0.100000,
    "remap": 0.230000,
}

STALL = {
    "bank_conflict_stalls": {"banked": 0.851856, "remap": 0.698986},
    "parity_fanout_stalls": {"b_ntx_wr": 0.172040, "h_ntx_rd": 0.662117, "hb_ntx": 0.742874},
    "write_pair_stalls": {"b_ntx_wr": 0.532421, "hb_ntx": 0.395632},
}

# drift guard: the fitted stall models must cover exactly the
# scheduler's stall taxonomy (re-fit after changing STALL_KEYS)
from repro.core.sim.arbiter import STALL_KEYS as _STALL_KEYS  # noqa: E402

assert set(STALL) == {f"{k}_stalls" for k in _STALL_KEYS}, \
    "surrogate STALL coefficients out of sync with STALL_KEYS; re-run " \
    "tools/fit_surrogate.py"

FIT_STATS = {
    "aes": {
        "rho": 0.9671,
        "medrel": 0.0576,
        "maxrel": 0.1112
    },
    "bfs_queue": {
        "rho": 0.9391,
        "medrel": 0.0346,
        "maxrel": 0.0879
    },
    "fft_strided": {
        "rho": 0.9715,
        "medrel": 0.0089,
        "maxrel": 0.1379
    },
    "gemm_ncubed": {
        "rho": 0.9556,
        "medrel": 0.02,
        "maxrel": 0.2143
    },
    "kmp": {
        "rho": None,
        "medrel": 0.0331,
        "maxrel": 0.0456
    },
    "md_knn": {
        "rho": 0.9578,
        "medrel": 0.0465,
        "maxrel": 0.0998
    },
    "nw": {
        "rho": 0.9381,
        "medrel": 0.1019,
        "maxrel": 0.2129
    },
    "radix_sort": {
        "rho": None,
        "medrel": 0.0808,
        "maxrel": 0.1239
    },
    "sort_merge": {
        "rho": 0.9334,
        "medrel": 0.0563,
        "maxrel": 0.1899
    },
    "spmv_crs": {
        "rho": 0.9493,
        "medrel": 0.0274,
        "maxrel": 0.1274
    },
    "stencil2d": {
        "rho": 0.9775,
        "medrel": 0.0112,
        "maxrel": 0.1463
    },
    "viterbi": {
        "rho": 0.9589,
        "medrel": 0.0112,
        "maxrel": 0.0976
    },
    "_all": {
        "n_rows": 312,
        "medrel": 0.0449,
        "maxrel": 0.2143
    }
}
