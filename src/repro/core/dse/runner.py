"""Sweep runner: parallel, cached DSE point evaluation.

The paper's Fig-4/5 loop evaluates one trace under O(64) accelerator
compositions.  This module is the execution engine for that loop:

* **Shared analysis** — the trace is prepared once
  (:class:`repro.core.sim.prepared.PreparedTrace`); each design point
  pays only for the port-constrained cycle loop.
* **Parallelism** — points are chunked into work units and evaluated on
  a ``concurrent.futures.ProcessPoolExecutor``; each worker prepares the
  trace once per process and then drains chunks.
* **Incremental re-sweeps** — an on-disk result cache keyed by
  ``(trace fingerprint, design, unroll, mem_latency, cache version)``
  makes re-runs and ``--full`` extensions of a previous sweep pay only
  for the new points.  A ``manifest.json`` alongside the cache maps
  benchmark identities to trace fingerprints so a *fully* cached sweep
  (:func:`run_sweep_bench`) skips trace generation and preparation
  entirely.
* **Surrogate pruning** — ``prune="surrogate"`` ranks the full grid
  with the analytic cycle predictor (:mod:`repro.core.dse.surrogate`),
  exact-simulates only the predicted Pareto band (plus a safety
  margin) in one batched C call with in-C front caps, and returns the
  retained points — a strict superset of the exact Pareto front at a
  fraction of the exhaustive cost.

Results are deterministic: the returned list is always ordered
``designs``-major / ``unrolls``-minor and each point is bitwise
identical whether it came from the serial path, a worker process, or
the cache.

CLI::

    python -m repro.core.dse.runner --bench gemm_ncubed --jobs 8
    python -m repro.core.dse.runner --bench md_knn --full \
        --cache-dir .dse_cache --unrolls 1,2,4,8
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import TYPE_CHECKING, Iterable, Sequence

if TYPE_CHECKING:   # deferred at runtime: keeps CLI startup light
    import json
    from concurrent.futures import ProcessPoolExecutor
    from pathlib import Path

from repro.core.sim import trace as T
from repro.core.sim.prepared import PreparedTrace, prepare_trace
from repro.core.dse.sweep import (DEFAULT_DESIGNS, DEFAULT_UNROLLS,
                                  DesignPoint, DSEPoint, evaluate_point)

# Bump when DSEPoint fields or the evaluation semantics change: stale
# cache entries from older layouts must miss, not deserialize garbage.
# v2: per-kind arbitration layer (stall breakdown fields; multipump /
# NTX / remap timing semantics).
# v3: multi-backend execution engine (c / py / jax); entries are
# backend-independent — the three cycle loops are pinned decision-for-
# decision equal — but pre-v3 entries predate the conformance harness
# that enforces it, so they must re-evaluate once.
# v4: checksummed entry envelope ({"sha256", "point"}) + DSEPoint res_*
# resilience fields.  Entries stay fault-agnostic: campaigns are
# attached after cache load, so the same entry serves faulted and
# fault-free sweeps.
CACHE_VERSION = 4

BACKENDS = ("auto", "c", "py", "jax")

_ENV_CACHE_DIR = "REPRO_DSE_CACHE"

# Minimum estimated work (uncached points x trace nodes) before fanning
# out to worker processes: below this, chunk pickling + pool latency
# outweigh the 2nd core.  Module-level so tests can patch it.
_MIN_PARALLEL_WORK = 300_000


# ----------------------------------------------------------------------
# cache
# ----------------------------------------------------------------------
def point_key(fingerprint: str, dp: DesignPoint, unroll: int,
              mem_latency: int) -> str:
    """Stable cache key for one (trace, design, unroll, latency) point."""
    import json

    payload = json.dumps(
        {"v": CACHE_VERSION, "trace": fingerprint,
         "design": dataclasses.asdict(dp), "unroll": unroll,
         "mem_latency": mem_latency},
        sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


class SweepCache:
    """One-JSON-file-per-point result cache under ``root``.

    Writes are atomic (tmp file + fsync + rename) so concurrent workers
    and interrupted sweeps never leave a torn entry behind, and every
    entry carries a sha256 of its payload: an entry corrupted *after*
    landing on disk (bit rot, partial copy, hand edits) fails the
    checksum and reads as a miss instead of deserializing garbage.
    """

    def __init__(self, root: "str | Path") -> None:
        from pathlib import Path

        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> "Path":
        return self.root / f"{key[:2]}" / f"{key}.json"

    @staticmethod
    def _digest(point_dict: dict) -> str:
        import json

        payload = json.dumps(point_dict, sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()

    def get(self, key: str) -> "DSEPoint | None":
        import json

        p = self._path(key)
        try:
            with open(p) as f:
                d = json.load(f)
            if d["sha256"] != self._digest(d["point"]):
                raise ValueError("cache entry checksum mismatch")
            pt = DSEPoint(**d["point"])
            self.hits += 1
            return pt
        except (OSError, ValueError, TypeError, KeyError):
            self.misses += 1
            return None

    def put(self, key: str, point: DSEPoint) -> None:
        import json

        d = dataclasses.asdict(point)
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(f".tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump({"sha256": self._digest(d), "point": d}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)

    # -- bench-identity -> trace-fingerprint manifest ------------------
    # Point keys need the trace *fingerprint*, which normally requires
    # generating + preparing the trace.  The manifest remembers the
    # mapping from a generation-free bench identity
    # (repro.core.bench.trace_cache_key) to the fingerprint, so a sweep
    # whose points are all cached never touches the trace at all.
    def _manifest_path(self) -> "Path":
        return self.root / "manifest.json"

    def _manifest_read(self) -> dict:
        import json

        try:
            with open(self._manifest_path()) as f:
                d = json.load(f)
            return d if isinstance(d, dict) else {}
        except (OSError, ValueError):
            return {}

    def manifest_get(self, bench_key: str) -> "str | None":
        return self._manifest_read().get(bench_key)

    def manifest_put(self, bench_key: str, fingerprint: str) -> None:
        import json

        d = self._manifest_read()
        if d.get(bench_key) == fingerprint:
            return
        d[bench_key] = fingerprint
        p = self._manifest_path()
        tmp = p.with_suffix(f".tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump(d, f, indent=0, sort_keys=True)
        os.replace(tmp, p)


def _resolve_cache(cache_dir: "str | Path | None") -> "SweepCache | None":
    if cache_dir is None:
        cache_dir = os.environ.get(_ENV_CACHE_DIR) or None
    return SweepCache(cache_dir) if cache_dir else None


# ----------------------------------------------------------------------
# parallel workers
# ----------------------------------------------------------------------
# Worker processes memoize prepared traces by fingerprint, so a sweep
# costs one trace unpickle + prepare per (worker, trace) and the pool
# can be reused across sweeps over different traces.  Small traces ride
# along with each chunk (cheap, lets the pool persist across sweeps);
# traces above _LARGE_TRACE_NODES get a dedicated pool whose initializer
# ships the trace exactly once per worker instead of once per chunk.
_WORKER_MEMO: dict[str, PreparedTrace] = {}
_WORKER_MEMO_MAX = 8
_LARGE_TRACE_NODES = 50_000

# One long-lived pool per process, sized on first use; recreated only if
# a later sweep asks for more workers.  shutdown_pool() is registered
# via atexit on first creation so the interpreter never exits with live
# worker processes.
_POOL: "ProcessPoolExecutor | None" = None
_POOL_WORKERS = 0
_ATEXIT_REGISTERED = False


def _worker_memoize(fingerprint: str, tr: T.Trace) -> PreparedTrace:
    while len(_WORKER_MEMO) >= _WORKER_MEMO_MAX:
        _WORKER_MEMO.pop(next(iter(_WORKER_MEMO)))
    pt = _WORKER_MEMO[fingerprint] = prepare_trace(tr)
    return pt


def _worker_init(fingerprint: str, tr: T.Trace) -> None:
    _worker_memoize(fingerprint, tr)


def _worker_eval_chunk(
    fingerprint: str, tr: "T.Trace | None",
    chunk: "list[tuple[int, DesignPoint, int]]", mem_latency: int,
    backend: str = "auto",
) -> "list[tuple[int, DSEPoint]]":
    pt = _WORKER_MEMO.get(fingerprint)
    if pt is None:
        assert tr is not None, "large-trace pool must be pre-initialized"
        pt = _worker_memoize(fingerprint, tr)
    return [(i, evaluate_point(pt, dp, u, mem_latency, backend=backend))
            for i, dp, u in chunk]


def _bare_trace(tr: T.Trace) -> T.Trace:
    """Copy without the memoized PreparedTrace so worker pickles stay small."""
    return dataclasses.replace(tr)


def _get_pool(jobs: int) -> "ProcessPoolExecutor":
    import atexit
    from concurrent.futures import ProcessPoolExecutor

    global _POOL, _POOL_WORKERS, _ATEXIT_REGISTERED
    if _POOL is not None and getattr(_POOL, "_broken", False):
        # a worker died (OOM kill, segfault, os._exit): the executor is
        # permanently unusable — replace it with a fresh one
        kill_pool()
    if _POOL is None or _POOL_WORKERS < jobs:
        if _POOL is not None:
            # drain the old pool before replacing it: shutdown(wait=False)
            # would abandon its workers mid-chunk and leak the processes
            _POOL.shutdown(wait=True)
        _POOL = ProcessPoolExecutor(max_workers=jobs)
        _POOL_WORKERS = jobs
        if not _ATEXIT_REGISTERED:
            atexit.register(shutdown_pool)
            _ATEXIT_REGISTERED = True
    return _POOL


def _kill_executor(pool: "ProcessPoolExecutor") -> None:
    """Forcibly tear down an executor whose workers may be hung or dead.

    ``shutdown(wait=True)`` would block forever on a hung worker, so
    terminate the processes first, then release the executor's threads
    without waiting.
    """
    for proc in list(getattr(pool, "_processes", {}).values()):
        try:
            proc.terminate()
        except (OSError, ValueError):
            pass  # already dead
    pool.shutdown(wait=False, cancel_futures=True)


def kill_pool() -> None:
    """Forcibly tear down the shared pool (broken/hung workers)."""
    global _POOL, _POOL_WORKERS
    if _POOL is not None:
        _kill_executor(_POOL)
        _POOL = None
        _POOL_WORKERS = 0


def shutdown_pool() -> None:
    """Tear down the shared worker pool (tests / atexit hygiene)."""
    global _POOL, _POOL_WORKERS
    if _POOL is not None:
        if getattr(_POOL, "_broken", False):
            _kill_executor(_POOL)
        else:
            _POOL.shutdown(wait=True)
        _POOL = None
        _POOL_WORKERS = 0


def _chunked(tasks: list, n_chunks: int) -> list[list]:
    size = max(1, (len(tasks) + n_chunks - 1) // n_chunks)
    return [tasks[i:i + size] for i in range(0, len(tasks), size)]


# ----------------------------------------------------------------------
# entry point
# ----------------------------------------------------------------------
def _attach_faults(points: list, designs: Sequence[DesignPoint],
                   faults) -> list:
    """Fill ``res_*`` fields from per-design fault campaigns.

    ``faults`` is a :class:`repro.core.fault.FaultConfig`, an int
    (fault-population size with default config), or None (no-op).
    Campaigns run at the canonical 256x32b geometry and are memoised
    per design, so this costs one campaign per distinct design label
    per process regardless of benches/unrolls.
    """
    if faults is None:
        return points
    from repro.core.fault import FaultConfig, attach_resilience

    if isinstance(faults, int):
        faults = FaultConfig(n_faults=faults)
    return attach_resilience(points, designs, cfg=faults)


def _vlog(verbose: bool, msg: str) -> None:
    if verbose:
        import sys

        print(f"[sweep] {msg}", file=sys.stderr, flush=True)


def _run_pruned(
    pt: PreparedTrace,
    designs: Sequence[DesignPoint],
    unrolls: "tuple[int, ...]",
    mem_latency: int,
    cache: "SweepCache | None",
    margin: "float | None",
    verbose: bool,
) -> list[DSEPoint]:
    """Surrogate-pruned sweep: rank the grid analytically, exact-simulate
    only the predicted Pareto band in one batched, front-capped C call.

    Returns the retained completed points (a designs-major subsequence
    of the full grid).  Guarantee: the returned set contains every
    member of the exact Pareto front — the surrogate band keeps all
    near-front candidates (``margin`` is the safety slack on predicted
    time) and the in-C cap only abandons points *proven* off-front
    against exact cheaper results.
    """
    from repro.core.dse.surrogate import (DEFAULT_MARGIN, grid_predictions,
                                          select_band)
    from repro.core.dse.sweep import evaluate_points

    if margin is None:
        margin = DEFAULT_MARGIN
    t0 = time.perf_counter()
    preds = grid_predictions(pt, designs, unrolls)
    keep = select_band(preds, margin)
    grid = [(dp, u) for dp in designs for u in unrolls]
    _vlog(verbose,
          f"{pt.trace.name}: surrogate ranked {len(grid)} points in "
          f"{time.perf_counter() - t0:.3f}s; band kept {sum(keep)} "
          f"(margin {margin:g})")

    results: dict[int, DSEPoint] = {}
    todo: list[tuple[int, "str | None"]] = []
    for i, k in enumerate(keep):
        if not k:
            continue
        dp, u = grid[i]
        key = (point_key(pt.fingerprint, dp, u, mem_latency)
               if cache else None)
        hit = cache.get(key) if cache else None
        if hit is not None:
            results[i] = hit
        else:
            todo.append((i, key))
    n_hits = sum(keep) - len(todo)

    if todo:
        t0 = time.perf_counter()
        out = evaluate_points(pt, [grid[i] for i, _ in todo], mem_latency,
                              front_cap=True)
        capped = 0
        for (i, key), p in zip(todo, out):
            if p is None:
                capped += 1
                continue
            results[i] = p
            if cache:
                cache.put(key, p)
        _vlog(verbose,
              f"{pt.trace.name}: simulated {len(todo) - capped} points "
              f"({capped} front-capped, {n_hits} cache hits) in "
              f"{time.perf_counter() - t0:.3f}s")
    return [results[i] for i in sorted(results)]


def _run_pooled(
    pt: PreparedTrace,
    chunks: "list[list[tuple[int, DesignPoint, int]]]",
    mem_latency: int,
    backend: str,
    results: "list[DSEPoint | None]",
    *,
    n_jobs: int,
    dedicated: bool,
    chunk_timeout: "float | None",
    chunk_retries: int,
    verbose: bool,
    done: int,
    total: int,
) -> None:
    """Dispatch ``chunks`` to worker processes with bounded self-repair.

    Failure handling (the chaos-test contract):

    * a chunk that raises a *real* exception propagates — worker bugs
      must not be silently retried;
    * a worker crash (``BrokenProcessPool``) or a chunk exceeding
      ``chunk_timeout`` marks the pool dead: it is forcibly torn down,
      a fresh pool is built after an exponential backoff, and every
      chunk whose result was not yet harvested is re-dispatched;
    * after ``chunk_retries`` failed rounds the surviving chunks are
      evaluated serially in-process — a sweep never returns partial
      results because of infrastructure failures.

    Results are written into ``results`` by grid index, so retries and
    the serial fallback are bitwise-invisible in the output.
    """
    from concurrent.futures import (BrokenExecutor, ProcessPoolExecutor,
                                    TimeoutError as _FutTimeout)

    bare = _bare_trace(pt.trace)
    tr_arg = None if dedicated else bare
    pending = chunks
    attempt = 0
    t0 = time.perf_counter()
    while pending:
        if dedicated:
            # ship the trace once per worker via the pool initializer
            pool = ProcessPoolExecutor(
                max_workers=n_jobs, initializer=_worker_init,
                initargs=(pt.fingerprint, bare))
        else:
            pool = _get_pool(n_jobs)
        futs = [(pool.submit(_worker_eval_chunk, pt.fingerprint, tr_arg,
                             c, mem_latency, backend), c) for c in pending]
        survivors: list = []
        broken = False
        for fut, chunk in futs:
            if broken:
                # the pool is already condemned: harvest chunks that did
                # finish, requeue the rest without waiting on them
                if fut.done() and fut.exception() is None:
                    for idx, point in fut.result():
                        results[idx] = point
                    done += len(chunk)
                else:
                    survivors.append(chunk)
                continue
            try:
                rows = fut.result(timeout=chunk_timeout)
            except (BrokenExecutor, _FutTimeout) as e:
                broken = True
                survivors.append(chunk)
                _vlog(verbose,
                      f"{pt.trace.name}: pool failure "
                      f"({type(e).__name__}) on a chunk of {len(chunk)}; "
                      f"attempt {attempt + 1}/{chunk_retries + 1}")
                continue
            for idx, point in rows:
                results[idx] = point
            done += len(chunk)
            _vlog(verbose,
                  f"{pt.trace.name}: chunk of {len(chunk)} done "
                  f"({done}/{total}) at {time.perf_counter() - t0:.3f}s")
        if broken:
            if dedicated:
                _kill_executor(pool)
            else:
                kill_pool()
        elif dedicated:
            pool.shutdown(wait=True)
        if not survivors:
            return
        attempt += 1
        if attempt > chunk_retries:
            _vlog(verbose,
                  f"{pt.trace.name}: {chunk_retries} pool retries "
                  f"exhausted; evaluating {sum(map(len, survivors))} "
                  "remaining points serially")
            for chunk in survivors:
                for idx, dp, u in chunk:
                    results[idx] = evaluate_point(pt, dp, u, mem_latency,
                                                  backend=backend)
                done += len(chunk)
            return
        time.sleep(min(1.0, 0.05 * 2 ** attempt))
        pending = survivors


def _run_batched_jax(
    pt: PreparedTrace,
    tasks: "list[tuple[int, DesignPoint, int]]",
    mem_latency: int,
    results: "list[DSEPoint | None]",
    batch_lanes: int = 256,
) -> None:
    """Evaluate uncached points through ``jax_cycle.schedule_batched``.

    One jit call per ``batch_lanes`` grid points (bounded device
    memory); costing happens host-side through the same
    ``point_from_schedule`` every other backend uses.
    """
    from repro.core.dse.sweep import point_from_schedule, schedule_config_for
    from repro.core.sim.jax_cycle import schedule_batched

    for lo in range(0, len(tasks), batch_lanes):
        chunk = tasks[lo:lo + batch_lanes]
        cfgs = [schedule_config_for(pt, dp, u, mem_latency)
                for _, dp, u in chunk]
        scheds = schedule_batched(pt, cfgs)
        for (idx, dp, u), cfg, res in zip(chunk, cfgs, scheds):
            results[idx] = point_from_schedule(pt, dp, u, cfg, res)


def _legality_pass(pt: PreparedTrace, designs: Sequence[DesignPoint],
                   mem_latency: int, points: "Sequence[DSEPoint]",
                   verbose: bool) -> None:
    """Independently re-check every sweep point's schedule legality.

    Each point's config is rebuilt from its design label, re-scheduled
    with issue-event logging, and validated by ``repro.core.verify``;
    the sweep's own cycle count is cross-checked against the audited
    run, so a stale/corrupt cache entry also fails here.  Raises
    ``LegalityError`` on the first violating point.
    """
    from repro.core.dse.sweep import schedule_config_for
    from repro.core.verify import Violation, check_schedule

    by_label = {dp.label: dp for dp in designs}
    t0 = time.perf_counter()
    for p in points:
        cfg = schedule_config_for(pt, by_label[p.design], p.unroll,
                                  mem_latency)
        rep = check_schedule(pt, cfg)
        if rep.result.cycles != p.cycles:
            rep.violations.append(Violation(
                "counter",
                f"sweep point {p.design}@u{p.unroll} reports {p.cycles} "
                f"cycles but the audited re-run took "
                f"{rep.result.cycles}"))
        rep.raise_if_failed()
    _vlog(verbose,
          f"{pt.trace.name}: legality-checked {len(points)} points in "
          f"{time.perf_counter() - t0:.3f}s (0 violations)")


def run_sweep(
    tr: "T.Trace | PreparedTrace",
    designs: Sequence[DesignPoint] = DEFAULT_DESIGNS,
    unrolls: Iterable[int] = DEFAULT_UNROLLS,
    *,
    mem_latency: int = 2,
    jobs: "int | None" = None,
    cache_dir: "str | Path | None" = None,
    cache: "SweepCache | None" = None,
    backend: str = "auto",
    prune: "str | None" = None,
    margin: "float | None" = None,
    faults=None,
    chunk_timeout: "float | None" = None,
    chunk_retries: int = 2,
    verbose: bool = False,
    check: bool = False,
) -> list[DSEPoint]:
    """Evaluate every ``(design, unroll)`` composition on one trace.

    Args:
      tr: trace (raw or prepared) to sweep.
      designs / unrolls: the composition grid; results are returned in
        ``designs``-major, ``unrolls``-minor order.
      mem_latency: load issue-to-data latency forwarded to the scheduler.
      jobs: worker processes.  ``None``/``0``/``1`` evaluates serially
        in-process; ``>1`` uses a shared process pool with chunked work
        units — but only once the estimated work clears
        ``_MIN_PARALLEL_WORK``, so tiny sweeps stay serial and fast.
        Ignored by the ``jax`` backend, which batches instead of forking.
      cache_dir: directory for the on-disk result cache (defaults to the
        ``REPRO_DSE_CACHE`` env var; no caching when unset).
      cache: pre-constructed :class:`SweepCache` (overrides cache_dir).
      backend: scheduler execution backend — ``auto``/``c`` (compiled C
        loop with pure-Python fallback), ``py`` (reference loop) or
        ``jax`` (whole-grid ``schedule_batched``; bypasses the process
        pool, keeps the on-disk cache).  All backends produce bitwise
        identical points, so cache entries are backend-independent.
      prune: ``"surrogate"`` ranks the grid with the analytic cycle
        predictor and exact-simulates only the predicted Pareto band
        (one batched C call with in-C front caps).  Returns a
        designs-major *subsequence* of the grid that still contains the
        exact time/area Pareto front; points it does return are bitwise
        identical to the exhaustive sweep (and share its cache entries).
        The surrogate is calibrated at ``mem_latency == 2`` on the
        MachSuite trace families (``surrogate.CALIBRATED_BENCHES``);
        other latencies and uncalibrated trace families (e.g. the
        LLM-serving benches) fall back to the exhaustive sweep.  The
        pruned path
        evaluates through the batched C scheduler, ignoring ``jobs``
        and ``backend``.
      margin: safety slack on predicted time for the surrogate band
        (default :data:`repro.core.dse.surrogate.DEFAULT_MARGIN`).
      faults: a :class:`repro.core.fault.FaultConfig` (or fault count
        int) to run a seeded fault campaign per distinct design and
        fill each point's ``res_*`` fields.  Campaigns run at a
        canonical 256x32b geometry — resilience is a property of the
        design, not the workload — and are attached *after* cache
        load/store, so cache entries stay fault-agnostic.
      chunk_timeout: seconds to wait for one pooled chunk before the
        pool is declared hung, torn down and the chunk re-dispatched
        (``None`` = wait forever).
      chunk_retries: pool rebuild attempts (crash or timeout) before
        the remaining chunks fall back to serial in-process evaluation.
      verbose: per-chunk progress lines on stderr (points done/total,
        cache hits, chunk wall-clock).
      check: run the independent legality checker
        (``repro.core.verify``) over every returned point after the
        sweep: each point's schedule is re-executed with issue-event
        logging, validated against rules compiled from its AMMSpecs,
        its static lower bounds, and the sweep's own cycle count
        (catching stale cache entries too).  Raises
        ``repro.core.verify.LegalityError`` on any violation.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    if prune not in (None, "surrogate"):
        raise ValueError(f"prune must be None or 'surrogate', got {prune!r}")
    unrolls = tuple(unrolls)
    pt = prepare_trace(tr)
    if cache is None:
        cache = _resolve_cache(cache_dir)

    if prune == "surrogate":
        from repro.core.dse.surrogate import (CALIBRATED_BENCHES,
                                              CALIBRATED_MEM_LATENCY)

        if mem_latency != CALIBRATED_MEM_LATENCY:
            _vlog(verbose,
                  f"{pt.trace.name}: surrogate calibrated at mem_latency="
                  f"{CALIBRATED_MEM_LATENCY}, got {mem_latency}: "
                  "running exhaustive")
        elif pt.trace.name not in CALIBRATED_BENCHES:
            # uncalibrated trace family (e.g. the serving benches):
            # exactness over speed — run the full grid
            _vlog(verbose,
                  f"{pt.trace.name}: trace family not in the surrogate "
                  "calibration set: running exhaustive")
        else:
            pruned = _run_pruned(pt, designs, unrolls, mem_latency, cache,
                                 margin, verbose)
            if check:
                _legality_pass(pt, designs, mem_latency, pruned, verbose)
            return _attach_faults(pruned, designs, faults)

    tasks: list[tuple[int, DesignPoint, int]] = []
    results: list["DSEPoint | None"] = []
    keys: list["str | None"] = []
    for dp in designs:
        for u in unrolls:
            idx = len(results)
            key = (point_key(pt.fingerprint, dp, u, mem_latency)
                   if cache else None)
            hit = cache.get(key) if cache else None
            results.append(hit)
            keys.append(key)
            if hit is None:
                tasks.append((idx, dp, u))

    total = len(designs) * len(unrolls)
    n_cached = total - len(tasks)
    _vlog(verbose, f"{pt.trace.name}: {n_cached}/{total} points cached, "
                   f"{len(tasks)} to evaluate")
    done = n_cached

    n_jobs = jobs or 0
    if backend == "jax":
        _run_batched_jax(pt, tasks, mem_latency, results)
    elif (n_jobs > 1 and len(tasks) > 1
            and len(tasks) * pt.n_nodes >= _MIN_PARALLEL_WORK):
        n_jobs = min(n_jobs, len(tasks))
        chunks = _chunked(tasks, n_jobs * 2)
        _run_pooled(pt, chunks, mem_latency, backend, results,
                    n_jobs=n_jobs,
                    dedicated=pt.n_nodes >= _LARGE_TRACE_NODES,
                    chunk_timeout=chunk_timeout,
                    chunk_retries=chunk_retries,
                    verbose=verbose, done=done, total=total)
    else:
        for chunk in _chunked(tasks, max(1, (len(tasks) + 15) // 16)):
            t0 = time.perf_counter()
            for idx, dp, u in chunk:
                results[idx] = evaluate_point(pt, dp, u, mem_latency,
                                              backend=backend)
            done += len(chunk)
            _vlog(verbose,
                  f"{pt.trace.name}: chunk of {len(chunk)} in "
                  f"{time.perf_counter() - t0:.3f}s ({done}/{total})")

    if cache:
        for idx, _, _ in tasks:
            cache.put(keys[idx], results[idx])

    assert all(p is not None for p in results)
    if check:
        _legality_pass(pt, designs, mem_latency, results, verbose)
    return _attach_faults(results, designs, faults)  # type: ignore


def run_sweep_bench(
    bench: str,
    designs: Sequence[DesignPoint] = DEFAULT_DESIGNS,
    unrolls: Iterable[int] = DEFAULT_UNROLLS,
    *,
    params=None,
    full: bool = False,
    mem_latency: int = 2,
    jobs: "int | None" = None,
    cache_dir: "str | Path | None" = None,
    cache: "SweepCache | None" = None,
    backend: str = "auto",
    prune: "str | None" = None,
    margin: "float | None" = None,
    faults=None,
    chunk_timeout: "float | None" = None,
    chunk_retries: int = 2,
    verbose: bool = False,
    check: bool = False,
    stats: "dict | None" = None,
) -> list[DSEPoint]:
    """Sweep a registered benchmark by name, with a cold fast path.

    When every grid point is already cached, the sweep never generates
    or prepares the trace: the cache's ``manifest.json`` maps the
    benchmark identity (:func:`repro.core.bench.trace_cache_key` — pure
    in the generator source + params) to the trace fingerprint, and the
    points are served straight from disk in designs-major order.  Any
    miss falls through to :func:`run_sweep` on the real trace, which
    then records the manifest entry for next time.

    The fast path always returns the *full* grid — with every point
    cached, pruning would save nothing.  ``stats`` (optional dict) gets
    ``fast_path`` (bool) and, when the trace was prepared,
    ``prepared`` (the :class:`PreparedTrace`).
    """
    import repro.core.bench as bench_mod

    if cache is None:
        cache = _resolve_cache(cache_dir)
    unrolls = tuple(unrolls)
    bkey = bench_mod.trace_cache_key(bench, params, full=full)

    # a legality audit re-runs every schedule against the real trace,
    # so the trace-free fully-cached fast path cannot serve it
    if cache is not None and not check:
        fp = cache.manifest_get(bkey)
        if fp is not None:
            hits: "list[DSEPoint] | None" = []
            for dp in designs:
                for u in unrolls:
                    hit = cache.get(point_key(fp, dp, u, mem_latency))
                    if hit is None:
                        hits = None
                        break
                    hits.append(hit)
                if hits is None:
                    break
            if hits is not None:
                _vlog(verbose, f"{bench}: fully cached ({len(hits)} "
                               "points), trace generation skipped")
                if stats is not None:
                    stats["fast_path"] = True
                return _attach_faults(hits, designs, faults)

    tr = bench_mod.get_trace(bench, params, full=full)
    pt = prepare_trace(tr)
    if stats is not None:
        stats["fast_path"] = False
        stats["prepared"] = pt
    res = run_sweep(pt, designs, unrolls, mem_latency=mem_latency,
                    jobs=jobs, cache=cache, backend=backend, prune=prune,
                    margin=margin, faults=faults,
                    chunk_timeout=chunk_timeout,
                    chunk_retries=chunk_retries, verbose=verbose,
                    check=check)
    if cache is not None:
        cache.manifest_put(bkey, pt.fingerprint)
    return res


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------
def _parse_unrolls(text: str) -> tuple[int, ...]:
    return tuple(int(x) for x in text.split(",") if x)


def main(argv: "Sequence[str] | None" = None) -> None:
    import argparse

    from repro.core.bench import BENCHMARKS
    from repro.core.dse.pareto import design_space_expansion, pareto_front

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.dse.runner",
        description="Parallel, cached DSE sweep over one MachSuite trace.")
    ap.add_argument("--bench", required=True, choices=sorted(BENCHMARKS),
                    help="benchmark trace to sweep")
    ap.add_argument("--jobs", type=int, default=os.cpu_count() or 1,
                    help="worker processes (1 = serial; default: #cpus)")
    ap.add_argument("--full", action="store_true",
                    help="full-size trace instead of TINY")
    ap.add_argument("--unrolls", type=_parse_unrolls,
                    default=DEFAULT_UNROLLS, metavar="1,2,4,8",
                    help="comma-separated unroll factors")
    ap.add_argument("--mem-latency", type=int, default=2)
    ap.add_argument("--cache-dir", default=None,
                    help=f"on-disk result cache (or ${_ENV_CACHE_DIR})")
    ap.add_argument("--backend", choices=BACKENDS, default="auto",
                    help="cycle-loop backend (jax = one batched jit call "
                         "for the whole grid, bypassing the process pool)")
    ap.add_argument("--prune", choices=("surrogate",), default=None,
                    help="surrogate-pruned sweep: exact-simulate only the "
                         "predicted Pareto band (subset output; exact "
                         "front preserved)")
    ap.add_argument("--margin", type=float, default=None,
                    help="surrogate band safety margin on predicted time "
                         "(default: surrogate.DEFAULT_MARGIN)")
    ap.add_argument("--faults", type=int, default=0, metavar="N",
                    help="inject an N-fault seeded campaign per design "
                         "and emit the res_* resilience columns (0 = off)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="campaign RNG seed (with --faults)")
    ap.add_argument("--fault-cycles", type=int, default=128,
                    help="campaign trace length in cycles (with --faults)")
    ap.add_argument("--chunk-timeout", type=float, default=None,
                    metavar="SEC",
                    help="per-chunk worker timeout before the pool is "
                         "torn down and the chunk re-dispatched")
    ap.add_argument("--chunk-retries", type=int, default=2,
                    help="pool rebuilds before serial fallback")
    ap.add_argument("--check", action="store_true",
                    help="audit every emitted point with the independent "
                         "legality checker (repro.core.verify): event-log "
                         "invariants + static hazard lower bounds; exits "
                         "nonzero on any violation")
    ap.add_argument("--front-only", action="store_true",
                    help="emit only Pareto-front rows (grid order kept); "
                         "pruned and exhaustive sweeps agree on this "
                         "output, so it diffs clean")
    ap.add_argument("--verbose", action="store_true",
                    help="per-chunk progress lines on stderr")
    args = ap.parse_args(argv)

    cache = _resolve_cache(args.cache_dir)
    faults = None
    if args.faults > 0:
        from repro.core.fault import FaultConfig

        faults = FaultConfig(n_faults=args.faults, seed=args.fault_seed,
                             n_cycles=args.fault_cycles)
    stats: dict = {}
    t0 = time.perf_counter()
    pts = run_sweep_bench(args.bench, DEFAULT_DESIGNS, args.unrolls,
                          full=args.full, mem_latency=args.mem_latency,
                          jobs=args.jobs, cache=cache,
                          backend=args.backend, prune=args.prune,
                          margin=args.margin, faults=faults,
                          chunk_timeout=args.chunk_timeout,
                          chunk_retries=args.chunk_retries,
                          verbose=args.verbose, check=args.check,
                          stats=stats)
    t_sweep = time.perf_counter() - t0

    emit = pts
    if args.front_only:
        on_front = {(p.design, p.unroll) for p in pareto_front(pts)}
        emit = [p for p in pts if (p.design, p.unroll) in on_front]

    # header and rows both derive from DSEPoint.row(): new fields (e.g.
    # cycle_ns) appear in the CSV automatically instead of drifting
    cols = [f.name for f in dataclasses.fields(DSEPoint)]
    print(",".join(cols))
    for p in emit:
        row = p.row()
        print(",".join(f"{row[c]:.6g}" if isinstance(row[c], float)
                       else str(row[c]) for c in cols))

    banking = [p for p in pts if not p.is_amm]
    amm = [p for p in pts if p.is_amm]
    pt = stats.get("prepared")
    trace_info = (f"nodes={pt.n_nodes} locality={pt.locality:.3f}"
                  if pt is not None else "trace=cached-manifest")
    print(f"# {trace_info} points={len(pts)} "
          f"sweep={t_sweep*1e3:.1f}ms jobs={args.jobs} "
          f"backend={args.backend}"
          + (f" prune={args.prune}" if args.prune else ""))
    if banking and amm:
        print(f"# expansion={design_space_expansion(banking, amm):.2f} "
              f"pareto_banked={len(pareto_front(banking))} "
              f"pareto_amm={len(pareto_front(amm))}")
    if args.check:
        # run_sweep_bench raised LegalityError before reaching here if
        # any point violated a rule or a static bound
        print(f"# legality: {len(pts)} points audited "
              "(event-log invariants + static bounds), 0 violations")
    if cache:
        print(f"# cache: dir={cache.root} hits={cache.hits} "
              f"misses={cache.misses}")


if __name__ == "__main__":
    main()
