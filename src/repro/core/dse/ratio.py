"""Performance Ratio (paper IV-C):

    PerfRatio = ((a_1 * ... * a_n) / (b_1 * ... * b_n))^(1/n)

where a_i is the area of the banking structure and b_i the area of the
AMM design *at similar execution times* — the geometric mean of the
area advantage over the common reachable time range.  >1 means AMM needs
less area than banking for the same speed (higher is better, Fig 5).

:func:`spearman_rho` quantifies the paper's Fig-5 claim across a suite:
the rank correlation between per-benchmark spatial locality and
performance ratio (the claim holds when it is clearly negative).
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from repro.core.dse.pareto import cost_at_time, pareto_front
from repro.core.dse.sweep import DSEPoint


def performance_ratio(points: Sequence[DSEPoint], n_samples: int = 12) -> float:
    banking = [p for p in points if not p.is_amm]
    amm = [p for p in points if p.is_amm]
    if not banking or not amm:
        return float("nan")
    fb = pareto_front(banking)
    fa = pareto_front(amm)
    # common reachable range: both families must reach t.  The lower
    # bound is the slower family's fastest point; the upper bound is the
    # *min* of the per-front maxima — sampling beyond the slower front's
    # last point would only re-measure both fronts' flat tails and pad
    # the geomean with constant ratios.
    t_lo = max(min(p.time_us for p in fb), min(p.time_us for p in fa))
    t_hi = min(max(p.time_us for p in fb), max(p.time_us for p in fa))
    if t_hi <= t_lo:
        t_hi = t_lo * 1.01
    ts = np.geomspace(t_lo, t_hi, n_samples)
    logs = []
    for t in ts:
        a = cost_at_time(fb, float(t))
        b = cost_at_time(fa, float(t))
        if math.isfinite(a) and math.isfinite(b) and a > 0 and b > 0:
            logs.append(math.log(a / b))
    if not logs:
        return float("nan")
    return math.exp(sum(logs) / len(logs))


def _ranks(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties share the mean of their rank range)."""
    order = np.argsort(x, kind="stable")
    ranks = np.empty(x.shape[0], np.float64)
    ranks[order] = np.arange(x.shape[0], dtype=np.float64)
    for v in np.unique(x):
        m = x == v
        if m.sum() > 1:
            ranks[m] = ranks[m].mean()
    return ranks


def spearman_rho(x: Sequence[float], y: Sequence[float]) -> float:
    """Spearman rank correlation (Pearson on average ranks), ``nan`` for
    fewer than 3 pairs or a constant sequence."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    keep = np.isfinite(x) & np.isfinite(y)
    x, y = x[keep], y[keep]
    if x.size < 3:
        return float("nan")
    rx, ry = _ranks(x), _ranks(y)
    sx, sy = rx.std(), ry.std()
    if sx == 0 or sy == 0:
        return float("nan")
    return float(np.mean((rx - rx.mean()) * (ry - ry.mean())) / (sx * sy))
