"""Performance Ratio (paper IV-C):

    PerfRatio = ((a_1 * ... * a_n) / (b_1 * ... * b_n))^(1/n)

where a_i is the area of the banking structure and b_i the area of the
AMM design *at similar execution times* — the geometric mean of the
area advantage over the common reachable time range.  >1 means AMM needs
less area than banking for the same speed (higher is better, Fig 5).
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from repro.core.dse.pareto import cost_at_time, pareto_front
from repro.core.dse.sweep import DSEPoint


def performance_ratio(points: Sequence[DSEPoint], n_samples: int = 12) -> float:
    banking = [p for p in points if not p.is_amm]
    amm = [p for p in points if p.is_amm]
    if not banking or not amm:
        return float("nan")
    fb = pareto_front(banking)
    fa = pareto_front(amm)
    # common reachable range: both families must reach t
    t_lo = max(min(p.time_us for p in fb), min(p.time_us for p in fa))
    t_hi = max(max(p.time_us for p in fb), max(p.time_us for p in fa))
    if t_hi <= t_lo:
        t_hi = t_lo * 1.01
    ts = np.geomspace(t_lo, t_hi, n_samples)
    logs = []
    for t in ts:
        a = cost_at_time(fb, float(t))
        b = cost_at_time(fa, float(t))
        if math.isfinite(a) and math.isfinite(b) and a > 0 and b > 0:
            logs.append(math.log(a / b))
    if not logs:
        return float("nan")
    return math.exp(sum(logs) / len(logs))
