"""Core library: the paper's contribution.

- ``repro.core.amm``      — algorithmic multi-port memory designs
- ``repro.core.sim``      — dynamic trace / DDG / port-constrained scheduler
- ``repro.core.cost``     — CACTI-like SRAM + synthesized-logic cost models
- ``repro.core.bench``    — MachSuite-like benchmark traces
- ``repro.core.locality`` — Weinberg spatial-locality metric
- ``repro.core.dse``      — design-space sweep, Pareto, performance ratio
"""
from repro.core.amm.spec import AMM_KINDS, AMMSpec
from repro.core.locality import (spatial_locality_jax, spatial_locality_np,
                                 trace_locality)

__all__ = [
    "AMMSpec", "AMM_KINDS", "make_amm",
    "spatial_locality_np", "spatial_locality_jax", "trace_locality",
]


def __getattr__(name: str):
    # make_amm pulls the JAX-backed AMM state machines; resolve lazily so
    # the numpy-only scheduler/DSE stack never pays the jax import.
    if name == "make_amm":
        from repro.core.amm import sim
        return sim.make_amm
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
