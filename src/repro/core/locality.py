"""Weinberg spatial-locality metric (paper eq. 1).

    L_spatial = sum_{stride=1..inf} P(stride) / stride

where *stride* is the byte difference between consecutive addresses
referenced by the program's load/store stream (Weinberg et al., SC'05).

Conventions (documented because the paper leaves them implicit):
  * strides are measured in **bytes** — the paper notes byte-oriented
    stride-one programs (KMP, AES) score ~1 while double-precision
    programs have a minimum stride of 8 bytes (-> max contribution 1/8);
  * negative strides contribute with their magnitude;
  * stride 0 (same address re-referenced) is *temporal*, not spatial
    locality, and is excluded from the distribution, matching Weinberg.

Both a numpy and a JAX implementation are provided; they agree exactly
(property-tested).
"""
from __future__ import annotations

import numpy as np

from repro.core._lazy import lazy_import

jax = lazy_import("jax")
jnp = lazy_import("jax.numpy")


def spatial_locality_np(addrs_bytes: np.ndarray) -> float:
    """Weinberg L_spatial over a dynamic byte-address reference stream."""
    a = np.asarray(addrs_bytes, dtype=np.int64)
    if a.size < 2:
        return 0.0
    strides = np.abs(np.diff(a))
    strides = strides[strides > 0]
    if strides.size == 0:
        return 0.0
    # P(stride)/stride summed over the empirical distribution ==
    # mean over references of 1/stride.
    total = np.sum(1.0 / strides.astype(np.float64))
    # Normalize by the number of *transitions* (incl. stride-0 ones), so
    # temporally-repeated references dilute spatial locality as in Weinberg.
    return float(total / (a.size - 1))


_SPATIAL_JAX_JIT = None


def spatial_locality_jax(addrs_bytes) -> "jax.Array":
    """JAX twin of :func:`spatial_locality_np` (jit-compiled on first use,
    so importing this module does not pull in jax).

    Robust to disabled x64: host arrays are differenced in exact int64
    *before* they reach the device (transferring raw int64 byte
    addresses under ``jax_enable_x64=False`` silently truncates them to
    int32, wrapping addresses above 2**31 into garbage strides), and the
    reciprocal is taken on float64-cast strides.
    """
    global _SPATIAL_JAX_JIT
    if _SPATIAL_JAX_JIT is None:
        @jax.jit
        def _impl(strides, n_transitions):
            # float64 when x64 is enabled, float32 otherwise (the exact
            # int64 differencing already happened host-side)
            strides = jnp.abs(strides).astype(jnp.result_type(float))
            contrib = jnp.where(strides > 0,
                                1.0 / jnp.maximum(strides, 1.0), 0.0)
            return jnp.sum(contrib) / jnp.maximum(n_transitions, 1)
        _SPATIAL_JAX_JIT = _impl
    if isinstance(addrs_bytes, jax.Array):
        strides = jnp.diff(addrs_bytes)
    else:
        strides = np.diff(np.asarray(addrs_bytes, np.int64)).astype(
            np.float64)
    return _SPATIAL_JAX_JIT(strides, strides.shape[0])


def per_array_locality(addrs_bytes: np.ndarray,
                       array_ids: np.ndarray) -> dict[int, float]:
    """L_spatial per logical array, as Aladdin partitions per array."""
    out: dict[int, float] = {}
    for aid in np.unique(array_ids):
        out[int(aid)] = spatial_locality_np(addrs_bytes[array_ids == aid])
    return out


def trace_locality(addrs_bytes: np.ndarray, array_ids: np.ndarray) -> float:
    """Access-weighted mean of per-array localities (the per-benchmark
    scalar plotted in the paper's Fig 5)."""
    ids = np.asarray(array_ids)
    total, weight = 0.0, 0
    for aid in np.unique(ids):
        m = ids == aid
        n = int(m.sum())
        total += spatial_locality_np(np.asarray(addrs_bytes)[m]) * n
        weight += n
    return total / max(weight, 1)
