"""Independent re-derivation of the per-kind memory geometry.

The legality checker must not trust the arbitration layer it checks, so
this module re-derives every structural fact straight from the
:class:`~repro.core.amm.spec.AMMSpec` — deliberately *not* importing
``arbiter.compile_spec`` / ``arbiter.ntx_tables`` and deliberately
using a different construction style (scalar recursion +
``itertools.product`` instead of the arbiter's vectorized bit loops).
A bug in the shared leaf-path formula therefore shows up as a
divergence here instead of being reproduced.

NTX geometry recap (paper Sec. II): a ``2**k``-read tree halves the
address space ``k`` times; at each level a word lives in one child
(its *direct* branch) while the third, *ref* branch stores the XOR of
the two children.  Labelling branches base-3 (0 = low half, 1 = high
half, 2 = ref), the direct leaf of a word is the base-3 number of its
half-choices, and a word is reconstructible from any leaf set obtained
by swapping, per level, the direct digit for {opposite-half, ref} —
the checker enumerates those ``2**k`` parity alternatives explicitly
as a cartesian product.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from itertools import product

from repro.core.amm.spec import AMMSpec

# base-3 branch digits
_LOW, _HIGH, _REF = 0, 1, 2


def _digits_to_leaf(digits: "tuple[int, ...]") -> int:
    leaf = 0
    for d in digits:
        leaf = leaf * 3 + d
    return leaf


@lru_cache(maxsize=None)
def leaf_paths(tree_depth: int, k: int
               ) -> "tuple[tuple[int, int, tuple[int, ...]], ...]":
    """Per-address ``(direct_leaf, leaf_offset, parity_leaves)`` of one
    NTX tree with ``k`` split levels over ``tree_depth`` words.

    ``parity_leaves`` is the full XOR path: per level the word's direct
    digit is replaced by one of {opposite half, ref}, so the path is
    the cartesian product of those two choices over all levels
    (``2**k`` leaves; for ``k == 0`` the path degenerates to the single
    root leaf, i.e. parity offers no alternative to the direct port).
    """
    out = []
    for addr in range(tree_depth):
        digits: list[int] = []
        off, span = addr, tree_depth
        for _ in range(k):
            span //= 2
            if off >= span:
                digits.append(_HIGH)
                off -= span
            else:
                digits.append(_LOW)
        direct = _digits_to_leaf(tuple(digits))
        # per level the parity path may use the opposite data half
        # (1 - digit) or the ref branch — every combination is a leaf
        # whose XOR chain reconstructs the word
        alts = [(1 - d, _REF) for d in digits]
        parity = tuple(sorted(_digits_to_leaf(c) for c in product(*alts)))
        out.append((direct, off, parity))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ArrayRules:
    """Declarative legality facts for one array's memory design.

    Everything is re-derived from the AMMSpec fields (kinds' structure
    per the paper), not read out of an ``ArbDescriptor``.
    """

    kind: str
    rd: int                     # loads issuable per cycle
    wr: int                     # stores issuable per cycle
    depth: int                  # words addressed (word % depth)
    slot_cap: "int | None"      # multipump: pumped total-access cap
    n_banks: int                # banked / remap internal banks
    lvt_broadcast: bool         # writes must be replica broadcasts
    # NTX structure (zeros/empty for other kinds)
    is_ntx: bool = False
    has_ref: bool = False       # b/hb: Ref tree twins every data access
    k: int = 0                  # read-tree split levels
    n_leaves: int = 1           # 3**k leaf banks per tree
    sub: int = 1                # word-interleaved sub-banks per leaf
    tree_depth: int = 1         # words per data tree
    half: int = 0               # b/hb top-level split point

    def key(self, tree: int, leaf: int, sub_off: int) -> int:
        """Pack one (tree, leaf, sub-bank) read-port id."""
        return (tree * self.n_leaves + leaf) * self.sub + sub_off


def compile_rules(spec: AMMSpec, ports_per_bank: int) -> ArrayRules:
    """Compile one AMMSpec into its declarative legality rules."""
    kind = spec.kind
    common = dict(kind=kind, rd=spec.n_read, wr=spec.n_write,
                  depth=spec.depth, slot_cap=None, n_banks=1,
                  lvt_broadcast=False)
    if kind == "multipump":
        # the advertised ports come from an internally double-clocked
        # dual-port macro: ports_per_bank accesses per internal cycle
        common["slot_cap"] = ports_per_bank * 2
    elif kind == "banked":
        common["n_banks"] = spec.n_banks
    elif kind == "remap":
        # one spare bank beyond the write ports makes steering total
        common["n_banks"] = spec.n_write + 1
    elif kind == "lvt":
        common["lvt_broadcast"] = True
    elif kind == "h_ntx_rd":
        k = spec.read_tree_levels
        return ArrayRules(**common, is_ntx=True, has_ref=False, k=k,
                          n_leaves=3 ** k, sub=max(spec.n_banks, 1),
                          tree_depth=spec.depth, half=0)
    elif kind in ("b_ntx_wr", "hb_ntx"):
        k = spec.read_tree_levels if kind == "hb_ntx" else 0
        return ArrayRules(**common, is_ntx=True, has_ref=True, k=k,
                          n_leaves=3 ** k, sub=max(spec.n_banks, 1),
                          tree_depth=spec.depth // 2, half=spec.depth // 2)
    elif kind != "ideal":
        raise ValueError(f"unknown AMM kind {kind!r}")
    return ArrayRules(**common)
