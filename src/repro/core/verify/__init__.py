"""Independent schedule-legality checking.

``check_schedule(trace, cfg)`` runs a backend with event logging on,
replays the paper's arbitration legality rules over the recorded
per-cycle issue events (:mod:`repro.core.verify.invariants`), and
asserts the static hazard certificates
(:mod:`repro.core.verify.static_bounds`) against the measured cycle
count.  The checker re-derives all geometry from the AMMSpec
(:mod:`repro.core.verify.geometry`) and shares no arbitration code
with ``repro.core.sim`` — a bug in a scheduler backend shows up as a
:class:`Violation` here instead of being silently reproduced.
"""
from __future__ import annotations

import dataclasses

from repro.core.sim.events import EventLog
from repro.core.sim.prepared import PreparedTrace, prepare_trace
from repro.core.verify.geometry import ArrayRules, compile_rules
from repro.core.verify.invariants import (RULE_CLASSES, Violation,
                                          verify_events)
from repro.core.verify.static_bounds import (BOUND_KINDS, check_bounds,
                                             static_bounds)

__all__ = [
    "ArrayRules", "BOUND_KINDS", "CheckReport", "LegalityError",
    "RULE_CLASSES", "Violation", "check_schedule", "check_bounds",
    "compile_rules", "static_bounds", "verify_events", "verify_result",
]


class LegalityError(AssertionError):
    """A schedule violated a legality rule or a static lower bound."""

    def __init__(self, report: "CheckReport") -> None:
        self.report = report
        lines = [f"{len(report.violations)} legality violation(s) "
                 f"(backend={report.backend}):"]
        lines += [f"  - {v}" for v in report.violations[:20]]
        if len(report.violations) > 20:
            lines.append(f"  ... {len(report.violations) - 20} more")
        super().__init__("\n".join(lines))


@dataclasses.dataclass
class CheckReport:
    """Everything one legality check produced."""

    result: "object"                    # the ScheduleResult
    events: EventLog
    violations: "list[Violation]"
    bounds: "dict[str, int]"            # static lower bounds, per kind
    backend: str

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_failed(self) -> None:
        if self.violations:
            raise LegalityError(self)


def verify_result(pt: PreparedTrace, cfg, res, events: EventLog,
                  backend: str = "?") -> CheckReport:
    """Check an already-run schedule's events + counters + bounds."""
    violations = verify_events(pt, cfg, res, events)
    bounds = static_bounds(pt, cfg)
    for kind, bound in sorted(bounds.items()):
        if res.cycles < bound:
            violations.append(Violation(
                "static_bound",
                f"measured {res.cycles} cycles is below the provable "
                f"{kind} lower bound of {bound}"))
    return CheckReport(result=res, events=events, violations=violations,
                       bounds=bounds, backend=backend)


def check_schedule(tr, cfg, backend: str = "auto") -> CheckReport:
    """Schedule ``tr`` under ``cfg`` with event logging and validate.

    ``tr`` may be a Trace or an already-prepared PreparedTrace.
    Returns the :class:`CheckReport`; callers that want an exception on
    failure use ``report.raise_if_failed()`` (as ``schedule(...,
    check=True)`` does).
    """
    from repro.core.sim.scheduler import schedule_events

    pt = prepare_trace(tr)
    res, events = schedule_events(pt, cfg, backend=backend)
    return verify_result(pt, cfg, res, events, backend=backend)
