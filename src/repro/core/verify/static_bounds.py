"""Static hazard certificates: provable cycle lower bounds.

Each bound is derived from the trace + config alone (no simulation) and
is *sound*: no legal schedule under the paper's arbitration semantics
can finish in fewer cycles.  The schedulers' measured ``cycles`` must
therefore satisfy ``cycles >= max(bounds)`` — a measured count below
any bound is a scheduler bug (or a checker bug), and
:func:`check_bounds` reports it as a ``static_bound`` violation.

All bounds use the repo-wide convention ``cycles == last finish + 1``:
an op stream of ``m`` accesses through a throughput-``t`` resource
issues its last op no earlier than cycle ``ceil(m/t) - 1``, which
finishes ``lmin`` cycles later (``lmin`` = the smallest latency among
those ops), so ``cycles >= ceil(m/t) + lmin``.

Bound kinds:

* ``critical_path`` — longest dependence chain (loads weighted at
  ``mem_latency``, other ops at their FU/store latency), plus one.
* ``port_pressure`` — per-array read/write port throughput, the
  multipump pumped-slot cap, and per-class FU counts.
* ``bank_conflict`` — banked: the fullest ``word % n_banks`` residue
  class through ``ports_per_bank`` macro ports; remap: the most-read
  single word (all live reads of a word target one bank per cycle).
* ``parity_pressure`` — NTX: a single address serves at most two reads
  per cycle (direct + one parity reconstruction; one when ``k == 0``),
  a (tree, sub-bank) group at most ``3**k`` reads per cycle (each read
  claims at least one leaf port), and a B/HB address half at most two
  stores per cycle (a plain write plus the single pair RMW).
"""
from __future__ import annotations

import numpy as np

from repro.core.sim.prepared import FU_ORDER, PreparedTrace
from repro.core.verify.geometry import compile_rules

BOUND_KINDS: tuple[str, ...] = ("critical_path", "port_pressure",
                                "bank_conflict", "parity_pressure")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _lat_eff(pt: PreparedTrace, mem_latency: int) -> np.ndarray:
    return np.where(pt.is_load_np.astype(bool), np.int64(mem_latency),
                    pt.latency_np)


def _critical_path(pt: PreparedTrace, mem_latency: int) -> int:
    """Longest-finish chain + 1.  Node ids are already topologically
    ordered (trace deps always reference earlier nodes), so one forward
    pass over the predecessor CSR suffices."""
    n = pt.trace.n_nodes
    if n == 0:
        return 0
    lat = _lat_eff(pt, mem_latency).tolist()
    pp = pt.trace.pred_ptr.tolist()
    pi = pt.trace.pred_idx.tolist()
    finish = [0] * n
    best = 0
    for v in range(n):
        start = 0
        for e in range(pp[v], pp[v + 1]):
            f = finish[pi[e]]
            if f > start:
                start = f
        fv = start + lat[v]
        finish[v] = fv
        if fv > best:
            best = fv
    return best + 1


def _throughput_bound(count: int, per_cycle: int, lmin: int) -> int:
    if count == 0:
        return 0
    return _ceil_div(count, max(per_cycle, 1)) + lmin


def static_bounds(pt: PreparedTrace, cfg) -> "dict[str, int]":
    """Compute every lower-bound kind for one (trace, config) pair."""
    n_arrays = pt.n_arrays
    klass = pt.klass_np
    is_load = pt.is_load_np.astype(bool)
    word = pt.word_index_np
    ml = cfg.mem_latency

    bounds = {k: 0 for k in BOUND_KINDS}
    bounds["critical_path"] = _critical_path(pt, ml)

    # ---- FU classes under port_pressure
    for f, name in enumerate(FU_ORDER):
        sel = klass == n_arrays + f
        cnt = int(sel.sum())
        if cnt:
            lmin = int(pt.latency_np[sel].min())
            bounds["port_pressure"] = max(
                bounds["port_pressure"],
                _throughput_bound(cnt, cfg.fu_counts.get(name, 1), lmin))

    for aid in range(n_arrays):
        spec = cfg.mem.get(aid)
        sel = klass == aid
        if spec is None or not sel.any():
            continue
        r = compile_rules(spec, cfg.ports_per_bank)
        loads = sel & is_load
        stores = sel & ~is_load
        n_l, n_s = int(loads.sum()), int(stores.sum())
        addrs = word[sel] % r.depth

        # ---- advertised read/write port throughput
        pp = max(_throughput_bound(n_l, r.rd, ml),
                 _throughput_bound(n_s, r.wr, 1))
        if r.slot_cap is not None:      # multipump shares pumped slots
            lmin = ml if n_l and (not n_s or ml < 1) else 1
            pp = max(pp, _throughput_bound(n_l + n_s, r.slot_cap, lmin))
        bounds["port_pressure"] = max(bounds["port_pressure"], pp)

        if r.kind == "banked":
            residues = addrs % r.n_banks
            lat_a = np.where(is_load[sel], ml, 1)
            for b in np.unique(residues):
                in_b = residues == b
                bounds["bank_conflict"] = max(
                    bounds["bank_conflict"],
                    _throughput_bound(int(in_b.sum()), cfg.ports_per_bank,
                                      int(lat_a[in_b].min())))
        elif r.kind == "remap":
            la = word[loads] % r.depth
            if la.size:
                # every live read of a word targets one bank that cycle
                top = int(np.bincount(la).max())
                bounds["bank_conflict"] = max(
                    bounds["bank_conflict"],
                    _throughput_bound(top, cfg.ports_per_bank, ml))
        elif r.is_ntx:
            la = word[loads] % r.depth
            if la.size:
                # one address: direct leaf + at most one parity rebuild
                cap = 2 if r.k > 0 else 1
                top = int(np.bincount(la).max())
                bounds["parity_pressure"] = max(
                    bounds["parity_pressure"],
                    _throughput_bound(top, cap, ml))
                # one (tree, sub-bank) group has 3**k leaf ports and
                # every read claims at least one of them
                trees = np.where(la >= r.half, 1, 0) if r.has_ref else \
                    np.zeros(la.shape, np.int64)
                tas = la - trees * r.half
                # leaf offset after k halvings is addr mod (depth >> k)
                span = max(r.tree_depth >> r.k, 1)
                subs = (tas % span) % r.sub
                grp = trees * r.sub + subs
                for g in np.unique(grp):
                    bounds["parity_pressure"] = max(
                        bounds["parity_pressure"],
                        _throughput_bound(int((grp == g).sum()),
                                          r.n_leaves, ml))
            if r.has_ref and n_s:
                sa = word[stores] % r.depth
                halves = np.where(sa >= r.half, 1, 0)
                for h in (0, 1):
                    cnt = int((halves == h).sum())
                    # per half: one plain write + the single pair RMW
                    bounds["parity_pressure"] = max(
                        bounds["parity_pressure"],
                        _throughput_bound(cnt, 2, 1))
    return bounds


def check_bounds(pt: PreparedTrace, cfg, cycles: int
                 ) -> "list[tuple[str, int]]":
    """Return the (kind, bound) pairs a measured cycle count violates."""
    return [(k, b) for k, b in static_bounds(pt, cfg).items()
            if cycles < b]
