"""Declarative legality rules, validated over issue-event logs.

Given a :class:`~repro.core.sim.prepared.PreparedTrace`, a
``ScheduleConfig`` and the :class:`~repro.core.sim.events.EventLog` a
backend recorded, :func:`verify_events` checks every invariant the
paper's arbitration semantics imply:

* **completeness** — every trace op issues exactly once, inside the
  reported cycle horizon;
* **dependence** — no op issues before every predecessor's value is
  available (predecessor issue + effective latency);
* **fu_budget** — at most ``fu_counts[class]`` compute issues per
  class per cycle, occupying distinct unit slots;
* **port_budget / slot_budget** — per-array read/write port budgets,
  plus multipump's pumped total-access cap;
* **slot_collision** — per-cycle per-class issue ordinals are the
  dense sequence 0..m-1 (no two ops share a port slot);
* **path_kind** — each design kind only emits its legal path kinds
  (LVT writes broadcast, remap writes steer, …);
* **bank_conflict** — banked accesses hit ``word % n_banks`` with at
  most ``ports_per_bank`` per bank; remap reads hit the *live* bank;
* **steering** — remap writes land exactly where the first-free-bank
  scan (re-implemented here) says they must;
* **parity_fanout / write_pair** — NTX leaf read-port exclusivity:
  direct reads claim their leaf (+Ref twin), parity reads claim the
  whole ``2**k`` fan-out, same-half write pairs claim the other-tree
  and Ref leaves through the single per-cycle Ref unit;
* **counter** — the ``ScheduleResult`` aggregates (issued counts,
  parity reads, pair RMWs, cycles, memory parallelism) must equal
  what the event log implies.

The implementation is numpy over the event arrays plus a per-cycle
replay for the stateful remap kind; it shares *no* code with
``repro.core.sim.arbiter`` (see :mod:`repro.core.verify.geometry`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sim.arbiter import STALL_KEYS
from repro.core.sim.events import (PATH_BROADCAST, PATH_COMPUTE, PATH_DIRECT,
                                   PATH_PAIR_RMW, PATH_PARITY, PATH_STEERED,
                                   PATH_NAMES, EventLog)
from repro.core.sim.prepared import FU_ORDER, PreparedTrace
from repro.core.verify.geometry import ArrayRules, compile_rules, leaf_paths

# every class a violation can carry; the structural-hazard classes are
# exactly the scheduler's stall taxonomy (STALL_KEYS) plus "steering"
# for remap write-placement errors
RULE_CLASSES: tuple[str, ...] = (
    "completeness", "dependence", "fu_budget", "port_budget",
    "slot_budget", "slot_collision", "path_kind", "steering", "counter",
    "static_bound") + STALL_KEYS


@dataclasses.dataclass(frozen=True)
class Violation:
    """One legality violation; ``rule`` is drawn from RULE_CLASSES."""

    rule: str
    detail: str
    node: int = -1
    array: int = -1
    cycle: int = -1

    def __str__(self) -> str:
        loc = []
        if self.node >= 0:
            loc.append(f"node {self.node}")
        if self.array >= 0:
            loc.append(f"array {self.array}")
        if self.cycle >= 0:
            loc.append(f"cycle {self.cycle}")
        where = f" [{', '.join(loc)}]" if loc else ""
        return f"{self.rule}: {self.detail}{where}"


_MAX_PER_RULE = 8          # cap repeated reports of one failure mode


class _Sink:
    def __init__(self) -> None:
        self.violations: "list[Violation]" = []
        self._per_rule: dict[str, int] = {}

    def add(self, rule: str, detail: str, node: int = -1, array: int = -1,
            cycle: int = -1) -> None:
        assert rule in RULE_CLASSES, rule
        seen = self._per_rule.get(rule, 0)
        self._per_rule[rule] = seen + 1
        if seen < _MAX_PER_RULE:
            self.violations.append(Violation(
                rule, detail, node=int(node), array=int(array),
                cycle=int(cycle)))


def _effective_latency(pt: PreparedTrace, mem_latency: int) -> np.ndarray:
    """Issue-to-result cycles per node: loads take ``mem_latency``,
    everything else its trace latency (stores 1, FU per class)."""
    return np.where(pt.is_load_np.astype(bool), np.int64(mem_latency),
                    pt.latency_np)


def verify_events(pt: PreparedTrace, cfg, res, events: EventLog,
                  ) -> "list[Violation]":
    """Validate one schedule's event log; returns all violations found."""
    sink = _Sink()
    n = pt.trace.n_nodes
    n_arrays = pt.n_arrays
    cyc = events.cycle
    path = events.path
    resr = events.resource
    slot = events.slot

    if events.n_nodes != n:
        sink.add("completeness",
                 f"event log has {events.n_nodes} entries, trace has {n}")
        return sink.violations
    if n == 0:
        if res.cycles != 0 or res.issued != 0 or res.mem_issued != 0:
            sink.add("counter", "empty trace with nonzero result counters")
        return sink.violations

    lat_eff = _effective_latency(pt, cfg.mem_latency)
    issued_ok = cyc >= 0

    # ---- completeness: every op issues exactly once, inside the horizon
    for node in np.flatnonzero(~issued_ok)[:_MAX_PER_RULE]:
        sink.add("completeness", "op never issued", node=node)
    finish = np.where(issued_ok, cyc + lat_eff, -1)
    horizon_bad = issued_ok & (finish > res.cycles - 1)
    for node in np.flatnonzero(horizon_bad)[:_MAX_PER_RULE]:
        sink.add("completeness",
                 f"op finishes at {int(finish[node])} beyond the reported "
                 f"{res.cycles}-cycle schedule", node=node,
                 cycle=int(cyc[node]))

    # ---- dependence: issue[s] >= issue[p] + effective_latency[p]
    succ_counts = np.diff(pt.succ_ptr)
    src = np.repeat(np.arange(n, dtype=np.int64), succ_counts)
    dst = pt.succ_idx
    edge_ok = issued_ok[src] & issued_ok[dst]
    viol = edge_ok & (cyc[dst] < cyc[src] + lat_eff[src])
    for e in np.flatnonzero(viol)[:_MAX_PER_RULE]:
        sink.add("dependence",
                 f"op issued at {int(cyc[dst[e]])} but its producer "
                 f"{int(src[e])} (issued {int(cyc[src[e]])}, latency "
                 f"{int(lat_eff[src[e]])}) was not complete",
                 node=int(dst[e]), cycle=int(cyc[dst[e]]))

    klass = pt.klass_np
    is_mem = klass < n_arrays
    # ---- path-kind sanity: compute <-> PATH_COMPUTE, memory never
    for node in np.flatnonzero(
            issued_ok & ~is_mem & (path != PATH_COMPUTE))[:_MAX_PER_RULE]:
        sink.add("path_kind", "compute op with a memory path kind",
                 node=node, cycle=int(cyc[node]))
    for node in np.flatnonzero(
            issued_ok & is_mem & (path == PATH_COMPUTE))[:_MAX_PER_RULE]:
        sink.add("path_kind", "memory op recorded as compute",
                 node=node, cycle=int(cyc[node]))

    # ---- FU budgets + slot uniqueness per (class, cycle)
    for f, name in enumerate(FU_ORDER):
        budget = cfg.fu_counts.get(name, 1)
        sel = issued_ok & (klass == n_arrays + f)
        if not sel.any():
            continue
        _check_slots(sink, np.flatnonzero(sel), cyc, slot, budget,
                     "fu_budget", f"FU class {name!r}", array=-1)

    # ---- per-array invariants
    rules: "list[ArrayRules | None]" = [None] * n_arrays
    for aid in range(n_arrays):
        spec = cfg.mem.get(aid)
        if spec is not None:
            rules[aid] = compile_rules(spec, cfg.ports_per_bank)
    word = pt.word_index_np
    is_load = pt.is_load_np.astype(bool)
    for aid in range(n_arrays):
        nodes = np.flatnonzero(issued_ok & (klass == aid))
        if nodes.size == 0:
            continue
        r = rules[aid]
        if r is None:
            sink.add("completeness",
                     "memory ops issued on an array with no AMMSpec",
                     node=int(nodes[0]), array=aid)
            continue
        _check_array(sink, aid, r, nodes, cyc, path, resr, slot, word,
                     is_load, cfg.ports_per_bank)

    # ---- result-counter reconciliation
    _check_counters(sink, pt, res, events, issued_ok, is_mem, finish)
    return sink.violations


def _check_slots(sink: _Sink, nodes: np.ndarray, cyc, slot, budget: int,
                 rule: str, what: str, array: int) -> None:
    """Per-cycle issue count <= budget and slots are dense 0..m-1."""
    cycles = cyc[nodes]
    slots = slot[nodes]
    order = np.lexsort((slots, cycles))
    cycles, slots, nodes = cycles[order], slots[order], nodes[order]
    boundaries = np.flatnonzero(np.diff(cycles)) + 1
    for grp, sl, nd in zip(np.split(cycles, boundaries),
                           np.split(slots, boundaries),
                           np.split(nodes, boundaries)):
        c = int(grp[0])
        if grp.size > budget:
            sink.add(rule,
                     f"{what}: {grp.size} issues in one cycle exceeds the "
                     f"budget of {budget}", node=int(nd[0]), array=array,
                     cycle=c)
        if not np.array_equal(sl, np.arange(grp.size)):
            sink.add("slot_collision",
                     f"{what}: issue slots {sl.tolist()} are not the dense "
                     f"sequence 0..{grp.size - 1}", node=int(nd[0]),
                     array=array, cycle=c)


def _check_array(sink: _Sink, aid: int, r: ArrayRules, nodes: np.ndarray,
                 cyc, path, resr, slot, word, is_load,
                 ports_per_bank: int) -> None:
    cycles = cyc[nodes]
    paths = path[nodes]
    ress = resr[nodes]
    slots = slot[nodes]
    loads = is_load[nodes]
    addrs = word[nodes] % r.depth

    # ---- per-direction port budgets (every kind)
    for sel, budget, what in ((loads, r.rd, "reads"),
                              (~loads, r.wr, "writes")):
        if not sel.any():
            continue
        cnt = np.bincount(cycles[sel])
        over = np.flatnonzero(cnt > budget)
        for c in over[:_MAX_PER_RULE]:
            nd = nodes[sel & (cycles == c)][0]
            sink.add("port_budget",
                     f"{int(cnt[c])} {what} in one cycle exceeds the "
                     f"{budget}-port budget", node=int(nd), array=aid,
                     cycle=int(c))

    # ---- slot density over the whole class (reads+writes share slots)
    _check_slots(sink, nodes, cyc, slot,
                 budget=r.rd + r.wr if r.slot_cap is None
                 else min(r.rd + r.wr, r.slot_cap),
                 rule="port_budget", what=f"array {aid}", array=aid)

    # ---- multipump pumped-slot accounting
    if r.slot_cap is not None:
        cnt = np.bincount(cycles)
        for c in np.flatnonzero(cnt > r.slot_cap)[:_MAX_PER_RULE]:
            nd = nodes[cycles == c][0]
            sink.add("slot_budget",
                     f"{int(cnt[c])} pumped accesses in one external cycle "
                     f"exceed {r.slot_cap} internal slots", node=int(nd),
                     array=aid, cycle=int(c))

    # ---- legal path kinds per design kind
    if r.is_ntx:
        legal_rd = (PATH_DIRECT, PATH_PARITY)
        legal_wr = (PATH_DIRECT,) if not r.has_ref \
            else (PATH_DIRECT, PATH_PAIR_RMW)
    elif r.kind == "remap":
        legal_rd, legal_wr = (PATH_DIRECT,), (PATH_STEERED,)
    elif r.lvt_broadcast:
        legal_rd, legal_wr = (PATH_DIRECT,), (PATH_BROADCAST,)
    else:
        legal_rd, legal_wr = (PATH_DIRECT,), (PATH_DIRECT,)
    bad = np.where(loads, ~np.isin(paths, legal_rd),
                   ~np.isin(paths, legal_wr))
    for i in np.flatnonzero(bad)[:_MAX_PER_RULE]:
        side = "read" if loads[i] else "write"
        sink.add("path_kind",
                 f"{r.kind} {side} took path "
                 f"{PATH_NAMES.get(int(paths[i]), '?')}",
                 node=int(nodes[i]), array=aid, cycle=int(cycles[i]))

    if r.kind == "banked":
        _check_banked(sink, aid, r, nodes, cycles, ress, addrs,
                      ports_per_bank)
    elif r.kind == "remap":
        _check_remap(sink, aid, r, nodes, cycles, slots, ress, addrs,
                     loads, ports_per_bank)
    elif r.is_ntx:
        _check_ntx(sink, aid, r, nodes, cycles, paths, ress, addrs, loads)


def _check_banked(sink, aid, r: ArrayRules, nodes, cycles, ress, addrs,
                  ports_per_bank: int) -> None:
    banks = addrs % r.n_banks
    wrong = ress != banks
    for i in np.flatnonzero(wrong)[:_MAX_PER_RULE]:
        sink.add("bank_conflict",
                 f"access to word {int(addrs[i])} served by bank "
                 f"{int(ress[i])}, but words interleave to bank "
                 f"{int(banks[i])}", node=int(nodes[i]), array=aid,
                 cycle=int(cycles[i]))
    # <= ports_per_bank accesses per (cycle, bank)
    key = cycles * r.n_banks + banks
    uniq, cnt = np.unique(key, return_counts=True)
    for kky in uniq[cnt > ports_per_bank][:_MAX_PER_RULE]:
        c, b = divmod(int(kky), r.n_banks)
        nd = nodes[key == kky][0]
        sink.add("bank_conflict",
                 f"bank {b} served {int(cnt[uniq == kky][0])} accesses in "
                 f"one cycle (dual-port macro allows {ports_per_bank})",
                 node=int(nd), array=aid, cycle=c)


def _check_remap(sink, aid, r: ArrayRules, nodes, cycles, slots, ress,
                 addrs, loads, ports_per_bank: int) -> None:
    """Ordered replay of the remap steering invariants.

    The live map mutates as writes issue, so per-cycle legality depends
    on within-cycle order — the recorded issue slots provide it.  The
    scan rule is re-implemented from the spec (first bank from the
    word's live bank with no write yet and a read port left), not
    imported from the arbiter.
    """
    nb = r.n_banks
    live = [0] * r.depth              # banks start compacted at bank 0
    order = np.lexsort((slots, cycles))
    ruse = [0] * nb
    wuse = [0] * nb
    cur_cycle = -1
    for i in order:
        c = int(cycles[i])
        if c != cur_cycle:
            ruse = [0] * nb
            wuse = [0] * nb
            cur_cycle = c
        a = int(addrs[i])
        got = int(ress[i])
        if loads[i]:
            want = live[a]
            if got != want:
                sink.add("bank_conflict",
                         f"read of word {a} served by bank {got}, but the "
                         f"live map holds it in bank {want}",
                         node=int(nodes[i]), array=aid, cycle=c)
                continue
        else:
            want = -1
            for j in range(nb):
                b = (live[a] + j) % nb
                if not wuse[b] and ruse[b] < ports_per_bank:
                    want = b
                    break
            if got != want:
                sink.add("steering",
                         f"write of word {a} steered to bank {got}; the "
                         f"first conflict-free bank scanning from "
                         f"{live[a]} is {want}", node=int(nodes[i]),
                         array=aid, cycle=c)
                if not 0 <= got < nb:
                    continue
            if wuse[got]:
                sink.add("bank_conflict",
                         f"two live writes share bank {got} in one cycle",
                         node=int(nodes[i]), array=aid, cycle=c)
            wuse[got] = 1
            live[a] = got
        ruse[got] += 1
        if ruse[got] > ports_per_bank:
            sink.add("bank_conflict",
                     f"bank {got} served {ruse[got]} accesses in one cycle "
                     f"(ports_per_bank={ports_per_bank})",
                     node=int(nodes[i]), array=aid, cycle=c)


def _check_ntx(sink, aid, r: ArrayRules, nodes, cycles, paths, ress,
               addrs, loads) -> None:
    """Leaf read-port exclusivity + write-pair (Ref unit) accounting."""
    geo = leaf_paths(r.tree_depth, r.k)
    trees = np.where(addrs >= r.half, 1, 0) if r.has_ref \
        else np.zeros(addrs.shape, np.int64)
    tas = addrs - trees * r.half

    # collect every (cycle, leaf-port key) claim; pair claims are
    # tagged so a duplicate involving one classifies as write_pair
    claim_cycle: "list[int]" = []
    claim_key: "list[int]" = []
    claim_pair: "list[bool]" = []
    claim_node: "list[int]" = []

    def claim(c, key, is_pair, node):
        claim_cycle.append(c)
        claim_key.append(key)
        claim_pair.append(is_pair)
        claim_node.append(node)

    pair_by_cycle: dict[int, int] = {}
    writes_by_cycle_half: dict[tuple[int, int], list[int]] = {}

    for i in range(nodes.shape[0]):
        c = int(cycles[i])
        node = int(nodes[i])
        tree = int(trees[i])
        direct, off, parity = geo[int(tas[i])]
        s = off % r.sub
        p = int(paths[i])
        if loads[i]:
            if p == PATH_DIRECT:
                want = r.key(tree, direct, s)
                if int(ress[i]) != want:
                    sink.add("parity_fanout",
                             f"direct read of word {int(addrs[i])} "
                             f"recorded leaf port {int(ress[i])}, its "
                             f"direct leaf is port {want}", node=node,
                             array=aid, cycle=c)
                claim(c, want, False, node)
                if r.has_ref:
                    claim(c, r.key(2, direct, s), False, node)
            elif p == PATH_PARITY:
                for pl in parity:
                    claim(c, r.key(tree, pl, s), False, node)
                    if r.has_ref:
                        claim(c, r.key(2, pl, s), False, node)
        else:
            if p == PATH_PAIR_RMW:
                pair_by_cycle[c] = pair_by_cycle.get(c, 0) + 1
                if pair_by_cycle[c] > 1:
                    sink.add("write_pair",
                             "two Ref re-pointing flows in one cycle "
                             "(the RMW unit is single)", node=node,
                             array=aid, cycle=c)
                claim(c, r.key(1 - tree, direct, s), True, node)
                claim(c, r.key(2, direct, s), True, node)
            if r.has_ref:
                writes_by_cycle_half.setdefault((c, tree), []).append(i)

    # ---- same-half write pairing: 2nd write per half must be the pair
    for (c, tree), idxs in writes_by_cycle_half.items():
        n_pair = sum(1 for i in idxs if paths[i] == PATH_PAIR_RMW)
        if len(idxs) > 2:
            sink.add("write_pair",
                     f"{len(idxs)} writes into one address half in one "
                     "cycle (a half takes a plain write plus one pair "
                     "RMW)", node=int(nodes[idxs[0]]), array=aid, cycle=c)
        if n_pair != max(len(idxs) - 1, 0):
            sink.add("write_pair",
                     f"{len(idxs)} same-half writes recorded {n_pair} "
                     f"pair RMWs (expected {max(len(idxs) - 1, 0)})",
                     node=int(nodes[idxs[0]]), array=aid, cycle=c)

    # ---- leaf-port exclusivity: each (cycle, key) claimed at most once
    if claim_key:
        ck = np.asarray(claim_cycle, np.int64) * (3 * r.n_leaves * r.sub) \
            + np.asarray(claim_key, np.int64)
        pair_f = np.asarray(claim_pair, bool)
        node_f = np.asarray(claim_node, np.int64)
        uniq, inv, cnt = np.unique(ck, return_inverse=True,
                                   return_counts=True)
        dup = np.flatnonzero(cnt[inv] > 1)
        seen: set[int] = set()
        for i in dup:
            g = int(inv[i])
            if g in seen:
                continue
            seen.add(g)
            members = np.flatnonzero(inv == g)
            rule = "write_pair" if pair_f[members].any() else \
                "parity_fanout"
            c = claim_cycle[int(members[0])]
            sink.add(rule,
                     f"leaf port {claim_key[int(members[0])]} claimed "
                     f"{members.size} times in one cycle by nodes "
                     f"{sorted(set(int(node_f[m]) for m in members))}",
                     node=int(node_f[members[0]]), array=aid, cycle=c)
            if len(seen) >= _MAX_PER_RULE:
                break


def _check_counters(sink: _Sink, pt: PreparedTrace, res, events: EventLog,
                    issued_ok, is_mem, finish) -> None:
    n = pt.trace.n_nodes
    cyc = events.cycle
    path = events.path
    mem_ev = issued_ok & is_mem
    checks = [
        ("issued", res.issued, int(issued_ok.sum())),
        ("mem_issued", res.mem_issued, int(mem_ev.sum())),
        ("parity_path_reads", res.parity_path_reads,
         int((mem_ev & (path == PATH_PARITY)).sum())),
        ("write_pair_rmws", res.write_pair_rmws,
         int((mem_ev & (path == PATH_PAIR_RMW)).sum())),
    ]
    expected_cycles = int(finish.max()) + 1 if n else 0
    checks.append(("cycles", res.cycles, expected_cycles))
    for aid, got in res.per_array_accesses.items():
        checks.append((f"per_array_accesses[{aid}]", got,
                       int((mem_ev & (pt.klass_np == aid)).sum())))
    for name, got, want in checks:
        if got != want:
            sink.add("counter",
                     f"result reports {name}={got}, the event log implies "
                     f"{want}")
    mem_cycles = np.unique(cyc[mem_ev]).size
    want_par = int(mem_ev.sum()) / max(mem_cycles, 1)
    if abs(res.avg_mem_parallelism - want_par) > 1e-9:
        sink.add("counter",
                 f"result reports avg_mem_parallelism="
                 f"{res.avg_mem_parallelism:.6f}, the event log implies "
                 f"{want_par:.6f}")
