"""Synthesized read/write-path logic cost tables @ 45nm (paper III-A).

Stand-ins for the paper's Synopsys DC @ UMC 45nm synthesis of the AMM
glue logic, tabulated per standard cell (typical 45nm educational/UMC
library values) and composed per design.  All functions return
(area_mm2, delay_ns, energy_pj_per_op, leakage_mw).
"""
from __future__ import annotations

import dataclasses
import math

# Per-cell constants, 45nm typical corner.
_XOR2_AREA_UM2 = 1.12
_XOR2_DELAY_NS = 0.042
_XOR2_ENERGY_FJ = 1.9
_MUX2_AREA_UM2 = 1.41
_MUX2_DELAY_NS = 0.038
_MUX2_ENERGY_FJ = 1.5
_DFF_AREA_UM2 = 4.52
_DFF_ENERGY_FJ = 3.1
_CMP_BIT_AREA_UM2 = 1.9
_LEAK_NW_PER_UM2 = 18.0


@dataclasses.dataclass(frozen=True)
class LogicCost:
    area_mm2: float
    delay_ns: float
    energy_pj: float
    leakage_mw: float

    def __add__(self, o: "LogicCost") -> "LogicCost":
        return LogicCost(
            self.area_mm2 + o.area_mm2,
            max(self.delay_ns, o.delay_ns),
            self.energy_pj + o.energy_pj,
            self.leakage_mw + o.leakage_mw,
        )


ZERO = LogicCost(0.0, 0.0, 0.0, 0.0)


def _mk(area_um2: float, delay_ns: float, energy_fj: float) -> LogicCost:
    return LogicCost(
        area_mm2=area_um2 * 1e-6,
        delay_ns=delay_ns,
        energy_pj=energy_fj * 1e-3,
        leakage_mw=area_um2 * _LEAK_NW_PER_UM2 * 1e-6,
    )


def xor_stage(width: int, fanin: int = 2) -> LogicCost:
    """XOR-reduce of ``fanin`` words of ``width`` bits (tree)."""
    n_gates = max(fanin - 1, 0) * width
    depth = max(1, math.ceil(math.log2(max(fanin, 2))))
    return _mk(_XOR2_AREA_UM2 * n_gates, _XOR2_DELAY_NS * depth,
               _XOR2_ENERGY_FJ * n_gates)


def mux_tree(width: int, ways: int) -> LogicCost:
    n_gates = max(ways - 1, 0) * width
    depth = max(1, math.ceil(math.log2(max(ways, 2))))
    return _mk(_MUX2_AREA_UM2 * n_gates, _MUX2_DELAY_NS * depth,
               _MUX2_ENERGY_FJ * n_gates)


def register_table(entries: int, bits_per_entry: int) -> LogicCost:
    """LVT / remap table held in flops (paper II-B)."""
    n = entries * bits_per_entry
    # table access energy: only one entry's flops toggle + read mux
    c = _mk(_DFF_AREA_UM2 * n, 0.12, _DFF_ENERGY_FJ * bits_per_entry)
    return c + mux_tree(bits_per_entry, max(2, entries // 64))


def bank_decoder(n_banks: int, addr_bits: int) -> LogicCost:
    n = max(1, n_banks) * addr_bits
    return _mk(_CMP_BIT_AREA_UM2 * n, 0.05 + 0.01 * math.log2(max(n_banks, 2)),
               1.2 * n)
