from repro.core.cost.compose import (FU_AREA_MM2, FU_LEAK_MW, FU_POWER_MW,
                                     MemoryCost, memory_cost)
from repro.core.cost.logic import LogicCost
from repro.core.cost.sram import MacroCost, sram_macro

__all__ = [
    "MemoryCost", "memory_cost", "MacroCost", "sram_macro", "LogicCost",
    "FU_AREA_MM2", "FU_POWER_MW", "FU_LEAK_MW",
]
