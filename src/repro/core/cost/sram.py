"""CACTI-like analytical SRAM macro model @ 45nm (paper III-A).

The paper synthesizes AMM read/write-path logic in Synopsys DC at UMC
45nm and uses CACTI for the SRAM macros.  Neither tool ships here, so we
use an analytical model with constants calibrated against published
CACTI 6.5 45nm ITRS-HP numbers for small scratchpad macros (1KB-256KB).
Calibration anchors (CACTI 6.5, 45nm, 1 bank, RW port):

    size    access(ns)  energy/rd(pJ)  area(mm^2)  leakage(mW)
    4KB     ~0.45       ~5.5           ~0.022      ~1.8
    32KB    ~0.78       ~12.9          ~0.121      ~11.6
    256KB   ~1.42       ~33.1          ~0.900      ~86.4

The model interpolates with the usual sqrt/log structure:
  access ~ a0 + a1*sqrt(bits)      (wordline/bitline RC)
  energy ~ e0 + e1*sqrt(bits)      (bitline swing dominates)
  area   ~ bitcell*bits*portf + periphery*sqrt(bits)
  leak   ~ l1*bits
Port scaling: a second independent port roughly doubles bitcell area
(6T->dual-ported 8T) and adds wordline load (x1.25 access, x1.4 energy).
True multiport beyond 2 ports is exactly what EDA flows do NOT offer
(paper I) — ``sram_macro`` therefore rejects ports > 2; multi-ported
behaviour must be composed algorithmically (see compose.py).
"""
from __future__ import annotations

import dataclasses
import math

# Calibrated constants (45nm).
_BITCELL_UM2 = {1: 0.342, 2: 0.647}       # 6T vs 8T-ish dual port
_AREA_PERIPH_UM2_PER_SQRT_BIT = 28.0
_ACCESS_NS_BASE = {1: 0.28, 2: 0.35}
_ACCESS_NS_PER_SQRT_BIT = 0.00082
_ENERGY_PJ_BASE = {1: 1.9, 2: 2.7}
_ENERGY_PJ_PER_SQRT_BIT = 0.0218
_LEAK_MW_PER_BIT = 3.3e-4


@dataclasses.dataclass(frozen=True)
class MacroCost:
    area_mm2: float
    access_ns: float
    energy_rd_pj: float
    energy_wr_pj: float
    leakage_mw: float
    bits: int

    def scaled(self, copies: int) -> "MacroCost":
        return MacroCost(
            self.area_mm2 * copies,
            self.access_ns,
            self.energy_rd_pj,
            self.energy_wr_pj,
            self.leakage_mw * copies,
            self.bits * copies,
        )


def sram_macro(depth: int, width: int, ports: int = 1) -> MacroCost:
    """Cost of one SRAM macro of ``depth`` words x ``width`` bits.

    ports=1: single RW port; ports=2: true dual port (1R1W or 2RW) —
    the limit of vendor memory-compiler support the paper builds on.
    """
    if ports not in (1, 2):
        raise ValueError(
            "no EDA support for true multiport SRAM beyond 2 ports "
            "(paper section I) — compose an AMM instead"
        )
    bits = depth * width
    if bits <= 0:
        raise ValueError("empty macro")
    sq = math.sqrt(bits)
    area_um2 = _BITCELL_UM2[ports] * bits + _AREA_PERIPH_UM2_PER_SQRT_BIT * sq
    access = _ACCESS_NS_BASE[ports] + _ACCESS_NS_PER_SQRT_BIT * sq
    e_rd = _ENERGY_PJ_BASE[ports] + _ENERGY_PJ_PER_SQRT_BIT * sq
    return MacroCost(
        area_mm2=area_um2 * 1e-6,
        access_ns=access,
        energy_rd_pj=e_rd,
        energy_wr_pj=e_rd * 1.12,  # write drivers swing full rail
        leakage_mw=_LEAK_MW_PER_BIT * bits,
        bits=bits,
    )
