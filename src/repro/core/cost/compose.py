"""Compose SRAM-macro + glue-logic costs into a full AMM design cost
(paper III-A: 'By combining the synthesis results of read-path and
write-path logic, and estimation from CACTI (SRAM) we can evaluate the
overall performance and cost of an AMM design').
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.amm.spec import AMMSpec
from repro.core.cost import logic as lg
from repro.core.cost.sram import MacroCost, sram_macro


@dataclasses.dataclass(frozen=True)
class MemoryCost:
    """Whole-memory cost for one AMMSpec."""
    area_mm2: float
    read_energy_pj: float     # per read access (all banks it touches)
    write_energy_pj: float    # per write access
    leakage_mw: float
    access_ns: float          # read path: macro + decode + XOR/mux
    cycle_ns: float           # min clock period the memory sustains
    max_freq_ghz: float

    @property
    def summary(self) -> dict:
        return dataclasses.asdict(self)


def _addr_bits(depth: int) -> int:
    return max(1, math.ceil(math.log2(max(depth, 2))))


def memory_cost(spec: AMMSpec) -> MemoryCost:
    """Area / energy / latency of one memory design point."""
    n_banks, bank_depth = spec.leaf_banks()
    width = spec.width
    k = spec.read_tree_levels

    if spec.kind in ("ideal",):
        # circuit-level true multiport: not manufacturable via compilers
        # (paper I); modelled as port-scaled bitcells for reference only.
        macro = sram_macro(spec.depth, width, ports=2)
        port_pairs = max(spec.n_read + spec.n_write - 1, 1)
        area = macro.area_mm2 * (0.55 * port_pairs + 0.45)
        glue = lg.ZERO
        access = macro.access_ns * (1.0 + 0.15 * (port_pairs - 1))
        e_rd, e_wr = macro.energy_rd_pj, macro.energy_wr_pj
        leak = macro.leakage_mw * (0.4 * port_pairs + 0.6)
        rd_banks = wr_banks = 1
    elif spec.kind == "multipump":
        macro = sram_macro(spec.depth, width, ports=2)
        glue = lg.bank_decoder(2, _addr_bits(spec.depth))
        area, access = macro.area_mm2, macro.access_ns
        e_rd, e_wr, leak = macro.energy_rd_pj, macro.energy_wr_pj, macro.leakage_mw
        rd_banks = wr_banks = 1
    elif spec.kind == "banked":
        macro = sram_macro(bank_depth, width, ports=2).scaled(n_banks)
        glue = lg.bank_decoder(n_banks, _addr_bits(spec.depth)) + lg.mux_tree(
            width, max(n_banks, 2)
        )
        area, access = macro.area_mm2, sram_macro(bank_depth, width, 2).access_ns
        e_rd = sram_macro(bank_depth, width, 2).energy_rd_pj
        e_wr = sram_macro(bank_depth, width, 2).energy_wr_pj
        leak = macro.leakage_mw
        rd_banks = wr_banks = 1
    elif spec.kind in ("h_ntx_rd", "b_ntx_wr", "hb_ntx"):
        # leaf sub-banking (banking-structure axis): each of the
        # n_banks leaf structures becomes `sub` smaller interleaved
        # macros — shorter wordlines (faster access, the cycle-time
        # coupling consumed by the scheduler's cycle_ns) at the price of
        # a per-leaf decoder/mux.
        sub = max(spec.n_banks, 1)
        one = sram_macro(-(-bank_depth // sub), width, ports=2)
        macro = one.scaled(n_banks * sub)
        area, leak = macro.area_mm2, macro.leakage_mw
        # Read path: bank select mux per level + XOR with ref on conflict
        # (and B-decode XOR for the write-paired variants).
        glue = lg.bank_decoder(n_banks, _addr_bits(spec.depth))
        glue = glue + lg.mux_tree(width, max(2 * k, 2))
        if sub > 1:
            glue = glue + lg.bank_decoder(sub, _addr_bits(bank_depth)) \
                + lg.mux_tree(width, sub)
        xor_fanin_rd = (2 if k > 0 else 1) + (1 if spec.kind != "h_ntx_rd" else 0)
        if xor_fanin_rd > 1:
            glue = glue + lg.xor_stage(width, xor_fanin_rd)
        # Write path: RMW XOR dance (read-other + ref update).
        glue = glue + lg.xor_stage(width, 3)
        access = one.access_ns
        # A read touches bank+ref on the conflict path; a write touches its
        # bank + ref (+ other-bank read on the B path).
        rd_banks = 1 + (1 if k > 0 else 0) + (1 if spec.kind != "h_ntx_rd" else 0)
        wr_banks = 2 if spec.kind == "h_ntx_rd" else 3
        e_rd = one.energy_rd_pj * rd_banks
        e_wr = one.energy_wr_pj * 2 + one.energy_rd_pj * (wr_banks - 2 + 1)
    elif spec.kind in ("lvt", "remap"):
        sub = max(spec.n_banks, 1)      # leaf sub-banking (cost/freq only)
        one = sram_macro(-(-bank_depth // sub), width, ports=2)
        macro = one.scaled(n_banks * sub)
        table_bits = max(1, spec.table_bits() // max(spec.depth, 1))
        table = lg.register_table(spec.depth, table_bits)
        glue = table + lg.mux_tree(width, max(spec.n_write + 1, 2)) + \
            lg.bank_decoder(n_banks, _addr_bits(spec.depth))
        if sub > 1:
            glue = glue + lg.bank_decoder(sub, _addr_bits(bank_depth)) \
                + lg.mux_tree(width, sub)
        area, leak = macro.area_mm2, macro.leakage_mw
        access = one.access_ns
        e_rd = one.energy_rd_pj + table.energy_pj
        if spec.kind == "lvt":
            # every write broadcasts to its bank's read replicas; the
            # arbitration descriptor is the single source of the fan-out
            from repro.core.sim.arbiter import compile_spec
            e_wr = one.energy_wr_pj * compile_spec(spec).write_broadcast \
                + table.energy_pj
        else:
            e_wr = one.energy_wr_pj + table.energy_pj
        rd_banks = wr_banks = 1
    else:  # pragma: no cover
        raise ValueError(spec.kind)

    area_total = area + glue.area_mm2
    leak_total = leak + glue.leakage_mw
    access_total = access + glue.delay_ns
    # Non-table AMMs operate at max frequency (paper I); multipump halves
    # the *external* frequency via frequency_factor.
    cycle = access_total / spec.frequency_factor
    return MemoryCost(
        area_mm2=area_total,
        read_energy_pj=e_rd + glue.energy_pj,
        write_energy_pj=e_wr + glue.energy_pj,
        leakage_mw=leak_total,
        access_ns=access_total,
        cycle_ns=cycle,
        max_freq_ghz=1.0 / cycle,
    )


# ----------------------------------------------------------------------
# Functional-unit costs (Aladdin-style 45nm FU library).
# ----------------------------------------------------------------------
FU_AREA_MM2 = {
    "fadd": 0.0031, "fmul": 0.0117, "fdiv": 0.0220,
    "iadd": 0.00028, "imul": 0.0019, "icmp": 0.00011, "logic": 0.00007,
}
FU_POWER_MW = {  # dynamic power at full utilization, 1 GHz
    "fadd": 1.9, "fmul": 6.3, "fdiv": 9.8,
    "iadd": 0.14, "imul": 1.2, "icmp": 0.06, "logic": 0.03,
}
FU_LEAK_MW = {k: v * 0.08 for k, v in FU_POWER_MW.items()}
