"""Block-size autotuning for the compiled kernel surface.

For each kernel + shape class + backend, ``tune()`` sweeps the legal
block/grid candidates, times the *compiled* executable (warm-up
iterations absorb trace+compile, ``block_until_ready`` fences every
measurement), and records the winner.  Winners are cached in the
checked-in table ``_autotune_cache.json`` keyed by
``kernel|backend|mode|shape-bucket`` — ``kernels.ops`` consults it on
every call, so callers transparently get tuned configurations; a miss
falls back to ``DEFAULTS``.

Shape buckets round every dimension up to a power of two: a tuned
winner for (v=1024, n=256) also serves (v=700, n=200), which keeps the
table small while the candidates themselves are re-legalized against
the *actual* shape at dispatch time (``ops._pick_block``).

Re-tune (e.g. on new hardware or after a kernel change) with::

    PYTHONPATH=src python -m repro.kernels.autotune [--repeat N] [--write]

which sweeps the standard shape classes below and rewrites the table.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Callable, Iterable

import numpy as np

_CACHE_FILE = os.path.join(os.path.dirname(__file__), "_autotune_cache.json")
_TABLE: dict[str, dict] | None = None

DEFAULTS: dict[str, dict[str, int]] = {
    "amm_gather": {"block_n": 128},
    "kv_decode": {"block_h": 1},
    "ssd_chunk": {"block_h": 1},
}


# -- shape bucketing / cache table -------------------------------------
def _pow2_bucket(x: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(x, 1)))), 0)


def shape_key(kernel: str, backend: str, mode: str, **dims: int) -> str:
    parts = ";".join(f"{k}={_pow2_bucket(v)}" for k, v in sorted(dims.items()))
    return f"{kernel}|{backend}|{mode}|{parts}"


def load_table(path: str = _CACHE_FILE, refresh: bool = False) -> dict:
    global _TABLE
    if _TABLE is None or refresh:
        try:
            with open(path) as f:
                _TABLE = json.load(f).get("entries", {})
        except (OSError, ValueError):
            _TABLE = {}
    return _TABLE


def save_table(entries: dict, path: str = _CACHE_FILE) -> None:
    global _TABLE
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": dict(sorted(entries.items()))},
                  f, indent=1)
        f.write("\n")
    _TABLE = entries


def get_config(kernel: str, backend: str, mode: str, **dims: int
               ) -> dict[str, int]:
    """Tuned config for this call site, or the kernel default on a miss."""
    hit = load_table().get(shape_key(kernel, backend, mode, **dims))
    if hit:
        return dict(hit["config"])
    return dict(DEFAULTS[kernel])


# -- candidate enumeration ---------------------------------------------
def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def candidates(kernel: str, **dims: int) -> list[dict[str, int]]:
    """Legal block configs for one kernel at one (actual) shape."""
    if kernel == "amm_gather":
        n = dims["n"]
        blocks = sorted({b for b in (32, 64, 128, 256, 512, 1024, n)
                         if b <= n and n % b == 0})
        return [{"block_n": b} for b in blocks] or [{"block_n": n}]
    if kernel == "kv_decode":
        group = max(dims["hq"] // dims["hkv"], 1)
        return [{"block_h": b} for b in _divisors(group)]
    if kernel == "ssd_chunk":
        return [{"block_h": b} for b in _divisors(dims["h"])]
    raise KeyError(f"unknown kernel {kernel!r}")


# -- timing ------------------------------------------------------------
def time_compiled(fn: Callable[[], Any], repeat: int = 30,
                  warmup: int = 2) -> tuple[float, float]:
    """(steady-state us/call, compile_ms).  The first call pays
    trace+compile; ``warmup`` more calls settle caches before timing."""
    import jax

    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    cold_ms = (time.perf_counter() - t0) * 1e3
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn())
    us = (time.perf_counter() - t0) / repeat * 1e6
    return us, max(cold_ms - us / 1e3, 0.0)


# -- the tuner ---------------------------------------------------------
def _make_call(kernel: str, args: tuple, cfg: dict[str, int], mode: str
               ) -> Callable[[], Any]:
    from repro.kernels import ops

    if kernel == "amm_gather":
        table, idx, nb = args
        return lambda: ops.amm_gather(table, idx, n_banks=nb, mode=mode,
                                      **cfg)
    if kernel == "kv_decode":
        q, k, v, lens, nb = args
        return lambda: ops.kv_decode(q, k, v, lens, n_banks=nb, mode=mode,
                                     **cfg)
    if kernel == "ssd_chunk":
        return lambda: ops.ssd_chunk(*args, mode=mode, **cfg)[0]
    raise KeyError(kernel)


def tune(kernel: str, args: tuple, dims: dict[str, int],
         mode: str = "compiled", repeat: int = 30,
         entries: dict | None = None) -> dict:
    """Sweep candidates for one kernel/shape, return the winning entry
    (and record it into ``entries`` when given)."""
    import jax

    from repro.kernels.lowering import resolve_mode

    resolved = resolve_mode(mode=mode)
    backend = jax.default_backend()
    rows = []
    for cfg in candidates(kernel, **dims):
        us, compile_ms = time_compiled(
            _make_call(kernel, args, cfg, resolved), repeat=repeat)
        rows.append({"config": cfg, "us": round(us, 2),
                     "compile_ms": round(compile_ms, 1)})
    best = min(rows, key=lambda r: r["us"])
    entry = {"config": best["config"], "us": best["us"],
             "compile_ms": best["compile_ms"], "mode": resolved,
             "swept": rows}
    if entries is not None:
        entries[shape_key(kernel, backend, resolved, **dims)] = entry
    return entry


# -- standard shape classes (the bench + serving shapes) ---------------
def _standard_problems() -> Iterable[tuple[str, tuple, dict[str, int]]]:
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    for v, d, nb, n in ((1024, 128, 4, 256), (4096, 64, 8, 2048)):
        table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, v, n), jnp.int32)
        yield "amm_gather", (table, idx, nb), dict(v=v, d=d, nb=nb, n=n)
    for b, hq, hkv, s, d, nb in ((4, 8, 4, 512, 64, 8),
                                 (8, 16, 2, 1024, 64, 8)):
        q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        v_ = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
        yield "kv_decode", (q, k, v_, lens, nb), dict(
            b=b, hq=hq, hkv=hkv, s=s, d=d, nb=nb)
    for bt, h, qq, p, n in ((2, 4, 64, 32, 16), (2, 8, 128, 64, 32)):
        x = jnp.asarray(rng.standard_normal((bt, h, qq, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.4, (bt, h, qq)), jnp.float32)
        cum = jnp.cumsum(-dt, axis=-1)
        B = jnp.asarray(rng.standard_normal((bt, qq, n)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((bt, qq, n)), jnp.float32)
        h0 = jnp.zeros((bt, h, p, n), jnp.float32)
        yield "ssd_chunk", (x, dt, cum, B, C, h0), dict(
            bt=bt, h=h, q=qq, p=p, n=n)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune",
        description="Re-tune kernel block sizes and rewrite the cache.")
    ap.add_argument("--repeat", type=int, default=30,
                    help="timed iterations per candidate")
    ap.add_argument("--mode", default="compiled",
                    choices=("compiled", "interpret", "xla", "pallas"))
    ap.add_argument("--dry-run", action="store_true",
                    help="print winners without rewriting the table")
    args = ap.parse_args(argv)

    entries = dict(load_table())
    for kernel, call_args, dims in _standard_problems():
        entry = tune(kernel, call_args, dims, mode=args.mode,
                     repeat=args.repeat, entries=entries)
        print(f"{kernel} {dims}: {entry['config']} "
              f"({entry['us']:.1f} us, compile {entry['compile_ms']:.0f} ms)")
    if not args.dry_run:
        save_table(entries)
        print(f"wrote {len(entries)} entries to {_CACHE_FILE}")


if __name__ == "__main__":
    main()
