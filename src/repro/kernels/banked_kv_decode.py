"""Banked KV-cache flash-decode — the paper's banking idea applied to
the decode-attention hot loop.

The KV cache of one (batch, kv-head) is partitioned into ``n_banks``
sequence banks (independent tiles).  A decode step is a multi-port read
burst over those banks; the kernel streams the banks with the
online-softmax (flash) recurrence, so each bank is read exactly once
per step and never materializes an [S] score vector in HBM.

Grid: (batch, q_heads / block_h).  ``block_h`` query heads are served
per grid cell — it must divide the GQA group so the whole block shares
one kv head, and the bank stream (the expensive loads) is then
amortized across the block instead of re-read per head.  Ragged
batches: ``lengths[b]`` masks each row's positions ``>= seq_len``
out of both the max and the weight sum (padded K/V content never
reaches the output), and a fully-empty row (``seq_len == 0``) returns
zeros rather than NaN — the shape class mixed-length serving batches
need.

The block body is backend-agnostic (values in, values out) and lowers
through every ``lowering.py`` mode: Pallas interpreter, real
``pallas_call``, and the compiled XLA grid path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.lowering import Spec, grid_call


def _decode_block(len_blk, q_blk, k_blk, v_blk, *, n_banks: int,
                  bank_len: int, scale: float):
    """len_blk: [1] int32; q_blk: [1, BH, D]; k/v_blk: [1, 1, NB, SB, D]
    -> [1, BH, D].  Flash recurrence over banks, vectorized over the
    BH-head block."""
    q = q_blk[0].astype(jnp.float32)                       # [BH, D]
    kv_len = len_blk[0]
    kb = k_blk[0, 0]                                       # [NB, SB, D]
    vb = v_blk[0, 0]
    bh, d = q.shape

    def bank_body(j, carry):
        m, l, acc = carry                                  # [BH] [BH] [BH,D]
        k = kb[j].astype(jnp.float32)
        v = vb[j].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                        # [BH, SB]
        pos = j * bank_len + lax.iota(jnp.int32, bank_len)
        valid = pos < kv_len                               # [SB]
        s = jnp.where(valid[None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)              # empty-bank exp(0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)     # [BH, D]
        return m_new, l_new, acc_new

    m0 = jnp.full((bh,), -1e30, jnp.float32)
    l0 = jnp.zeros((bh,), jnp.float32)
    a0 = jnp.zeros((bh, d), jnp.float32)
    carry = (m0, l0, a0)
    for j in range(n_banks):       # static unroll: NB is a compile-time
        carry = bank_body(j, carry)  # constant, loop overhead vanishes
    m, l, acc = carry
    # seq_len == 0 leaves l == 0: define the row as zeros, not NaN
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    return out[None].astype(q_blk.dtype)


def banked_kv_decode(q: jax.Array, k_banks: jax.Array, v_banks: jax.Array,
                     lengths: jax.Array, block_h: int = 1,
                     mode: str = "interpret") -> jax.Array:
    """q: [B, Hq, D]; k/v_banks: [B, Hkv, NB, SB, D]; lengths: [B] int32.
    Returns [B, Hq, D].  ``block_h`` must divide the GQA group
    (Hq // Hkv); ``mode`` must be resolved, see ``lowering.resolve_mode``."""
    b, hq, d = q.shape
    _, hkv, nb, sb, _ = k_banks.shape
    group = hq // hkv
    block_h = min(block_h, group)
    assert group % block_h == 0, "head block must divide the GQA group"
    scale = 1.0 / (d ** 0.5)
    call = grid_call(
        functools.partial(_decode_block, n_banks=nb, bank_len=sb,
                          scale=scale),
        grid=(b, hq // block_h),
        in_specs=[
            Spec((1,), lambda i, h: (i,)),
            Spec((1, block_h, d), lambda i, h: (i, h, 0)),
            Spec((1, 1, nb, sb, d),
                 lambda i, h: (i, (h * block_h) // group, 0, 0, 0)),
            Spec((1, 1, nb, sb, d),
                 lambda i, h: (i, (h * block_h) // group, 0, 0, 0)),
        ],
        out_specs=[Spec((1, block_h, d), lambda i, h: (i, h, 0))],
        out_shapes=[jax.ShapeDtypeStruct((b, hq, d), q.dtype)],
        mode=mode,
    )
    return call(lengths, q, k_banks, v_banks)
