"""Banked KV-cache flash-decode — the paper's banking idea applied to
the decode-attention hot loop.

The KV cache of one (batch, kv-head) is partitioned into ``n_banks``
sequence banks (independent VMEM tiles).  A decode step is a multi-port
read burst over those banks; the kernel streams the banks with the
online-softmax (flash) recurrence, so each bank is read exactly once
per step and never materializes an [S] score vector in HBM.

Grid: (batch, q_heads).  GQA is handled in the index_map — q head h
reads kv head h // group.  Per grid cell:
  q:   [D]                (block of the [B, Hq, D] query)
  k/v: [NB, SB, D]        (that kv head's banked cache)
  out: [D]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(len_ref, q_ref, k_ref, v_ref, out_ref, *, n_banks: int,
            bank_len: int, scale: float):
    q = q_ref[0, 0, :].astype(jnp.float32)                 # [D]
    kv_len = len_ref[0]

    def bank_body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, j].astype(jnp.float32)             # [SB, D]
        v = v_ref[0, 0, j].astype(jnp.float32)
        s = jnp.dot(k, q) * scale                          # [SB]
        pos = j * bank_len + jax.lax.iota(jnp.int32, bank_len)
        s = jnp.where(pos < kv_len, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s))
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < kv_len, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p)
        acc_new = acc * alpha + jnp.dot(p, v)              # [D]
        return m_new, l_new, acc_new

    d = q.shape[0]
    m0 = jnp.float32(-1e30)
    l0 = jnp.float32(0.0)
    a0 = jnp.zeros((d,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_banks, bank_body, (m0, l0, a0))
    out_ref[0, 0, :] = (acc / jnp.maximum(l, 1e-30)).astype(out_ref.dtype)


def banked_kv_decode(q: jax.Array, k_banks: jax.Array, v_banks: jax.Array,
                     lengths: jax.Array, interpret: bool = True) -> jax.Array:
    """q: [B, Hq, D]; k/v_banks: [B, Hkv, NB, SB, D]; lengths: [B] int32.
    Returns [B, Hq, D]."""
    b, hq, d = q.shape
    _, hkv, nb, sb, _ = k_banks.shape
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    grid = (b, hq)
    return pl.pallas_call(
        functools.partial(_kernel, n_banks=nb, bank_len=sb, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, h: (i,)),
            pl.BlockSpec((1, 1, d), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, 1, nb, sb, d), lambda i, h: (i, h // group, 0, 0, 0)),
            pl.BlockSpec((1, 1, nb, sb, d), lambda i, h: (i, h // group, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, h: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k_banks, v_banks)
