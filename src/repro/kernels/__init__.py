from repro.kernels.ops import amm_gather, kv_decode, pack_amm_banks, ssd_chunk

__all__ = ["amm_gather", "kv_decode", "ssd_chunk", "pack_amm_banks"]
