from repro.kernels.lowering import resolve_mode, supports_pallas_lowering
from repro.kernels.ops import amm_gather, kv_decode, pack_amm_banks, ssd_chunk

__all__ = ["amm_gather", "kv_decode", "ssd_chunk", "pack_amm_banks",
           "resolve_mode", "supports_pallas_lowering"]
