"""AMM XOR-banked gather — the paper's H-NTX-Rd read path as a Pallas
TPU kernel.

TPU adaptation: the logical table (an embedding shard, an expert bank,
a KV page table) is depth-partitioned into ``n_banks`` VMEM-resident
banks plus one XOR parity bank (parity[o] = XOR_b bank_b[o]).  Each
grid step serves a block of gather requests two-at-a-time (2 read
ports): even slots read the *direct* path, odd slots read the
*reconstruction* path — parity XOR all other banks — which is what
hardware does when both requests of a cycle hit the same bank.  Either
path returns the same word (the H-NTX-Rd invariant), so the kernel is
conflict-free by construction, independent of the request pattern's
spatial locality.

Payloads are bitcast to unsigned ints for XOR; ops.py handles fp views.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, banks_ref, parity_ref, out_ref, *, n_banks: int,
            rows: int, block_n: int):
    def body(i, _):
        a = idx_ref[i]
        bank = a // rows
        off = a - bank * rows
        direct = pl.load(banks_ref, (bank, off, slice(None)))
        # reconstruction path: parity ^ XOR_{j != bank} bank_j[off]
        acc = pl.load(parity_ref, (off, slice(None)))
        for j in range(n_banks):              # static unroll, n_banks small
            # index with a traced scalar: newer pallas rejects raw ints
            row = pl.load(banks_ref, (jnp.asarray(j, jnp.int32), off,
                                      slice(None)))
            acc = jnp.where(j == bank, acc, acc ^ row)
        use_recon = (i % 2) == 1               # odd slot = second port
        pl.store(out_ref, (i, slice(None)),
                 jnp.where(use_recon, acc, direct))
        return 0

    jax.lax.fori_loop(0, block_n, body, 0)


def amm_gather_u32(banks: jax.Array, parity: jax.Array, idx: jax.Array,
                   block_n: int = 128, interpret: bool = True) -> jax.Array:
    """banks: [NB, R, D] uint; parity: [R, D] uint; idx: [N] int32.
    Returns [N, D] uint gathered rows."""
    nb, rows, d = banks.shape
    n = idx.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0, "request count must divide by block"
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_kernel, n_banks=nb, rows=rows, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((nb, rows, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((rows, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), banks.dtype),
        interpret=interpret,
    )(idx, banks, parity)
