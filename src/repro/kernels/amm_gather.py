"""AMM XOR-banked gather — the paper's H-NTX-Rd read path as a blocked
kernel.

The logical table (an embedding shard, an expert bank, a KV page table)
is depth-partitioned into ``n_banks`` banks plus one XOR parity bank
(parity[o] = XOR_b bank_b[o]).  Each grid step serves a block of
``block_n`` gather requests two-at-a-time (2 read ports): even slots
read the *direct* path, odd slots read the *reconstruction* path —
parity XOR all other banks — which is what the hardware does when both
requests of a cycle hit the same bank.  Either path returns the same
word (the H-NTX-Rd invariant), so the kernel is conflict-free by
construction, independent of the request pattern's spatial locality.

The block body is fully vectorized (one gather + ``n_banks`` masked XOR
sweeps per block — no per-request scalar loads, no Python-int ref
indexing), so the same function lowers through every ``lowering.py``
mode: the Pallas interpreter, real ``pallas_call``, and the compiled
XLA grid path.  Payloads are bitcast to unsigned ints for XOR; ops.py
handles fp views.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lowering import Spec, grid_call


def _gather_block(idx, banks, parity, *, n_banks: int, rows: int):
    """idx: [BN] int32; banks: [NB, R, D] uint; parity: [R, D] uint
    -> [BN, D] uint.  Even request slots take the direct bank read,
    odd slots the XOR-reconstruction (parity) path."""
    bank = idx // rows
    off = idx - bank * rows
    direct = banks[bank, off]                     # [BN, D] vector gather
    # reconstruction path: parity[off] ^ XOR_{j != bank} bank_j[off]
    acc = parity[off]
    for j in range(n_banks):                      # static unroll, NB small
        acc = jnp.where((bank == j)[:, None], acc, acc ^ banks[j, off])
    slot = jax.lax.iota(jnp.int32, idx.shape[0])
    use_recon = (slot % 2) == 1                   # odd slot = second port
    return jnp.where(use_recon[:, None], acc, direct)


def amm_gather_u32(banks: jax.Array, parity: jax.Array, idx: jax.Array,
                   block_n: int = 128, mode: str = "interpret") -> jax.Array:
    """banks: [NB, R, D] uint; parity: [R, D] uint; idx: [N] int32.
    Returns [N, D] uint gathered rows.  ``mode`` must be resolved
    ('pallas'|'interpret'|'xla'), see ``lowering.resolve_mode``."""
    nb, rows, d = banks.shape
    n = idx.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0, "request count must divide by block"
    call = grid_call(
        functools.partial(_gather_block, n_banks=nb, rows=rows),
        grid=(n // block_n,),
        in_specs=[
            Spec((block_n,), lambda i: (i,)),
            Spec((nb, rows, d), lambda i: (0, 0, 0)),
            Spec((rows, d), lambda i: (0, 0)),
        ],
        out_specs=[Spec((block_n, d), lambda i: (i, 0))],
        out_shapes=[jax.ShapeDtypeStruct((n, d), banks.dtype)],
        mode=mode,
    )
    return call(idx, banks, parity)
