"""Backend-aware lowering for the Pallas kernel surface.

Every kernel in this package is one *blocked program*: a grid, a set of
``BlockSpec``-style (block_shape, index_map) pairs, and a block function
that maps input block **values** to output block values.  The math
lives entirely in the block function — refs are touched only at
whole-block load/store boundaries — so a single body serves three
execution modes:

  ``pallas``    — real ``pl.pallas_call`` lowering.  Only available on
                  backends with a Pallas compiler (TPU Mosaic, GPU
                  Triton); CPU raises in upstream JAX.
  ``interpret`` — ``pl.pallas_call(interpret=True)``: the Pallas
                  interpreter walks the grid in Python.  Slow, but runs
                  everywhere and is the debugging/conformance anchor.
  ``xla``       — the Triton/Mosaic-free compiled path: the *same*
                  (grid, BlockSpec, block_fn) program executed as pure
                  XLA — a ``lax.fori_loop`` over the flattened grid with
                  ``dynamic_slice``/``dynamic_update_slice`` block
                  movement — which jit-compiles to native code on any
                  backend, including CPU where Pallas cannot lower.

``mode="compiled"`` resolves to ``pallas`` where a real lowering exists
and ``xla`` otherwise, so callers can ask for "fast and compiled"
without caring which compiler provides it.  The environment variable
``REPRO_KERNEL_MODE`` overrides the *default* resolution (it never
overrides an explicit ``mode=`` argument), which gives CI an
interpret-only leg for environments whose lowering support regresses.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

MODES = ("pallas", "interpret", "xla", "compiled")

_ENV_MODE = "REPRO_KERNEL_MODE"


@dataclasses.dataclass(frozen=True)
class Spec:
    """One operand's blocking: shape of the block moved per grid step
    plus the grid-coords -> *block index* map (Pallas BlockSpec
    semantics: element offset = block_index * block_shape)."""
    block_shape: tuple[int, ...]
    index_map: Callable[..., tuple[Any, ...]]

    def to_pallas(self) -> pl.BlockSpec:
        return pl.BlockSpec(self.block_shape, self.index_map)


def supports_pallas_lowering(backend: str | None = None) -> bool:
    """True when ``pl.pallas_call(interpret=False)`` has a real compiler
    on the active (or given) JAX backend."""
    b = backend or jax.default_backend()
    return b in ("tpu", "gpu", "cuda", "rocm")


def resolve_mode(interpret: bool | None = None, mode: str | None = None,
                 backend: str | None = None) -> str:
    """Resolve user intent to a concrete mode: 'pallas'|'interpret'|'xla'.

    Explicit ``mode`` wins; otherwise the legacy ``interpret`` flag maps
    True -> interpret, False/None -> compiled.  ``REPRO_KERNEL_MODE``
    overrides only this default resolution, never an explicit ``mode``.
    """
    if mode is None:
        mode = os.environ.get(_ENV_MODE) or (
            "interpret" if interpret is True else "compiled")
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "compiled":
        mode = "pallas" if supports_pallas_lowering(backend) else "xla"
    return mode


def _unravel(step: jax.Array, grid: Sequence[int]) -> tuple[jax.Array, ...]:
    """Flat grid step -> coords, last dimension fastest (Pallas order)."""
    coords = []
    for size in reversed(grid):
        coords.append(step % size)
        step = step // size
    return tuple(reversed(coords))


def _block_starts(spec: Spec, coords: Sequence[jax.Array]
                  ) -> tuple[jax.Array, ...]:
    idx = spec.index_map(*coords)
    if len(idx) != len(spec.block_shape):
        raise ValueError(
            f"index_map produced {len(idx)} coords for block rank "
            f"{len(spec.block_shape)}")
    return tuple(jnp.asarray(i, jnp.int32) * b
                 for i, b in zip(idx, spec.block_shape))


def _xla_call(block_fn: Callable, grid: Sequence[int], in_specs: Sequence[Spec],
              out_specs: Sequence[Spec],
              out_shapes: Sequence[jax.ShapeDtypeStruct], args: Sequence):
    """Execute the blocked program as pure XLA ops (the interpreter-bypass
    path).  Each grid step slices its input blocks, runs the block
    function, and writes the output blocks back; XLA compiles the loop
    to native code on every backend."""
    steps = math.prod(grid)
    outs0 = [jnp.zeros(s.shape, s.dtype) for s in out_shapes]

    def one_step(step, outs):
        coords = _unravel(jnp.asarray(step, jnp.int32), grid)
        ins = [lax.dynamic_slice(a, _block_starts(s, coords), s.block_shape)
               for a, s in zip(args, in_specs)]
        res = block_fn(*ins)
        res = res if isinstance(res, (tuple, list)) else (res,)
        return [lax.dynamic_update_slice(o, v.astype(o.dtype),
                                         _block_starts(s, coords))
                for o, v, s in zip(outs, res, out_specs)]

    if steps == 1:
        outs = one_step(0, outs0)
    else:
        outs = lax.fori_loop(0, steps, one_step, outs0)
    return tuple(outs)


def _pallas_wrap(block_fn: Callable, n_in: int) -> Callable:
    """Adapt a value->value block function to a Pallas ref kernel:
    whole-block loads, call, whole-block stores."""
    def kernel(*refs):
        ins = [r[...] for r in refs[:n_in]]
        res = block_fn(*ins)
        res = res if isinstance(res, (tuple, list)) else (res,)
        for r, v in zip(refs[n_in:], res):
            r[...] = v.astype(r.dtype)
    return kernel


def grid_call(block_fn: Callable, *, grid: Sequence[int],
              in_specs: Sequence[Spec], out_specs: Sequence[Spec],
              out_shapes: Sequence[jax.ShapeDtypeStruct], mode: str,
              unpack: bool | None = None) -> Callable:
    """Build the executable for one blocked kernel program.

    Returns ``f(*args) -> out`` (single out_shape) or ``-> tuple``.
    ``mode`` must already be resolved ('pallas'|'interpret'|'xla').
    """
    grid = tuple(int(g) for g in grid)
    out_shapes = list(out_shapes)
    single = len(out_shapes) == 1 if unpack is None else unpack

    def call(*args):
        if len(args) != len(in_specs):
            raise ValueError(f"expected {len(in_specs)} operands, "
                             f"got {len(args)}")
        if mode == "xla":
            outs = _xla_call(block_fn, grid, in_specs, out_specs,
                             out_shapes, args)
        elif mode in ("pallas", "interpret"):
            outs = pl.pallas_call(
                _pallas_wrap(block_fn, len(in_specs)),
                grid=grid,
                in_specs=[s.to_pallas() for s in in_specs],
                out_specs=[s.to_pallas() for s in out_specs],
                out_shape=out_shapes,
                interpret=(mode == "interpret"),
            )(*args)
            outs = tuple(outs) if isinstance(outs, (tuple, list)) else (outs,)
        else:
            raise ValueError(f"unresolved mode {mode!r}; call resolve_mode")
        return outs[0] if single else tuple(outs)

    return call
