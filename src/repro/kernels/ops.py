"""Jit'd public wrappers around the Pallas kernels.

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True`` (the default off-TPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.amm_gather import amm_gather_u32
from repro.kernels.banked_kv_decode import banked_kv_decode
from repro.kernels.ssd_scan import ssd_chunk_step

_UINT_FOR = {2: jnp.uint16, 4: jnp.uint32}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_amm_banks(table: jax.Array, n_banks: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Depth-partition [V, D] into XOR banks [NB, V/NB, D] + parity."""
    v, d = table.shape
    assert v % n_banks == 0, "table depth must divide into banks"
    u = _UINT_FOR[table.dtype.itemsize]
    banks = jax.lax.bitcast_convert_type(table, u).reshape(
        n_banks, v // n_banks, d)
    parity = banks[0]
    for j in range(1, n_banks):
        parity = parity ^ banks[j]
    return banks, parity


@partial(jax.jit, static_argnames=("n_banks", "interpret"))
def amm_gather(table: jax.Array, idx: jax.Array, n_banks: int = 4,
               interpret: bool | None = None) -> jax.Array:
    """Conflict-free XOR-banked gather.  table: [V, D]; idx: [N]."""
    if interpret is None:
        interpret = not _on_tpu()
    banks, parity = pack_amm_banks(table, n_banks)
    out = amm_gather_u32(banks, parity, idx.astype(jnp.int32),
                         interpret=interpret)
    return jax.lax.bitcast_convert_type(out, table.dtype)


@partial(jax.jit, static_argnames=("n_banks", "interpret"))
def kv_decode(q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array,
              n_banks: int = 8, interpret: bool | None = None) -> jax.Array:
    """Flash-decode over a bank-partitioned KV cache.
    q: [B, Hq, D]; k/v: [B, Hkv, S, D]; lengths: [B]."""
    if interpret is None:
        interpret = not _on_tpu()
    b, hkv, s, d = k.shape
    assert s % n_banks == 0
    kb = k.reshape(b, hkv, n_banks, s // n_banks, d)
    vb = v.reshape(b, hkv, n_banks, s // n_banks, d)
    return banked_kv_decode(q, kb, vb, lengths.astype(jnp.int32),
                            interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, cum, B, C, h_in, interpret: bool | None = None):
    """One SSD chunk step (see ssd_scan.py for the contract)."""
    if interpret is None:
        interpret = not _on_tpu()
    return ssd_chunk_step(x, dt, cum, B, C, h_in, interpret=interpret)
