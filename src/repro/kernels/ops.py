"""Public wrappers around the blocked kernels.

Dispatch: every wrapper resolves an execution mode (see
``lowering.resolve_mode``) and a block configuration (explicit argument
> autotuned table in ``_autotune_cache.json`` > kernel default).  The
default mode is *compiled* — real ``pallas_call`` lowering on TPU/GPU,
the XLA grid path on CPU — and runs through a jit'd implementation
with mode and blocks held static.

``mode="interpret"`` (or ``interpret=True``) is the conformance and
debugging anchor, and is dispatched *eagerly*: the Pallas interpreter
actually walks the grid in Python per call, so refs stay inspectable
and prints/breakpoints work.  (Inside an outer ``jax.jit`` the call
traces like any JAX code, so library users embedding these ops in a
jitted model keep compiled performance regardless of mode.)  The
seed wrapped the interpreter in ``jax.jit``, which traces it into
near-identical XLA — neither real interpretation nor a real lowering;
the two roles are now genuinely distinct, which is exactly what the
``kernel.* `` vs ``kernel.*_compiled`` BENCH rows measure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.amm_gather import amm_gather_u32
from repro.kernels.banked_kv_decode import banked_kv_decode
from repro.kernels.lowering import resolve_mode
from repro.kernels.ssd_scan import ssd_chunk_step

_UINT_FOR = {2: jnp.uint16, 4: jnp.uint32}


def _pick_block(target: int, n: int) -> int:
    """Largest block <= target that divides n (re-legalizes a bucketed
    autotune winner against the actual shape)."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def _config(kernel: str, mode: str, **dims: int) -> dict[str, int]:
    return autotune.get_config(kernel, jax.default_backend(), mode, **dims)


def pack_amm_banks(table: jax.Array, n_banks: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Depth-partition [V, D] into XOR banks [NB, V/NB, D] + parity."""
    v, d = table.shape
    assert v % n_banks == 0, "table depth must divide into banks"
    u = _UINT_FOR[table.dtype.itemsize]
    banks = jax.lax.bitcast_convert_type(table, u).reshape(
        n_banks, v // n_banks, d)
    parity = banks[0]
    for j in range(1, n_banks):
        parity = parity ^ banks[j]
    return banks, parity


def _amm_gather_impl(table, idx, n_banks, mode, block_n):
    banks, parity = pack_amm_banks(table, n_banks)
    out = amm_gather_u32(banks, parity, idx.astype(jnp.int32),
                         block_n=block_n, mode=mode)
    return jax.lax.bitcast_convert_type(out, table.dtype)


_amm_gather = jax.jit(_amm_gather_impl,
                      static_argnames=("n_banks", "mode", "block_n"))


def amm_gather(table: jax.Array, idx: jax.Array, n_banks: int = 4,
               interpret: bool | None = None, mode: str | None = None,
               block_n: int | None = None) -> jax.Array:
    """Conflict-free XOR-banked gather.  table: [V, D]; idx: [N]."""
    mode = resolve_mode(interpret, mode)
    v, d = table.shape
    n = int(idx.shape[0])
    if block_n is None:
        block_n = _config("amm_gather", mode, v=v, d=d, nb=n_banks,
                          n=n)["block_n"]
    fn = _amm_gather_impl if mode == "interpret" else _amm_gather
    return fn(table, idx, n_banks, mode, _pick_block(block_n, n))


def _kv_decode_impl(q, k, v, lengths, n_banks, mode, block_h):
    b, hkv, s, d = k.shape
    kb = k.reshape(b, hkv, n_banks, s // n_banks, d)
    vb = v.reshape(b, hkv, n_banks, s // n_banks, d)
    return banked_kv_decode(q, kb, vb, lengths.astype(jnp.int32),
                            block_h=block_h, mode=mode)


_kv_decode = jax.jit(_kv_decode_impl,
                     static_argnames=("n_banks", "mode", "block_h"))


def kv_decode(q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array,
              n_banks: int = 8, interpret: bool | None = None,
              mode: str | None = None, block_h: int | None = None
              ) -> jax.Array:
    """Flash-decode over a bank-partitioned KV cache.
    q: [B, Hq, D]; k/v: [B, Hkv, S, D]; lengths: [B] (per-row valid
    sequence lengths; rows with length 0 decode to zeros)."""
    mode = resolve_mode(interpret, mode)
    b, hkv, s, d = k.shape
    hq = q.shape[1]
    assert s % n_banks == 0
    group = max(hq // hkv, 1)
    if block_h is None:
        block_h = _config("kv_decode", mode, b=b, hq=hq, hkv=hkv, s=s,
                          d=d, nb=n_banks)["block_h"]
    fn = _kv_decode_impl if mode == "interpret" else _kv_decode
    return fn(q, k, v, lengths, n_banks, mode, _pick_block(block_h, group))


def _ssd_chunk_impl(x, dt, cum, B, C, h_in, mode, block_h):
    return ssd_chunk_step(x, dt, cum, B, C, h_in, block_h=block_h,
                          mode=mode)


_ssd_chunk = jax.jit(_ssd_chunk_impl, static_argnames=("mode", "block_h"))


def ssd_chunk(x, dt, cum, B, C, h_in, interpret: bool | None = None,
              mode: str | None = None, block_h: int | None = None):
    """One SSD chunk step (see ssd_scan.py for the contract)."""
    mode = resolve_mode(interpret, mode)
    bt, h, q, p = x.shape
    if block_h is None:
        block_h = _config("ssd_chunk", mode, bt=bt, h=h, q=q, p=p,
                          n=B.shape[-1])["block_h"]
    fn = _ssd_chunk_impl if mode == "interpret" else _ssd_chunk
    return fn(x, dt, cum, B, C, h_in, mode, _pick_block(block_h, h))
