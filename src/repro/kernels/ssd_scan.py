"""Mamba2 SSD chunk kernel — one chunk step of the state-space dual form
as two MXU matmuls plus a decay mask.

Grid: (batch, heads / block_h).  Per grid cell, for a chunk of Q tokens
and a block of ``block_h`` heads:
  inputs : x [BH, Q, P], dt [BH, Q], cum [BH, Q] (cumulative log-decay),
           B [Q, N], C [Q, N], h_in [BH, P, N]
  outputs: y [BH, Q, P], h_out [BH, P, N]

  L[i,j]  = exp(cum_i - cum_j)        for j <= i, else 0
  y       = ((C B^T) * L) @ (dt * x)  +  (C * exp(cum)) @ h_in^T
  h_out   = exp(cum_Q) h_in + (exp(cum_Q - cum) * dt * x)^T @ B

The [Q,N]x[N,Q] and [Q,Q]x[Q,P] contractions are MXU-shaped when
Q, N, P are multiples of 128/8; the inter-chunk recurrence stays a
lax.scan in repro.models.ssm (sequential by nature).  ``block_h``
batches heads through one grid cell so the shared B/C projections are
loaded once per block; the body is backend-agnostic and lowers through
every ``lowering.py`` mode (Pallas interpreter, real ``pallas_call``,
compiled XLA grid path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lowering import Spec, grid_call


def _ssd_block(x_blk, dt_blk, cum_blk, b_blk, c_blk, h_blk, *, q: int):
    x = x_blk[0].astype(jnp.float32)           # [BH, Q, P]
    dt = dt_blk[0].astype(jnp.float32)         # [BH, Q]
    cum = cum_blk[0].astype(jnp.float32)       # [BH, Q]
    B = b_blk[0].astype(jnp.float32)           # [Q, N]
    C = c_blk[0].astype(jnp.float32)           # [Q, N]
    h = h_blk[0].astype(jnp.float32)           # [BH, P, N]

    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    diff = jnp.where(jj[None] <= ii[None],
                     cum[:, :, None] - cum[:, None, :], -1e30)
    decay = jnp.exp(diff)                                     # [BH, Q, Q]
    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # [Q, Q]
    scores = cb[None] * decay
    y = jnp.einsum("hij,hjp->hip", scores, dt[:, :, None] * x,
                   preferred_element_type=jnp.float32)        # [BH, Q, P]
    y = y + jnp.einsum("hin,hpn->hip", C[None] * jnp.exp(cum)[:, :, None], h,
                       preferred_element_type=jnp.float32)
    tail = jnp.exp(cum[:, -1:] - cum) * dt                    # [BH, Q]
    h_out = jnp.exp(cum[:, -1])[:, None, None] * h + jnp.einsum(
        "hjp,jn->hpn", tail[:, :, None] * x, B,
        preferred_element_type=jnp.float32)
    return y[None].astype(x_blk.dtype), h_out[None].astype(h_blk.dtype)


def ssd_chunk_step(x: jax.Array, dt: jax.Array, cum: jax.Array,
                   B: jax.Array, C: jax.Array, h_in: jax.Array,
                   block_h: int = 1, mode: str = "interpret"
                   ) -> tuple[jax.Array, jax.Array]:
    """x: [Bt, H, Q, P]; dt/cum: [Bt, H, Q]; B/C: [Bt, Q, N];
    h_in: [Bt, H, P, N] -> (y [Bt,H,Q,P], h_out [Bt,H,P,N]).
    ``block_h`` must divide H; ``mode`` must be resolved."""
    bt, h, q, p = x.shape
    n = B.shape[-1]
    block_h = min(block_h, h)
    assert h % block_h == 0, "head block must divide the head count"
    call = grid_call(
        functools.partial(_ssd_block, q=q),
        grid=(bt, h // block_h),
        in_specs=[
            Spec((1, block_h, q, p), lambda i, j: (i, j, 0, 0)),
            Spec((1, block_h, q), lambda i, j: (i, j, 0)),
            Spec((1, block_h, q), lambda i, j: (i, j, 0)),
            Spec((1, q, n), lambda i, j: (i, 0, 0)),
            Spec((1, q, n), lambda i, j: (i, 0, 0)),
            Spec((1, block_h, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            Spec((1, block_h, q, p), lambda i, j: (i, j, 0, 0)),
            Spec((1, block_h, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_shapes=[
            jax.ShapeDtypeStruct((bt, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bt, h, p, n), jnp.float32),
        ],
        mode=mode,
    )
    return call(x, dt, cum, B, C, h_in)
