"""Mamba2 SSD chunk kernel — one chunk step of the state-space dual form
as two MXU matmuls plus a decay mask.

Grid: (batch, heads).  Per grid cell, for a chunk of Q tokens:
  inputs : x [Q, P], dt [Q], cum [Q] (cumulative log-decay),
           B [Q, N], C [Q, N], h_in [P, N]
  outputs: y [Q, P], h_out [P, N]

  L[i,j]  = exp(cum_i - cum_j)        for j <= i, else 0
  y       = ((C B^T) * L) @ (dt * x)  +  (C * exp(cum)) @ h_in^T
  h_out   = exp(cum_Q) h_in + (exp(cum_Q - cum) * dt * x)^T @ B

The [Q,N]x[N,Q] and [Q,Q]x[Q,P] contractions are MXU-shaped when
Q, N, P are multiples of 128/8; the inter-chunk recurrence stays a
lax.scan in repro.models.ssm (sequential by nature).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, h_ref, y_ref, hout_ref,
            *, q: int):
    x = x_ref[0, 0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [Q]
    cum = cum_ref[0, 0].astype(jnp.float32)    # [Q]
    B = b_ref[0].astype(jnp.float32)           # [Q, N]
    C = c_ref[0].astype(jnp.float32)           # [Q, N]
    h = h_ref[0, 0].astype(jnp.float32)        # [P, N]

    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    diff = jnp.where(jj <= ii, cum[:, None] - cum[None, :], -1e30)
    decay = jnp.exp(diff)                                     # [Q, Q]
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32) * decay
    y = jnp.dot(scores, dt[:, None] * x,
                preferred_element_type=jnp.float32)           # [Q, P]
    y = y + jnp.dot(C * jnp.exp(cum)[:, None], h.T,
                    preferred_element_type=jnp.float32)
    tail = jnp.exp(cum[-1] - cum) * dt                        # [Q]
    h_out = jnp.exp(cum[-1]) * h + jnp.dot((tail[:, None] * x).T, B,
                                           preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    hout_ref[0, 0] = h_out.astype(hout_ref.dtype)


def ssd_chunk_step(x: jax.Array, dt: jax.Array, cum: jax.Array,
                   B: jax.Array, C: jax.Array, h_in: jax.Array,
                   interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x: [Bt, H, Q, P]; dt/cum: [Bt, H, Q]; B/C: [Bt, Q, N];
    h_in: [Bt, H, P, N] -> (y [Bt,H,Q,P], h_out [Bt,H,P,N])."""
    bt, h, q, p = x.shape
    n = B.shape[-1]
    grid = (bt, h)
    y, hout = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bt, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, cum, B, C, h_in)
    return y, hout
