"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose
kernel-vs-ref across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def amm_gather_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """table: [V, D]; idx: [N] -> [N, D]."""
    return jnp.take(table, idx, axis=0)


_UINT_FOR = {2: jnp.uint16, 4: jnp.uint32}


def amm_gather_replay_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Replay-backed oracle for ``amm_gather``: the gather is an op trace
    on the H-NTX-Rd *functional model* (``repro.core.amm.replay``),
    batched with vmap across the payload columns — one AMM instance per
    column, all replaying the same request stream in a single scan.

    Requests are paired two per cycle (the kernel's 2 read ports): even
    slots decode through the direct path, odd slots through the
    XOR-reconstruction (parity) path, exactly like the kernel's
    conflict-free second port.  table: [V, D]; idx: [N] -> [N, D].
    """
    from repro.core.amm.replay import init_flat, replay_batched
    from repro.core.amm.spec import AMMSpec

    v, d = table.shape
    u = _UINT_FOR[table.dtype.itemsize]
    cols = jax.lax.bitcast_convert_type(table, u).astype(jnp.uint32).T  # [D,V]
    spec = AMMSpec("h_ntx_rd", n_read=2, n_write=1, depth=v)
    states = jax.vmap(lambda c: init_flat(spec, c))(cols)

    n = idx.shape[0]
    padded = jnp.concatenate([idx.astype(jnp.int32),
                              jnp.zeros((n % 2,), jnp.int32)])
    cycles = padded.shape[0] // 2
    ra = padded.reshape(cycles, 2)
    wa = jnp.zeros((cycles, 1), jnp.int32)
    wv = jnp.zeros((cycles, 1), jnp.uint32)
    wm = jnp.zeros((cycles, 1), bool)
    _, result = replay_batched(spec, states, ra, wa, wv, wm, share_trace=True)

    # [D, T, 2]: keep direct reads from even slots, parity from odd slots
    slots = jnp.stack([result.read_vals[..., 0], result.parity_vals[..., 1]],
                      axis=-1)
    flat = slots.reshape(d, cycles * 2)[:, :n].T            # [N, D]
    return jax.lax.bitcast_convert_type(flat.astype(u), table.dtype)


def kv_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  lengths: jax.Array) -> jax.Array:
    """Masked dense reference.  q: [B, Hq, D]; k/v: [B, Hkv, S, D];
    lengths: [B] per-row valid lengths -> [B, Hq, D].

    Positions ``>= lengths[b]`` are excluded from the softmax, so padded
    K/V content never reaches the output; a fully-empty row
    (``lengths[b] == 0``) decodes to zeros — the same ragged-batch
    semantics the banked kernel implements (softmax over -inf would
    otherwise be NaN)."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d)
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(scores - jnp.where(jnp.isfinite(m), m, 0.0)),
                  0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def ssd_chunk_ref(x, dt, cum, B, C, h_in):
    """Same contract as ssd_scan.ssd_chunk_step, dense einsums."""
    la = jnp.where(
        jnp.arange(cum.shape[-1])[None, None, :, None]
        >= jnp.arange(cum.shape[-1])[None, None, None, :],
        cum[..., :, None] - cum[..., None, :], -1e30)
    decay = jnp.exp(la)                                        # [b,h,i,j]
    scores = jnp.einsum("bin,bjn->bij", C.astype(jnp.float32),
                        B.astype(jnp.float32))[:, None] * decay
    y = jnp.einsum("bhij,bhj,bhjp->bhip", scores, dt.astype(jnp.float32),
                   x.astype(jnp.float32))
    y = y + jnp.einsum("bin,bhi,bhpn->bhip", C.astype(jnp.float32),
                       jnp.exp(cum), h_in.astype(jnp.float32))
    tail = jnp.exp(cum[..., -1:] - cum) * dt                   # [b,h,q]
    h_out = jnp.exp(cum[..., -1])[..., None, None] * h_in + jnp.einsum(
        "bhj,bhjp,bjn->bhpn", tail, x.astype(jnp.float32),
        B.astype(jnp.float32))
    return y, h_out
