"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose
kernel-vs-ref across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def amm_gather_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """table: [V, D]; idx: [N] -> [N, D]."""
    return jnp.take(table, idx, axis=0)


def kv_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  lengths: jax.Array) -> jax.Array:
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; lengths: [B] -> [B, Hq, D]."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d)
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def ssd_chunk_ref(x, dt, cum, B, C, h_in):
    """Same contract as ssd_scan.ssd_chunk_step, dense einsums."""
    la = jnp.where(
        jnp.arange(cum.shape[-1])[None, None, :, None]
        >= jnp.arange(cum.shape[-1])[None, None, None, :],
        cum[..., :, None] - cum[..., None, :], -1e30)
    decay = jnp.exp(la)                                        # [b,h,i,j]
    scores = jnp.einsum("bin,bjn->bij", C.astype(jnp.float32),
                        B.astype(jnp.float32))[:, None] * decay
    y = jnp.einsum("bhij,bhj,bhjp->bhip", scores, dt.astype(jnp.float32),
                   x.astype(jnp.float32))
    y = y + jnp.einsum("bin,bhi,bhpn->bhip", C.astype(jnp.float32),
                       jnp.exp(cum), h_in.astype(jnp.float32))
    tail = jnp.exp(cum[..., -1:] - cum) * dt                   # [b,h,q]
    h_out = jnp.exp(cum[..., -1])[..., None, None] * h_in + jnp.einsum(
        "bhj,bhjp,bjn->bhpn", tail, x.astype(jnp.float32),
        B.astype(jnp.float32))
    return y, h_out
