"""Banked embedding table: the AMM plan applied to vocab gathers.

``banked_embedding_lookup`` routes through the XOR-banked Pallas kernel
when the planner chose AMM for the embedding stream (low-locality,
zipf-skewed token ids); otherwise it uses the plain XLA gather.  The
kernel runs compiled on every backend (real Pallas lowering on TPU/GPU,
the XLA grid path on CPU) — tests assert both paths agree bit-exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import amm_gather
from repro.memory.planner import MemoryPlan, StreamPlan


def banked_embedding_lookup(table: jax.Array, token_ids: jax.Array,
                            plan: StreamPlan | None = None,
                            interpret: bool | None = None) -> jax.Array:
    """table: [V, D]; token_ids: [...] int -> [..., D]."""
    flat = token_ids.reshape(-1)
    if plan is not None and plan.use_amm and table.shape[0] % plan.n_banks == 0:
        out = amm_gather(table, flat, n_banks=plan.n_banks,
                         interpret=interpret)
    else:
        out = jnp.take(table, flat, axis=0)
    return out.reshape(*token_ids.shape, table.shape[1])
