"""MemoryPlanner — the paper's DSE loop applied to LM-serving memories.

For each memory-bound access stream of an (arch x shape) workload —
embedding-table gathers, KV-cache decode reads, MoE expert dispatch —
the planner:

  1. synthesizes the dynamic address trace (same role as Aladdin's LLVM
     trace; repro.data generates token streams, the router distribution
     generates expert streams),
  2. computes Weinberg spatial locality (paper eq. 1) at *element*
     granularity — on TPU the transfer unit is a table row / KV page /
     expert bank, not a byte, so streams are scored on unit indices
     (the paper's byte-granularity form stays in repro.core.locality
     for the MachSuite reproduction),
  3. applies the paper's empirical law: true-multiport (AMM) layouts pay
     off below L < 0.3; stride-friendly streams stay banked,
  4. runs the cost model over candidate configs and picks the cheapest
     conflict-free one, which parameterizes the Pallas kernels
     (n_banks for amm_gather / kv_decode) and the cluster-level shard
     layout (bank = shard).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.amm.spec import AMMSpec
from repro.core.cost import memory_cost
from repro.core.locality import spatial_locality_np

AMM_LOCALITY_THRESHOLD = 0.3   # paper IV-C


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    stream: str
    locality: float
    use_amm: bool
    n_banks: int
    n_read_ports: int
    est_area_mm2: float
    note: str = ""


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    arch: str
    shape: str
    streams: tuple[StreamPlan, ...]

    def for_stream(self, name: str) -> StreamPlan | None:
        for s in self.streams:
            if s.stream == name:
                return s
        return None


# ----------------------------------------------------------------------
# Trace synthesis per stream
# ----------------------------------------------------------------------
def embedding_stream(arch: ArchConfig, n: int = 8192,
                     zipf_alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """Token-id gather addresses into the (sharded) embedding table."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, arch.padded_vocab + 1, dtype=np.float64)
    p = ranks ** (-zipf_alpha)
    p /= p.sum()
    ids = rng.choice(arch.padded_vocab, size=n, p=p)
    return ids.astype(np.int64)                 # unit = one table row


def expert_stream(arch: ArchConfig, n: int = 8192, seed: int = 1
                  ) -> np.ndarray | None:
    if arch.n_experts == 0:
        return None
    rng = np.random.default_rng(seed)
    # router skew: realistic MoE routing is mildly zipfian over experts
    ranks = np.arange(1, arch.n_experts + 1, dtype=np.float64)
    p = ranks ** -0.7
    p /= p.sum()
    e = rng.choice(arch.n_experts, size=n, p=p)
    return e.astype(np.int64)                   # unit = one expert bank


def kv_stream(arch: ArchConfig, shape: ShapeConfig, n: int = 8192,
              page: int = 16, seed: int = 2) -> np.ndarray | None:
    """Paged-KV read stream at decode: each step walks every page of a
    random subset of sequences (continuous batching makes the page walk
    interleave across sequences -> low spatial locality)."""
    if not arch.has_attention or not shape.is_decode:
        return None
    rng = np.random.default_rng(seed)
    n_pages = max(shape.seq_len // page, 1)
    seqs = rng.integers(0, max(shape.global_batch, 1), size=n)
    pages = rng.integers(0, n_pages, size=n)   # pages allocated non-contig
    return (seqs * n_pages + pages).astype(np.int64)  # unit = one KV page


# ----------------------------------------------------------------------
def _choose(stream: str, addrs: np.ndarray, depth: int,
            width_bits: int) -> StreamPlan:
    L = spatial_locality_np(addrs)
    use_amm = L < AMM_LOCALITY_THRESHOLD
    depth = max(64, 1 << (int(depth) - 1).bit_length())
    if use_amm:
        candidates = [AMMSpec("hb_ntx", r, 2, depth, width_bits)
                      for r in (2, 4)] + \
                     [AMMSpec("lvt", r, 2, depth, width_bits) for r in (2, 4)]
        costed = sorted(candidates, key=lambda s: memory_cost(s).area_mm2)
        best = costed[0]
        nb = best.leaf_banks()[0]
        return StreamPlan(stream, float(L), True, nb, best.n_read,
                          memory_cost(best).area_mm2,
                          f"AMM {best.kind} (L={L:.3f} < 0.3)")
    nb = 8
    spec = AMMSpec("banked", 2 * nb, 2 * nb, depth, width_bits, n_banks=nb)
    return StreamPlan(stream, float(L), False, nb, 2 * nb,
                      memory_cost(spec).area_mm2,
                      f"banked (L={L:.3f} >= 0.3)")


def plan_memory(arch: ArchConfig, shape: ShapeConfig) -> MemoryPlan:
    streams: list[StreamPlan] = []
    emb = embedding_stream(arch)
    streams.append(_choose("embedding", emb, arch.padded_vocab, 64))
    es = expert_stream(arch)
    if es is not None:
        streams.append(_choose("moe_experts", es, max(arch.n_experts, 64), 64))
    ks = kv_stream(arch, shape)
    if ks is not None:
        streams.append(_choose("kv_pages", ks,
                               shape.global_batch * shape.seq_len // 16, 64))
    if arch.family in ("ssm", "hybrid"):
        # SSM state walk is dense/stride-1: locality ~ 1 -> banking; the
        # paper's technique is *inapplicable in its benefit regime* here.
        addrs = np.arange(4096, dtype=np.int64)  # unit-stride state walk
        sp = _choose("ssm_state", addrs, 4096, 32)
        streams.append(dataclasses.replace(
            sp, note=sp.note + "; AMM inapplicable for stride-1 state walks"))
    return MemoryPlan(arch.name, shape.name, tuple(streams))
