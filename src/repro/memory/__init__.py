from repro.memory.embedding import banked_embedding_lookup
from repro.memory.kv_cache import BankedKVCache
from repro.memory.planner import (AMM_LOCALITY_THRESHOLD, MemoryPlan,
                                  StreamPlan, plan_memory)

__all__ = ["plan_memory", "MemoryPlan", "StreamPlan",
           "AMM_LOCALITY_THRESHOLD", "banked_embedding_lookup",
           "BankedKVCache"]
