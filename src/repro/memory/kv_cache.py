"""Banked KV cache: the AMM plan applied to decode attention.

The cache for one layer is [B, Hkv, S, D]; the plan's bank count
partitions S into independent banks (cluster analogue: one bank = one
"model"-axis shard, see launch/sharding.cache_pspecs).  ``decode_read``
is the multi-port read burst of a decode step, served by the banked
flash-decode Pallas kernel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import kv_decode
from repro.memory.planner import StreamPlan


@dataclasses.dataclass
class BankedKVCache:
    k: jax.Array            # [B, Hkv, S, D]
    v: jax.Array
    length: jax.Array       # [B] int32 current lengths
    n_banks: int = 8

    @classmethod
    def create(cls, batch: int, n_kv_heads: int, max_len: int, head_dim: int,
               dtype=jnp.bfloat16, plan: StreamPlan | None = None
               ) -> "BankedKVCache":
        nb = plan.n_banks if (plan and plan.use_amm) else 8
        if nb <= 0:
            raise ValueError(f"plan.n_banks must be positive, got {nb}")
        nb = min(nb, max_len)
        # the kernel needs S divisible by the bank count: round down to
        # the largest divisor of max_len <= nb (a plain halving loop
        # collapses any non-power-of-two request, e.g. 6 banks over
        # S=64, all the way to a single bank)
        while max_len % nb:
            nb -= 1
        return cls(
            k=jnp.zeros((batch, n_kv_heads, max_len, head_dim), dtype),
            v=jnp.zeros((batch, n_kv_heads, max_len, head_dim), dtype),
            length=jnp.zeros((batch,), jnp.int32),
            n_banks=nb,
        )

    def append(self, k_new: jax.Array, v_new: jax.Array) -> "BankedKVCache":
        """k/v_new: [B, Hkv, 1, D] written at each row's *own* current
        length — mixed-length batches (ragged serving traffic) place
        each row's token independently via a per-row scatter.

        Full-row contract: a row at capacity (``length == max_len``)
        drops the append — its k/v stay untouched and its length stays
        clamped at ``max_len`` (eviction/rotation is the caller's job).
        Without ``mode="drop"`` JAX *clamps* the out-of-bounds scatter
        index, silently overwriting the newest token in the last slot
        while ``length`` kept growing past the cache size."""
        rows = jnp.arange(self.k.shape[0])
        max_len = self.k.shape[2]
        k = self.k.at[rows, :, self.length].set(
            k_new[:, :, 0].astype(self.k.dtype), mode="drop")
        v = self.v.at[rows, :, self.length].set(
            v_new[:, :, 0].astype(self.v.dtype), mode="drop")
        length = jnp.minimum(self.length + 1, max_len)
        return dataclasses.replace(self, k=k, v=v, length=length)

    def decode_read(self, q: jax.Array, interpret: bool | None = None
                    ) -> jax.Array:
        """q: [B, Hq, D] -> attention output [B, Hq, D] via the banked
        flash-decode kernel."""
        return kv_decode(q, self.k, self.v, self.length,
                         n_banks=self.n_banks, interpret=interpret)
