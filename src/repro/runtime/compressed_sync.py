"""Compressed cross-pod gradient synchronization (shard_map).

Replaces the cross-pod bf16 all-reduce of gradients with:
  quantize int8 (per-tensor scale) -> all-gather over "pod" ->
  dequantize + mean locally.

Ring all-reduce moves ~2(n-1)/n x 2 bytes/elem; int8 all-gather moves
(n-1)/n x 1 byte/elem (+ one f32 scale per tensor) — a ~4x cut of the
cross-pod wire traffic, at the cost of n_pods x receive buffers and the
quantization error (error feedback in ``repro.runtime.ft`` keeps the
optimizer unbiased over steps; exactness bounds tested).

Integration point: pods compute *local* gradients (grads sharded with a
pod-local psum via shard_map over "pod"), then this sync produces the
global mean.  ``benchmarks/run.py grad_sync_bench`` lowers both variants
on the 2x16x16 mesh and reports HLO collective bytes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _q_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def compressed_pod_mean(grads: Any, mesh: Mesh, axis: str = "pod") -> Any:
    """Mean of per-pod gradient pytrees across the pod axis, int8 wire
    format.  Input/output: pytree sharded P() along `axis` (replicated
    within a pod, distinct across pods -> mean across pods)."""
    n = mesh.shape[axis]

    def sync_leaf(g):
        def inner(gl):
            q, s = _q_int8(gl)
            # int8 across the wire; one f32 scale per tensor
            q_all = jax.lax.all_gather(q, axis)           # [n, ...] int8
            s_all = jax.lax.all_gather(s, axis)           # [n] f32
            deq = q_all.astype(jnp.float32) * s_all.reshape(
                (n,) + (1,) * gl.ndim)
            return jnp.mean(deq, axis=0).astype(gl.dtype)

        # in reality the grads VARY across pods (per-pod local grads) but
        # are replicated within a pod; P() can't express that, so the
        # static replication check is disabled.
        return shard_map(
            inner, mesh=mesh,
            in_specs=P(), out_specs=P(), check_rep=False,
        )(g)

    return jax.tree.map(sync_leaf, grads)


def uncompressed_pod_mean(grads: Any, mesh: Mesh, axis: str = "pod") -> Any:
    """Baseline: bf16 psum-mean across pods (what XLA inserts)."""
    n = mesh.shape[axis]

    def sync_leaf(g):
        def inner(gl):
            return (jax.lax.psum(gl.astype(jnp.bfloat16), axis)
                    / n).astype(gl.dtype)

        return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_rep=False)(g)

    return jax.tree.map(sync_leaf, grads)
