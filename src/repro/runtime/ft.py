"""Fault-tolerance runtime: heartbeat/straggler monitoring, elastic
re-meshing after chip loss, and int8 gradient compression with error
feedback for the cross-pod all-reduce.

These are the control-plane pieces a 1000+-node deployment needs around
the SPMD program; they are exercised with simulated failures in tests
(this container has one real device).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


# ======================================================================
# Straggler / heartbeat monitoring
# ======================================================================
@dataclasses.dataclass
class StragglerPolicy:
    window: int = 16               # step-time history per worker
    threshold: float = 2.5         # x median -> straggler
    min_history: int = 4
    max_drop_frac: float = 0.125   # never drop more than this many workers


class HeartbeatMonitor:
    """Tracks per-worker step times; flags stragglers and dead workers.

    In a real deployment every host reports a heartbeat per step; here
    the same logic is driven by recorded step times (tests inject
    synthetic delays)."""

    def __init__(self, n_workers: int, policy: StragglerPolicy | None = None,
                 dead_after_s: float = 60.0) -> None:
        self.n = n_workers
        self.policy = policy or StragglerPolicy()
        self.dead_after_s = dead_after_s
        self._hist: list[list[float]] = [[] for _ in range(n_workers)]
        self._last_seen = [time.monotonic()] * n_workers

    def report(self, worker: int, step_time_s: float,
               now: float | None = None) -> None:
        h = self._hist[worker]
        h.append(step_time_s)
        if len(h) > self.policy.window:
            h.pop(0)
        self._last_seen[worker] = now if now is not None else time.monotonic()

    def stragglers(self) -> list[int]:
        med = np.median([np.median(h) for h in self._hist
                         if len(h) >= self.policy.min_history] or [0.0])
        if med <= 0:
            return []
        out = [w for w, h in enumerate(self._hist)
               if len(h) >= self.policy.min_history
               and np.median(h) > self.policy.threshold * med]
        cap = max(1, int(self.n * self.policy.max_drop_frac))
        return sorted(out, key=lambda w: -np.median(self._hist[w]))[:cap]

    def dead(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.monotonic()
        return [w for w, t in enumerate(self._last_seen)
                if now - t > self.dead_after_s]


# ======================================================================
# Elastic re-meshing
# ======================================================================
def elastic_mesh_shape(n_devices: int, model_parallel: int = 16,
                       multi_pod_threshold: int = 512
                       ) -> dict[str, Any]:
    """Best mesh for the devices that survive a failure.

    Keeps TP ("model") fixed at the largest power-of-two <= requested
    that divides the device count (TP degree is baked into weight
    shards), puts the rest on data (and pod when >= threshold)."""
    m = model_parallel
    while m > 1 and n_devices % m:
        m //= 2
    rest = n_devices // m
    if rest >= (multi_pod_threshold // m) and rest % 2 == 0:
        return {"shape": (2, rest // 2, m), "axes": ("pod", "data", "model")}
    return {"shape": (rest, m), "axes": ("data", "model")}


@dataclasses.dataclass
class ElasticPlan:
    old_devices: int
    new_devices: int
    mesh: dict[str, Any]
    batch_ratio: float      # global batch kept constant -> more accum steps

    @property
    def extra_accum_factor(self) -> int:
        return max(1, int(round(self.batch_ratio)))


def plan_rescale(old_devices: int, new_devices: int,
                 model_parallel: int = 16) -> ElasticPlan:
    mesh = elastic_mesh_shape(new_devices, model_parallel)
    return ElasticPlan(old_devices, new_devices, mesh,
                       batch_ratio=old_devices / max(new_devices, 1))


# ======================================================================
# Gradient compression (int8 + error feedback)
# ======================================================================
def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_grad_tree(grads: Any, error_state: Any | None = None
                         ) -> tuple[Any, Any]:
    """Quantize a grad pytree with error feedback: the quantization
    residual is carried and added back next step, so compression error
    does not bias the optimizer.  Returns (decompressed grads for the
    all-reduce path, new error state)."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree.map(one, grads, error_state)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, err
