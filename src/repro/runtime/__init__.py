from repro.runtime.ft import (ElasticPlan, HeartbeatMonitor, StragglerPolicy,
                              compress_int8, compressed_grad_tree,
                              decompress_int8, elastic_mesh_shape,
                              plan_rescale)

__all__ = ["HeartbeatMonitor", "StragglerPolicy", "elastic_mesh_shape",
           "plan_rescale", "ElasticPlan", "compress_int8", "decompress_int8",
           "compressed_grad_tree"]
