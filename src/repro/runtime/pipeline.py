"""GPipe-style pipeline parallelism over a mesh axis.

The layer stack is split into P contiguous stages; each device along the
pipeline axis holds one stage's parameters.  Microbatches stream through
with the classic (M + P - 1)-tick schedule; boundary activations move
between neighbouring stages with ``jax.lax.ppermute`` inside
``shard_map``.  Intended for the "pod" axis of the production mesh
(cross-pod ICI is the slow link, and PP moves only boundary activations
across it — DESIGN.md §4); the dry-run default keeps pod as pure DP.

``pipeline_apply`` is deterministic, jit-able, and validated against the
equivalent sequential stack in tests/test_pipeline.py.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _pvary(x: jax.Array, axes: tuple) -> jax.Array:
    """Mark ``x`` device-varying along ``axes`` (jax >= 0.6 ``lax.pvary``).

    Older jax has no varying-axis type system inside ``shard_map``;
    there the marker is semantically the identity, so fall back to it.
    """
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axes) if fn is not None else x


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """Reshape [L, ...] stacked layer params to [P, L/P, ...]."""

    def resh(x):
        l = x.shape[0]
        assert l % n_stages == 0, "layers must divide stages"
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree.map(resh, stacked_params)


def pipeline_apply(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    staged_params: Any,          # [P, L/P, ...] pytree
    microbatches: jax.Array,     # [M, mb, ...] inputs
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the staged stack over microbatches; returns [M, mb, ...]."""
    n_stages = mesh.shape[axis]
    m = microbatches.shape[0]
    ticks = m + n_stages - 1

    def stage_fwd(params_local, h):
        """Apply this device's L/P layers (params_local: [L/P, ...])."""

        def body(carry, lp):
            return layer_fn(lp, carry), None

        out, _ = jax.lax.scan(body, h, params_local)
        return out

    def shard_fn(staged_local, mbs):
        # staged_local: [1, L/P, ...] (this stage's params)
        # mbs: full [M, mb, ...] (replicated along the pipe axis)
        stage_id = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda x: x[0], staged_local)
        mb_shape = mbs.shape[1:]
        # carriers must be marked device-varying along the pipe axis
        h = _pvary(jnp.zeros(mb_shape, mbs.dtype), (axis,))
        outs = _pvary(jnp.zeros((m,) + mb_shape, mbs.dtype), (axis,))
        mbs = _pvary(mbs, (axis,))

        def tick(carry, t):
            h, outs = carry
            # first stage ingests microbatch t (while valid)
            mb_in = mbs[jnp.clip(t, 0, m - 1)]
            h = jnp.where(stage_id == 0, mb_in, h)
            h = stage_fwd(params_local, h)
            # last stage retires microbatch (t - P + 1)
            out_idx = t - (n_stages - 1)
            valid = jnp.logical_and(out_idx >= 0, stage_id == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h, jnp.maximum(out_idx, 0), 0),
                lambda o: o,
                outs,
            )
            # shift boundary activations to the next stage
            h = jax.lax.ppermute(
                h, axis,
                perm=[(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (h, outs), None

        (h, outs), _ = jax.lax.scan(tick, (h, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them back
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, 0.0), axis)
        return outs

    pspec_params = jax.tree.map(lambda _: P(axis), staged_params)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(pspec_params, P()),
        out_specs=P(),
    )
    return fn(staged_params, microbatches)
