"""Scenario: full paper-style design-space exploration on one benchmark.

Reproduces the Fig-4 flow for a chosen MachSuite benchmark: sweep
banking factors x AMM designs x unroll, print the (time, area, power)
points, both Pareto fronts, the design-space expansion, and the Fig-5
performance ratio.

Run:  PYTHONPATH=src python examples/dse_machsuite.py [bench] [--full]
"""
import sys

from repro.core.bench import BENCHMARKS
from repro.core.dse import (DEFAULT_DESIGNS, design_space_expansion,
                            pareto_front, performance_ratio, sweep)
from repro.core.locality import trace_locality

bench = sys.argv[1] if len(sys.argv) > 1 and not sys.argv[1].startswith("-") \
    else "gemm_ncubed"
full = "--full" in sys.argv
mod = BENCHMARKS[bench]
params = mod.Params() if full else mod.TINY

tr = mod.gen_trace(params)
addrs, aids = tr.mem_addrs_and_arrays()
print(f"benchmark={bench}  nodes={tr.n_nodes}  mem_ops={tr.n_mem}  "
      f"L_spatial={trace_locality(addrs, aids):.3f}\n")

pts = sweep(tr, DEFAULT_DESIGNS, unrolls=(1, 2, 4, 8))
print(f"{'design':16s} {'unroll':6s} {'cycles':>8s} {'time_us':>9s} "
      f"{'area_mm2':>9s} {'power_mW':>9s} {'stalls':>8s}")
for p in sorted(pts, key=lambda p: p.time_us):
    print(f"{p.design:16s} {p.unroll:<6d} {p.cycles:8d} {p.time_us:9.2f} "
          f"{p.area_mm2:9.4f} {p.power_mw:9.1f} {p.bank_conflict_stalls:8d}")

banking = [p for p in pts if not p.is_amm]
amm = [p for p in pts if p.is_amm]
print("\nbanking Pareto (time, area):",
      [(round(p.time_us, 2), round(p.area_mm2, 4)) for p in pareto_front(banking)])
print("AMM Pareto     (time, area):",
      [(round(p.time_us, 2), round(p.area_mm2, 4)) for p in pareto_front(amm)])
print(f"\ndesign-space expansion (fastest banked / fastest AMM): "
      f"{design_space_expansion(banking, amm):.2f}x")
print(f"performance ratio (geomean banked-area / AMM-area at iso-time): "
      f"{performance_ratio(pts):.2f}  (>1 means AMM is the better design)")
