"""Scenario: full paper-style design-space exploration on one benchmark.

Reproduces the Fig-4 flow for a chosen MachSuite benchmark: sweep
banking factors x AMM designs x unroll on the parallel sweep runner,
print the (time, area, power) points, both Pareto fronts, the
design-space expansion, and the Fig-5 performance ratio.

Run:  PYTHONPATH=src python examples/dse_machsuite.py [bench] [--full]
          [--jobs N] [--cache-dir DIR]
"""
import argparse
import os

from repro.core.bench import BENCHMARKS, get_trace
from repro.core.dse import (DEFAULT_DESIGNS, design_space_expansion,
                            pareto_front, performance_ratio, run_sweep)
from repro.core.sim import prepare_trace

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("bench", nargs="?", default="gemm_ncubed",
                choices=sorted(BENCHMARKS))
ap.add_argument("--full", action="store_true")
ap.add_argument("--jobs", type=int, default=os.cpu_count() or 1)
ap.add_argument("--cache-dir", default=None)
args = ap.parse_args()

pt = prepare_trace(get_trace(args.bench, full=args.full))
print(f"benchmark={args.bench}  nodes={pt.n_nodes}  "
      f"mem_ops={pt.trace.n_mem}  L_spatial={pt.locality:.3f}\n")

pts = run_sweep(pt, DEFAULT_DESIGNS, unrolls=(1, 2, 4, 8),
                jobs=args.jobs, cache_dir=args.cache_dir)
print(f"{'design':18s} {'unroll':6s} {'cycles':>8s} {'time_us':>9s} "
      f"{'area_mm2':>9s} {'power_mW':>9s} {'bank_st':>8s} {'parity_st':>9s} "
      f"{'pair_st':>7s}")
for p in sorted(pts, key=lambda p: p.time_us):
    print(f"{p.design:18s} {p.unroll:<6d} {p.cycles:8d} {p.time_us:9.2f} "
          f"{p.area_mm2:9.4f} {p.power_mw:9.1f} {p.bank_conflict_stalls:8d} "
          f"{p.parity_fanout_stalls:9d} {p.write_pair_stalls:7d}")

banking = [p for p in pts if not p.is_amm]
amm = [p for p in pts if p.is_amm]
print("\nbanking Pareto (time, area):",
      [(round(p.time_us, 2), round(p.area_mm2, 4)) for p in pareto_front(banking)])
print("AMM Pareto     (time, area):",
      [(round(p.time_us, 2), round(p.area_mm2, 4)) for p in pareto_front(amm)])
print(f"\ndesign-space expansion (fastest banked / fastest AMM): "
      f"{design_space_expansion(banking, amm):.2f}x")
print(f"performance ratio (geomean banked-area / AMM-area at iso-time): "
      f"{performance_ratio(pts):.2f}  (>1 means AMM is the better design)")
