"""Scenario: batched serving with the AMM memory planner.

Runs the planner (locality -> AMM-vs-banked decision per memory stream),
prefills a batch of prompts and decodes continuations, printing
tokens/s.  Try --arch minicpm3-4b to see the MLA latent cache, or
--arch mamba2-130m for the attention-free path.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-1.7b]
"""
import sys

from repro.launch.serve import main

argv = sys.argv[1:] if len(sys.argv) > 1 else []
if "--arch" not in argv:
    argv += ["--arch", "qwen3-1.7b"]
main(argv + ["--preset", "tiny", "--batch", "4",
             "--prompt-len", "64", "--gen", "32"])
