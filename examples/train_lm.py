"""Scenario: end-to-end training driver.

Trains the ~100M-parameter preset on the synthetic corpus for a few
hundred steps with checkpointing + crash-recovery enabled, asserting the
loss goes down.  (This is the deliverable-(b) end-to-end example; the
same driver scales to the full archs on a real mesh.)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys

from repro.launch.train import main

steps = "300"
if "--steps" in sys.argv:
    steps = sys.argv[sys.argv.index("--steps") + 1]

main([
    "--preset", "m100",
    "--steps", steps,
    "--batch", "8",
    "--seq", "256",
    "--ckpt-dir", "/tmp/repro_train_lm",
    "--ckpt-every", "100",
    "--simulate-failure", "150",
    "--log-every", "25",
])
