"""Quickstart: the paper's pipeline in 60 lines.

1. Build an algorithmic multi-port memory (HB-NTX-RdWr, 4R2W) out of
   2-port banks and show conflict-free multi-port semantics.
2. Trace a MachSuite benchmark, measure its Weinberg spatial locality.
3. Run the Mem-Aladdin DSE sweep and print the paper's headline
   comparison: AMM vs banked area at matched execution time.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import AMMSpec, make_amm, trace_locality
from repro.core.bench import BENCHMARKS
from repro.core.dse import (DesignPoint, design_space_expansion,
                            performance_ratio, sweep)

# --- 1. a 4R2W memory built from dual-port banks ------------------------
spec = AMMSpec("hb_ntx", n_read=4, n_write=2, depth=256)
sim = make_amm(spec, jnp.arange(256, dtype=jnp.uint32))
state = sim.state

# four reads + two conflicting writes in ONE cycle, no stalls:
reads = jnp.array([0, 1, 128, 255])
w_addr = jnp.array([7, 9])          # both land in the same half -> conflict
w_val = jnp.array([111, 222], dtype=jnp.uint32)
state, vals = sim.step(state, reads, w_addr, w_val, jnp.array([True, True]))
print("4 parallel reads  :", vals)
print("conflicting writes:", sim.read(state, jnp.int32(7)),
      sim.read(state, jnp.int32(9)), "(via XOR ref re-pointing)")
print("parity-path read  :", sim.read_parity(state, jnp.int32(9)),
      "(reconstructed from the other bank + Ref)")
banks, depth = spec.leaf_banks()
print(f"built from {banks} two-port banks of depth {depth} "
      f"(storage overhead {spec.storage_bits() / (256 * 32):.2f}x)")

# ...and verify the whole design against a RAM oracle in ONE compiled
# call: replay a 1024-cycle random op trace through lax.scan.
from repro.core.amm import replay as rp
ra, wa, wv, wm = rp.make_trace(spec, n_cycles=1024, seed=0)
state, res = sim.replay(state, ra, wa, wv, wm)
oracle = np.arange(256, dtype=np.uint32)
oracle[7], oracle[9] = 111, 222          # the two writes above
read_vals = np.asarray(res.read_vals)
ok = True
for t in range(1024):
    ok &= bool((read_vals[t] == oracle[ra[t]]).all())
    oracle[wa[t][wm[t]]] = wv[t][wm[t]]
print(f"1024-cycle replay vs RAM oracle: {'OK' if ok else 'MISMATCH'}; "
      f"parity path agrees: {bool((res.read_vals == res.parity_vals).all())}\n")

# --- 2. spatial locality of a benchmark ---------------------------------
for name in ("kmp", "md_knn"):
    mod = BENCHMARKS[name]
    tr = mod.gen_trace(mod.TINY)
    addrs, aids = tr.mem_addrs_and_arrays()
    print(f"{name:8s} L_spatial = {trace_locality(addrs, aids):.3f}")

# --- 3. mini DSE: does true multi-port pay off? --------------------------
designs = [DesignPoint("banked", n_banks=4), DesignPoint("banked", n_banks=16),
           DesignPoint("hb_ntx", 4, 2), DesignPoint("lvt", 4, 2)]
for name in ("kmp", "md_knn"):
    mod = BENCHMARKS[name]
    pts = sweep(mod.gen_trace(mod.TINY), designs, unrolls=(2, 8))
    ratio = performance_ratio(pts)
    print(f"{name:8s} perf-ratio (banked area / AMM area, geomean) = "
          f"{ratio:.2f}  {'-> AMM wins' if ratio > 1 else '-> banking wins'}")
print("\nThe paper's law: AMM pays off when L_spatial < 0.3 (low locality).")
