"""Benchmark harness — one function per paper table/figure + framework
microbenchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

  fig4_dse          — area-cycles / power-cycles DSE per benchmark (Fig 4)
  fig5_locality     — spatial locality + performance ratio (Fig 5)
  serving_dse       — LLM-serving traces (KV decode / paged KV / MoE
                      routing): full-grid sweep + AMM kind ranking
  tab_synthesis     — AMM design cost table (Sec III-A synthesis results)
  kernel_microbench — blocked kernels: interpret vs compiled rows
                      (--interpret/--compiled restrict to one mode)
  scheduler_microbench — C cycle loop vs pure-Python fallback (large trace)
  scheduler_batched — batched JAX grid vs per-point C / python loops
  dse_matrix        — full 15x13 DSE matrix: exhaustive C vs
                      surrogate-pruned batched-C vs warm cache
  fault_campaign    — seeded fault-injection campaigns per design kind
                      (SDC rate / corrected / detected fractions)
  lm_smoke_bench    — tiny-arch train/decode step wall times (CPU)

Full-size runs: ``python -m benchmarks.run --full`` (minutes).
DSE tables run on the parallel sweep runner; control worker processes
with ``--jobs N`` and enable the incremental on-disk result cache with
``--cache-dir DIR``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# set by main() from argparse; module-level so the table functions and
# ad-hoc imports (e.g. REPL use) see consistent defaults
FULL = False
JOBS = os.cpu_count() or 1
CACHE_DIR = None
BACKEND = "auto"  # scheduler cycle-loop backend for the DSE tables
ARTIFACT_DIR = None  # where fig5_locality drops fig5.csv (None = don't)
KERNEL_MODES = ("interpret", "compiled")  # kernel_microbench legs
KERNEL_REPEAT = 20  # timed iterations per kernel row (after warm-up)
# the interpret legs run the *eager* Pallas interpreter (per-call Python
# grid walk — the point of the row pair); a few iterations suffice
KERNEL_REPEAT_INTERPRET = 3
ROWS: list[dict] = []  # every _row() call, for --json


def _t(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6


def _row(name: str, us: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us": round(us, 1), "derived": derived})
    print(f"{name},{us:.1f},{derived}")


# ======================================================================
def fig4_dse() -> None:
    """Paper Fig 4: design-space exploration per benchmark."""
    from repro.core.bench import PAPER_FIG4, get_trace
    from repro.core.dse import (DEFAULT_DESIGNS, design_space_expansion,
                                pareto_front, run_sweep)
    from repro.core.sim import prepare_trace

    unrolls = (1, 2, 4, 8) if FULL else (2, 8)
    designs = DEFAULT_DESIGNS if FULL else DEFAULT_DESIGNS[::2]
    for name in PAPER_FIG4:
        tr = get_trace(name, full=FULL)
        t0 = time.perf_counter()
        pts = run_sweep(prepare_trace(tr), designs, unrolls,
                        jobs=JOBS, cache_dir=CACHE_DIR, backend=BACKEND)
        dt = (time.perf_counter() - t0) * 1e6
        banking = [p for p in pts if not p.is_amm]
        amm = [p for p in pts if p.is_amm]
        exp = design_space_expansion(banking, amm)
        fb = pareto_front(banking)
        fa = pareto_front(amm)
        best_b = min(p.time_us for p in banking)
        best_a = min(p.time_us for p in amm)
        # stall aggregates per kind-family: `bank_conflict_stalls` means
        # leaf sub-banking conflicts on NTX points but steering misses on
        # remap points — summing them across all AMM points (the old
        # `amm_steer_stalls` column) conflated the two mechanisms.
        ntx = [p for p in amm if p.design.split("-")[0]
               in ("h_ntx_rd", "b_ntx_wr", "hb_ntx")]
        remap = [p for p in amm if p.design.startswith("remap")]
        _row(f"fig4_dse.{name}", dt,
             f"points={len(pts)};expansion={exp:.2f};"
             f"fastest_banked_us={best_b:.2f};fastest_amm_us={best_a:.2f};"
             f"pareto_banked={len(fb)};pareto_amm={len(fa)};"
             f"bank_stalls={sum(p.bank_conflict_stalls for p in banking)};"
             f"ntx_parity_stalls={sum(p.parity_fanout_stalls for p in ntx)};"
             f"ntx_pair_stalls={sum(p.write_pair_stalls for p in ntx)};"
             f"ntx_leaf_stalls={sum(p.bank_conflict_stalls for p in ntx)};"
             f"remap_steer_stalls="
             f"{sum(p.bank_conflict_stalls for p in remap)}")


def fig5_locality() -> None:
    """Paper Fig 5: spatial locality vs AMM performance ratio over the
    full 15-benchmark suite (12 MachSuite-style kernels + the 3
    LLM-serving traces), summarized by Spearman rank correlation
    (the paper's claim holds when the ratio *decreases* with locality,
    i.e. rho < 0).  Writes ``fig5.csv`` under ``--artifact-dir``.

    Locality is a property of the workload, so this table always
    characterizes the *full-size* traces (TINY traces are dependence-
    bound and flatten the banking-vs-AMM timing signal the ratio
    measures); ``--full`` widens the design grid instead.
    """
    from repro.core.bench import BENCHMARKS, get_trace
    from repro.core.dse import (DEFAULT_DESIGNS, design_space_expansion,
                                performance_ratio, run_sweep, spearman_rho)
    from repro.core.sim import prepare_trace

    unrolls = (1, 2, 4, 8) if FULL else (2, 8)
    designs = DEFAULT_DESIGNS if FULL else DEFAULT_DESIGNS[::2]
    out = []
    for name in sorted(BENCHMARKS):
        tr = get_trace(name, full=True)
        t0 = time.perf_counter()
        pt = prepare_trace(tr)
        L = pt.locality
        pts = run_sweep(pt, designs, unrolls, jobs=JOBS,
                        cache_dir=CACHE_DIR, backend=BACKEND)
        ratio = performance_ratio(pts)
        exp = design_space_expansion([p for p in pts if not p.is_amm],
                                     [p for p in pts if p.is_amm])
        dt = (time.perf_counter() - t0) * 1e6
        out.append({"bench": name, "nodes": pt.n_nodes,
                    "mem_ops": pt.trace.n_mem, "L_spatial": L,
                    "perf_ratio": ratio, "expansion": exp,
                    "sweep_points": len(pts)})
        _row(f"fig5_locality.{name}", dt,
             f"L_spatial={L:.3f};perf_ratio={ratio:.3f};"
             f"expansion={exp:.3f}")
    rho = spearman_rho([r["L_spatial"] for r in out],
                       [r["perf_ratio"] for r in out])
    rho_exp = spearman_rho([r["L_spatial"] for r in out],
                           [r["expansion"] for r in out])
    n_ok = sum(np.isfinite(r["perf_ratio"]) for r in out)
    claim = "indeterminate" if not np.isfinite(rho) else rho < 0
    _row("fig5_locality.summary", 0.0,
         f"benchmarks={len(out)};finite_ratios={n_ok};"
         f"spearman_rho={rho:.3f};spearman_rho_expansion={rho_exp:.3f};"
         f"paper_claim_holds={claim}")
    if ARTIFACT_DIR:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        path = os.path.join(ARTIFACT_DIR, "fig5.csv")
        with open(path, "w") as f:
            f.write("bench,nodes,mem_ops,L_spatial,perf_ratio,expansion,"
                    "sweep_points\n")
            for r in sorted(out, key=lambda r: r["L_spatial"]):
                f.write(f"{r['bench']},{r['nodes']},{r['mem_ops']},"
                        f"{r['L_spatial']:.4f},{r['perf_ratio']:.4f},"
                        f"{r['expansion']:.4f},{r['sweep_points']}\n")
        # keep the artifact strictly tabular (no comment footer: CSV
        # readers would ingest it as a row); the rho summary lives in
        # the stdout rows / --json output
        print(f"# wrote {path} (spearman_rho={rho:.4f})", file=sys.stderr)


def serving_dse() -> None:
    """LLM-serving workload family: full-grid DSE over the three
    serving traces (batched mixed-length KV decode, paged-KV gather
    with block-table indirection, MoE top-k routing) and a ranking of
    every AMM kind family by its fastest point on each bench.

    Unlike the other DSE tables this one always sweeps the *full*
    20-design grid — the smoke stride would drop the ``b_ntx_wr`` kind
    and the sub-banked ``*-b4`` points, and the whole point of the
    table is a complete kind ranking (smoke runs thin the unroll axis
    instead; ``--full`` also switches to full-size traces).
    """
    from repro.core.bench import SERVING, get_trace
    from repro.core.dse import (DEFAULT_DESIGNS, design_space_expansion,
                                pareto_front, run_sweep)
    from repro.core.sim import prepare_trace

    unrolls = (1, 2, 4, 8) if FULL else (2, 8)
    kind_of = {d.label: d.kind for d in DEFAULT_DESIGNS}
    for name in SERVING:
        tr = get_trace(name, full=FULL)
        pt = prepare_trace(tr)
        t0 = time.perf_counter()
        pts = run_sweep(pt, DEFAULT_DESIGNS, unrolls, jobs=JOBS,
                        cache_dir=CACHE_DIR, backend=BACKEND)
        dt = (time.perf_counter() - t0) * 1e6
        banking = [p for p in pts if not p.is_amm]
        amm = [p for p in pts if p.is_amm]
        fastest: dict[str, float] = {}
        for p in pts:
            k = kind_of[p.design]
            fastest[k] = min(fastest.get(k, float("inf")), p.time_us)
        ranking = ">".join(sorted(fastest, key=fastest.get))
        exp = design_space_expansion(banking, amm)
        _row(f"serving_dse.{name}", dt,
             f"L_spatial={pt.locality:.3f};points={len(pts)};"
             f"kinds={len(fastest)};ranking={ranking};"
             f"winner={min(pts, key=lambda p: p.time_us).design};"
             f"fastest_banked_us={min(p.time_us for p in banking):.2f};"
             f"fastest_amm_us={min(p.time_us for p in amm):.2f};"
             f"expansion={exp:.2f};"
             f"pareto_amm={len(pareto_front(amm))}")


def tab_synthesis() -> None:
    """Sec III-A: synthesized cost of each AMM design point."""
    from repro.core.amm.spec import AMMSpec
    from repro.core.cost import memory_cost

    specs = [
        AMMSpec("banked", 8, 8, 4096, n_banks=4),
        AMMSpec("banked", 32, 32, 4096, n_banks=16),
        AMMSpec("multipump", 2, 2, 4096),
        AMMSpec("h_ntx_rd", 2, 1, 4096),
        AMMSpec("h_ntx_rd", 4, 1, 4096),
        AMMSpec("b_ntx_wr", 1, 2, 4096),
        AMMSpec("hb_ntx", 2, 2, 4096),
        AMMSpec("hb_ntx", 4, 2, 4096),
        AMMSpec("lvt", 2, 2, 4096),
        AMMSpec("lvt", 4, 2, 4096),
        AMMSpec("remap", 2, 2, 4096),
    ]
    for s in specs:
        us = _t(memory_cost, s, repeat=10)
        c = memory_cost(s)
        _row(f"tab_synthesis.{s.describe()}", us,
             f"area_mm2={c.area_mm2:.4f};rd_pj={c.read_energy_pj:.2f};"
             f"ns={c.access_ns:.3f};fmax_ghz={c.max_freq_ghz:.2f}")


def kernel_microbench() -> None:
    """The blocked kernel surface, interpret mode (the conformance
    anchor — dispatched *eagerly*, the Pallas interpreter walks the
    grid in Python per call) vs the compiled path (real Pallas lowering
    on TPU/GPU, the XLA grid executor on CPU).  Methodology: warm-up +
    ``block_until_ready`` keep trace/compile out of the timed loop;
    ``compile_ms`` is reported separately in ``derived`` along with the
    autotuned block sizes.  ``--interpret`` / ``--compiled`` restrict
    the run to one mode (default: both, so every ``kernel.X`` row gets
    a ``kernel.X_compiled`` twin recording the speedup)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import amm_gather, kv_decode, ssd_chunk
    from repro.kernels.autotune import get_config, time_compiled
    from repro.kernels.lowering import resolve_mode

    backend = jax.default_backend()
    rng = np.random.default_rng(0)

    def both_modes(name, make_call, extra, tuned):
        us_int = None
        if "interpret" in KERNEL_MODES:
            us_int, cms = time_compiled(make_call("interpret"),
                                        repeat=KERNEL_REPEAT_INTERPRET,
                                        warmup=1)
            _row(f"kernel.{name}", us_int,
                 f"{extra};interpret=True;eager=True;compile_ms={cms:.0f}")
        if "compiled" in KERNEL_MODES:
            mode = resolve_mode(mode="compiled")
            us, cms = time_compiled(make_call("compiled"),
                                    repeat=KERNEL_REPEAT)
            blocks = ";".join(f"{k}={v}" for k, v in sorted(tuned.items()))
            d = f"{extra};mode={mode};{blocks};compile_ms={cms:.0f}"
            if us_int is not None:
                d += f";speedup_vs_interpret={us_int / us:.1f}x"
            _row(f"kernel.{name}_compiled", us, d)

    table = jnp.asarray(rng.standard_normal((1024, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 1024, 256), jnp.int32)
    both_modes(
        "amm_gather_1024x128_n256",
        lambda m: lambda: amm_gather(table, idx, n_banks=4, mode=m),
        "banks=4",
        get_config("amm_gather", backend, resolve_mode(mode="compiled"),
                   v=1024, d=128, nb=4, n=256))

    q = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((4, 4, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4, 4, 512, 64)), jnp.float32)
    lens = jnp.asarray([512, 300, 100, 512], jnp.int32)
    both_modes(
        "kv_decode_b4_s512",
        lambda m: lambda: kv_decode(q, k, v, lens, n_banks=8, mode=m),
        "banks=8",
        get_config("kv_decode", backend, resolve_mode(mode="compiled"),
                   b=4, hq=8, hkv=4, s=512, d=64, nb=8))

    x = jnp.asarray(rng.standard_normal((2, 4, 64, 32)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.4, (2, 4, 64)), jnp.float32)
    cum = jnp.cumsum(-dt, axis=-1)
    B = jnp.asarray(rng.standard_normal((2, 64, 16)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((2, 64, 16)), jnp.float32)
    h0 = jnp.zeros((2, 4, 32, 16), jnp.float32)
    both_modes(
        "ssd_chunk_q64",
        lambda m: lambda: ssd_chunk(x, dt, cum, B, C, h0, mode=m)[0],
        "bt2xh4",
        get_config("ssd_chunk", backend, resolve_mode(mode="compiled"),
                   bt=2, h=4, q=64, p=32, n=16))

    # serving-scale decode: the ROADMAP's LLM-workload shape class
    # (large batch, long context, mixed request lengths)
    bs, hqs, hkvs, ss, ds = 8, 16, 4, 1024, 64
    q2 = jnp.asarray(rng.standard_normal((bs, hqs, ds)), jnp.float32)
    k2 = jnp.asarray(rng.standard_normal((bs, hkvs, ss, ds)), jnp.float32)
    v2 = jnp.asarray(rng.standard_normal((bs, hkvs, ss, ds)), jnp.float32)
    lens2 = jnp.asarray(rng.integers(0, ss + 1, bs), jnp.int32)
    both_modes(
        "kv_decode_serving_b8_s1024",
        lambda m: lambda: kv_decode(q2, k2, v2, lens2, n_banks=8, mode=m),
        "banks=8;ragged=True",
        get_config("kv_decode", backend, resolve_mode(mode="compiled"),
                   b=bs, hq=hqs, hkv=hkvs, s=ss, d=ds, nb=8))


def amm_replay() -> None:
    """Whole-trace functional-sim replay (lax.scan) vs the per-step
    Python loop, plus vmap-batched replay across seeds."""
    import jax
    import jax.numpy as jnp

    from repro.core.amm import AMMSpec, make_amm
    from repro.core.amm import replay as rp

    depth = 1024 if FULL else 256
    n_cycles = 2048 if FULL else 512
    n_seeds = 8
    rng = np.random.default_rng(0)
    for spec in (AMMSpec("hb_ntx", 4, 2, depth),
                 AMMSpec("lvt", 4, 2, depth),
                 AMMSpec("remap", 2, 3, depth)):
        init = jnp.asarray(rng.integers(0, 2**32, depth, dtype=np.uint32))
        ra, wa, wv, wm = (jnp.asarray(x)
                          for x in rp.make_trace(spec, n_cycles, seed=1))
        sim = make_amm(spec, init)

        def step_loop():
            st = sim.state
            for t in range(n_cycles):
                st, vals = sim.step(st, ra[t], wa[t], wv[t], wm[t])
            return jax.block_until_ready(vals)

        def replay_once():
            _, res = rp.replay(spec, rp.init_flat(spec, init),
                               ra, wa, wv, wm)
            return jax.block_until_ready(res.read_vals)

        step_us = _t(step_loop, repeat=1)
        replay_us = _t(replay_once)
        _row(f"amm_replay.{spec.kind}", replay_us,
             f"T={n_cycles};depth={depth};step_loop_us={step_us:.1f};"
             f"speedup={step_us / replay_us:.1f}x")

        # vmap across seeds: batched oracle verification throughput
        states = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[rp.init_flat(spec, init) for _ in range(n_seeds)])
        traces = [rp.make_trace(spec, n_cycles, seed=s)
                  for s in range(n_seeds)]
        bra, bwa, bwv, bwm = (jnp.asarray(np.stack([tr[i] for tr in traces]))
                              for i in range(4))

        def replay_vmapped():
            _, res = rp.replay_batched(spec, states, bra, bwa, bwv, bwm)
            return jax.block_until_ready(res.read_vals)

        us = _t(replay_vmapped)
        _row(f"amm_replay.{spec.kind}_vmap{n_seeds}", us,
             f"T={n_cycles};per_trace_us={us / n_seeds:.1f}")


def scheduler_microbench() -> None:
    """Compiled C cycle loop vs the pure-Python reference loop on a
    large prepared trace, across arbitration-heavy memory kinds."""
    from repro.core.bench import BENCHMARKS, get_trace
    from repro.core.dse.sweep import _BASE_FU, DesignPoint, _spec_for
    from repro.core.sim import _cycle_ext, prepare_trace
    from repro.core.sim.scheduler import (ScheduleConfig, _schedule_c,
                                          _schedule_py)

    # ~7k nodes in smoke runs, the full 56k-node trace with --full
    params = BENCHMARKS["gemm_ncubed"].Params() if FULL \
        else BENCHMARKS["gemm_ncubed"].Params(n=12)
    pt = prepare_trace(get_trace("gemm_ncubed", params))
    fast = _cycle_ext.load()
    for dp in (DesignPoint("banked", n_banks=8),
               DesignPoint("hb_ntx", 4, 2),
               DesignPoint("remap", 4, 2)):
        specs = {aid: _spec_for(dp, pt.array_depths[aid],
                                pt.trace.word_bytes[aid] * 8)
                 for aid in pt.trace.array_names}
        cfg = ScheduleConfig(
            mem=specs,
            fu_counts={k: v * 4 for k, v in _BASE_FU.items()})
        t0 = time.perf_counter()
        res = _schedule_py(pt, cfg)             # one timed run, result kept
        py_us = (time.perf_counter() - t0) * 1e6
        if fast is None:
            _row(f"scheduler.{dp.label}_py_only", py_us,
                 f"nodes={pt.n_nodes};cycles={res.cycles};no C compiler")
            continue
        c_res = _schedule_c(fast, pt, cfg)
        if c_res != res:
            raise RuntimeError(f"C/python loops diverged on {dp.label}")
        c_us = _t(_schedule_c, fast, pt, cfg, repeat=5)
        _row(f"scheduler.{dp.label}_c_loop", c_us,
             f"nodes={pt.n_nodes};cycles={res.cycles};"
             f"py_loop_us={py_us:.0f};speedup={py_us / c_us:.1f}x")


def scheduler_batched() -> None:
    """Batched JAX grid evaluation vs the per-point C and pure-Python
    loops on full gemm Fig-4 design grids.

    One ``schedule_batched`` jit call evaluates the whole 20-design x
    4-unroll composition grid; the per-point loops evaluate the same
    configs one call at a time.  Rows record the measured grid-vs-point
    ratios both ways, and on host CPUs they are a *loss* for the jax
    engine at every practical size: the deferral scan is sequential
    (~60-180 pops/cycle) and each XLA while-loop step carries
    microseconds of overhead vs nanoseconds per C pop, which vmap
    amortizes across lanes but cannot eliminate.  The rows exist to
    keep that trade-off measured and honest across PRs; the jax path's
    value is the three-way conformance matrix + accelerator scale-out,
    not host-CPU wall time.  See README "Execution backends".
    """
    from repro.core.bench import BENCHMARKS, get_trace
    from repro.core.dse.sweep import (DEFAULT_DESIGNS, DEFAULT_UNROLLS,
                                      schedule_config_for)
    from repro.core.sim import _cycle_ext, prepare_trace
    from repro.core.sim.jax_cycle import schedule_batched
    from repro.core.sim.scheduler import _schedule_c, _schedule_py

    # TINY-size trace: the batched engine's sequential deferral scan
    # makes larger traces impractically slow on host CPUs (the point of
    # this table is to measure that honestly, not to hide it)
    params = BENCHMARKS["gemm_ncubed"].Params(n=8) if FULL \
        else BENCHMARKS["gemm_ncubed"].Params(n=6)
    pt = prepare_trace(get_trace("gemm_ncubed", params))
    grid = [(dp, u) for dp in DEFAULT_DESIGNS for u in DEFAULT_UNROLLS]
    cfgs = [schedule_config_for(pt, dp, u) for dp, u in grid]

    t0 = time.perf_counter()
    res = schedule_batched(pt, cfgs)          # compile + first run
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = schedule_batched(pt, cfgs)
    jax_us = (time.perf_counter() - t0) * 1e6

    fast = _cycle_ext.load()
    c_us = float("nan")
    if fast is not None:
        t0 = time.perf_counter()
        c_res = [_schedule_c(fast, pt, cfg) for cfg in cfgs]
        c_us = (time.perf_counter() - t0) * 1e6
        if c_res != res:
            raise RuntimeError("jax grid diverged from the C loop")
    _row("scheduler_batched.grid_vs_c", jax_us,
         f"nodes={pt.n_nodes};points={len(cfgs)};c_loop_us={c_us:.0f};"
         f"jax_vs_c={c_us / jax_us:.3f}x;compile_s={compile_s:.1f}")

    # pure-Python comparison on a subset (the reference loop is slow)
    sub = [(dp, u) for dp in DEFAULT_DESIGNS[::4] for u in (2, 8)]
    sub_cfgs = [schedule_config_for(pt, dp, u) for dp, u in sub]
    jr = schedule_batched(pt, sub_cfgs)
    t0 = time.perf_counter()
    jr = schedule_batched(pt, sub_cfgs)
    jax_sub_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    py_res = [_schedule_py(pt, cfg) for cfg in sub_cfgs]
    py_us = (time.perf_counter() - t0) * 1e6
    if py_res != jr:
        raise RuntimeError("jax grid diverged from the python loop")
    _row("scheduler_batched.grid_vs_py", jax_sub_us,
         f"nodes={pt.n_nodes};points={len(sub_cfgs)};"
         f"py_loop_us={py_us:.0f};jax_vs_py={py_us / jax_sub_us:.1f}x")


def dse_matrix() -> None:
    """Full 15-bench x 13-design x 4-unroll DSE matrix three ways:
    exhaustive per-point C sweep, surrogate-pruned batched-C sweep
    (band prune + in-C Pareto front caps; uncalibrated serving traces
    fall back to exhaustive inside run_sweep) and the fully-warm
    on-disk cache (manifest fast path, trace generation skipped).  The
    unroll axis is the default sweep grid (1/2/4/8), the design axis
    the 13-design calibration matrix.

    Traces are generated and prepared in a prepass so the timed legs
    measure sweep compute only; the surrogate leg *does* pay for its
    own feature extraction (it is part of the pruned-sweep cost).
    Derived fields pin the headline claims: pruned-vs-exhaustive
    speedup and Pareto-front identity on every bench.
    """
    import tempfile

    from repro.core.bench import BENCHMARKS, get_trace, trace_cache_key
    from repro.core.dse.pareto import pareto_front
    from repro.core.dse.runner import (SweepCache, point_key, run_sweep,
                                       run_sweep_bench)
    from repro.core.dse.surrogate import CALIBRATION_DESIGNS
    from repro.core.dse.sweep import DEFAULT_UNROLLS, evaluate_point
    from repro.core.sim import prepare_trace

    designs = list(CALIBRATION_DESIGNS.values())
    unrolls = DEFAULT_UNROLLS
    grid = [(dp, u) for dp in designs for u in unrolls]
    names = sorted(BENCHMARKS)
    prepared = {n: prepare_trace(get_trace(n, full=FULL)) for n in names}
    n_pts = len(names) * len(grid)

    # leg 1: exhaustive — every grid point through the per-point C loop
    t0 = time.perf_counter()
    full_res = {n: [evaluate_point(prepared[n], dp, u) for dp, u in grid]
                for n in names}
    t_exh = time.perf_counter() - t0

    # leg 2: surrogate-pruned (analytic ranking + batched C + front caps)
    t0 = time.perf_counter()
    pruned_res = {n: run_sweep(prepared[n], designs, unrolls,
                               prune="surrogate") for n in names}
    t_prn = time.perf_counter() - t0

    fronts_ok = 0
    n_kept = 0
    for n in names:
        n_kept += len(pruned_res[n])
        ff = {(p.design, p.unroll) for p in pareto_front(full_res[n])}
        fp = {(p.design, p.unroll) for p in pareto_front(pruned_res[n])}
        fronts_ok += ff == fp

    # leg 3: warm cache — manifest fast path, trace generation skipped
    with tempfile.TemporaryDirectory() as d:
        cache = SweepCache(d)
        for n in names:
            fp_ = prepared[n].fingerprint
            for (dp, u), p in zip(grid, full_res[n]):
                cache.put(point_key(fp_, dp, u, 2), p)
            cache.manifest_put(trace_cache_key(n, full=FULL), fp_)
        t0 = time.perf_counter()
        for n in names:
            run_sweep_bench(n, designs, unrolls, full=FULL, cache=cache)
        t_warm = time.perf_counter() - t0

    _row("dse_matrix.exhaustive_c", t_exh * 1e6,
         f"benches={len(names)};points={n_pts}")
    _row("dse_matrix.surrogate_pruned", t_prn * 1e6,
         f"kept={n_kept}/{n_pts};speedup={t_exh / t_prn:.2f}x;"
         f"fronts_identical={fronts_ok}/{len(names)}")
    _row("dse_matrix.warm_cache", t_warm * 1e6,
         f"points={n_pts};speedup={t_exh / t_warm:.1f}x")


def fault_campaign() -> None:
    """Seeded fault-injection campaigns per design kind (ISSUE 7): wall
    time of one batched campaign plus the resilience record — SDC rate,
    corrected/detected fractions of affected reads, mean detection
    latency.  Smoke runs use the golden campaign shape
    (32 faults x 96 cycles, seed 7) so rows are directly comparable to
    ``tests/golden_faults.json``; ``--full`` widens the population."""
    from repro.core.dse.sweep import DEFAULT_DESIGNS, _spec_for
    from repro.core.fault import FaultConfig, run_campaign

    labels = ("banked8", "multipump-2R2W", "h_ntx_rd-4R1W", "b_ntx_wr-1R2W",
              "hb_ntx-4R2W", "lvt-2R2W", "lvt-4R2W", "remap-4R2W")
    cfg = FaultConfig(n_faults=128, n_cycles=256, seed=7) if FULL \
        else FaultConfig(n_faults=32, n_cycles=96, seed=7)
    by_label = {d.label: d for d in DEFAULT_DESIGNS}
    for label in labels:
        spec = _spec_for(by_label[label], 256, 32)
        t0 = time.perf_counter()
        res = run_campaign(spec, cfg)
        us = (time.perf_counter() - t0) * 1e6
        r = res.resilience
        _row(f"fault_campaign.{label}", us,
             f"cover={r.cover};faults={r.n_faults};"
             f"sdc_rate={r.sdc_rate:.4f};corrected={r.corrected_frac:.3f};"
             f"detected={r.detected_frac:.3f};latency={r.det_latency:.2f}")


def lm_smoke_bench() -> None:
    """Tiny-config train/decode step wall time per assigned arch."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCH_NAMES, get_arch, tiny_variant
    from repro.configs.base import RuntimeConfig
    from repro.launch.steps import make_decode_step, make_train_step
    from repro.models import DTypePolicy, init_model, make_cache
    from repro.optim import adamw

    rt = RuntimeConfig(remat="none")
    policy = DTypePolicy.standard()
    names = ARCH_NAMES if FULL else ARCH_NAMES[:4]
    for name in names:
        arch = tiny_variant(get_arch(name))
        params = init_model(jax.random.PRNGKey(0), arch, policy)
        opt = adamw.init(params, policy)
        batch = {"tokens": jnp.ones((2, 64), jnp.int32),
                 "labels": jnp.ones((2, 64), jnp.int32)}
        if arch.family == "vlm":
            batch["patches"] = jnp.ones((2, arch.n_patches, arch.vit_dim),
                                        jnp.float32)
        if arch.is_encdec:
            batch["frames"] = jnp.ones((2, 64, arch.d_model), jnp.float32)
        step = jax.jit(make_train_step(arch, rt, policy))
        us = _t(lambda: jax.block_until_ready(step(params, opt, batch)))
        _row(f"lm_train_tiny.{name}", us, "b2xs64")
        cache = make_cache(arch, 32, 2)
        dec = jax.jit(make_decode_step(arch, rt, policy))
        tok = jnp.ones((2, 1), jnp.int32)
        us = _t(lambda: jax.block_until_ready(dec(params, cache, tok)))
        _row(f"lm_decode_tiny.{name}", us, "cache32")


def grad_sync_bench() -> None:
    """Cross-pod grad sync: bf16 all-reduce vs int8 compressed
    (collective wire bytes from the compiled HLO, 2-pod test mesh)."""
    import subprocess
    import sys as _sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.launch.roofline import analyze_hlo
        from repro.runtime.compressed_sync import (compressed_pod_mean,
                                                   uncompressed_pod_mean)
        mesh = make_test_mesh((2, 4), ("pod", "data"))
        g = {"w": jnp.zeros((4096, 1024), jnp.float32)}
        ref = jax.jit(lambda x: uncompressed_pod_mean(x, mesh)).lower(g).compile()
        cmp_ = jax.jit(lambda x: compressed_pod_mean(x, mesh)).lower(g).compile()
        b0 = analyze_hlo(ref.as_text())["collective_bytes"]
        b1 = analyze_hlo(cmp_.as_text())["collective_bytes"]
        print(f"{b0},{b1},{b1/b0:.3f}")
    """)
    out = subprocess.run([_sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=300)
    if out.returncode == 0:
        b0, b1, ratio = out.stdout.strip().splitlines()[-1].split(",")
        _row("grad_sync.bf16_allreduce_bytes", float(b0), "16M grads")
        _row("grad_sync.int8_compressed_bytes", float(b1),
             f"ratio={ratio};error_feedback=repro.runtime.ft")
    else:
        _row("grad_sync.error", 0.0, out.stderr[-120:].replace("\n", " "))


# ======================================================================
TABLES = {
    "fig4_dse": fig4_dse,
    "fig5_locality": fig5_locality,
    "serving_dse": serving_dse,
    "tab_synthesis": tab_synthesis,
    "kernel_microbench": kernel_microbench,
    "amm_replay": amm_replay,
    "scheduler_microbench": scheduler_microbench,
    "scheduler_batched": scheduler_batched,
    "dse_matrix": dse_matrix,
    "fault_campaign": fault_campaign,
    "lm_smoke_bench": lm_smoke_bench,
    "grad_sync_bench": grad_sync_bench,
}


def _only_list(arg: str | None) -> list[str] | None:
    if arg is None:
        return None
    names = [n.strip() for n in arg.split(",") if n.strip()]
    unknown = sorted(set(names) - set(TABLES))
    if unknown:
        raise SystemExit(f"unknown table(s) {unknown}; "
                         f"choose from {sorted(TABLES)}")
    return names


def main(argv=None) -> None:
    global FULL, JOBS, CACHE_DIR, BACKEND, ARTIFACT_DIR
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Paper table/figure benchmark harness (CSV to stdout).")
    ap.add_argument("--full", action="store_true",
                    help="full-size traces/archs (minutes)")
    ap.add_argument("--only", default=None, metavar="TABLE[,TABLE...]",
                    help=f"run a subset of {sorted(TABLES)}")
    ap.add_argument("--jobs", type=int, default=os.cpu_count() or 1,
                    help="worker processes for DSE sweeps (1 = serial)")
    ap.add_argument("--backend", choices=("auto", "c", "py", "jax"),
                    default="auto",
                    help="scheduler cycle-loop backend for DSE tables")
    ap.add_argument("--cache-dir", default=None,
                    help="on-disk DSE result cache for incremental re-runs")
    ap.add_argument("--artifact-dir", default=None, metavar="DIR",
                    help="directory for table CSV artifacts "
                         "(fig5_locality writes fig5.csv there)")
    mode_grp = ap.add_mutually_exclusive_group()
    mode_grp.add_argument("--interpret", action="store_true",
                          help="kernel_microbench: interpret rows only")
    mode_grp.add_argument("--compiled", action="store_true",
                          help="kernel_microbench: compiled rows only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as machine-readable JSON "
                         "(e.g. BENCH.json) for cross-PR perf tracking")
    args = ap.parse_args(argv)
    only = _only_list(args.only)
    FULL, JOBS, CACHE_DIR = args.full, args.jobs, args.cache_dir
    BACKEND = args.backend
    ARTIFACT_DIR = args.artifact_dir
    global KERNEL_MODES
    if args.interpret:
        KERNEL_MODES = ("interpret",)
    elif args.compiled:
        KERNEL_MODES = ("compiled",)

    print("name,us_per_call,derived")
    for name, fn in TABLES.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        fn()
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"full": FULL, "rows": ROWS}, f, indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
